//! Offline drop-in subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository is fully offline (see
//! DESIGN notes in `rust/src/util/mod.rs`), so the small slice of
//! `anyhow` the crate actually uses is vendored here as a path
//! dependency: [`Error`], [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros. Semantics mirror upstream for everything the code relies
//! on:
//!
//! * `{}` displays the outermost message only;
//! * `{:#}` displays the whole context chain, colon-separated
//!   (`"outer: inner: root"`);
//! * `.context(..)` / `.with_context(..)` wrap an error with an outer
//!   message, preserving the inner chain;
//! * `From<E: std::error::Error>` captures the error and its
//!   `source()` chain, so `?` works on `io::Error`, `ParseIntError`,
//!   etc.

use std::convert::Infallible;
use std::fmt::{self, Display};

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened error: a chain of human-readable messages, outermost
/// context first. Deliberately *not* `std::error::Error`, exactly like
/// upstream `anyhow::Error`, so the blanket `From` impl is coherent.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` produces).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(context.to_string());
        chain.extend(self.chain);
        Error { chain }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E>: Sized {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(e.to_string(), "outer");
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e: Error = Error::from(io_err()).context("mid").context("outer");
        assert_eq!(format!("{e:#}"), "outer: mid: missing thing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", 42)).unwrap_err();
        assert_eq!(e.to_string(), "missing 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", "message");
        assert_eq!(e.to_string(), "plain message");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
