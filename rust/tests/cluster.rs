//! Cluster-tier integration tests: the consistent-hash node layer
//! observed through the public [`ClusterFrontend`] API.
//!
//! Covers the tier's core promises end to end, all offline:
//! * **minimal remapping** — removing a ring member remaps only the
//!   departed node's keys, and a rejoin restores the original map
//!   exactly;
//! * **affinity** — while its home node is `Live`, a kernel always
//!   lands there, so cluster-wide compile misses equal the number of
//!   distinct kernels — the distributed bitstream-cache property;
//! * **overflow spill** — a saturated home spills to a strictly
//!   less-loaded live sibling, typed, counted, and tenant-attributed
//!   in the spill log;
//! * **failover without hangs** — killing a node mid-stream resolves
//!   every outstanding handle (completed, or failed with a typed
//!   reason) and re-routes the node's ring range to its successors;
//! * **warm rejoin** — a revived node restarts from its cache
//!   snapshot and serves its shard with zero new compile misses.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::cluster::{ClusterConfig, ClusterFrontend, HashRing, Health, SpillReason};
use overlay_jit::coordinator::{
    Admission, CoordinatorConfig, DispatchHandle, Priority, SubmitArg,
};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::util::XorShiftRng;

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

/// Random input buffers (with stencil slack) for a benchmark's params.
fn random_args(ctx: &Context, source: &str, n: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    let nparams = overlay_jit::frontend::parse_kernel(source).unwrap().params.len();
    (0..nparams)
        .map(|_| {
            let buf = ctx.create_buffer(n + 16);
            let data: Vec<i32> = (0..n + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

/// Poll a handle to a terminal outcome with a hard ceiling — the
/// zero-hang check: a handle that never resolves fails the test
/// instead of wedging it.
fn resolve(h: &DispatchHandle, what: &str) -> Result<(), String> {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if let Some(outcome) = h.try_wait_typed() {
            return match outcome {
                Ok(r) => {
                    assert_eq!(r.verified, Some(true), "{what}: completed unverified");
                    Ok(())
                }
                Err(e) => Err(e.reason().name().to_string()),
            };
        }
        if Instant::now() >= deadline {
            panic!("{what}: handle hung past the 60s ceiling");
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn ring_removal_remaps_only_departed_keys_and_rejoin_restores() {
    const NODES: usize = 5;
    const KEYS: usize = 4_000;
    let mut rng = XorShiftRng::new(0x41B9);
    let keys: Vec<u64> = (0..KEYS).map(|_| rng.next_u64()).collect();

    let mut ring = HashRing::with_nodes(NODES, 64);
    let before: BTreeMap<u64, usize> =
        keys.iter().map(|&k| (k, ring.home(k).unwrap())).collect();

    let departed = 2;
    assert!(ring.remove(departed));
    assert!(!ring.contains(departed));
    let mut moved = 0usize;
    for &k in &keys {
        let now = ring.home(k).unwrap();
        if before[&k] == departed {
            moved += 1;
            assert_ne!(now, departed, "key {k:#x} still maps to the departed node");
        } else {
            // the minimal-remap property: every other key stays put
            assert_eq!(
                now, before[&k],
                "key {k:#x} moved although its home {} never left",
                before[&k]
            );
        }
    }
    // the departed node owned a real share of the keyspace
    assert!(
        moved > KEYS / (NODES * 4),
        "departed node owned implausibly few keys ({moved})"
    );

    // rejoin restores the original map exactly — vnode hashes depend
    // only on (node, replica), so placement is history-independent
    ring.add(departed);
    for &k in &keys {
        assert_eq!(ring.home(k).unwrap(), before[&k]);
    }
}

#[test]
fn affinity_keeps_every_kernel_on_its_home_node() {
    let node_cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    let mut cfg = ClusterConfig::sim_cluster(3, node_cfg);
    // spill disabled: this test isolates the affinity property
    cfg.spill_threshold = 1_000_000;
    let cluster = ClusterFrontend::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xAFF1);

    const ROUNDS: usize = 4;
    const ITEMS: usize = 256;
    for round in 0..ROUNDS {
        for b in BENCHMARKS {
            let args = random_args(&ctx, b.source, ITEMS, &mut rng);
            let h = cluster.submit(b.source, &args, ITEMS, Priority::Interactive).unwrap();
            let r = h.wait().unwrap();
            assert_eq!(r.verified, Some(true), "{} round {round}", b.name);
        }
    }

    let stats = cluster.stats();
    let total = (ROUNDS * BENCHMARKS.len()) as u64;
    assert_eq!(stats.routed_total(), total);
    assert_eq!(stats.affinity_hits, total, "every dispatch must land on its ring home");
    assert_eq!(stats.affinity_rate(), 1.0);
    assert_eq!(stats.spills, 0);
    assert_eq!(stats.failovers, 0);
    assert!(cluster.spill_log().is_empty());

    // the distributed cache-affinity property: each distinct kernel
    // compiles exactly once cluster-wide (on its home), every repeat
    // is a hit there
    assert_eq!(stats.merged.cache.misses, BENCHMARKS.len() as u64);
    assert_eq!(stats.merged.cache.hits, total - BENCHMARKS.len() as u64);
    assert_eq!(stats.merged.total_dispatches, total);

    // the routed histogram matches the ring placement exactly
    let mut expected = vec![0u64; 3];
    for b in BENCHMARKS {
        expected[cluster.home_of(b.source)] += ROUNDS as u64;
    }
    for (node, want) in expected.iter().enumerate() {
        assert_eq!(stats.per_node[node].routed, *want, "node {node} routed histogram");
        assert_eq!(stats.per_node[node].health, Health::Live);
        assert!(stats.per_node[node].up);
    }
    cluster.shutdown();
}

#[test]
fn saturated_home_spills_to_least_loaded_sibling() {
    let node_cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    let mut cfg = ClusterConfig::sim_cluster(3, node_cfg);
    // any queued-or-executing job counts as saturation
    cfg.spill_threshold = 0;
    let cluster = ClusterFrontend::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x5B1);

    // wide batches of one kernel, fired without waiting: the home
    // executes the first while later submits see its non-empty queue
    const WIDE: usize = 16_384;
    const BURST: usize = 6;
    let b = &BENCHMARKS[0];
    let home = cluster.home_of(b.source);
    let mut handles = Vec::new();
    for _ in 0..BURST {
        let args = random_args(&ctx, b.source, WIDE, &mut rng);
        match cluster
            .submit_gated("burst-tenant", b.source, &args, WIDE, Priority::Batch, None)
            .unwrap()
        {
            Admission::Admitted(h) => handles.push(h),
            Admission::Rejected(r) => panic!("ungated cluster rejected: {r}"),
        }
    }
    for (i, h) in handles.iter().enumerate() {
        resolve(h, &format!("burst {i}")).expect("no node died; every dispatch completes");
    }

    let stats = cluster.stats();
    assert_eq!(stats.routed_total(), BURST as u64);
    assert!(stats.spills >= 1, "a saturated home must spill: {}", stats.render());
    assert_eq!(stats.failovers, 0, "nobody died; off-home routing is all overflow");
    assert_eq!(stats.spills + stats.affinity_hits, BURST as u64);
    assert_eq!(stats.dropped_spill_records, 0);

    // the spill log carries the typed reason and the admission tenant
    let log = cluster.spill_log();
    assert_eq!(log.len() as u64, stats.spills);
    for rec in &log {
        assert_eq!(rec.reason, SpillReason::HomeOverloaded);
        assert_eq!(rec.reason.name(), "home_overloaded");
        assert_eq!(rec.tenant, "burst-tenant");
        assert_eq!(rec.from, home);
        assert_ne!(rec.to, home, "a spill by definition leaves the home node");
        assert_eq!(rec.kernel_key, ClusterFrontend::kernel_key(b.source));
    }
    cluster.shutdown();
}

#[test]
fn node_death_mid_stream_resolves_every_handle_and_fails_over() {
    let node_cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    let mut cfg = ClusterConfig::sim_cluster(3, node_cfg);
    cfg.spill_threshold = 1_000_000; // isolate failover from overflow spill
    let cluster = ClusterFrontend::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xDEAD);

    const WIDE: usize = 16_384;
    let b = &BENCHMARKS[0];
    let victim = cluster.home_of(b.source);
    assert_eq!(cluster.health_of(victim), Health::Live);

    // a stream of wide jobs piles onto the victim's queue...
    let mut pre_kill = Vec::new();
    for _ in 0..4 {
        let args = random_args(&ctx, b.source, WIDE, &mut rng);
        pre_kill.push(cluster.submit(b.source, &args, WIDE, Priority::Batch).unwrap());
    }
    // ...and the victim dies mid-stream
    assert!(cluster.kill_node(victim).unwrap());
    assert_eq!(cluster.health_of(victim), Health::Down);
    assert!(!cluster.kill_node(victim).unwrap(), "double-kill reports already down");

    // zero hangs: every outstanding handle resolves — completed
    // (drained before the kill) or failed with a typed reason
    let mut completed = 0usize;
    let mut failed = 0usize;
    for (i, h) in pre_kill.iter().enumerate() {
        match resolve(h, &format!("pre-kill {i}")) {
            Ok(()) => completed += 1,
            Err(reason) => {
                failed += 1;
                assert!(
                    ["worker_died", "shed", "deadline_rejected"].contains(&reason.as_str()),
                    "pre-kill {i}: unexpected fail reason {reason:?}"
                );
            }
        }
    }
    assert_eq!(completed + failed, 4);

    // the victim's ring range now serves from its successors: new
    // submits of the same kernel succeed as typed failovers
    for i in 0..3 {
        let args = random_args(&ctx, b.source, 256, &mut rng);
        let h = cluster.submit(b.source, &args, 256, Priority::Interactive).unwrap();
        resolve(&h, &format!("failover {i}")).expect("failover dispatch must complete");
    }
    let stats = cluster.stats();
    assert_eq!(stats.failovers, 3, "{}", stats.render());
    let log = cluster.spill_log();
    assert_eq!(log.len(), 3);
    for rec in &log {
        assert_eq!(rec.reason, SpillReason::HomeDown);
        assert_eq!(rec.from, victim);
        assert_ne!(rec.to, victim);
    }
    // the dead node stays down and visible in the per-node rows
    assert!(!stats.per_node[victim].up);
    assert_eq!(stats.per_node[victim].health, Health::Down);
    cluster.shutdown();
}

#[test]
fn revived_node_warm_starts_and_reclaims_its_ring_range() {
    let dir = std::env::temp_dir().join(format!(
        "overlay-jit-cluster-rejoin-{}",
        std::process::id()
    ));
    let node_cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    let mut cfg = ClusterConfig::sim_cluster(3, node_cfg);
    cfg.spill_threshold = 1_000_000;
    cfg.snapshot_base = Some(dir.clone());
    let cluster = ClusterFrontend::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xECA);

    const ITEMS: usize = 256;
    let b = &BENCHMARKS[0];
    let victim = cluster.home_of(b.source);

    // first contact compiles once on the home
    let args = random_args(&ctx, b.source, ITEMS, &mut rng);
    cluster.submit(b.source, &args, ITEMS, Priority::Interactive).unwrap().wait().unwrap();
    assert_eq!(cluster.stats().merged.cache.misses, 1);

    // kill (flushes the snapshot) and rejoin
    assert!(cluster.kill_node(victim).unwrap());
    cluster.revive_node(victim).unwrap();
    assert_eq!(cluster.health_of(victim), Health::Live);

    // the revived home reclaims its range and serves it warm: no new
    // compile miss anywhere in the cluster
    let args = random_args(&ctx, b.source, ITEMS, &mut rng);
    let r = cluster
        .submit(b.source, &args, ITEMS, Priority::Interactive)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.verified, Some(true));
    let stats = cluster.stats();
    assert_eq!(
        stats.merged.cache.misses, 1,
        "rejoin must warm-start from the snapshot, not recompile: {}",
        stats.render()
    );
    assert!(stats.merged.cache.hits >= 1);
    assert_eq!(stats.failovers, 0, "a Live rejoined home takes its range back");
    assert_eq!(stats.per_node[victim].routed, 2);
    cluster.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
