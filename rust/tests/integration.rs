//! Cross-layer integration tests: the full JIT → execute stack.
//!
//! The heavyweight correctness signal is the **backend agreement**
//! suite: every benchmark kernel, JIT-compiled and executed both
//! through the Rust cycle simulator and through the AOT XLA/PJRT
//! overlay emulator (`artifacts/overlay_exec_i32.hlo.txt`, built from
//! the Pallas kernel), must produce bit-identical int32 results.
//! Those tests need both the `pjrt` cargo feature (the vendored `xla`
//! crate) and `make artifacts` outputs, so they are compiled only with
//! `--features pjrt` and skip themselves when the artifacts are
//! absent. The cycle-simulator flow below runs everywhere.

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::runtime_ocl::{Backend, CommandQueue, Context, Platform, Program};
use overlay_jit::util::XorShiftRng;

#[test]
fn cycle_sim_backend_device_flow_on_all_benchmarks() {
    // device-level flow with random buffers per benchmark (sim backend)
    let platform = Platform::with_device(reference_overlay(), Backend::CycleSim);
    let ctx = Context::new(&platform.devices()[0]);
    for b in &BENCHMARKS {
        let mut program = Program::from_source(&ctx, b.source);
        program.build().unwrap();
        let kernel = program.create_kernel(b.name).unwrap();
        let n = 512;
        let nparams = kernel.compiled.params.len();
        let mut rng = XorShiftRng::new(7 * (1 + b.paper.replication as u64));
        let mut buffers = Vec::new();
        for p in 0..nparams {
            let buf = ctx.create_buffer(n + 8); // slack for stencil taps
            let data: Vec<i32> = (0..n + 8).map(|_| rng.gen_i64(-20, 20) as i32).collect();
            buf.write(&data);
            kernel.set_arg(p, &buf).unwrap();
            buffers.push(buf);
        }
        let q = CommandQueue::new(&ctx);
        let ev = q.enqueue_nd_range(&kernel, n).unwrap();
        assert_eq!(ev.global_size, n, "{}", b.name);
    }
}

#[test]
fn pjrt_platform_fails_cleanly_without_feature_or_artifacts() {
    // Platform::with_pjrt must never panic: without the pjrt feature it
    // reports the stubbed backend; with it (but no artifacts) it
    // reports the missing geometry file.
    if std::path::Path::new("artifacts/geometry.json").exists() && cfg!(feature = "pjrt") {
        return; // a real PJRT environment — covered by the suite below
    }
    let err = Platform::with_pjrt("artifacts", reference_overlay());
    assert!(err.is_err());
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS, CHEBYSHEV};
    use overlay_jit::compiler::JitCompiler;
    use overlay_jit::overlay::{FuType, OverlaySpec};
    use overlay_jit::runtime::PjrtRuntime;
    use overlay_jit::runtime_ocl::{CommandQueue, Context, Platform, Program};
    use overlay_jit::sim;
    use overlay_jit::util::XorShiftRng;

    fn artifacts_available() -> bool {
        let ok = std::path::Path::new("artifacts/overlay_exec_i32.hlo.txt").exists();
        if !ok {
            eprintln!("skipping PJRT test: artifacts missing — run `make artifacts` first");
        }
        ok
    }

    fn random_streams(n_streams: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = XorShiftRng::new(seed);
        (0..n_streams)
            .map(|_| (0..len).map(|_| rng.gen_i64(-50, 50) as i32).collect())
            .collect()
    }

    #[test]
    fn pjrt_backend_matches_cycle_sim_on_all_benchmarks() {
        if !artifacts_available() {
            return;
        }
        let rt = PjrtRuntime::new("artifacts").unwrap();
        let jit = JitCompiler::new(reference_overlay());
        for b in &BENCHMARKS {
            let k = jit.compile(b.source).unwrap();
            let streams = random_streams(
                k.schedule.num_inputs,
                2500,
                0xC0FFEE ^ b.paper.replication as u64,
            );
            let n = streams.first().map_or(0, |s| s.len());
            let sim_out = sim::execute(&k.schedule, &streams, n).unwrap();
            let pjrt_out = rt.execute_overlay(&k.schedule, &streams, n).unwrap();
            assert_eq!(sim_out.len(), pjrt_out.len(), "{}", b.name);
            for (o, (s, p)) in sim_out.iter().zip(&pjrt_out).enumerate() {
                assert_eq!(s, p, "{} output {o} diverges between backends", b.name);
            }
        }
    }

    #[test]
    fn pjrt_opencl_flow_end_to_end() {
        if !artifacts_available() {
            return;
        }
        let platform = Platform::with_pjrt("artifacts", reference_overlay()).unwrap();
        let ctx = Context::new(&platform.devices()[0]);
        let mut program = Program::from_source(&ctx, CHEBYSHEV);
        program.build().unwrap();
        let kernel = program.create_kernel("chebyshev").unwrap();
        let n = 5000;
        let a = ctx.create_buffer(n);
        let b = ctx.create_buffer(n);
        let xs: Vec<i32> = (0..n).map(|i| (i as i32 % 17) - 8).collect();
        a.write(&xs);
        kernel.set_arg(0, &a).unwrap();
        kernel.set_arg(1, &b).unwrap();
        let q = CommandQueue::new(&ctx);
        let ev = q.enqueue_nd_range(&kernel, n).unwrap();
        let out = b.read();
        for (i, (&x, &y)) in xs.iter().zip(&out).enumerate() {
            let want = x.wrapping_mul(
                x.wrapping_mul(16i32.wrapping_mul(x).wrapping_mul(x).wrapping_sub(20))
                    .wrapping_mul(x)
                    .wrapping_add(5),
            );
            assert_eq!(y, want, "item {i}");
        }
        // profiling sanity: config ≈ 42 µs class, modeled exec is II=1
        assert!(ev.config_seconds > 30e-6 && ev.config_seconds < 60e-6);
        assert!(ev.modeled.gops > 0.0);
    }

    #[test]
    fn pjrt_direct_chebyshev_artifact_runs() {
        if !artifacts_available() {
            return;
        }
        // the fixed-function baseline artifact also loads and runs
        let rt = PjrtRuntime::new("artifacts").unwrap();
        let exe = rt.load("chebyshev_i32").unwrap();
        let xs: Vec<i32> = (0..1024).map(|i| (i % 11) - 5).collect();
        let x_l = xla::Literal::vec1(&xs);
        let out = exe.execute::<xla::Literal>(&[x_l]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<i32>()
            .unwrap();
        for (&x, &y) in xs.iter().zip(&out) {
            assert_eq!(y, x * (x * (16 * x * x - 20) * x + 5));
        }
    }

    #[test]
    fn sim_and_pjrt_agree_on_every_overlay_size() {
        if !artifacts_available() {
            return;
        }
        let rt = PjrtRuntime::new("artifacts").unwrap();
        for spec in OverlaySpec::size_sweep(FuType::Dsp2) {
            let jit = JitCompiler::new(spec.clone());
            let k = jit.compile(CHEBYSHEV).unwrap();
            let streams = random_streams(k.schedule.num_inputs, 300, spec.fu_count() as u64);
            let n = streams.first().map_or(0, |s| s.len());
            let sim_out = sim::execute(&k.schedule, &streams, n).unwrap();
            let pjrt_out = rt.execute_overlay(&k.schedule, &streams, n).unwrap();
            assert_eq!(sim_out, pjrt_out, "overlay {}", spec.name());
        }
    }
}
