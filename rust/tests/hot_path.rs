//! Hot-path data-plane audit: the blocked SoA simulator must be
//! bit-exact with the scalar reference walker on every benchmark
//! kernel at block-boundary-hostile sizes, pooled scratch must never
//! leak state between dispatches of different kernels, and the serving
//! dispatch path must perform zero heap growth once the scratch pool
//! has warmed up on the working set.

use overlay_jit::arena::StreamArena;
use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::prelude::*;
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::sim::{self, SimScratch, SIM_BLOCK};
use overlay_jit::util::XorShiftRng;

fn random_streams(num_inputs: usize, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = XorShiftRng::new(seed);
    (0..num_inputs)
        .map(|_| (0..n).map(|_| rng.gen_i64(-60, 60) as i32).collect())
        .collect()
}

/// The acceptance gate: all six bench kernels, at one item, one lane
/// short of a block, exactly a block, one lane past, and a large
/// many-block dispatch — blocked output must equal the scalar walker
/// bit for bit.
#[test]
fn blocked_executor_is_bit_exact_on_all_bench_kernels() {
    let jit = JitCompiler::new(OverlaySpec::zynq_default());
    for b in &BENCHMARKS {
        let k = jit.compile(b.source).unwrap();
        for n in [1usize, SIM_BLOCK - 1, SIM_BLOCK, SIM_BLOCK + 1, 16_384] {
            let streams = random_streams(k.schedule.num_inputs, n, 0xC0FFEE ^ n as u64);
            let blocked = sim::execute(&k.schedule, &streams, n).unwrap();
            let reference = sim::execute_reference(&k.schedule, &streams, n).unwrap();
            assert_eq!(blocked, reference, "{} diverges at n={n}", b.name);
        }
    }
}

/// One SimScratch + arena pair serves all six kernels back to back,
/// twice: every dispatch must still match the reference (no immediate
/// pool, slot table, or output residue from the previous kernel), and
/// the second pass must perform zero heap growth.
#[test]
fn pooled_scratch_reuse_never_leaks_state_between_kernels() {
    let jit = JitCompiler::new(OverlaySpec::zynq_default());
    let kernels: Vec<_> = BENCHMARKS
        .iter()
        .map(|b| (b.name, jit.compile(b.source).unwrap()))
        .collect();
    let n = SIM_BLOCK + 17;
    let mut scratch = SimScratch::new();
    let mut arena = StreamArena::new();
    let mut out = StreamArena::new();

    let run_all = |scratch: &mut SimScratch,
                       arena: &mut StreamArena,
                       out: &mut StreamArena| {
        for (name, k) in &kernels {
            let streams = random_streams(k.schedule.num_inputs, n, 0xF00D);
            arena.fill_from(&streams, n);
            sim::execute_into(&k.schedule, arena, n, scratch, out).unwrap();
            let reference = sim::execute_reference(&k.schedule, &streams, n).unwrap();
            assert_eq!(out.to_vecs(), reference, "{name} leaked state");
        }
    };

    run_all(&mut scratch, &mut arena, &mut out);
    let warm =
        scratch.grow_events() + arena.grow_events() + out.grow_events();
    run_all(&mut scratch, &mut arena, &mut out);
    assert_eq!(
        scratch.grow_events() + arena.grow_events() + out.grow_events(),
        warm,
        "second pass over the working set must not touch the allocator"
    );
}

/// The serving dispatch path end to end: after the coordinator's
/// scratch pool has seen the working set once, repeat dispatches
/// produce zero pool growth (the §E11 "0 allocations per dispatch
/// after warm-up" row), and the pack/scatter event split nests inside
/// the measured wall time.
#[test]
fn coordinator_dispatch_path_is_allocation_free_after_warmup() {
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2))
            .unwrap();
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&dev);
    let n = 1024;
    // sequential dispatches keep every run the same shape, so heap
    // growth after the first one is a genuine data-plane regression
    let submit_wave = |rounds: usize| {
        (0..rounds)
            .map(|_| {
                let a = ctx.create_buffer(n);
                let b = ctx.create_buffer(n);
                a.write(&(0..n as i32).map(|i| i % 11 - 5).collect::<Vec<_>>());
                coord
                    .submit(
                        overlay_jit::bench_kernels::CHEBYSHEV,
                        &[SubmitArg::Buffer(a), SubmitArg::Buffer(b)],
                        n,
                        Priority::Interactive,
                    )
                    .unwrap()
                    .wait()
                    .unwrap()
            })
            .collect::<Vec<_>>()
    };

    // warm-up: compile, first scratch creation, first arena growth
    submit_wave(4);
    let warm = coord.pool_stats();
    assert!(warm.created >= 1);

    let results = submit_wave(16);
    let steady = coord.pool_stats();
    assert_eq!(
        steady.grow_events, warm.grow_events,
        "steady-state dispatches must not grow any pooled arena"
    );
    assert_eq!(steady.created, warm.created, "no new scratches in steady state");
    assert!(steady.checkouts > steady.created, "scratches are reused, not recreated");
    assert_eq!(steady.pooled as u64, steady.created, "all scratches parked when idle");

    for r in &results {
        assert_eq!(r.verified, Some(true));
        assert!(
            r.event.pack_ns + r.event.scatter_ns <= r.event.wall.as_nanos() as u64,
            "pack/scatter split must nest inside the wall time"
        );
    }

    // the pool counters surface through the public serving stats too
    let stats = coord.stats();
    assert_eq!(stats.scratch_pool.created, steady.created);
    assert!(stats.render().contains("scratch"));
}

/// Fused batch-lane dispatches pack into one arena at per-job offsets;
/// each job's scattered outputs must still be exactly its own.
#[test]
fn fused_runs_split_correctly_by_lane_offset() {
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.verify = true;
    let coord = Coordinator::new(cfg).unwrap();
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&dev);
    let cheb = |x: i32| {
        x.wrapping_mul(
            x.wrapping_mul(16i32.wrapping_mul(x).wrapping_mul(x).wrapping_sub(20))
                .wrapping_mul(x)
                .wrapping_add(5),
        )
    };
    // distinct sizes per job force distinct chunks — the offsets the
    // fused split must get right
    let sizes = [257usize, 512, 96, 1024];
    let mut jobs = Vec::new();
    for (j, &n) in sizes.iter().enumerate() {
        let a = ctx.create_buffer(n);
        let b = ctx.create_buffer(n);
        let xs: Vec<i32> = (0..n as i32).map(|i| (i + j as i32) % 13 - 6).collect();
        a.write(&xs);
        let h = coord
            .submit(
                overlay_jit::bench_kernels::CHEBYSHEV,
                &[SubmitArg::Buffer(a), SubmitArg::Buffer(b.clone())],
                n,
                Priority::Batch,
            )
            .unwrap();
        jobs.push((xs, b, h));
    }
    for (xs, b, h) in jobs {
        let r = h.wait().unwrap();
        assert_eq!(r.verified, Some(true));
        let out = b.read();
        for (x, y) in xs.iter().zip(&out) {
            assert_eq!(*y, cheb(*x));
        }
    }
}
