//! Chunk-boundary batch-preemption invariants, observed through the
//! public serving API:
//!
//! * **no lost or duplicated jobs** — every preempted handle resolves
//!   exactly once, with the full dispatch count accounted for;
//! * **bit-exact resume** — a preempted-and-resumed batch job scatters
//!   byte-identical outputs to an unpreempted run of the same inputs;
//! * **interactive immunity** — interactive runs are never preempted,
//!   even with the flag raised continuously;
//! * **budget caps livelock** — under continuous preemption pressure
//!   every job still completes, and no job bounces more than
//!   [`MAX_PREEMPTIONS`] times;
//! * **counters agree with records** — `preempted_continuations`
//!   equals the typed continuation records (retained + dropped), and
//!   both round-trip through the Prometheus exposition.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{
    Coordinator, CoordinatorConfig, SubmitArg, MAX_PREEMPTIONS,
};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::prelude::*;
use overlay_jit::runtime_ocl::{Context, Device};
use overlay_jit::util::XorShiftRng;

const ITEMS: usize = 256;
const SLACK: usize = 16;

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

fn param_count(source: &str) -> usize {
    overlay_jit::frontend::parse_kernel(source).unwrap().params.len()
}

/// Deterministic per-job input data (with stencil slack), so two
/// coordinators can be fed byte-identical work.
fn job_data(nparams: usize, jobs: usize, seed: u64) -> Vec<Vec<Vec<i32>>> {
    let mut rng = XorShiftRng::new(seed);
    (0..jobs)
        .map(|_| {
            (0..nparams)
                .map(|_| {
                    (0..ITEMS + SLACK)
                        .map(|_| rng.gen_i64(-30, 30) as i32)
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// Materialize one job's buffers from its data rows.
fn buffers_for(ctx: &Context, rows: &[Vec<i32>]) -> Vec<SubmitArg> {
    rows.iter()
        .map(|row| {
            let buf = ctx.create_buffer(row.len());
            buf.write(row);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

fn read_all(args: &[SubmitArg]) -> Vec<Vec<i32>> {
    args.iter()
        .map(|a| match a {
            SubmitArg::Buffer(b) => b.read(),
            other => panic!("test only submits buffers, got {other:?}"),
        })
        .collect()
}

/// Counters must agree with the typed continuation records, and both
/// must survive the Prometheus exposition.
fn assert_counters_agree(coord: &Coordinator) {
    let stats = coord.stats();
    let (records, dropped) = coord.preemption_continuations();
    assert_eq!(
        stats.preempted_continuations,
        records.len() as u64 + dropped,
        "continuation counter must equal retained + dropped records"
    );
    for r in &records {
        assert!(
            (1..=MAX_PREEMPTIONS).contains(&r.preemptions),
            "record carries an out-of-budget bounce count: {r:?}"
        );
    }
    let text = stats.prometheus();
    assert!(
        text.contains(&format!(
            "overlay_jit_preempted_runs_total {}",
            stats.preempted_runs
        )),
        "preempted_runs must round-trip through prometheus():\n{text}"
    );
    assert!(
        text.contains(&format!(
            "overlay_jit_preempted_continuations_total {}",
            stats.preempted_continuations
        )),
        "preempted_continuations must round-trip through prometheus():\n{text}"
    );
}

#[test]
fn preempted_batch_run_resumes_bit_exact_with_no_loss_or_duplication() {
    const JOBS: usize = 4;
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    let data = job_data(nparams, JOBS, 0x9EE9);
    let ctx = host_ctx();

    // ground truth: the same jobs through a run-to-completion fleet
    let baseline = Coordinator::new(CoordinatorConfig::sim_fleet(
        OverlaySpec::zynq_default(),
        1,
    ))
    .unwrap();
    let mut expected = Vec::new();
    for rows in &data {
        let args = buffers_for(&ctx, rows);
        let r = baseline
            .submit(b.source, &args, ITEMS, Priority::Batch)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.verified, Some(true));
        expected.push(read_all(&args));
    }

    // preemption-armed single-partition fleet: the continuation
    // requeues behind the interactive lane on the same partition
    // (requeue_sibling's single-partition fallback)
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.preempt = true;
    cfg.fusion_window = Duration::from_millis(250);
    let coord = Coordinator::new(cfg).unwrap();

    // the flag is sticky until a batch run consumes it at a chunk
    // boundary, so raising before the submits is race-free; the
    // retry loop only guards against the fusion window expiring on a
    // pathologically slow machine (each round is bit-exact checked
    // regardless of whether it preempted)
    let mut rounds = 0;
    loop {
        rounds += 1;
        coord.raise_preempt(0);
        let all_args: Vec<Vec<SubmitArg>> =
            data.iter().map(|rows| buffers_for(&ctx, rows)).collect();
        let handles: Vec<_> = all_args
            .iter()
            .map(|args| coord.submit(b.source, args, ITEMS, Priority::Batch).unwrap())
            .collect();
        // no lost or hung jobs: every handle resolves, exactly once
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.wait().unwrap();
            assert_eq!(r.verified, Some(true), "job {i} must stay sim-verified");
        }
        // bit-exact resume: byte-identical buffers vs the baseline
        for (i, args) in all_args.iter().enumerate() {
            assert_eq!(
                read_all(args),
                expected[i],
                "job {i} outputs must match the unpreempted run exactly"
            );
        }
        let stats = coord.stats();
        // no duplicated jobs: each completes as exactly one dispatch
        assert_eq!(stats.total_dispatches, (rounds * JOBS) as u64);
        assert_eq!(stats.dispatch_errors, 0);
        assert_eq!(stats.verify_failures, 0);
        if stats.preempted_runs >= 1 {
            break;
        }
        assert!(rounds < 5, "no run preempted in {rounds} rounds");
    }

    let stats = coord.stats();
    assert!(stats.preempted_runs >= 1);
    assert!(stats.preempted_continuations >= 1);
    let (records, _) = coord.preemption_continuations();
    assert!(!records.is_empty());
    for r in &records {
        assert_eq!(r.from, 0, "single-partition fleet preempts on partition 0");
        assert_eq!(r.to, 0, "continuation falls back to the only partition");
    }
    assert_counters_agree(&coord);
}

#[test]
fn interactive_runs_are_never_preempted_even_under_continuous_pressure() {
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    let data = job_data(nparams, 6, 0x1A7E);
    let ctx = host_ctx();

    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.preempt = true;
    let coord = Arc::new(Coordinator::new(cfg).unwrap());

    // hammer the flag from a second thread for the whole test
    let done = Arc::new(AtomicBool::new(false));
    let raiser = {
        let coord = coord.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            while !done.load(Ordering::Relaxed) {
                coord.raise_preempt(0);
                std::thread::yield_now();
            }
        })
    };

    let handles: Vec<_> = data
        .iter()
        .map(|rows| {
            let args = buffers_for(&ctx, rows);
            coord
                .submit(b.source, &args, ITEMS, Priority::Interactive)
                .unwrap()
        })
        .collect();
    for h in handles {
        assert_eq!(h.wait().unwrap().verified, Some(true));
    }
    done.store(true, Ordering::Relaxed);
    raiser.join().unwrap();

    let stats = coord.stats();
    assert_eq!(stats.preempted_runs, 0, "interactive runs must never preempt");
    assert_eq!(stats.preempted_continuations, 0);
    let (records, dropped) = coord.preemption_continuations();
    assert!(records.is_empty());
    assert_eq!(dropped, 0);
}

#[test]
fn preemption_budget_caps_livelock_under_continuous_pressure() {
    const JOBS: usize = 6;
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    let data = job_data(nparams, JOBS, 0xB0D6);
    let ctx = host_ctx();

    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.preempt = true;
    cfg.fusion_window = Duration::from_millis(100);
    let coord = Arc::new(Coordinator::new(cfg).unwrap());

    let done = Arc::new(AtomicBool::new(false));
    let raiser = {
        let coord = coord.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            while !done.load(Ordering::Relaxed) {
                coord.raise_preempt(0);
                std::thread::yield_now();
            }
        })
    };

    let handles: Vec<_> = data
        .iter()
        .map(|rows| {
            let args = buffers_for(&ctx, rows);
            coord.submit(b.source, &args, ITEMS, Priority::Batch).unwrap()
        })
        .collect();
    // liveness: every job completes despite the flag being re-raised
    // at every opportunity — the run head always executes, and a job
    // past its budget turns non-preemptible
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().unwrap();
        assert_eq!(r.verified, Some(true), "job {i} must complete verified");
    }
    done.store(true, Ordering::Relaxed);
    raiser.join().unwrap();

    let stats = coord.stats();
    assert_eq!(stats.total_dispatches, JOBS as u64);
    assert_eq!(stats.dispatch_errors, 0);
    let (records, dropped) = coord.preemption_continuations();
    // the budget: no dispatch sequence number bounces more than
    // MAX_PREEMPTIONS times, and every record stays within budget
    let mut per_seq = std::collections::HashMap::new();
    for r in &records {
        *per_seq.entry(r.seq).or_insert(0u32) += 1;
        assert!(r.preemptions <= MAX_PREEMPTIONS, "{r:?}");
    }
    if dropped == 0 {
        for (seq, bounces) in per_seq {
            assert!(
                bounces <= MAX_PREEMPTIONS,
                "seq {seq} preempted {bounces} times (budget {MAX_PREEMPTIONS})"
            );
        }
    }
    assert_counters_agree(&coord);
}

#[test]
fn disabled_preemption_ignores_a_raised_flag() {
    const JOBS: usize = 3;
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    let data = job_data(nparams, JOBS, 0x0FF);
    let ctx = host_ctx();

    // default config: preempt is off — the run-to-completion baseline
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.fusion_window = Duration::from_millis(100);
    assert!(!cfg.preempt);
    let coord = Coordinator::new(cfg).unwrap();
    coord.raise_preempt(0); // registered but never polled

    let handles: Vec<_> = data
        .iter()
        .map(|rows| {
            let args = buffers_for(&ctx, rows);
            coord.submit(b.source, &args, ITEMS, Priority::Batch).unwrap()
        })
        .collect();
    for h in handles {
        assert_eq!(h.wait().unwrap().verified, Some(true));
    }
    let stats = coord.stats();
    assert_eq!(stats.preempted_runs, 0);
    assert_eq!(stats.preempted_continuations, 0);
}
