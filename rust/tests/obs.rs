//! Observability integration tests: the [`overlay_jit::obs`] span
//! recorder observed through the public serving APIs.
//!
//! Covers the layer's two core promises end to end, all offline:
//! * **tracing off costs nothing** — a coordinator built without a
//!   trace handle allocates zero ring spans and records zero spans or
//!   traces while serving (the no-op recorder's counters stay 0);
//! * **tracing on is structurally complete** — every submit yields a
//!   trace with exactly one root and no orphaned parent references,
//!   the serving phases appear under it, and cluster-front-door
//!   traces keep a single root across the node boundary with the hop
//!   attributed to the node that served the dispatch;
//! * **worker stamps are measured** — the pack/exec/scatter/verify
//!   span boundaries come from clock reads at the stage transitions,
//!   so each stage ends exactly where the next begins;
//! * **sampling thins traces, never metrics** — a 1/N head sampler
//!   admits a deterministic subset of submits (whole trees, no
//!   partial traces) while the latency histograms still count every
//!   completion.

use std::time::{Duration, Instant};

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::cluster::{ClusterConfig, ClusterFrontend};
use overlay_jit::coordinator::{
    Admission, Coordinator, CoordinatorConfig, DispatchHandle, Priority, SubmitArg,
};
use overlay_jit::obs::{
    check_traces, chrome_trace, Phase, Sampler, TraceHandle, TraceSink, CLASS_TAIL,
    FRONTEND_NODE,
};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::util::{JsonValue, XorShiftRng};

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

fn random_args(ctx: &Context, source: &str, n: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    let nparams = overlay_jit::frontend::parse_kernel(source).unwrap().params.len();
    (0..nparams)
        .map(|_| {
            let buf = ctx.create_buffer(n + 16);
            let data: Vec<i32> = (0..n + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

fn resolve(h: DispatchHandle, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        match h.try_wait_typed() {
            Some(Ok(_)) => return,
            Some(Err(e)) => panic!("{what}: dispatch failed: {e}"),
            None => {
                assert!(Instant::now() < deadline, "{what}: dispatch hung");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// Tracing off is the default, and it must be a true no-op: the
/// disabled sink owns zero ring capacity and a full serve/complete
/// cycle bumps none of its counters.
#[test]
fn tracing_off_allocates_and_records_nothing() {
    let disabled = TraceSink::disabled();
    assert!(!disabled.enabled());
    let st = disabled.stats();
    assert_eq!(st.allocated_spans, 0, "disabled sink must own no ring memory");
    assert_eq!(st.shards, 0);

    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1))
            .unwrap();
    assert!(!coord.trace().enabled());

    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x0B5);
    for b in BENCHMARKS.iter().take(2) {
        let args = random_args(&ctx, b.source, 256, &mut rng);
        let h = coord
            .submit(b.source, &args, 256, Priority::Interactive)
            .unwrap();
        resolve(h, b.name);
    }
    coord.drain_background();

    let st = coord.trace().sink.stats();
    assert_eq!(st.allocated_spans, 0, "serving must not grow ring memory");
    assert_eq!(st.recorded, 0, "no spans may be recorded with tracing off");
    assert_eq!(st.traces, 0, "no traces may be opened with tracing off");
    assert!(coord.trace().sink.spans().is_empty());
    assert!(coord.trace().sink.exemplars().is_empty());
}

/// With the recorder armed, every submit produces a structurally
/// complete trace — one root, no orphans — carrying the serving
/// phases, and the slowest completion is pinned as the tail exemplar.
#[test]
fn enabled_traces_are_rooted_and_orphan_free() {
    let sink = TraceSink::new(2, 4096);
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.trace = Some(TraceHandle::new(sink.clone(), 0));
    let coord = Coordinator::new(cfg).unwrap();
    assert!(coord.trace().enabled());

    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x0B6);
    const SUBMITS: usize = 3;
    for _ in 0..SUBMITS {
        let b = &BENCHMARKS[0];
        let args = random_args(&ctx, b.source, 256, &mut rng);
        let h = coord
            .submit(b.source, &args, 256, Priority::Interactive)
            .unwrap();
        resolve(h, b.name);
    }
    coord.drain_background();

    let spans = sink.spans();
    let st = sink.stats();
    assert_eq!(st.overwritten, 0);
    let chk = check_traces(&spans);
    assert_eq!(chk.traces, SUBMITS, "one trace per submit");
    assert_eq!(chk.rooted, chk.traces, "every trace has exactly one root");
    assert_eq!(chk.orphans, 0, "every parent reference resolves in-trace");

    // the serving phases all appear: admission-free submit → route →
    // cache (miss then hits) → slot pick → worker timeline
    for phase in [
        Phase::Submit,
        Phase::Route,
        Phase::SlotPick,
        Phase::QueueWait,
        Phase::Pack,
        Phase::Exec,
        Phase::Scatter,
        Phase::Verify,
    ] {
        assert!(
            spans.iter().any(|s| s.phase == phase),
            "phase {} missing from the trace set",
            phase.name()
        );
    }
    assert!(
        spans.iter().any(|s| s.phase == Phase::Compile && s.tag == "miss"),
        "first submit must record the cold compile"
    );
    assert!(
        spans.iter().any(|s| s.phase == Phase::CacheLookup && s.tag == "hit"),
        "warm submits must record cache hits"
    );

    // worker spans carry a real worker id; submit-path spans do not
    assert!(spans.iter().any(|s| s.phase == Phase::Exec && s.worker >= 0));
    assert!(spans.iter().all(|s| s.phase != Phase::Submit || s.worker < 0));

    // the flight recorder pinned a tail exemplar for a live trace
    let tail = sink.exemplar(CLASS_TAIL, "e2e").expect("tail exemplar pinned");
    assert!(spans.iter().any(|s| s.trace_id == tail.trace_id));
    assert_eq!(tail.count as usize, SUBMITS);

    // the Chrome exporter round-trips every span
    let doc = chrome_trace(&spans, 0).render();
    let parsed = JsonValue::parse(&doc).unwrap();
    let events = parsed.get("traceEvents").and_then(|v| v.as_array()).unwrap();
    assert_eq!(events.len(), spans.len());
}

/// Worker-timeline spans carry **measured** sub-stage timestamps: the
/// pack/exec/scatter/verify boundaries are clock reads taken at the
/// stage transitions, so within every trace each stage ends exactly
/// where the next begins and nothing runs backwards.
#[test]
fn worker_spans_are_measured_and_stage_boundaries_are_monotone() {
    let sink = TraceSink::new(2, 4096);
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.trace = Some(TraceHandle::new(sink.clone(), 0));
    let coord = Coordinator::new(cfg).unwrap();

    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x0B8);
    const SUBMITS: usize = 4;
    for _ in 0..SUBMITS {
        let b = &BENCHMARKS[0];
        let args = random_args(&ctx, b.source, 4096, &mut rng);
        let h = coord
            .submit(b.source, &args, 4096, Priority::Interactive)
            .unwrap();
        resolve(h, b.name);
    }
    coord.drain_background();

    let spans = sink.spans();
    let chk = check_traces(&spans);
    assert_eq!(chk.traces, SUBMITS);
    for trace in spans.iter().filter(|s| s.parent == 0).map(|s| s.trace_id) {
        let stage = |phase: Phase| {
            spans
                .iter()
                .find(|s| s.trace_id == trace && s.phase == phase)
                .unwrap_or_else(|| panic!("trace {trace} lacks a {} span", phase.name()))
        };
        let chain = [
            stage(Phase::QueueWait),
            stage(Phase::Pack),
            stage(Phase::Exec),
            stage(Phase::Scatter),
            stage(Phase::Verify),
        ];
        for pair in chain.windows(2) {
            assert_eq!(
                pair[0].start_us + pair[0].dur_us,
                pair[1].start_us,
                "trace {trace}: {} must end exactly where {} starts",
                pair[0].phase.name(),
                pair[1].phase.name()
            );
        }
        // the verify marker sits at the measured completion stamp
        assert_eq!(chain[4].dur_us, 0);
    }
    // the stamps are real clock reads, not all-zero placeholders
    assert!(
        spans
            .iter()
            .any(|s| s.phase == Phase::QueueWait && s.start_us > 0),
        "measured queue-wait stamps must come from the sink clock"
    );
}

/// Head-based sampling: a 1/4 sampler consumes one candidate per
/// submit (deterministically — candidates 6 and 9 of 1..=12 hash in),
/// sampled-out submits run untraced, and the latency books still
/// count every completion.
#[test]
fn sampled_sink_drops_spans_but_histograms_keep_every_completion() {
    let sink = TraceSink::sampled(2, 4096, Sampler::ratio(4));
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.trace = Some(TraceHandle::new(sink.clone(), 0));
    let coord = Coordinator::new(cfg).unwrap();

    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x0B9);
    const SUBMITS: usize = 12;
    for _ in 0..SUBMITS {
        let b = &BENCHMARKS[0];
        let args = random_args(&ctx, b.source, 256, &mut rng);
        let h = coord
            .submit(b.source, &args, 256, Priority::Interactive)
            .unwrap();
        resolve(h, b.name);
    }
    coord.drain_background();

    let st = sink.stats();
    assert_eq!(st.traces + st.sampled_out, SUBMITS as u64, "one candidate per submit");
    assert_eq!(st.traces, 2, "candidates 6 and 9 hash in at denom 4");
    assert_eq!(st.sampled_out, 10);

    // the surviving traces are complete trees, not partial records
    let spans = sink.spans();
    let chk = check_traces(&spans);
    assert_eq!(chk.traces, 2);
    assert_eq!(chk.rooted, 2);
    assert_eq!(chk.orphans, 0);

    // sampling never thins the metrics plane: every completion is in
    // the histogram, and the percentile view covers all twelve
    let stats = coord.stats();
    assert_eq!(stats.latency_hist.count(), SUBMITS as u64);
    assert_eq!(stats.latency.count, SUBMITS);
    assert!(stats.latency.p99_ms >= stats.latency.p50_ms);
}

/// A cluster front-door trace stays one tree across the node
/// boundary: the frontend root is the only root, the serving node's
/// submit span parents to it, and node attribution survives.
#[test]
fn cluster_trace_propagates_across_the_node_boundary() {
    let sink = TraceSink::new(2, 4096);
    let mut cfg = ClusterConfig::sim_cluster(
        2,
        CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1),
    );
    cfg.trace = Some(sink.clone());
    let cluster = ClusterFrontend::new(cfg).unwrap();

    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x0B7);
    let b = &BENCHMARKS[0];
    let args = random_args(&ctx, b.source, 256, &mut rng);
    match cluster
        .submit_gated("t0", b.source, &args, 256, Priority::Interactive, None)
        .unwrap()
    {
        Admission::Admitted(h) => resolve(h, b.name),
        Admission::Rejected(r) => panic!("ungated cluster rejected: {r}"),
    }
    cluster.drain();

    let spans = sink.spans();
    let chk = check_traces(&spans);
    assert_eq!(chk.traces, 1);
    assert_eq!(chk.rooted, 1, "exactly one root across both layers");
    assert_eq!(chk.orphans, 0, "the node-side spans parent into the frontend trace");

    let root = spans
        .iter()
        .find(|s| s.parent == 0)
        .expect("frontend root span");
    assert_eq!(root.phase, Phase::Frontend);
    assert_eq!(root.node, FRONTEND_NODE);
    let submit = spans
        .iter()
        .find(|s| s.phase == Phase::Submit)
        .expect("node-side submit span");
    assert_eq!(submit.parent, root.span_id, "submit parents to the frontend root");
    assert!(submit.node != FRONTEND_NODE, "submit carries the serving node's id");
    // worker spans executed on the same node the submit landed on
    let exec = spans.iter().find(|s| s.phase == Phase::Exec).expect("exec span");
    assert_eq!(exec.node, submit.node);
    cluster.shutdown();
}
