//! Property-based tests over the compiler's core invariants.
//!
//! `proptest` is not available in the offline vendored set, so these
//! use the crate's own seeded [`XorShiftRng`] to generate hundreds of
//! random well-formed kernels and check invariants on every one —
//! same methodology, explicit seeds, reproducible failures (the seed
//! is in every assertion message).

use overlay_jit::compiler::{CompileOptions, JitCompiler, Replication};
use overlay_jit::dfg::NodeKind;
use overlay_jit::frontend::parse_kernel;
use overlay_jit::fuaware::{fuse_muladd, to_fu_graph};
use overlay_jit::ir::{lower_kernel, optimize};
use overlay_jit::overlay::{FuType, OverlaySpec};
use overlay_jit::sim;
use overlay_jit::util::XorShiftRng;

/// Generate a random straight-line kernel: a DAG of int expressions
/// over two input buffers, one output store.
fn random_kernel(rng: &mut XorShiftRng, max_stmts: usize) -> String {
    let n_stmts = 1 + rng.gen_range(max_stmts);
    let mut body = String::from("  int i = get_global_id(0);\n");
    body.push_str("  int v0 = A[i];\n  int v1 = B[i];\n");
    let mut vars = 2usize;
    for s in 0..n_stmts {
        let a = rng.gen_range(vars);
        let b = rng.gen_range(vars);
        let expr = match rng.gen_range(6) {
            0 => format!("v{a} + v{b}"),
            1 => format!("v{a} - v{b}"),
            2 => format!("v{a} * v{b}"),
            3 => format!("v{a} * {} + v{b}", rng.gen_i64(-9, 9)),
            4 => format!("max(v{a}, v{b})"),
            _ => format!("min(v{a}, v{b}) * {}", rng.gen_i64(1, 7)),
        };
        body.push_str(&format!("  int v{} = {expr};\n", vars));
        vars += 1;
        let _ = s;
    }
    body.push_str(&format!("  C[i] = v{};\n", vars - 1));
    format!(
        "__kernel void randk(__global int *A, __global int *B, __global int *C) {{\n{body}}}"
    )
}

/// Reference evaluation of the generated kernel in plain Rust.
fn eval_reference(src: &str, a: &[i32], b: &[i32]) -> Vec<i32> {
    // interpret the generated source line by line (it has a fixed shape)
    let mut out = vec![0i32; a.len()];
    for i in 0..a.len() {
        let mut vals: Vec<i32> = vec![a[i], b[i]];
        for line in src.lines() {
            let line = line.trim();
            if let Some(rest) = line.strip_prefix("int v") {
                if rest.starts_with("0 =") || rest.starts_with("1 =") {
                    continue;
                }
                let (_, expr) = rest.split_once('=').unwrap();
                let expr = expr.trim().trim_end_matches(';');
                vals.push(eval_expr(expr, &vals));
            }
        }
        out[i] = *vals.last().unwrap();
    }
    out
}

fn eval_expr(expr: &str, vals: &[i32]) -> i32 {
    let v = |tok: &str| -> i32 {
        let tok = tok.trim();
        if let Some(n) = tok.strip_prefix('v') {
            vals[n.parse::<usize>().unwrap()]
        } else {
            tok.parse::<i32>().unwrap()
        }
    };
    if let Some(rest) = expr.strip_prefix("max(") {
        let inner = rest.trim_end_matches(')');
        let (x, y) = inner.split_once(',').unwrap();
        return v(x).max(v(y));
    }
    if let Some(rest) = expr.strip_prefix("min(") {
        // may be `min(va, vb) * k`
        let (inner, tail) = rest.split_once(')').unwrap();
        let (x, y) = inner.split_once(',').unwrap();
        let m = v(x).min(v(y));
        let tail = tail.trim();
        if let Some(k) = tail.strip_prefix('*') {
            return m.wrapping_mul(v(k));
        }
        return m;
    }
    // forms: x + y | x - y | x * y | x * k + y
    let toks: Vec<&str> = expr.split_whitespace().collect();
    match toks.as_slice() {
        [x, "+", y] => v(x).wrapping_add(v(y)),
        [x, "-", y] => v(x).wrapping_sub(v(y)),
        [x, "*", y] => v(x).wrapping_mul(v(y)),
        [x, "*", k, "+", y] => v(x).wrapping_mul(v(k)).wrapping_add(v(y)),
        [x, "*", k, "-", y] => v(x).wrapping_mul(v(k)).wrapping_sub(v(y)),
        other => panic!("unparsed expr {other:?}"),
    }
}

#[test]
fn prop_compiled_kernels_compute_their_source_semantics() {
    // compile 60 random kernels, execute on the cycle sim, compare to
    // the independent reference interpreter
    let mut rng = XorShiftRng::new(2024);
    let jit = JitCompiler::with_options(
        OverlaySpec::zynq_default(),
        CompileOptions { replication: Replication::Fixed(1), ..Default::default() },
    );
    for case in 0..60 {
        let src = random_kernel(&mut rng, 10);
        let k = match jit.compile(&src) {
            Ok(k) => k,
            Err(e) => panic!("case {case}: compile failed: {e:#}\n{src}"),
        };
        let n = 64;
        let a: Vec<i32> = (0..n).map(|_| rng.gen_i64(-30, 30) as i32).collect();
        let b: Vec<i32> = (0..n).map(|_| rng.gen_i64(-30, 30) as i32).collect();
        let want = eval_reference(&src, &a, &b);
        // inputs in DFG port order (A then B when both used); fully
        // constant kernels legitimately have zero streams
        let mut streams = Vec::new();
        for m in &k.dfg.input_meta {
            streams.push(if m.param == 0 { a.clone() } else { b.clone() });
        }
        let got = sim::execute(&k.schedule, &streams, n).unwrap();
        assert_eq!(got[0], want, "case {case} (seed 2024)\n{src}");
    }
}

#[test]
fn prop_fusion_preserves_op_semantics_and_reduces_nodes() {
    let mut rng = XorShiftRng::new(99);
    for case in 0..80 {
        let src = random_kernel(&mut rng, 12);
        let f = lower_kernel(&parse_kernel(&src).unwrap()).unwrap();
        let (ir, _) = optimize(&f);
        let dfg = match overlay_jit::dfg::extract_dfg(&ir) {
            Ok(d) => d,
            Err(_) => continue,
        };
        let fused = fuse_muladd(&dfg).unwrap();
        assert!(fused.num_ops() <= dfg.num_ops(), "case {case}: fusion grew the DFG");
        fused.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        // same I/O
        assert_eq!(fused.num_inputs(), dfg.num_inputs());
        assert_eq!(fused.num_outputs(), dfg.num_outputs());
    }
}

#[test]
fn prop_clustering_never_exceeds_pin_budget() {
    let mut rng = XorShiftRng::new(4242);
    for case in 0..80 {
        let src = random_kernel(&mut rng, 14);
        let f = lower_kernel(&parse_kernel(&src).unwrap()).unwrap();
        let (ir, _) = optimize(&f);
        let Ok(dfg) = overlay_jit::dfg::extract_dfg(&ir) else { continue };
        let fg = to_fu_graph(&dfg, 2).unwrap();
        for fu in &fg.fus {
            let pins = fg.input_pins(fu.id);
            assert!(
                pins.len() <= overlay_jit::fuaware::MAX_FU_INPUTS,
                "case {case}: FU{} has {} pins",
                fu.id,
                pins.len()
            );
            assert!(fu.ops.len() <= 2, "case {case}");
        }
        // every op assigned to exactly one FU
        let total: usize = fg.fus.iter().map(|f| f.ops.len()).sum();
        assert_eq!(total, fg.dfg.num_ops(), "case {case}");
    }
}

#[test]
fn prop_slot_schedule_sources_are_always_backward() {
    let mut rng = XorShiftRng::new(31337);
    for case in 0..80 {
        let src = random_kernel(&mut rng, 12);
        let f = lower_kernel(&parse_kernel(&src).unwrap()).unwrap();
        let (ir, _) = optimize(&f);
        let Ok(dfg) = overlay_jit::dfg::extract_dfg(&ir) else { continue };
        let fused = fuse_muladd(&dfg).unwrap();
        let s =
            overlay_jit::configgen::slot_schedule(&fused, overlay_jit::configgen::EmuGeometry::DEFAULT)
                .unwrap();
        let out_base = s.geometry.out_base();
        for t in 0..s.n_slots() {
            for col in [s.src_a[t], s.src_b[t], s.src_c[t]] {
                let col = col as usize;
                assert!(col < s.geometry.num_slots(), "case {case}");
                if col >= out_base {
                    assert!(col - out_base < t, "case {case}: slot {t} reads forward");
                }
            }
        }
    }
}

#[test]
fn prop_replication_factors_scale_resources_linearly() {
    let mut rng = XorShiftRng::new(555);
    for case in 0..30 {
        let src = random_kernel(&mut rng, 8);
        let f = lower_kernel(&parse_kernel(&src).unwrap()).unwrap();
        let (ir, _) = optimize(&f);
        let Ok(dfg) = overlay_jit::dfg::extract_dfg(&ir) else { continue };
        let fused = fuse_muladd(&dfg).unwrap();
        for r in [2usize, 3, 5] {
            let rep = overlay_jit::replicate::replicate_dfg(&fused, r);
            rep.validate().unwrap();
            assert_eq!(rep.num_ops(), r * fused.num_ops(), "case {case}");
            assert_eq!(rep.num_io(), r * fused.num_io(), "case {case}");
            // copies are disjoint: no edge crosses copy boundaries
            let per = fused.nodes.len();
            for e in &rep.edges {
                assert_eq!(e.src / per, e.dst / per, "case {case}: cross-copy edge");
            }
        }
    }
}

#[test]
fn prop_dfg_nodes_all_reach_an_output() {
    let mut rng = XorShiftRng::new(808);
    for case in 0..60 {
        let src = random_kernel(&mut rng, 10);
        let f = lower_kernel(&parse_kernel(&src).unwrap()).unwrap();
        let (ir, _) = optimize(&f);
        let Ok(dfg) = overlay_jit::dfg::extract_dfg(&ir) else { continue };
        // pruned() is applied inside extract_dfg: every op node must
        // reach an outvar
        let mut reaches = vec![false; dfg.nodes.len()];
        for n in &dfg.nodes {
            if matches!(n.kind, NodeKind::OutVar { .. }) {
                reaches[n.id] = true;
            }
        }
        let mut changed = true;
        while changed {
            changed = false;
            for e in &dfg.edges {
                if reaches[e.dst] && !reaches[e.src] {
                    reaches[e.src] = true;
                    changed = true;
                }
            }
        }
        for n in &dfg.nodes {
            assert!(reaches[n.id], "case {case}: N{} is dead", n.id);
        }
    }
}
