//! Serving-layer integration tests: the coordinator across a fleet of
//! cycle-simulated overlay partitions.
//!
//! Covers the three properties the subsystem promises:
//! * compile-cache behaviour (hit/miss accounting, bounded capacity,
//!   deterministic LRU eviction) observed through the serving API;
//! * slot-aware scheduling under contention (affinity to configured
//!   partitions, reconfiguration only when the working set exceeds the
//!   fleet);
//! * a mixed-kernel soak in which **every** dispatch is verified
//!   against the cycle simulator — the scattered output buffers must
//!   hold the simulator's values bit-for-bit.

use std::time::Duration;

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{wait_all, Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::runtime_ocl::{Backend, Buffer, Context, Device};
use overlay_jit::util::XorShiftRng;

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

/// Random input buffers (with stencil slack) for a benchmark's params.
fn random_args(ctx: &Context, nparams: usize, n: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let buf = ctx.create_buffer(n + 16);
            let data: Vec<i32> = (0..n + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

fn param_count(source: &str) -> usize {
    overlay_jit::frontend::parse_kernel(source).unwrap().params.len()
}

#[test]
fn mixed_kernel_soak_verifies_every_dispatch() {
    let spec = OverlaySpec::zynq_default();
    let coord = Coordinator::new(CoordinatorConfig::sim_fleet(spec, 2)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x50AC);

    const ROUNDS: usize = 5;
    const ITEMS: usize = 192;
    let mut handles = Vec::new();
    // a mixed stream: all six benchmarks interleaved, ROUNDS times
    for _ in 0..ROUNDS {
        for b in &BENCHMARKS {
            let args = random_args(&ctx, param_count(b.source), ITEMS, &mut rng);
            handles.push(coord.submit(b.source, &args, ITEMS, Priority::Interactive).unwrap());
        }
    }
    let results = wait_all(handles).unwrap();
    let total = ROUNDS * BENCHMARKS.len();
    assert_eq!(results.len(), total);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.verified,
            Some(true),
            "dispatch {i} diverged from the cycle simulator"
        );
        assert!(r.partition < 2);
        assert_eq!(r.event.global_size, ITEMS);
        assert!(r.batch_size >= 1);
    }

    let stats = coord.stats();
    assert_eq!(stats.total_dispatches, total as u64);
    assert_eq!(stats.total_items, (total * ITEMS) as u64);
    assert_eq!(stats.verify_failures, 0);
    assert_eq!(stats.dispatch_errors, 0);
    // six compiles, everything else served from the cache
    assert_eq!(stats.cache.misses, 6);
    assert_eq!(stats.cache.hits, (total - 6) as u64);
    assert!(stats.cache.hit_rate() > 0.7, "{}", stats.cache.hit_rate());
    // 6 kernels over 2 partitions: reconfiguration churn is inevitable
    // but bounded by the dispatch count
    assert!(stats.reconfig_count >= 6);
    assert!(stats.reconfig_count <= stats.total_dispatches);
    assert!(stats.reconfig_seconds > 0.0);
    assert_eq!(stats.partitions.len(), 2);
    let dispatched: u64 = stats.partitions.iter().map(|p| p.dispatches).sum();
    assert_eq!(dispatched, stats.total_dispatches);
    // both partitions actually served work
    assert!(stats.partitions.iter().all(|p| p.dispatches > 0));
    assert!(stats.latency.count == total && stats.latency.p99_ms >= stats.latency.p50_ms);
}

#[test]
fn working_set_fitting_the_fleet_stops_reconfiguring() {
    // two kernels on two partitions: after the cold start, zero churn
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(1);
    let kernels = [&BENCHMARKS[0], &BENCHMARKS[4]]; // chebyshev, poly1
    for _ in 0..6 {
        for b in kernels {
            let args = random_args(&ctx, param_count(b.source), 64, &mut rng);
            let r = coord.submit(b.source, &args, 64, Priority::Interactive).unwrap().wait().unwrap();
            assert_eq!(r.verified, Some(true));
        }
    }
    let stats = coord.stats();
    // exactly one configuration load per kernel, ever
    assert_eq!(stats.reconfig_count, 2, "{:?}", stats.partitions);
    assert_eq!(stats.cache.misses, 2);
    assert_eq!(stats.cache.hits, 10);
}

#[test]
fn bounded_cache_evicts_deterministically_and_recompiles() {
    // cache of 2 serving 3 kernels round-robin: every round evicts
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2);
    cfg.cache_capacity = 2;
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(2);
    let kernels = [&BENCHMARKS[0], &BENCHMARKS[4], &BENCHMARKS[5]];
    for _ in 0..3 {
        for b in kernels {
            let args = random_args(&ctx, param_count(b.source), 48, &mut rng);
            let r = coord.submit(b.source, &args, 48, Priority::Interactive).unwrap().wait().unwrap();
            assert_eq!(r.verified, Some(true));
        }
    }
    let stats = coord.stats();
    // round-robin over 3 keys with capacity 2 defeats LRU: every
    // lookup misses, every insert evicts the next key in sequence
    assert_eq!(stats.cache.misses, 9, "hits={} ", stats.cache.hits);
    assert_eq!(stats.cache.hits, 0);
    assert_eq!(stats.cache.evictions, 7);
    assert_eq!(stats.cache.entries, 2);
    assert_eq!(stats.verify_failures, 0);
}

#[test]
fn single_partition_alternation_is_worst_case_churn() {
    // one partition, two alternating kernels: every dispatch after the
    // first two reconfigures — the scheduler's documented worst case
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(3);
    let kernels = [&BENCHMARKS[0], &BENCHMARKS[4]];
    let mut n_dispatch = 0u64;
    for _ in 0..4 {
        for b in kernels {
            let args = random_args(&ctx, param_count(b.source), 32, &mut rng);
            let r = coord.submit(b.source, &args, 32, Priority::Interactive).unwrap().wait().unwrap();
            assert_eq!(r.partition, 0);
            assert!(r.event.config_seconds > 0.0, "every alternation must reconfigure");
            n_dispatch += 1;
        }
    }
    let stats = coord.stats();
    assert_eq!(stats.reconfig_count, n_dispatch);
}

#[test]
fn fusion_window_fuses_trickle_batch_arrivals() {
    // one partition, a generous cross-batch window: two batch-lane
    // dispatches of the same kernel arriving ~30 ms apart must still
    // execute as ONE fused backend invocation
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.fusion_window = Duration::from_millis(800);
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xF05E);
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    // warm up (pays the JIT) so the trickle submits enqueue instantly
    let warm = random_args(&ctx, nparams, 64, &mut rng);
    coord
        .submit(b.source, &warm, 64, Priority::Batch)
        .unwrap()
        .wait()
        .unwrap();

    let args_a = random_args(&ctx, nparams, 64, &mut rng);
    let h_a = coord.submit(b.source, &args_a, 64, Priority::Batch).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    let args_b = random_args(&ctx, nparams, 64, &mut rng);
    let h_b = coord.submit(b.source, &args_b, 64, Priority::Batch).unwrap();
    let r_a = h_a.wait().unwrap();
    let r_b = h_b.wait().unwrap();
    assert_eq!(r_a.verified, Some(true));
    assert_eq!(r_b.verified, Some(true));
    assert_eq!(r_a.fused, 2, "trickle arrival must ride the same invocation");
    assert_eq!(r_b.fused, 2);
    assert!(coord.stats().fused_batches >= 1);
}

#[test]
fn zero_fusion_window_is_the_default_and_changes_nothing() {
    let cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    assert_eq!(cfg.fusion_window, Duration::ZERO);
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xF06E);
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    // sequential submit+wait: each dispatch runs alone, no fusion
    for _ in 0..3 {
        let args = random_args(&ctx, nparams, 64, &mut rng);
        let r = coord
            .submit(b.source, &args, 64, Priority::Batch)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.fused, 1);
        assert_eq!(r.verified, Some(true));
    }
    assert_eq!(coord.stats().fused_batches, 0);
}

#[test]
fn periodic_snapshots_flush_in_the_background() {
    let dir = std::env::temp_dir().join(format!(
        "overlay-jit-periodic-snapshot-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
        cfg.snapshot_dir = Some(dir.clone());
        cfg.snapshot_every = Some(3);
        let coord = Coordinator::new(cfg).unwrap();
        let ctx = host_ctx();
        let mut rng = XorShiftRng::new(0x5A9);
        let b = &BENCHMARKS[0];
        let nparams = param_count(b.source);
        for _ in 0..7 {
            let args = random_args(&ctx, nparams, 64, &mut rng);
            coord
                .submit(b.source, &args, 64, Priority::Interactive)
                .unwrap()
                .wait()
                .unwrap();
        }
        coord.drain_background();
        // 7 submits at a cadence of 3 → flushes after #3 and #6
        assert_eq!(coord.background_snapshots_written(), 2);
        assert_eq!(coord.background_snapshot_errors(), 0);
    }
    // the periodic snapshot warm-starts a restarted coordinator with
    // zero compiles, exactly like an explicit save_snapshot
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.snapshot_dir = Some(dir.clone());
    let warm = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x5AA);
    let b = &BENCHMARKS[0];
    let args = random_args(&ctx, param_count(b.source), 64, &mut rng);
    let r = warm
        .submit(b.source, &args, 64, Priority::Interactive)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.verified, Some(true));
    assert!(r.cache_hit, "periodic snapshot must warm-start the cache");
    assert_eq!(warm.stats().cache.misses, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_dispatches_complete_and_account() {
    // end-to-end: a deadline submit flows through pick/complete
    // without leaking shield state (unit tests cover victim choice)
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xDEAD);
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    for _ in 0..4 {
        let args = random_args(&ctx, nparams, 64, &mut rng);
        let r = coord
            .submit_with_deadline(
                b.source,
                &args,
                64,
                Priority::Interactive,
                Some(Duration::from_millis(50)),
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.verified, Some(true));
    }
    let stats = coord.stats();
    assert_eq!(stats.total_dispatches, 4);
    assert_eq!(stats.dispatch_errors, 0);
}

#[test]
fn scalar_arguments_flow_through_the_coordinator() {
    let src = "__kernel void scale(__global int *A, const int n, __global int *B) {
        int i = get_global_id(0);
        B[i] = A[i] * n + 1;
    }";
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)).unwrap();
    let ctx = host_ctx();
    let n = 64;
    let a = ctx.create_buffer(n);
    let b: Buffer = ctx.create_buffer(n);
    a.write(&(0..n as i32).collect::<Vec<i32>>());
    let r = coord
        .submit(
            src,
            &[
                SubmitArg::Buffer(a),
                SubmitArg::Scalar(7),
                SubmitArg::Buffer(b.clone()),
            ],
            n,
            Priority::Batch,
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.verified, Some(true));
    let out = b.read();
    for (i, &y) in out.iter().enumerate() {
        assert_eq!(y, (i as i32) * 7 + 1);
    }
}

#[test]
fn sharded_log_merge_matches_the_submitted_workload() {
    // The serving counters are sharded per worker and merged on read;
    // under a mixed-priority load across several partitions the merged
    // totals must equal the submitted workload exactly — same
    // invariants the old global-mutex log guaranteed (hit/miss,
    // reconfig, fused and per-spec counters included).
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 3)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x5EED);

    const ROUNDS: usize = 4;
    const ITEMS: usize = 160;
    let mut handles = Vec::new();
    for round in 0..ROUNDS {
        for (i, b) in BENCHMARKS.iter().enumerate() {
            let pri = if (round + i) % 2 == 0 { Priority::Interactive } else { Priority::Batch };
            let args = random_args(&ctx, param_count(b.source), ITEMS, &mut rng);
            handles.push(coord.submit(b.source, &args, ITEMS, pri).unwrap());
        }
    }
    let results = wait_all(handles).unwrap();
    let total = (ROUNDS * BENCHMARKS.len()) as u64;

    let stats = coord.stats();
    // merged counters equal the workload
    assert_eq!(stats.total_dispatches, total);
    assert_eq!(stats.total_items, total * ITEMS as u64);
    assert_eq!(stats.dispatch_errors, 0);
    assert_eq!(stats.verify_failures, 0);
    assert!(results.iter().all(|r| r.verified == Some(true)));
    // per-partition dispatch counts (scheduler side) sum to the merged
    // log total (worker-shard side)
    let per_partition: u64 = stats.partitions.iter().map(|p| p.dispatches).sum();
    assert_eq!(per_partition, stats.total_dispatches);
    // per-spec routing counters are preserved across the shard merge
    assert_eq!(stats.per_spec.len(), 1);
    assert_eq!(stats.per_spec[0].routed, total);
    assert_eq!(stats.per_spec[0].cross_spec_hits, 0);
    // cache accounting is unchanged: one miss per kernel
    assert_eq!(stats.cache.misses, BENCHMARKS.len() as u64);
    assert_eq!(stats.cache.hits, total - BENCHMARKS.len() as u64);
    // every latency sample the shards kept is a real dispatch
    assert_eq!(stats.latency.count as u64, total);
    // fused-run reporting agrees between per-result metadata and the
    // merged counter: if any result says it rode a fused invocation,
    // the counter saw at least one fused batch (and vice versa the
    // counter never exceeds the dispatch count)
    let saw_fused = results.iter().any(|r| r.fused > 1);
    assert_eq!(saw_fused, stats.fused_batches > 0);
    assert!(stats.fused_batches <= stats.total_dispatches);
}
