//! Serving-layer integration tests: the coordinator across a fleet of
//! cycle-simulated overlay partitions.
//!
//! Covers the three properties the subsystem promises:
//! * compile-cache behaviour (hit/miss accounting, bounded capacity,
//!   deterministic LRU eviction) observed through the serving API;
//! * slot-aware scheduling under contention (affinity to configured
//!   partitions, reconfiguration only when the working set exceeds the
//!   fleet);
//! * a mixed-kernel soak in which **every** dispatch is verified
//!   against the cycle simulator — the scattered output buffers must
//!   hold the simulator's values bit-for-bit.

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{wait_all, Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::runtime_ocl::{Backend, Buffer, Context, Device};
use overlay_jit::util::XorShiftRng;

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

/// Random input buffers (with stencil slack) for a benchmark's params.
fn random_args(ctx: &Context, nparams: usize, n: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let buf = ctx.create_buffer(n + 16);
            let data: Vec<i32> = (0..n + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

fn param_count(source: &str) -> usize {
    overlay_jit::frontend::parse_kernel(source).unwrap().params.len()
}

#[test]
fn mixed_kernel_soak_verifies_every_dispatch() {
    let spec = OverlaySpec::zynq_default();
    let coord = Coordinator::new(CoordinatorConfig::sim_fleet(spec, 2)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x50AC);

    const ROUNDS: usize = 5;
    const ITEMS: usize = 192;
    let mut handles = Vec::new();
    // a mixed stream: all six benchmarks interleaved, ROUNDS times
    for _ in 0..ROUNDS {
        for b in &BENCHMARKS {
            let args = random_args(&ctx, param_count(b.source), ITEMS, &mut rng);
            handles.push(coord.submit(b.source, &args, ITEMS, Priority::Interactive).unwrap());
        }
    }
    let results = wait_all(handles).unwrap();
    let total = ROUNDS * BENCHMARKS.len();
    assert_eq!(results.len(), total);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.verified,
            Some(true),
            "dispatch {i} diverged from the cycle simulator"
        );
        assert!(r.partition < 2);
        assert_eq!(r.event.global_size, ITEMS);
        assert!(r.batch_size >= 1);
    }

    let stats = coord.stats();
    assert_eq!(stats.total_dispatches, total as u64);
    assert_eq!(stats.total_items, (total * ITEMS) as u64);
    assert_eq!(stats.verify_failures, 0);
    assert_eq!(stats.dispatch_errors, 0);
    // six compiles, everything else served from the cache
    assert_eq!(stats.cache.misses, 6);
    assert_eq!(stats.cache.hits, (total - 6) as u64);
    assert!(stats.cache.hit_rate() > 0.7, "{}", stats.cache.hit_rate());
    // 6 kernels over 2 partitions: reconfiguration churn is inevitable
    // but bounded by the dispatch count
    assert!(stats.reconfig_count >= 6);
    assert!(stats.reconfig_count <= stats.total_dispatches);
    assert!(stats.reconfig_seconds > 0.0);
    assert_eq!(stats.partitions.len(), 2);
    let dispatched: u64 = stats.partitions.iter().map(|p| p.dispatches).sum();
    assert_eq!(dispatched, stats.total_dispatches);
    // both partitions actually served work
    assert!(stats.partitions.iter().all(|p| p.dispatches > 0));
    assert!(stats.latency.count == total && stats.latency.p99_ms >= stats.latency.p50_ms);
}

#[test]
fn working_set_fitting_the_fleet_stops_reconfiguring() {
    // two kernels on two partitions: after the cold start, zero churn
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(1);
    let kernels = [&BENCHMARKS[0], &BENCHMARKS[4]]; // chebyshev, poly1
    for _ in 0..6 {
        for b in kernels {
            let args = random_args(&ctx, param_count(b.source), 64, &mut rng);
            let r = coord.submit(b.source, &args, 64, Priority::Interactive).unwrap().wait().unwrap();
            assert_eq!(r.verified, Some(true));
        }
    }
    let stats = coord.stats();
    // exactly one configuration load per kernel, ever
    assert_eq!(stats.reconfig_count, 2, "{:?}", stats.partitions);
    assert_eq!(stats.cache.misses, 2);
    assert_eq!(stats.cache.hits, 10);
}

#[test]
fn bounded_cache_evicts_deterministically_and_recompiles() {
    // cache of 2 serving 3 kernels round-robin: every round evicts
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2);
    cfg.cache_capacity = 2;
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(2);
    let kernels = [&BENCHMARKS[0], &BENCHMARKS[4], &BENCHMARKS[5]];
    for _ in 0..3 {
        for b in kernels {
            let args = random_args(&ctx, param_count(b.source), 48, &mut rng);
            let r = coord.submit(b.source, &args, 48, Priority::Interactive).unwrap().wait().unwrap();
            assert_eq!(r.verified, Some(true));
        }
    }
    let stats = coord.stats();
    // round-robin over 3 keys with capacity 2 defeats LRU: every
    // lookup misses, every insert evicts the next key in sequence
    assert_eq!(stats.cache.misses, 9, "hits={} ", stats.cache.hits);
    assert_eq!(stats.cache.hits, 0);
    assert_eq!(stats.cache.evictions, 7);
    assert_eq!(stats.cache.entries, 2);
    assert_eq!(stats.verify_failures, 0);
}

#[test]
fn single_partition_alternation_is_worst_case_churn() {
    // one partition, two alternating kernels: every dispatch after the
    // first two reconfigures — the scheduler's documented worst case
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1)).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(3);
    let kernels = [&BENCHMARKS[0], &BENCHMARKS[4]];
    let mut n_dispatch = 0u64;
    for _ in 0..4 {
        for b in kernels {
            let args = random_args(&ctx, param_count(b.source), 32, &mut rng);
            let r = coord.submit(b.source, &args, 32, Priority::Interactive).unwrap().wait().unwrap();
            assert_eq!(r.partition, 0);
            assert!(r.event.config_seconds > 0.0, "every alternation must reconfigure");
            n_dispatch += 1;
        }
    }
    let stats = coord.stats();
    assert_eq!(stats.reconfig_count, n_dispatch);
}

#[test]
fn scalar_arguments_flow_through_the_coordinator() {
    let src = "__kernel void scale(__global int *A, const int n, __global int *B) {
        int i = get_global_id(0);
        B[i] = A[i] * n + 1;
    }";
    let coord =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)).unwrap();
    let ctx = host_ctx();
    let n = 64;
    let a = ctx.create_buffer(n);
    let b: Buffer = ctx.create_buffer(n);
    a.write(&(0..n as i32).collect::<Vec<i32>>());
    let r = coord
        .submit(
            src,
            &[
                SubmitArg::Buffer(a),
                SubmitArg::Scalar(7),
                SubmitArg::Buffer(b.clone()),
            ],
            n,
            Priority::Batch,
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.verified, Some(true));
    let out = b.read();
    for (i, &y) in out.iter().enumerate() {
        assert_eq!(y, (i as i32) * 7 + 1);
    }
}
