//! Autoscaler integration tests: the closed loop from serving metrics
//! back into the JIT compiler, end to end through the coordinator.
//!
//! Covers the four properties ISSUE 4 demands of the subsystem:
//! * **No oscillation** — under a constant load the factor converges
//!   and the `ScaleEvent` log then stays silent forever;
//! * **Convergence on a phase shift** — a wide → small → wide stream
//!   scales down, back up, and down again, with the second cycle
//!   served entirely from the kernel cache (misses do not grow);
//! * **Swap under fire** — rescales land while dispatches are in
//!   flight and not a single handle fails;
//! * **Audit log** — every event records the direction, factors and
//!   the trigger snapshot it was decided on.

use std::time::Duration;

use overlay_jit::autoscale::{AutoscalePolicy, ScaleDirection, ScaleOutcome};
use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{
    wait_all, Coordinator, CoordinatorConfig, Priority, SubmitArg,
};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::util::XorShiftRng;

/// Demand arithmetic (router default target_chunk = 1024):
/// WIDE wants 16 copies — chebyshev's 8×8 ceiling; SMALL wants 1.
const WIDE: usize = 16_384;
const SMALL: usize = 512;

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

fn policy4() -> AutoscalePolicy {
    AutoscalePolicy { window: 4, cooldown: 4, ..Default::default() }
}

fn autoscaling_coordinator(partitions: usize, policy: AutoscalePolicy) -> Coordinator {
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), partitions);
    cfg.autoscale = Some(policy);
    Coordinator::new(cfg).unwrap()
}

/// Submit one chebyshev dispatch of `items` and wait for it
/// (sequential submits keep observed queue depths at zero, so every
/// scaling decision in these tests is demand-driven and
/// deterministic).
fn serve_one(coord: &Coordinator, ctx: &Context, items: usize, rng: &mut XorShiftRng) {
    let b = &BENCHMARKS[0]; // chebyshev: 2 params, ceiling 16 on 8×8
    let args: Vec<SubmitArg> = (0..2)
        .map(|_| {
            let buf = ctx.create_buffer(items + 16);
            let data: Vec<i32> = (0..items + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect();
    let r = coord
        .submit(b.source, &args, items, Priority::Interactive)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.verified, Some(true), "every dispatch must stay sim-verified");
}

#[test]
fn constant_load_converges_then_never_scales_again() {
    let coord = autoscaling_coordinator(1, policy4());
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xA5C0);

    // constant medium load: 2048 items want 2 copies, far below the
    // plan's 16 — one scale-down, then a provable fixed point
    for _ in 0..20 {
        serve_one(&coord, &ctx, 2_048, &mut rng);
    }
    coord.drain_background();
    let events = coord.scale_log();
    assert_eq!(events.len(), 1, "exactly one convergence event: {events:#?}");
    assert_eq!(events[0].direction, ScaleDirection::Down);
    assert_eq!((events[0].from_factor, events[0].to_factor), (16, 2));

    // keep hammering the same load: ZERO further events
    for _ in 0..30 {
        serve_one(&coord, &ctx, 2_048, &mut rng);
    }
    coord.drain_background();
    assert_eq!(
        coord.scale_log().len(),
        1,
        "constant load after convergence must record zero scale events"
    );
    let stats = coord.stats();
    let a = stats.autoscale.expect("autoscaler configured");
    assert_eq!((a.scale_ups, a.scale_downs, a.failed_rescales), (0, 1, 0));
    assert_eq!(a.active_variants, 1);
    assert_eq!(stats.dispatch_errors, 0);
    assert_eq!(stats.verify_failures, 0);
}

#[test]
fn phase_shift_scales_down_up_down_with_cached_scale_backs() {
    let coord = autoscaling_coordinator(1, policy4());
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xA5C1);

    // phase A — wide: demand 16 == plan factor 16, no events
    for _ in 0..8 {
        serve_one(&coord, &ctx, WIDE, &mut rng);
    }
    coord.drain_background();
    assert!(coord.scale_log().is_empty(), "at-plan load must not scale");

    // phase B — small: scale down to 1 (fresh variant compile)
    for _ in 0..16 {
        serve_one(&coord, &ctx, SMALL, &mut rng);
    }
    coord.drain_background();
    let events = coord.scale_log();
    assert_eq!(events.len(), 1, "{events:#?}");
    assert_eq!(events[0].direction, ScaleDirection::Down);
    assert_eq!((events[0].from_factor, events[0].to_factor), (16, 1));

    // phase C — wide again: scale back up to the plan factor; the
    // artifact was compiled in phase A, so this rescale is a cache hit
    for _ in 0..12 {
        serve_one(&coord, &ctx, WIDE, &mut rng);
    }
    coord.drain_background();
    let events = coord.scale_log();
    assert_eq!(events.len(), 2, "{events:#?}");
    assert_eq!(events[1].direction, ScaleDirection::Up);
    assert_eq!((events[1].from_factor, events[1].to_factor), (1, 16));
    let misses_after_first_cycle = coord.stats().cache.misses;

    // phase D — small again: scale down to 1 must be a cache hit;
    // misses do not grow across the second cycle
    for _ in 0..16 {
        serve_one(&coord, &ctx, SMALL, &mut rng);
    }
    coord.drain_background();
    let events = coord.scale_log();
    assert_eq!(events.len(), 3, "{events:#?}");
    assert_eq!(events[2].direction, ScaleDirection::Down);
    assert_eq!((events[2].from_factor, events[2].to_factor), (16, 1));

    let stats = coord.stats();
    assert_eq!(
        stats.cache.misses, misses_after_first_cycle,
        "scaling back to previously compiled factors must be cache hits"
    );
    // base compile + the factor-1 variant: exactly two JIT runs ever
    assert_eq!(stats.cache.misses, 2);
    let a = stats.autoscale.unwrap();
    assert_eq!((a.scale_ups, a.scale_downs), (1, 2));
    assert!(
        a.rescale_cache_hits >= 2,
        "the up (phase C) and second down (phase D) both hit: {a:?}"
    );
    assert_eq!(stats.dispatch_errors, 0);
    assert_eq!(stats.verify_failures, 0);
}

#[test]
fn swaps_under_fire_fail_zero_in_flight_handles() {
    let coord = autoscaling_coordinator(2, policy4());
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xA5C2);
    let b = &BENCHMARKS[0];
    let make_args = |items: usize, rng: &mut XorShiftRng| -> Vec<SubmitArg> {
        (0..2)
            .map(|_| {
                let buf = ctx.create_buffer(items + 16);
                let data: Vec<i32> =
                    (0..items + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
                buf.write(&data);
                SubmitArg::Buffer(buf)
            })
            .collect()
    };

    // three phases, submitted in overlapping async rounds so rescale
    // installs land while dispatches are queued and executing
    let mut total = 0u64;
    for phase_items in [WIDE, SMALL, WIDE] {
        for _round in 0..6 {
            let mut handles = Vec::new();
            for _ in 0..4 {
                let args = make_args(phase_items, &mut rng);
                handles.push(
                    coord
                        .submit(b.source, &args, phase_items, Priority::Batch)
                        .unwrap(),
                );
                total += 1;
            }
            // wait this round while the NEXT round's submits will
            // overlap any background compile still in flight
            let results = wait_all(handles).expect("no in-flight handle may fail");
            for r in results {
                assert_eq!(r.verified, Some(true));
            }
        }
        coord.drain_background();
    }

    let stats = coord.stats();
    assert_eq!(stats.total_dispatches, total);
    assert_eq!(stats.dispatch_errors, 0, "zero failed handles during rescales");
    assert_eq!(stats.verify_failures, 0);
    let a = stats.autoscale.unwrap();
    assert!(a.scale_downs >= 1, "the small phase must scale down: {a:?}");
    assert!(a.scale_ups >= 1, "the final wide phase must scale back up: {a:?}");
    assert_eq!(a.failed_rescales, 0);
}

#[test]
fn audit_log_records_factors_triggers_and_outcomes() {
    let coord = autoscaling_coordinator(1, policy4());
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xA5C3);
    for _ in 0..8 {
        serve_one(&coord, &ctx, WIDE, &mut rng);
    }
    for _ in 0..12 {
        serve_one(&coord, &ctx, SMALL, &mut rng);
    }
    for _ in 0..12 {
        serve_one(&coord, &ctx, WIDE, &mut rng);
    }
    coord.drain_background();

    let events = coord.scale_log();
    assert_eq!(events.len(), 2, "{events:#?}");
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "sequence numbers are dense and ordered");
        assert_eq!(e.kernel, "chebyshev");
        assert_eq!(e.spec, "8x8-dsp2");
        assert_ne!(e.from_factor, e.to_factor);
        match e.direction {
            ScaleDirection::Up => assert!(e.to_factor > e.from_factor),
            ScaleDirection::Down => assert!(e.to_factor < e.from_factor),
        }
        // the trigger snapshot is the evidence the decision was made on
        assert!(e.trigger.samples >= 4, "a full window backed the decision");
        assert!(e.trigger.mean_demand > 0.0);
        assert!(matches!(e.outcome, ScaleOutcome::Applied { .. }));
        assert!(!e.queue_triggered, "sequential load never queues");
    }
    // the scale-up returned to an artifact compiled in the first wide
    // phase — audited as a cache hit with a ~free compile
    match &events[1].outcome {
        ScaleOutcome::Applied { cache_hit, compile_seconds } => {
            assert!(*cache_hit);
            assert!(*compile_seconds < 1.0);
        }
        other => panic!("expected Applied, got {other:?}"),
    }
}

/// Long-form convergence soak (`make soak`; ignored in the default
/// suite). Six full wide↔small cycles with a fixed seed: the event
/// count must stay exactly one per phase shift — no flapping, no
/// drift — and the second and later cycles must be all cache hits.
#[test]
#[ignore = "long-form soak; run via `make soak`"]
fn soak_phase_cycles_converge_every_time_without_flapping() {
    let coord = autoscaling_coordinator(2, AutoscalePolicy::default());
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x50AC);

    const CYCLES: usize = 6;
    const PER_PHASE: usize = 40;
    for cycle in 0..CYCLES {
        for _ in 0..PER_PHASE {
            serve_one(&coord, &ctx, WIDE, &mut rng);
        }
        coord.drain_background();
        for _ in 0..PER_PHASE {
            serve_one(&coord, &ctx, SMALL, &mut rng);
        }
        coord.drain_background();
        let events = coord.scale_log();
        // cycle 0: one down. every later cycle adds one up + one down.
        let expected = 1 + 2 * cycle;
        assert_eq!(
            events.len(),
            expected,
            "cycle {cycle}: flapping detected — {events:#?}"
        );
    }
    let stats = coord.stats();
    assert_eq!(stats.dispatch_errors, 0);
    assert_eq!(stats.verify_failures, 0);
    assert_eq!(stats.cache.misses, 2, "later cycles must be pure cache hits");
    let a = stats.autoscale.unwrap();
    assert_eq!(a.failed_rescales, 0);
    assert_eq!(a.scale_downs as usize, CYCLES);
    assert_eq!(a.scale_ups as usize, CYCLES - 1);
    // sanity on wall-clock health of the loop itself
    assert!(a.rescale_compile_seconds < Duration::from_secs(60).as_secs_f64());
}
