//! Heterogeneous-fleet integration tests: the coordinator over mixed
//! 8×8 + 4×4 overlay partitions.
//!
//! Locks in the properties the fleet subsystem promises:
//! * **placement** — small (interactive, low-demand) dispatches never
//!   occupy an 8×8 partition while a 4×4 partition is idle, and wide
//!   data-parallel dispatches always land on the spec with the
//!   highest replication throughput (audited via the routing log);
//! * **isolation** — per-spec kernel-cache shards never exchange
//!   entries (zero cross-spec hits; one compile per (kernel, spec));
//! * **liveness** — every benchmark kernel is eventually served and
//!   verified, whatever mix of specs it fits (the router-starvation
//!   regression);
//! * **fusion** — same-kernel dispatches drained in one worker batch
//!   execute as a single wider simulator invocation, bit-exactly;
//! * **QoS** — the priority class rides through to the completion
//!   record.

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{
    wait_all, Coordinator, CoordinatorConfig, Priority, SubmitArg,
};
use overlay_jit::fleet::RouteReason;
use overlay_jit::overlay::{FuType, OverlaySpec};
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::util::XorShiftRng;

const SMALL_ITEMS: usize = 256;
const WIDE_ITEMS: usize = 16_384;

fn big_spec() -> OverlaySpec {
    OverlaySpec::zynq_default()
}

fn small_spec() -> OverlaySpec {
    OverlaySpec::new(4, 4, FuType::Dsp2)
}

fn mixed_coordinator(big_parts: usize, small_parts: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig::sim_fleet_mixed(vec![
        (big_spec(), big_parts),
        (small_spec(), small_parts),
    ]))
    .unwrap()
}

fn host_ctx() -> Context {
    let dev = Device {
        spec: big_spec(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

/// Random input buffers (with stencil slack) for a benchmark's params.
fn random_args(ctx: &Context, nparams: usize, n: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let buf = ctx.create_buffer(n + 16);
            let data: Vec<i32> = (0..n + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

fn param_count(source: &str) -> usize {
    overlay_jit::frontend::parse_kernel(source).unwrap().params.len()
}

#[test]
fn mixed_fleet_soak_places_by_size_and_verifies() {
    let coord = mixed_coordinator(2, 2);
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xF1EE7);
    let small_fp = small_spec().fingerprint();
    let big_fp = big_spec().fingerprint();

    // contended stream: wide chebyshev batches interleaved with small
    // interactive dispatches, all in flight at once
    let smalls = [&BENCHMARKS[0], &BENCHMARKS[4], &BENCHMARKS[5]]; // chebyshev, poly1, poly2
    let cheb = &BENCHMARKS[0];
    let mut handles = Vec::new();
    for _ in 0..4 {
        let wargs = random_args(&ctx, param_count(cheb.source), WIDE_ITEMS, &mut rng);
        handles.push(
            coord
                .submit(cheb.source, &wargs, WIDE_ITEMS, Priority::Batch)
                .unwrap(),
        );
        for s in &smalls {
            let sargs = random_args(&ctx, param_count(s.source), SMALL_ITEMS, &mut rng);
            handles.push(
                coord
                    .submit(s.source, &sargs, SMALL_ITEMS, Priority::Interactive)
                    .unwrap(),
            );
        }
    }
    let results = wait_all(handles).unwrap();
    assert!(results.iter().all(|r| r.verified == Some(true)));

    // audit every routing decision
    let log = coord.routing_log();
    assert_eq!(log.len(), results.len());
    let mut small_served = 0;
    for rec in &log {
        let small_obs = rec
            .specs
            .iter()
            .find(|o| o.fingerprint == small_fp)
            .expect("small spec observed");
        if rec.global_size == SMALL_ITEMS && !rec.fallback {
            // the headline invariant: a small kernel never occupies a
            // large partition while any small partition is idle
            if small_obs.adequate && small_obs.min_queue_depth == 0 {
                assert_eq!(
                    rec.chosen, small_fp,
                    "{} (small) routed to the big tier while a 4x4 was idle",
                    rec.kernel
                );
            }
            if rec.chosen == small_fp {
                small_served += 1;
            }
        }
        if rec.global_size == WIDE_ITEMS {
            // wide data-parallel work always takes the widest spec
            assert_eq!(
                rec.chosen, big_fp,
                "wide {} dispatch routed off the 8x8 tier",
                rec.kernel
            );
            assert!(rec.copies_wanted > 5, "wide demand exceeds the 4x4 factor");
        }
    }
    assert!(small_served > 0, "the 4x4 tier never served a small kernel");

    let stats = coord.stats();
    assert_eq!(stats.verify_failures, 0);
    assert_eq!(stats.dispatch_errors, 0);
    assert!(stats.per_spec.iter().all(|s| s.cross_spec_hits == 0));
    // both tiers served work
    for s in &stats.per_spec {
        assert!(s.routed > 0, "spec {} served nothing", s.spec);
    }
    // replication histograms are per spec: the 8x8 serves chebyshev at
    // 16 copies, the 4x4 at 5
    let big_stats = stats.per_spec.iter().find(|s| s.fingerprint == big_fp).unwrap();
    assert!(big_stats.replication_histogram.iter().any(|&(f, _)| f == 16));
    let small_stats =
        stats.per_spec.iter().find(|s| s.fingerprint == small_fp).unwrap();
    assert!(small_stats.replication_histogram.iter().all(|&(f, _)| f <= 5));
}

#[test]
fn per_spec_cache_shards_are_isolated() {
    let coord = mixed_coordinator(1, 1);
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x15A);
    let cheb = &BENCHMARKS[0];
    let nparams = param_count(cheb.source);

    // chebyshev lands on both tiers: small → 4x4, wide → 8x8; each
    // shard compiles it once, repeats hit the shard's own cache
    for _ in 0..3 {
        let sargs = random_args(&ctx, nparams, SMALL_ITEMS, &mut rng);
        let r = coord
            .submit(cheb.source, &sargs, SMALL_ITEMS, Priority::Interactive)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.spec, "4x4-dsp2");
        assert_eq!(r.verified, Some(true));
        let wargs = random_args(&ctx, nparams, WIDE_ITEMS, &mut rng);
        let r = coord
            .submit(cheb.source, &wargs, WIDE_ITEMS, Priority::Batch)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.spec, "8x8-dsp2");
        assert_eq!(r.verified, Some(true));
    }

    let stats = coord.stats();
    // one compile per (kernel, spec) — six dispatches, two misses
    assert_eq!(stats.cache.misses, 2);
    assert_eq!(stats.cache.hits, 4);
    assert_eq!(stats.per_spec.len(), 2);
    for s in &stats.per_spec {
        assert_eq!(s.cache.misses, 1, "spec {} compiled more than once", s.spec);
        assert_eq!(s.cache.hits, 2);
        assert_eq!(s.cross_spec_hits, 0, "shard isolation violated on {}", s.spec);
        assert_eq!(s.routed, 3);
    }
}

#[test]
fn every_benchmark_is_eventually_served() {
    // router-starvation regression: the full six-benchmark stream over
    // a minimal mixed fleet, small and wide, everything completes
    let coord = mixed_coordinator(1, 1);
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x5EED);
    let mut handles = Vec::new();
    let mut names = Vec::new();
    for _ in 0..2 {
        for b in &BENCHMARKS {
            let nparams = param_count(b.source);
            let sargs = random_args(&ctx, nparams, SMALL_ITEMS, &mut rng);
            handles.push(
                coord
                    .submit(b.source, &sargs, SMALL_ITEMS, Priority::Interactive)
                    .unwrap(),
            );
            names.push(b.name);
            let wargs = random_args(&ctx, nparams, WIDE_ITEMS, &mut rng);
            handles.push(
                coord
                    .submit(b.source, &wargs, WIDE_ITEMS, Priority::Batch)
                    .unwrap(),
            );
            names.push(b.name);
        }
    }
    let results = wait_all(handles).unwrap();
    for b in &BENCHMARKS {
        let served = results
            .iter()
            .zip(&names)
            .filter(|(r, n)| **n == b.name && r.verified == Some(true))
            .count();
        assert_eq!(served, 4, "benchmark {} starved", b.name);
    }
    let stats = coord.stats();
    assert_eq!(stats.dispatch_errors, 0);
    for s in &stats.per_spec {
        assert!(s.routed > 0, "spec {} starved", s.spec);
    }
    // the routing log records only-fit placements for kernels too
    // large for the 4x4 tier (e.g. qspline) without starving them
    let log = coord.routing_log();
    assert!(log
        .iter()
        .all(|r| r.reason != RouteReason::OnlyFit || r.chosen == big_spec().fingerprint()));
}

#[test]
fn consecutive_same_kernel_jobs_fuse_into_one_invocation() {
    // single partition: occupy the worker with a long dispatch, queue
    // four more of the same kernel behind it — they drain together and
    // must fuse, bit-exactly
    let coord = Coordinator::new(CoordinatorConfig::sim_fleet(big_spec(), 1)).unwrap();
    let ctx = host_ctx();
    let cheb = &BENCHMARKS[0];

    let cheb_ref = |x: i32| {
        x.wrapping_mul(
            x.wrapping_mul(16i32.wrapping_mul(x).wrapping_mul(x).wrapping_sub(20))
                .wrapping_mul(x)
                .wrapping_add(5),
        )
    };

    // warm the cache so the queued submits are O(lookup)
    let warm_in = ctx.create_buffer(64);
    let warm_out = ctx.create_buffer(64);
    warm_in.write(&vec![1; 64]);
    coord
        .submit(
            cheb.source,
            &[SubmitArg::Buffer(warm_in), SubmitArg::Buffer(warm_out)],
            64,
            Priority::Interactive,
        )
        .unwrap()
        .wait()
        .unwrap();

    // the long dispatch that holds the worker busy
    let n_long = 1 << 19;
    let long_in = ctx.create_buffer(n_long);
    let long_out = ctx.create_buffer(n_long);
    long_in.write(&(0..n_long as i32).map(|i| i % 11 - 5).collect::<Vec<_>>());
    let long_handle = coord
        .submit(
            cheb.source,
            &[SubmitArg::Buffer(long_in), SubmitArg::Buffer(long_out)],
            n_long,
            Priority::Interactive,
        )
        .unwrap();

    // four quick same-kernel dispatches queue behind it
    let n = 128;
    let mut handles = Vec::new();
    let mut outputs = Vec::new();
    for round in 0..4 {
        let a = ctx.create_buffer(n);
        let b = ctx.create_buffer(n);
        let xs: Vec<i32> = (0..n as i32).map(|i| (i % 9) - 4 + round).collect();
        a.write(&xs);
        handles.push(
            coord
                .submit(
                    cheb.source,
                    &[SubmitArg::Buffer(a), SubmitArg::Buffer(b.clone())],
                    n,
                    Priority::Interactive,
                )
                .unwrap(),
        );
        outputs.push((xs, b));
    }
    long_handle.wait().unwrap();
    let results = wait_all(handles).unwrap();

    // every fused dispatch is verified and bit-exact per job
    assert!(results.iter().all(|r| r.verified == Some(true)));
    for (xs, b) in outputs {
        let out = b.read();
        for (x, y) in xs.iter().zip(&out) {
            assert_eq!(*y, cheb_ref(*x));
        }
    }
    let stats = coord.stats();
    assert!(
        stats.fused_batches >= 1,
        "queued same-kernel dispatches did not fuse (fused_batches = {})",
        stats.fused_batches
    );
    assert!(
        results.iter().any(|r| r.fused >= 2),
        "no dispatch reports a fusion width >= 2"
    );
    assert_eq!(stats.verify_failures, 0);
}

#[test]
fn priority_class_rides_through_to_completion() {
    let coord = mixed_coordinator(1, 1);
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(7);
    let cheb = &BENCHMARKS[0];
    let nparams = param_count(cheb.source);
    let args = random_args(&ctx, nparams, SMALL_ITEMS, &mut rng);
    let ri = coord
        .submit(cheb.source, &args, SMALL_ITEMS, Priority::Interactive)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(ri.priority, Priority::Interactive);
    let args = random_args(&ctx, nparams, SMALL_ITEMS, &mut rng);
    let rb = coord
        .submit(cheb.source, &args, SMALL_ITEMS, Priority::Batch)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(rb.priority, Priority::Batch);
}

#[test]
fn mixed_fleet_snapshot_warm_starts_both_shards() {
    let dir = std::env::temp_dir().join(format!(
        "overlay-jit-fleet-test-snapshot-{}",
        std::process::id()
    ));
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x5A9);
    let cheb = &BENCHMARKS[0];
    let nparams = param_count(cheb.source);
    {
        let coord = mixed_coordinator(1, 1);
        // populate both shards
        let s = random_args(&ctx, nparams, SMALL_ITEMS, &mut rng);
        coord
            .submit(cheb.source, &s, SMALL_ITEMS, Priority::Interactive)
            .unwrap()
            .wait()
            .unwrap();
        let w = random_args(&ctx, nparams, WIDE_ITEMS, &mut rng);
        coord
            .submit(cheb.source, &w, WIDE_ITEMS, Priority::Batch)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(coord.save_snapshot(&dir).unwrap(), 2);
    }
    let mut cfg = CoordinatorConfig::sim_fleet_mixed(vec![
        (big_spec(), 1),
        (small_spec(), 1),
    ]);
    cfg.snapshot_dir = Some(dir.clone());
    let warm = Coordinator::new(cfg).unwrap();
    let s = random_args(&ctx, nparams, SMALL_ITEMS, &mut rng);
    let r1 = warm
        .submit(cheb.source, &s, SMALL_ITEMS, Priority::Interactive)
        .unwrap()
        .wait()
        .unwrap();
    let w = random_args(&ctx, nparams, WIDE_ITEMS, &mut rng);
    let r2 = warm
        .submit(cheb.source, &w, WIDE_ITEMS, Priority::Batch)
        .unwrap()
        .wait()
        .unwrap();
    assert!(r1.cache_hit && r2.cache_hit, "warm fleet recompiled");
    assert_eq!(r1.verified, Some(true));
    assert_eq!(r2.verified, Some(true));
    let stats = warm.stats();
    assert_eq!(stats.cache.misses, 0);
    assert_eq!(stats.compile_seconds, 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}
