//! Admission-control and fault-recovery integration tests: the
//! overload gate and the seeded fault plane observed through the
//! public serving API.
//!
//! Covers the robustness promises end to end:
//! * **fairness** — a tenant bursting at 10x its quota exhausts only
//!   its own token bucket; compliant tenants' reject rate stays at
//!   exactly zero while fleet capacity remains;
//! * **typed rejection** — a deadline that cannot be met is refused
//!   *before* any compile or scheduling work is spent, and batch-lane
//!   shedding under pressure never touches interactive work;
//! * **fault matrix** — every [`FaultKind`] is injected from a
//!   scripted, seeded plan and the struck dispatch deterministically
//!   recovers (completes on a sibling partition, or heals the
//!   poisoned `(kernel, spec)` pair through a TTL re-probe).

use std::time::Duration;

use overlay_jit::admission::ALL_FAULT_KINDS;
use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{Admission, Coordinator, CoordinatorConfig, SubmitArg};
use overlay_jit::overlay::{FuType, OverlaySpec};
use overlay_jit::prelude::*;
use overlay_jit::runtime_ocl::{Context, Device};
use overlay_jit::util::XorShiftRng;

fn host_ctx() -> Context {
    let dev = Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    Context::new(&dev)
}

/// Random input buffers (with stencil slack) for a benchmark's params.
fn random_args(ctx: &Context, nparams: usize, n: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let buf = ctx.create_buffer(n + 16);
            let data: Vec<i32> = (0..n + 16).map(|_| rng.gen_i64(-30, 30) as i32).collect();
            buf.write(&data);
            SubmitArg::Buffer(buf)
        })
        .collect()
}

fn param_count(source: &str) -> usize {
    overlay_jit::frontend::parse_kernel(source).unwrap().params.len()
}

/// A near-zero refill rate: buckets are effectively their burst
/// capacity for the duration of any test run, so quota outcomes do
/// not depend on wall-clock speed.
fn frozen_quota(burst: f64) -> AdmissionConfig {
    AdmissionConfig {
        tenant_rate_per_sec: 0.001,
        tenant_burst: burst,
        // a stall depth no test queue reaches: pressure stays zero, so
        // quota is the only admission dimension in play
        queue_stall_depth: 1_000_000,
        ..AdmissionConfig::default()
    }
}

#[test]
fn bursting_tenant_cannot_raise_compliant_reject_rate() {
    let spec = OverlaySpec::zynq_default();
    let mut cfg = CoordinatorConfig::sim_fleet(spec, 2);
    cfg.admission = Some(frozen_quota(8.0));
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xFA1);

    const ITEMS: usize = 64;
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);

    // the spammer fires 10x its burst; compliant tenants stay at their
    // burst allowance. Interleaved so the spam brackets every
    // compliant submit.
    let mut spammer_rejects = 0u64;
    let mut compliant_rejects = 0u64;
    let mut handles = Vec::new();
    for round in 0..8 {
        for _ in 0..10 {
            let args = random_args(&ctx, nparams, ITEMS, &mut rng);
            match coord
                .submit_gated("spammer", b.source, &args, ITEMS, Priority::Interactive, None)
                .unwrap()
            {
                Admission::Admitted(h) => handles.push(h),
                Admission::Rejected(r) => {
                    assert_eq!(r.kind(), "quota", "only quota can reject here: {r}");
                    spammer_rejects += 1;
                }
            }
        }
        for tenant in ["alice", "bob", "carol"] {
            let args = random_args(&ctx, nparams, ITEMS, &mut rng);
            match coord
                .submit_gated(tenant, b.source, &args, ITEMS, Priority::Interactive, None)
                .unwrap()
            {
                Admission::Admitted(h) => handles.push(h),
                Admission::Rejected(r) => {
                    compliant_rejects += 1;
                    panic!("compliant tenant {tenant} rejected in round {round}: {r}");
                }
            }
        }
    }

    // capacity remained: every admitted dispatch completes verified
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.verified, Some(true));
    }
    assert_eq!(compliant_rejects, 0);
    // burst 8, refill frozen: at least 80 - 8 - 1 spam rejects
    assert!(spammer_rejects >= 71, "expected >= 71 spam rejects, got {spammer_rejects}");
    let adm = coord.admission_stats().unwrap();
    assert_eq!(adm.rejected_quota, spammer_rejects);
    assert_eq!(adm.rejected_deadline, 0);
    assert_eq!(adm.shed, 0);
    // 3 compliant + 1 spammer bucket
    assert_eq!(adm.tenants, 4);
}

#[test]
fn doomed_deadline_is_rejected_before_any_compile() {
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.admission = Some(frozen_quota(64.0));
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xD00);

    let b = &BENCHMARKS[0];
    let args = random_args(&ctx, param_count(b.source), 64, &mut rng);
    let outcome = coord
        .submit_gated("t", b.source, &args, 64, Priority::Interactive, Some(Duration::from_nanos(1)))
        .unwrap();
    match outcome {
        Admission::Rejected(RejectReason::DeadlineUnmeetable { needed_ms, budget_ms }) => {
            assert!(needed_ms > budget_ms);
        }
        other => panic!("expected a typed deadline rejection, got {other:?}"),
    }
    let stats = coord.stats();
    // refused before compilation: the kernel cache was never touched
    assert_eq!(stats.cache.misses, 0);
    assert_eq!(stats.cache.hits, 0);
    let adm = stats.admission.unwrap();
    assert_eq!(adm.rejected_deadline, 1);
    assert_eq!(adm.admitted, 0);
    // the doomed submit consumed no quota token
    let args = random_args(&ctx, param_count(b.source), 64, &mut rng);
    match coord.submit_gated("t", b.source, &args, 64, Priority::Interactive, None).unwrap() {
        Admission::Admitted(h) => assert_eq!(h.wait().unwrap().verified, Some(true)),
        Admission::Rejected(r) => panic!("clean submit rejected: {r}"),
    }
}

#[test]
fn pressure_sheds_batch_but_never_interactive() {
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2);
    cfg.admission = Some(AdmissionConfig {
        tenant_rate_per_sec: 0.001,
        tenant_burst: 64.0,
        // stall depth 0: every observed queue counts as stalled, so
        // pressure sits at 1.0 from the first gauge sample — shedding
        // is deterministic without racing real queue depths
        queue_stall_depth: 0,
        shed_pressure: 0.5,
        ..AdmissionConfig::default()
    });
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0x5ED);

    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    let mut shed = 0u64;
    let mut handles = Vec::new();
    for _ in 0..6 {
        let args = random_args(&ctx, nparams, 64, &mut rng);
        match coord.submit_gated("t", b.source, &args, 64, Priority::Interactive, None).unwrap() {
            Admission::Admitted(h) => handles.push(h),
            Admission::Rejected(r) => panic!("interactive must ride out pressure: {r}"),
        }
        let args = random_args(&ctx, nparams, 64, &mut rng);
        match coord.submit_gated("t", b.source, &args, 64, Priority::Batch, None).unwrap() {
            Admission::Rejected(RejectReason::Shed { pressure }) => {
                assert!(pressure >= 0.5);
                shed += 1;
            }
            other => panic!("expected batch shed under saturated pressure, got {other:?}"),
        }
    }
    for h in handles {
        assert_eq!(h.wait().unwrap().verified, Some(true));
    }
    let adm = coord.admission_stats().unwrap();
    assert_eq!(adm.shed, shed);
    assert_eq!(shed, 6);
    assert!(adm.pressure >= 0.5);
}

#[test]
fn scripted_fault_matrix_every_kind_injects_and_recovers() {
    // the three dispatch-plane faults on a homogeneous 2-partition
    // fleet: the struck dispatch must complete on the sibling
    for kind in [FaultKind::WorkerKill, FaultKind::ReconfigFail, FaultKind::VerifyCorrupt] {
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2);
        cfg.faults = Some(FaultPlanConfig {
            seed: 0x5EED,
            scripted: vec![(0, kind)],
            ..FaultPlanConfig::default()
        });
        let coord = Coordinator::new(cfg).unwrap();
        let ctx = host_ctx();
        let mut rng = XorShiftRng::new(7);
        let b = &BENCHMARKS[0];
        let args = random_args(&ctx, param_count(b.source), 64, &mut rng);
        let r = coord
            .submit(b.source, &args, 64, Priority::Interactive)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.verified, Some(true), "{}: recovery must re-verify", kind.name());

        let tally = coord.fault_tally().unwrap();
        assert_eq!(tally.injected_of(kind), 1, "{} injected", kind.name());
        assert_eq!(tally.recovered_of(kind), 1, "{} recovered", kind.name());
        for other in ALL_FAULT_KINDS {
            if other != kind {
                assert_eq!(tally.injected_of(other), 0, "{} uninvolved", other.name());
            }
        }
        let stats = coord.stats();
        assert_eq!(stats.verify_failures, 0);
        assert_eq!(stats.dispatch_errors, 0);
        match kind {
            // requeue-path faults go through the recovery plane
            FaultKind::WorkerKill | FaultKind::VerifyCorrupt => {
                assert!(stats.retried_dispatches >= 1, "{}", kind.name())
            }
            // the reconfig retry happens on the submit path, before a
            // queue is ever involved
            _ => assert_eq!(stats.retried_dispatches, 0),
        }
    }
}

#[test]
fn compile_fault_poisons_then_heals_via_reprobe() {
    // a heterogeneous fleet so the struck compile has a fallback spec,
    // and the poisoned pair can later be re-probed
    let big = OverlaySpec::zynq_default();
    let small = OverlaySpec::new(4, 4, FuType::Dsp2);
    let mut cfg =
        CoordinatorConfig::sim_fleet_mixed(vec![(big.clone(), 1), (small.clone(), 1)]);
    cfg.faults = Some(FaultPlanConfig {
        seed: 0xC0,
        scripted: vec![(0, FaultKind::CompileFail)],
        ..FaultPlanConfig::default()
    });
    let coord = Coordinator::new(cfg).unwrap();
    let ctx = host_ctx();
    let mut rng = XorShiftRng::new(0xC0);

    // wide enough that copies x throughput ranks the big spec first —
    // the scripted strike fires on the first-ranked (salt 0) compile
    const WIDE: usize = 16_384;
    let b = &BENCHMARKS[0];
    let nparams = param_count(b.source);
    let args = random_args(&ctx, nparams, WIDE, &mut rng);
    let r = coord.submit(b.source, &args, WIDE, Priority::Batch).unwrap().wait().unwrap();
    // the fallback spec served it
    assert_eq!(r.verified, Some(true));
    assert_eq!(r.spec, small.name(), "struck compile must fall through to the sibling spec");

    let tally = coord.fault_tally().unwrap();
    assert_eq!(tally.injected_of(FaultKind::CompileFail), 1);
    assert_eq!(tally.recovered_of(FaultKind::CompileFail), 0, "not yet re-probed");
    let poison = coord.stats().poison;
    assert_eq!(poison.active, 1, "the (kernel, big-spec) pair is cooling off");
    assert_eq!(poison.recoveries, 0);

    // each submit ticks the decay clock; once the TTL expires the pair
    // is offered back and the clean compile heals it
    let mut healed_at = None;
    for i in 0..20 {
        let args = random_args(&ctx, nparams, WIDE, &mut rng);
        let r = coord.submit(b.source, &args, WIDE, Priority::Batch).unwrap().wait().unwrap();
        assert_eq!(r.verified, Some(true));
        if coord.fault_tally().unwrap().recovered_of(FaultKind::CompileFail) == 1 {
            healed_at = Some(i);
            break;
        }
    }
    let healed_at = healed_at.expect("poisoned pair never healed within 20 re-submissions");
    // the base TTL is 8 profile ticks: healing cannot happen instantly
    assert!(healed_at >= 5, "healed suspiciously early (iteration {healed_at})");
    let poison = coord.stats().poison;
    assert_eq!(poison.active, 0);
    assert_eq!(poison.probes, 1);
    assert_eq!(poison.recoveries, 1);
    // and the healed spec serves the kernel again
    let args = random_args(&ctx, nparams, WIDE, &mut rng);
    let r = coord.submit(b.source, &args, WIDE, Priority::Batch).unwrap().wait().unwrap();
    assert_eq!(r.verified, Some(true));
    assert_eq!(r.spec, big.name(), "the re-probed spec must win wide work back");
}
