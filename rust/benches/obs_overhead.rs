//! Bench: §E14 — what the span recorder costs the serving hot path.
//!
//! Quantifies the tracing tax at both granularities and emits the
//! results machine-readably to `BENCH_obs.json` (override with the
//! `BENCH_JSON` environment variable):
//!
//! * **record micro-cost** — ns per `TraceSink::record` into a shard
//!   ring (the per-span price every instrumented stage pays), against
//!   the disabled sink's first-branch return;
//! * **submit hot path** — µs per `Coordinator::submit` + wait of a
//!   cache-resident kernel with tracing off vs on, the end-to-end
//!   overhead a production deployment would see per dispatch;
//! * **latency carrier** — ns per recorded sample into the
//!   log-bucketed [`LatencyHist`] vs the stride-decimating reservoir
//!   it replaced (replicated locally below), the price §E15 pays for
//!   lossless merge;
//! * **head sampling** — µs per dispatch with a 1/8 [`Sampler`] on
//!   the armed sink vs tracing every submit, the knob that keeps
//!   always-on tracing affordable.
//!
//! Run: `cargo bench --bench obs_overhead` (or `make bench`).

use std::collections::BTreeMap;
use std::time::Instant;

use overlay_jit::bench_kernels::BENCHMARKS;
use overlay_jit::coordinator::{Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::metrics::TextTable;
use overlay_jit::obs::{LatencyHist, Phase, Sampler, Span, TraceHandle, TraceSink, NO_WORKER};
use overlay_jit::overlay::OverlaySpec;
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::util::{JsonValue, XorShiftRng};

const RECORDS: usize = 200_000;
const DISPATCHES: usize = 200;
const ITEMS: usize = 512;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench_record(sink: &TraceSink) -> f64 {
    let span = Span {
        trace_id: 1,
        span_id: 1,
        parent: 0,
        phase: Phase::Exec,
        tag: "warm",
        node: 0,
        worker: NO_WORKER,
        start_us: 0,
        dur_us: 1,
        a0: 0,
        a1: 0,
    };
    let t = Instant::now();
    for i in 0..RECORDS {
        let mut s = span;
        s.trace_id = i as u64 + 1;
        sink.record(s);
    }
    t.elapsed().as_nanos() as f64 / RECORDS as f64
}

/// The pre-§E15 latency carrier, replicated for an apples-to-apples
/// record cost: an unbounded-stream reservoir that decimates in place
/// and doubles its stride whenever the buffer fills. Kept local so
/// the library only ships the histogram.
struct LegacyReservoir {
    samples: Vec<f64>,
    stride: usize,
    seen: usize,
}

impl LegacyReservoir {
    fn new(cap: usize) -> Self {
        Self { samples: Vec::with_capacity(cap), stride: 1, seen: 0 }
    }

    fn record_ms(&mut self, ms: f64) {
        if self.seen % self.stride == 0 {
            if self.samples.len() == self.samples.capacity() {
                let mut i = 0;
                self.samples.retain(|_| {
                    i += 1;
                    i % 2 == 1
                });
                self.stride *= 2;
            }
            self.samples.push(ms);
        }
        self.seen += 1;
    }
}

/// ns per recorded latency sample: log-bucketed histogram vs the
/// stride-decimating reservoir it replaced.
fn bench_latency_carriers(rng: &mut XorShiftRng) -> (f64, f64) {
    let ms: Vec<f64> =
        (0..RECORDS).map(|_| rng.gen_i64(1, 400_000) as f64 / 1000.0).collect();

    let mut hist = LatencyHist::new();
    let t = Instant::now();
    for &m in &ms {
        hist.record_ms(m);
    }
    let hist_ns = t.elapsed().as_nanos() as f64 / RECORDS as f64;
    assert_eq!(hist.count(), RECORDS as u64);

    let mut res = LegacyReservoir::new(1024);
    let t = Instant::now();
    for &m in &ms {
        res.record_ms(m);
    }
    let res_ns = t.elapsed().as_nanos() as f64 / RECORDS as f64;
    assert_eq!(res.seen, RECORDS);

    (hist_ns, res_ns)
}

/// Median µs for submit + wait of a cache-resident kernel.
fn bench_submit(coord: &Coordinator, ctx: &Context, rng: &mut XorShiftRng) -> f64 {
    let b = &BENCHMARKS[0];
    let nparams = overlay_jit::frontend::parse_kernel(b.source).unwrap().params.len();
    let make_args = |rng: &mut XorShiftRng| {
        (0..nparams)
            .map(|_| {
                let buf = ctx.create_buffer(ITEMS + 16);
                let data: Vec<i32> =
                    (0..ITEMS + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
                buf.write(&data);
                SubmitArg::Buffer(buf)
            })
            .collect::<Vec<SubmitArg>>()
    };
    // warm: pay the one-time JIT outside the timed loop
    let args = make_args(rng);
    coord
        .submit(b.source, &args, ITEMS, Priority::Interactive)
        .unwrap()
        .wait()
        .unwrap();

    let mut us = Vec::with_capacity(DISPATCHES);
    for _ in 0..DISPATCHES {
        let args = make_args(rng);
        let t = Instant::now();
        coord
            .submit(b.source, &args, ITEMS, Priority::Interactive)
            .unwrap()
            .wait()
            .unwrap();
        us.push(t.elapsed().as_micros() as f64);
    }
    median(us)
}

fn main() {
    let mut rng = XorShiftRng::new(0x0B5E);

    // record micro-cost: armed ring vs the no-op recorder
    let armed = TraceSink::new(8, 65_536);
    let on_ns = bench_record(&armed);
    let disabled = TraceSink::disabled();
    let off_ns = bench_record(&disabled);

    // submit hot path: two identical single-partition fleets
    let ctx = Context::new(&Device {
        spec: OverlaySpec::zynq_default(),
        backend: Backend::CycleSim,
        name: "host".into(),
    });
    let coord_off =
        Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1))
            .unwrap();
    let off_us = bench_submit(&coord_off, &ctx, &mut rng);

    let sink = TraceSink::new(8, 65_536);
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.trace = Some(TraceHandle::new(sink.clone(), 0));
    let coord_on = Coordinator::new(cfg).unwrap();
    let on_us = bench_submit(&coord_on, &ctx, &mut rng);
    let per_dispatch_spans =
        sink.stats().recorded as f64 / (DISPATCHES + 1) as f64;

    // head sampling: same armed fleet, 1/8 of submits open a trace
    let sampled_sink = TraceSink::sampled(8, 65_536, Sampler::ratio(8));
    let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
    cfg.trace = Some(TraceHandle::new(sampled_sink.clone(), 0));
    let coord_sampled = Coordinator::new(cfg).unwrap();
    let sampled_us = bench_submit(&coord_sampled, &ctx, &mut rng);
    let sk = sampled_sink.stats();
    assert!(sk.sampled_out > 0, "1/8 sampler must decline most submits");

    // latency carrier: histogram vs the reservoir it replaced
    let (hist_ns, res_ns) = bench_latency_carriers(&mut rng);

    let mut table = TextTable::new(vec!["path", "tracing off", "tracing on", "overhead"]);
    table.row(vec![
        "record ns/span".to_string(),
        format!("{off_ns:.1}"),
        format!("{on_ns:.1}"),
        format!("+{:.1} ns", on_ns - off_ns),
    ]);
    table.row(vec![
        "submit+wait µs/dispatch".to_string(),
        format!("{off_us:.1}"),
        format!("{on_us:.1}"),
        format!("{:+.1}%", 100.0 * (on_us - off_us) / off_us),
    ]);
    table.row(vec![
        "submit+wait µs, sampled 1/8".to_string(),
        format!("{off_us:.1}"),
        format!("{sampled_us:.1}"),
        format!("{:+.1}%", 100.0 * (sampled_us - off_us) / off_us),
    ]);
    table.row(vec![
        "latency carrier ns/sample".to_string(),
        format!("{res_ns:.1} (reservoir)"),
        format!("{hist_ns:.1} (histogram)"),
        format!("{:+.1} ns", hist_ns - res_ns),
    ]);
    println!("{}", table.render());
    println!(
        "({} records, {} timed dispatches, ~{:.1} spans recorded per dispatch)",
        RECORDS, DISPATCHES, per_dispatch_spans
    );

    let mut doc = BTreeMap::new();
    doc.insert("record_ns_off".to_string(), JsonValue::Number(off_ns));
    doc.insert("record_ns_on".to_string(), JsonValue::Number(on_ns));
    doc.insert("submit_us_off".to_string(), JsonValue::Number(off_us));
    doc.insert("submit_us_on".to_string(), JsonValue::Number(on_us));
    doc.insert(
        "spans_per_dispatch".to_string(),
        JsonValue::Number(per_dispatch_spans),
    );
    doc.insert("submit_us_sampled".to_string(), JsonValue::Number(sampled_us));
    doc.insert("hist_record_ns".to_string(), JsonValue::Number(hist_ns));
    doc.insert("reservoir_record_ns".to_string(), JsonValue::Number(res_ns));
    let path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_obs.json".to_string());
    std::fs::write(&path, JsonValue::Object(doc).render()).expect("write bench json");
    println!("wrote {path}");
}
