//! Bench: regenerate **Fig. 5** — resource-aware replication of the
//! Chebyshev kernel on overlay sizes 2×2 … 8×8.
//!
//! Prints the replication factor, the binding resource and full JIT
//! compile timing per overlay size, plus the same sweep for the other
//! five benchmarks as an extension table.
//! Run: `cargo bench --bench fig5_replication`

use std::time::Instant;

use overlay_jit::bench_kernels::{BENCHMARKS, CHEBYSHEV};
use overlay_jit::metrics::TextTable;
use overlay_jit::prelude::*;

fn main() {
    println!("# Fig. 5 — Chebyshev replication across overlay sizes\n");
    let mut t = TextTable::new(vec![
        "overlay", "copies", "limit", "FUs used", "pads used", "JIT ms (median of 5)",
    ]);
    for spec in OverlaySpec::size_sweep(FuType::Dsp2) {
        let jit = JitCompiler::new(spec.clone());
        let mut times = Vec::new();
        let mut last = None;
        for _ in 0..5 {
            let t0 = Instant::now();
            let k = jit.compile(CHEBYSHEV).expect("compile");
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            last = Some(k);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let k = last.unwrap();
        t.row(vec![
            spec.name(),
            k.copies().to_string(),
            k.plan.limit.name().to_string(),
            format!("{}/{}", k.fg.num_fus(), spec.fu_count()),
            format!("{}/{}", k.dfg.num_io() * k.copies(), spec.io_pads()),
            format!("{:.2}", times[2]),
        ]);
    }
    println!("{}", t.render());
    println!("paper Fig. 5: 1 copy on 2x2 ... 16 copies on 8x8 (I/O-limited).\n");

    println!("# Extension — replication of all benchmarks per overlay size\n");
    let mut t2 = TextTable::new(vec![
        "benchmark", "2x2", "3x3", "4x4", "5x5", "6x6", "7x7", "8x8", "paper@8x8",
    ]);
    for b in &BENCHMARKS {
        let mut row = vec![b.name.to_string()];
        for spec in OverlaySpec::size_sweep(FuType::Dsp2) {
            let jit = JitCompiler::new(spec.clone());
            row.push(match jit.compile(b.source) {
                Ok(k) => k.copies().to_string(),
                Err(_) => "-".into(),
            });
        }
        row.push(format!("{}", b.paper.replication));
        t2.row(row);
    }
    println!("{}", t2.render());
}
