//! Bench: regenerate **Table III** — overlay implementations vs direct
//! FPGA implementations of the six replicated benchmarks.
//!
//! Columns mirror the paper: PAR time, Fmax and resources for both
//! flows, then the resource penalty, Fmax improvement and PAR speedup.
//! Our overlay row is measured (PAR) + published-constant (Fmax,
//! slices); the direct row comes from the fine-grained stand-in flow.
//! Paper values are printed underneath each measured row.
//!
//! Run: `cargo bench --bench table3_compare`

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::fpga::{self, FpgaParOptions};
use overlay_jit::metrics::{self, TextTable};
use overlay_jit::prelude::*;
use overlay_jit::replicate::replicate_dfg;

fn main() {
    let effort: f64 = std::env::args()
        .skip(1)
        .find(|a| a.parse::<f64>().is_ok())
        .and_then(|a| a.parse().ok())
        .unwrap_or(0.1);
    let spec = reference_overlay();
    let jit = JitCompiler::new(spec.clone());
    let ovl_slices = metrics::overlay_slices(&spec);

    println!("# Table III — overlay vs direct FPGA (fine effort {effort})\n");
    let mut t = TextTable::new(vec![
        "benchmark", "src", "PARs", "Fmax", "DSP", "Slices",
        "penDSP", "penSlice", "FmaxGain", "speedup",
    ]);
    let mut pen_dsp = Vec::new();
    let mut pen_slice = Vec::new();
    let mut gains = Vec::new();
    let mut speedups = Vec::new();
    for b in &BENCHMARKS {
        let k = jit.compile(b.source).expect("compile");
        let overlay_par = k.report.par_time().as_secs_f64();

        let gates = fpga::techmap(&replicate_dfg(&k.dfg, b.paper.replication)).unwrap();
        let fine = fpga::par(&gates, &FpgaParOptions { effort, ..Default::default() })
            .unwrap();

        let pd = spec.dsp_count() as f64 / fine.dsps.max(1) as f64;
        let ps = ovl_slices as f64 / fine.slices.max(1) as f64;
        let fg = spec.fmax_mhz() / fine.fmax_mhz;
        let su = fine.par_time.as_secs_f64() / overlay_par;
        pen_dsp.push(pd);
        pen_slice.push(ps);
        gains.push(fg);
        speedups.push(su);

        t.row(vec![
            format!("{}({})", b.name, b.paper.replication),
            "ours".into(),
            format!("{overlay_par:.3}/{:.1}", fine.par_time.as_secs_f64()),
            format!("{:.0}/{:.0}", spec.fmax_mhz(), fine.fmax_mhz),
            format!("{}/{}", spec.dsp_count(), fine.dsps),
            format!("{}/{}", ovl_slices, fine.slices),
            format!("{pd:.1}x"),
            format!("{ps:.0}x"),
            format!("{fg:.1}x"),
            format!("{su:.0}x"),
        ]);
        t.row(vec![
            "".into(),
            "paper".into(),
            format!("{:.2}/{:.0}", b.paper.overlay_par_s, b.paper.vivado_par_s),
            format!("300/{:.0}", b.paper.fpga_fmax_mhz),
            format!("128/{}", b.paper.fpga_dsp),
            format!("12617/{}", b.paper.fpga_slices),
            format!("{:.1}x", 128.0 / b.paper.fpga_dsp as f64),
            format!("{:.0}x", 12617.0 / b.paper.fpga_slices as f64),
            format!("{:.1}x", 300.0 / b.paper.fpga_fmax_mhz),
            format!("{:.0}x", b.paper.vivado_par_s / b.paper.overlay_par_s),
        ]);
    }
    println!("{}", t.render());
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "averages (ours): DSP penalty {:.1}x, slice penalty {:.0}x, Fmax gain\n\
         {:.1}x, PAR speedup {:.0}x\n\
         averages (paper): 3.4x DSP, 32x slices, 1.6x Fmax, 1250x PAR",
        avg(&pen_dsp),
        avg(&pen_slice),
        avg(&gains),
        avg(&speedups)
    );
}
