//! Bench: homogeneous vs heterogeneous fleets on a bimodal kernel mix
//! (EXPERIMENTS.md §E9).
//!
//! The workload interleaves **small** interactive dispatches (512
//! items — one kernel copy suffices) with **wide** batch dispatches
//! (16384 items — wants every copy the 8×8 overlay can replicate).
//! Three fleets serve the identical stream:
//!
//! * `4x 8x8` — the homogeneous baseline: small kernels occupy big
//!   partitions and churn their configurations;
//! * `2x 8x8 + 2x 4x4` — the heterogeneous fleet: the resource-aware
//!   router best-fits small dispatches onto the 4×4 tier (≈62% of the
//!   baseline's DSP area) and keeps the 8×8 partitions for wide work;
//! * `2x 8x8` — the big tier alone, to separate the routing win from
//!   raw capacity.
//!
//! Reported: wall time, Mitems/s, p99 latency, reconfiguration loads,
//! fused batches, and the per-spec routing split.
//!
//! Run: `cargo bench --bench fleet_routing`

use std::time::Instant;

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::coordinator::{wait_all, Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::metrics::TextTable;
use overlay_jit::prelude::*;
use overlay_jit::util::XorShiftRng;

const ROUNDS: usize = 8;
const WIDE_ITEMS: usize = 16_384;
const SMALL_ITEMS: usize = 512;

fn args_for(ctx: &Context, nparams: usize, items: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let b = ctx.create_buffer(items + 16);
            let data: Vec<i32> =
                (0..items + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
            b.write(&data);
            SubmitArg::Buffer(b)
        })
        .collect()
}

fn main() {
    let big = reference_overlay();
    let small = OverlaySpec::new(4, 4, FuType::Dsp2);
    let host = Device {
        spec: big.clone(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&host);

    // small-kernel pool: benchmarks with modest FU demand; wide pool:
    // the full six
    let smalls = [&BENCHMARKS[0], &BENCHMARKS[4], &BENCHMARKS[5]]; // chebyshev, poly1, poly2
    let nparams: Vec<usize> = BENCHMARKS
        .iter()
        .map(|b| {
            overlay_jit::frontend::parse_kernel(b.source)
                .expect("benchmark parses")
                .params
                .len()
        })
        .collect();
    let nparams_of = |name: &str| {
        BENCHMARKS
            .iter()
            .position(|b| b.name == name)
            .map(|i| nparams[i])
            .expect("known benchmark")
    };

    println!(
        "# §E9 — fleet routing ({} rounds, wide {} + small {} items)\n",
        ROUNDS, WIDE_ITEMS, SMALL_ITEMS
    );
    let mut table = TextTable::new(vec![
        "fleet",
        "wall s",
        "Mitems/s",
        "p99 ms",
        "reconfigs",
        "fused",
        "routed per spec",
    ]);

    let fleets: Vec<(String, Vec<(OverlaySpec, usize)>)> = vec![
        ("4x 8x8 (homogeneous)".into(), vec![(big.clone(), 4)]),
        (
            "2x 8x8 + 2x 4x4 (heterogeneous)".into(),
            vec![(big.clone(), 2), (small.clone(), 2)],
        ),
        ("2x 8x8 (big tier only)".into(), vec![(big.clone(), 2)]),
    ];

    for (label, groups) in fleets {
        let mut cfg = CoordinatorConfig::sim_fleet_mixed(groups);
        cfg.verify = false; // throughput measurement, not a correctness run
        let coord = Coordinator::new(cfg).expect("coordinator");
        let mut rng = XorShiftRng::new(0xF1EE7);

        let t0 = Instant::now();
        let mut handles = Vec::new();
        for round in 0..ROUNDS {
            // one wide dispatch per benchmark, rotating
            let wide = &BENCHMARKS[round % BENCHMARKS.len()];
            let wargs = args_for(&ctx, nparams_of(wide.name), WIDE_ITEMS, &mut rng);
            handles.push(
                coord
                    .submit(wide.source, &wargs, WIDE_ITEMS, Priority::Batch)
                    .expect("wide submit"),
            );
            // a burst of small interactive dispatches
            for s in &smalls {
                let sargs = args_for(&ctx, nparams_of(s.name), SMALL_ITEMS, &mut rng);
                handles.push(
                    coord
                        .submit(s.source, &sargs, SMALL_ITEMS, Priority::Interactive)
                        .expect("small submit"),
                );
            }
        }
        let results = wait_all(handles).expect("serve");
        let wall = t0.elapsed().as_secs_f64();

        let mut lat: Vec<f64> = results
            .iter()
            .map(|r| (r.queue_wait + r.event.wall).as_secs_f64() * 1e3)
            .collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = coord.stats();
        let routed: Vec<String> = stats
            .per_spec
            .iter()
            .map(|s| format!("{}={}", s.spec, s.routed))
            .collect();
        table.row(vec![
            label,
            format!("{wall:.2}"),
            format!("{:.2}", stats.total_items as f64 / wall / 1e6),
            format!("{:.3}", overlay_jit::metrics::percentile(&lat, 0.99)),
            format!("{}", stats.reconfig_count),
            format!("{}", stats.fused_batches),
            routed.join(" "),
        ]);
    }

    println!("{}", table.render());
    println!(
        "the heterogeneous fleet serves the same stream with the small tier\n\
         absorbing interactive work: fewer 8x8 reconfigurations, and the\n\
         wide batch dispatches keep the full 16-copy replication to themselves."
    );
}
