//! Bench: §E11 — the zero-copy, lock-light serving data plane.
//!
//! Quantifies each leg of the data-plane rebuild and emits the
//! results machine-readably to `BENCH_hotpath.json` (override with
//! the `BENCH_JSON` environment variable) so the perf trajectory can
//! be tracked across commits:
//!
//! * **scalar vs blocked** — `sim::execute_reference` (one work-item
//!   at a time through the slot table) against the blocked SoA
//!   executor (`sim::execute_into` with a warmed scratch), ns/item
//!   per benchmark kernel;
//! * **cloned vs arena** — the legacy dispatch composition
//!   (`pack_streams` → `execute` → `scatter_outputs`, fresh vectors
//!   and argument clones per call) against the snapshot + arena path
//!   (`snapshot_args` → `pack_streams_into` → `execute_into` →
//!   `scatter_outputs_from`), µs/dispatch;
//! * **global vs sharded log** — N threads hammering one
//!   mutex-guarded counter pair vs per-thread atomic shards merged at
//!   the end, ns/op (the `ServeLog` sharding);
//! * **submit hot path** — µs per `Coordinator::submit` of a
//!   cache-resident kernel (the narrowed router/scheduler critical
//!   sections live here).
//!
//! Run: `cargo bench --bench hot_path` (or `make bench-json`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use overlay_jit::arena::{ScratchPool, StreamArena};
use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS, CHEBYSHEV};
use overlay_jit::coordinator::wait_all;
use overlay_jit::metrics::TextTable;
use overlay_jit::prelude::*;
use overlay_jit::runtime_ocl::{Backend, Context, Device};
use overlay_jit::sim::{self, SimScratch};
use overlay_jit::util::{JsonValue, XorShiftRng};

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn num(v: f64) -> JsonValue {
    JsonValue::Number(v)
}

fn obj(fields: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Object(
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>(),
    )
}

/// Scalar walker vs blocked SoA executor, per benchmark kernel.
fn bench_scalar_vs_blocked(jit: &JitCompiler) -> (JsonValue, String) {
    let mut table = TextTable::new(vec![
        "benchmark", "items", "scalar ns/item", "blocked ns/item", "speedup",
    ]);
    let mut rows = Vec::new();
    for b in &BENCHMARKS {
        let k = jit.compile(b.source).expect("compile");
        let chunk = 16 * 1024;
        let items = chunk * k.copies(); // work-items per invocation
        let mut rng = XorShiftRng::new(11);
        let streams: Vec<Vec<i32>> = (0..k.schedule.num_inputs)
            .map(|_| (0..chunk).map(|_| rng.gen_i64(-40, 40) as i32).collect())
            .collect();
        let mut arena = StreamArena::new();
        arena.fill_from(&streams, chunk);
        let mut scratch = SimScratch::new();
        let mut out = StreamArena::new();
        // warm both paths once
        sim::execute_into(&k.schedule, &arena, chunk, &mut scratch, &mut out).unwrap();
        let reference = sim::execute_reference(&k.schedule, &streams, chunk).unwrap();
        assert_eq!(out.to_vecs(), reference, "{}: blocked output diverged", b.name);

        let mut scalar_s = Vec::new();
        let mut blocked_s = Vec::new();
        for _ in 0..5 {
            let t0 = Instant::now();
            sim::execute_reference(&k.schedule, &streams, chunk).unwrap();
            scalar_s.push(t0.elapsed().as_secs_f64());
            let t1 = Instant::now();
            sim::execute_into(&k.schedule, &arena, chunk, &mut scratch, &mut out).unwrap();
            blocked_s.push(t1.elapsed().as_secs_f64());
        }
        let scalar_ns = median(scalar_s) * 1e9 / items as f64;
        let blocked_ns = median(blocked_s) * 1e9 / items as f64;
        table.row(vec![
            b.name.to_string(),
            items.to_string(),
            format!("{scalar_ns:.2}"),
            format!("{blocked_ns:.2}"),
            format!("{:.2}x", scalar_ns / blocked_ns),
        ]);
        rows.push(obj(vec![
            ("kernel", JsonValue::String(b.name.to_string())),
            ("items", num(items as f64)),
            ("scalar_ns_per_item", num(scalar_ns)),
            ("blocked_ns_per_item", num(blocked_ns)),
            ("speedup", num(scalar_ns / blocked_ns)),
        ]));
    }
    (JsonValue::Array(rows), table.render())
}

/// Legacy cloned dispatch composition vs the snapshot + arena path.
fn bench_cloned_vs_arena(jit: &JitCompiler) -> (JsonValue, String) {
    let k = Arc::new(jit.compile(CHEBYSHEV).expect("compile").servable());
    let kernel = Kernel::from_servable(k.clone());
    let dev = Device {
        spec: reference_overlay(),
        backend: Backend::CycleSim,
        name: "bench".into(),
    };
    let ctx = Context::new(&dev);
    let n = 16 * 1024;
    let a = ctx.create_buffer(n);
    let b = ctx.create_buffer(n);
    a.write(&(0..n as i32).map(|i| i % 19 - 9).collect::<Vec<_>>());
    kernel.set_arg(0, &a).unwrap();
    kernel.set_arg(1, &b).unwrap();

    let reps = 20;
    // legacy composition: fresh vectors + an argument-table clone in
    // every one of pack, scatter (and execute allocating its outputs)
    let mut cloned_s = Vec::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        let (streams, chunk) = kernel.pack_streams(n).unwrap();
        let outs = sim::execute(&k.schedule, &streams, chunk).unwrap();
        kernel.scatter_outputs(&outs, n);
        cloned_s.push(t0.elapsed().as_secs_f64());
    }
    // arena composition: one snapshot, pooled scratch, zero steady-
    // state allocations
    let pool = ScratchPool::new();
    let mut arena_s = Vec::new();
    for _ in 0..reps + 1 {
        let t0 = Instant::now();
        let mut scratch = pool.checkout();
        let snap = kernel.snapshot_args().unwrap();
        let chunk = kernel.chunk_for(n);
        scratch.inputs.reset(k.schedule.num_inputs, chunk);
        kernel.pack_streams_into(&snap, n, &mut scratch.inputs, 0).unwrap();
        sim::execute_into(&k.schedule, &scratch.inputs, chunk, &mut scratch.sim, &mut scratch.outputs)
            .unwrap();
        kernel.scatter_outputs_from(&snap, &scratch.outputs, 0, n);
        pool.checkin(scratch);
        arena_s.push(t0.elapsed().as_secs_f64());
    }
    arena_s.remove(0); // warm-up rep grows the arenas; steady state doesn't
    let cloned_us = median(cloned_s) * 1e6;
    let arena_us = median(arena_s) * 1e6;
    let stats = pool.stats();
    let text = format!(
        "cloned path : {cloned_us:.1} us/dispatch ({n} items)\n\
         arena path  : {arena_us:.1} us/dispatch ({:.2}x), {} heap growths over {} dispatches\n",
        cloned_us / arena_us,
        stats.grow_events,
        stats.checkouts,
    );
    (
        obj(vec![
            ("items", num(n as f64)),
            ("cloned_us_per_dispatch", num(cloned_us)),
            ("arena_us_per_dispatch", num(arena_us)),
            ("speedup", num(cloned_us / arena_us)),
            ("arena_grow_events", num(stats.grow_events as f64)),
            ("arena_dispatches", num(stats.checkouts as f64)),
        ]),
        text,
    )
}

/// One mutex-guarded counter pair vs per-thread atomic shards.
fn bench_log_sharding() -> (JsonValue, String) {
    let threads = 4usize;
    let ops = 200_000u64;

    let global = Arc::new(Mutex::new((0u64, 0u64)));
    let t0 = Instant::now();
    let hs: Vec<_> = (0..threads)
        .map(|_| {
            let g = global.clone();
            thread::spawn(move || {
                for i in 0..ops {
                    let mut l = g.lock().unwrap();
                    l.0 += 1;
                    l.1 += i;
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let global_s = t0.elapsed().as_secs_f64();
    assert_eq!(global.lock().unwrap().0, threads as u64 * ops);

    let shards: Vec<Arc<(AtomicU64, AtomicU64)>> =
        (0..threads).map(|_| Arc::new((AtomicU64::new(0), AtomicU64::new(0)))).collect();
    let t1 = Instant::now();
    let hs: Vec<_> = shards
        .iter()
        .map(|s| {
            let s = s.clone();
            thread::spawn(move || {
                for i in 0..ops {
                    s.0.fetch_add(1, Ordering::Relaxed);
                    s.1.fetch_add(i, Ordering::Relaxed);
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let sharded_s = t1.elapsed().as_secs_f64();
    let merged: u64 = shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum();
    assert_eq!(merged, threads as u64 * ops);

    let total_ops = (threads as u64 * ops) as f64;
    let global_ns = global_s * 1e9 / total_ops;
    let sharded_ns = sharded_s * 1e9 / total_ops;
    let text = format!(
        "global mutex log : {global_ns:.1} ns/op ({threads} threads)\n\
         sharded atomics  : {sharded_ns:.1} ns/op ({:.2}x)\n",
        global_ns / sharded_ns
    );
    (
        obj(vec![
            ("threads", num(threads as f64)),
            ("ops_per_thread", num(ops as f64)),
            ("global_mutex_ns_per_op", num(global_ns)),
            ("sharded_atomic_ns_per_op", num(sharded_ns)),
            ("speedup", num(global_ns / sharded_ns)),
        ]),
        text,
    )
}

/// µs per `submit` of a cache-resident kernel — the end-to-end cost
/// of the narrowed router/scheduler critical sections.
fn bench_submit_hot_path() -> (JsonValue, String) {
    let coord = Coordinator::new(CoordinatorConfig::sim_fleet(reference_overlay(), 2))
        .expect("coordinator");
    let dev = Device {
        spec: reference_overlay(),
        backend: Backend::CycleSim,
        name: "bench".into(),
    };
    let ctx = Context::new(&dev);
    let n = 1024;
    let submit = |count: usize| {
        let handles: Vec<_> = (0..count)
            .map(|_| {
                let a = ctx.create_buffer(n);
                let b = ctx.create_buffer(n);
                a.write(&(0..n as i32).map(|i| i % 7 - 3).collect::<Vec<_>>());
                coord
                    .submit(
                        CHEBYSHEV,
                        &[SubmitArg::Buffer(a), SubmitArg::Buffer(b)],
                        n,
                        Priority::Interactive,
                    )
                    .unwrap()
            })
            .collect();
        wait_all(handles).unwrap();
    };
    submit(8); // compile + warm the pool and caches
    let rounds = 200;
    let t0 = Instant::now();
    submit(rounds);
    let total_s = t0.elapsed().as_secs_f64();
    let us = total_s * 1e6 / rounds as f64;
    let pool = coord.pool_stats();
    let text = format!(
        "submit hot path  : {us:.1} us/dispatch e2e (cache-resident, {} pool growths)\n",
        pool.grow_events
    );
    (
        obj(vec![
            ("dispatches", num(rounds as f64)),
            ("e2e_us_per_dispatch", num(us)),
            ("pool_grow_events", num(pool.grow_events as f64)),
            ("pool_created", num(pool.created as f64)),
        ]),
        text,
    )
}

fn main() {
    let spec = reference_overlay();
    let jit = JitCompiler::new(spec);

    println!("# §E11 — scalar vs blocked SoA executor\n");
    let (sim_json, sim_text) = bench_scalar_vs_blocked(&jit);
    println!("{sim_text}");

    println!("# §E11 — cloned vs arena dispatch path (chebyshev x16)\n");
    let (pack_json, pack_text) = bench_cloned_vs_arena(&jit);
    println!("{pack_text}");

    println!("# §E11 — global mutex vs sharded serving log\n");
    let (log_json, log_text) = bench_log_sharding();
    println!("{log_text}");

    println!("# §E11 — coordinator submit hot path\n");
    let (submit_json, submit_text) = bench_submit_hot_path();
    println!("{submit_text}");

    let doc = obj(vec![
        ("bench", JsonValue::String("hot_path".to_string())),
        ("sim_block", num(overlay_jit::sim::SIM_BLOCK as f64)),
        ("scalar_vs_blocked", sim_json),
        ("cloned_vs_arena", pack_json),
        ("log_sharding", log_json),
        ("submit_hot_path", submit_json),
    ]);
    let path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&path, doc.render()).expect("writing bench JSON");
    println!("wrote {path}");
}
