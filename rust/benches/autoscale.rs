//! Bench: frozen replication plans vs the adaptive autoscaler under a
//! bursty bimodal stream (EXPERIMENTS.md §E10).
//!
//! The workload alternates **bursts**: a wide phase (16384-item
//! dispatches — chebyshev's full 16-copy demand on the 8×8) followed
//! by a small phase (512-item dispatches — one copy suffices), over
//! several cycles. Three identical 2× 8×8 fleets serve the identical
//! stream:
//!
//! * `frozen` — every kernel keeps the replication factor of its
//!   first (resource-aware, overlay-filling) compile, so small-phase
//!   dispatches drag the full 16-copy configuration;
//! * `demand-band` — the feedback loop re-replicates on the demand
//!   signal: the small phase scales down to 1 copy, the wide phase
//!   scales back up — a kernel-cache **hit** from the second cycle
//!   on, but one 16↔1 flap per phase shift;
//! * `slo-targeted` — the controller is driven by the interactive
//!   windowed p99 against a latency target (2× the frozen fleet's
//!   measured p99) instead of the demand band: scale-ups fire only
//!   while the objective is missed, and the hysteresis hold blocks
//!   scale-downs until p99 clears 0.8× target — capacity is held
//!   while the objective is at risk, at the cost of reacting one
//!   window late.
//!
//! Reported: wall time, Mitems/s, p50/p99 latency, reconfiguration
//! loads and modeled µs, scale events and rescale cache hits.
//!
//! Run: `cargo bench --bench autoscale`

use std::time::Instant;

use overlay_jit::autoscale::AutoscalePolicy;
use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::coordinator::{Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::metrics::{percentile, TextTable};
use overlay_jit::prelude::*;
use overlay_jit::util::XorShiftRng;

const CYCLES: usize = 3;
const PER_PHASE: usize = 24;
const WIDE_ITEMS: usize = 16_384;
const SMALL_ITEMS: usize = 512;

fn args_for(ctx: &Context, items: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..2)
        .map(|_| {
            let b = ctx.create_buffer(items + 16);
            let data: Vec<i32> =
                (0..items + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
            b.write(&data);
            SubmitArg::Buffer(b)
        })
        .collect()
}

fn main() {
    let spec = reference_overlay();
    let host = Device {
        spec: spec.clone(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&host);
    let cheb = &BENCHMARKS[0];

    println!(
        "# §E10 — adaptive vs frozen replication ({CYCLES} cycles x \
         {PER_PHASE} wide + {PER_PHASE} small dispatches, 2x {})\n",
        spec.name()
    );
    let mut table = TextTable::new(vec![
        "mode",
        "wall s",
        "Mitems/s",
        "p50 ms",
        "p99 ms",
        "reconfigs",
        "reconfig us",
        "scale events",
        "rescale hits",
    ]);

    let mut frozen_p99 = 0.0f64;
    for mode in ["frozen", "demand-band", "slo-targeted"] {
        let mut cfg = CoordinatorConfig::sim_fleet(spec.clone(), 2);
        cfg.verify = false; // throughput measurement, not a correctness run
        match mode {
            "demand-band" => cfg.autoscale = Some(AutoscalePolicy::default()),
            "slo-targeted" => {
                cfg.autoscale = Some(AutoscalePolicy::default());
                // arm SLO-targeted mode: an achievable latency target
                // (2x the frozen fleet's measured p99) drives the
                // controller instead of the demand band
                cfg.slo = Some(overlay_jit::obs::SloPolicy::serving(
                    (frozen_p99 * 2.0).max(0.05),
                    0.99,
                ));
            }
            _ => {}
        }
        let slo_armed = cfg.slo.is_some();
        let coord = Coordinator::new(cfg).expect("coordinator");
        let mut rng = XorShiftRng::new(0xB1_D0D);

        let t0 = Instant::now();
        let mut lat: Vec<f64> = Vec::new();
        let mut tick = 0u64;
        let mut nsub = 0u64;
        for _cycle in 0..CYCLES {
            for items in [WIDE_ITEMS, SMALL_ITEMS] {
                for _ in 0..PER_PHASE {
                    let args = args_for(&ctx, items, &mut rng);
                    let r = coord
                        .submit(cheb.source, &args, items, Priority::Interactive)
                        .expect("submit")
                        .wait()
                        .expect("serve");
                    lat.push((r.queue_wait + r.event.wall).as_secs_f64() * 1e3);
                    nsub += 1;
                    // close an SLO window every 8 dispatches so the
                    // windowed-p99 control signal tracks the phase
                    if slo_armed && nsub % 8 == 0 {
                        tick += 1;
                        let _ = coord.slo_tick(tick * 1_000_000_000);
                    }
                }
                coord.drain_background();
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if mode == "frozen" {
            frozen_p99 = percentile(&lat, 0.99);
        }
        let stats = coord.stats();
        let (events, hits) = stats
            .autoscale
            .map(|a| (a.applied(), a.rescale_cache_hits))
            .unwrap_or((0, 0));
        table.row(vec![
            mode.to_string(),
            format!("{wall:.2}"),
            format!("{:.2}", stats.total_items as f64 / wall / 1e6),
            format!("{:.3}", percentile(&lat, 0.50)),
            format!("{:.3}", percentile(&lat, 0.99)),
            format!("{}", stats.reconfig_count),
            format!("{:.1}", stats.reconfig_seconds * 1e6),
            format!("{events}"),
            format!("{hits}"),
        ]);
    }

    println!("{}", table.render());
    println!(
        "demand-band scales chebyshev 16 -> 1 for each small burst (1-copy\n\
         bitstream: cheaper reconfigurations, no idle copies) and back to 16\n\
         for each wide burst; from the second cycle every rescale is a\n\
         kernel-cache hit, so the adaptation itself costs no JIT.\n\
         slo-targeted moves only when the windowed p99 crosses its target\n\
         and holds capacity until p99 clears the 0.8x hysteresis band —\n\
         fewer flaps than demand-band, one window of reaction lag."
    );
}
