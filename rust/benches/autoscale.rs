//! Bench: frozen replication plans vs the adaptive autoscaler under a
//! bursty bimodal stream (EXPERIMENTS.md §E10).
//!
//! The workload alternates **bursts**: a wide phase (16384-item
//! dispatches — chebyshev's full 16-copy demand on the 8×8) followed
//! by a small phase (512-item dispatches — one copy suffices), over
//! several cycles. Two identical 2× 8×8 fleets serve the identical
//! stream:
//!
//! * `frozen` — today's behavior: every kernel keeps the replication
//!   factor of its first (resource-aware, overlay-filling) compile,
//!   so small-phase dispatches drag the full 16-copy configuration;
//! * `adaptive` — the feedback loop re-replicates at run time: the
//!   small phase scales down to 1 copy (smaller bitstream, cheaper
//!   reconfiguration, no idle copies), the wide phase scales back up
//!   — a kernel-cache **hit** from the second cycle on.
//!
//! Reported: wall time, Mitems/s, p50/p99 latency, reconfiguration
//! loads and modeled µs, scale events and rescale cache hits.
//!
//! Run: `cargo bench --bench autoscale`

use std::time::Instant;

use overlay_jit::autoscale::AutoscalePolicy;
use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::coordinator::{Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::metrics::{percentile, TextTable};
use overlay_jit::prelude::*;
use overlay_jit::util::XorShiftRng;

const CYCLES: usize = 3;
const PER_PHASE: usize = 24;
const WIDE_ITEMS: usize = 16_384;
const SMALL_ITEMS: usize = 512;

fn args_for(ctx: &Context, items: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..2)
        .map(|_| {
            let b = ctx.create_buffer(items + 16);
            let data: Vec<i32> =
                (0..items + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
            b.write(&data);
            SubmitArg::Buffer(b)
        })
        .collect()
}

fn main() {
    let spec = reference_overlay();
    let host = Device {
        spec: spec.clone(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&host);
    let cheb = &BENCHMARKS[0];

    println!(
        "# §E10 — adaptive vs frozen replication ({CYCLES} cycles x \
         {PER_PHASE} wide + {PER_PHASE} small dispatches, 2x {})\n",
        spec.name()
    );
    let mut table = TextTable::new(vec![
        "mode",
        "wall s",
        "Mitems/s",
        "p50 ms",
        "p99 ms",
        "reconfigs",
        "reconfig us",
        "scale events",
        "rescale hits",
    ]);

    for adaptive in [false, true] {
        let mut cfg = CoordinatorConfig::sim_fleet(spec.clone(), 2);
        cfg.verify = false; // throughput measurement, not a correctness run
        if adaptive {
            cfg.autoscale = Some(AutoscalePolicy::default());
        }
        let coord = Coordinator::new(cfg).expect("coordinator");
        let mut rng = XorShiftRng::new(0xB1_D0D);

        let t0 = Instant::now();
        let mut lat: Vec<f64> = Vec::new();
        for _cycle in 0..CYCLES {
            for items in [WIDE_ITEMS, SMALL_ITEMS] {
                for _ in 0..PER_PHASE {
                    let args = args_for(&ctx, items, &mut rng);
                    let r = coord
                        .submit(cheb.source, &args, items, Priority::Interactive)
                        .expect("submit")
                        .wait()
                        .expect("serve");
                    lat.push((r.queue_wait + r.event.wall).as_secs_f64() * 1e3);
                }
                coord.drain_background();
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = coord.stats();
        let (events, hits) = stats
            .autoscale
            .map(|a| (a.applied(), a.rescale_cache_hits))
            .unwrap_or((0, 0));
        table.row(vec![
            if adaptive { "adaptive".to_string() } else { "frozen".to_string() },
            format!("{wall:.2}"),
            format!("{:.2}", stats.total_items as f64 / wall / 1e6),
            format!("{:.3}", percentile(&lat, 0.50)),
            format!("{:.3}", percentile(&lat, 0.99)),
            format!("{}", stats.reconfig_count),
            format!("{:.1}", stats.reconfig_seconds * 1e6),
            format!("{events}"),
            format!("{hits}"),
        ]);
    }

    println!("{}", table.render());
    println!(
        "adaptive scales chebyshev 16 -> 1 for each small burst (1-copy\n\
         bitstream: cheaper reconfigurations, no idle copies) and back to 16\n\
         for each wide burst; from the second cycle every rescale is a\n\
         kernel-cache hit, so the adaptation itself costs no JIT."
    );
}
