//! Bench: cluster routing — one node vs three, and three with a
//! mid-stream death (EXPERIMENTS.md §E13).
//!
//! The workload is the §E9 bimodal mix (wide batch + small interactive
//! bursts of the six paper benchmarks) fired through the
//! [`ClusterFrontend`] in three shapes:
//!
//! * `1 node` — the ring degenerates to a pass-through: every dispatch
//!   is an affinity hit; this is the single-coordinator baseline plus
//!   the front-door routing overhead;
//! * `3 nodes` — the consistent-hash tier: each kernel compiles once
//!   on its home node, the keyspace serves in parallel;
//! * `3 nodes + death` — the same stream with one node killed halfway:
//!   its range fails over to ring successors, queued work fails typed,
//!   and the survivors absorb the load.
//!
//! Reported: wall time, Mitems/s, affinity rate, spills/failovers,
//! typed failures, and the per-node routed histogram.
//!
//! Run: `cargo bench --bench cluster_routing`

use std::time::{Duration, Instant};

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::cluster::{ClusterConfig, ClusterFrontend};
use overlay_jit::coordinator::{CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::metrics::TextTable;
use overlay_jit::prelude::*;
use overlay_jit::util::XorShiftRng;

const ROUNDS: usize = 8;
const WIDE_ITEMS: usize = 16_384;
const SMALL_ITEMS: usize = 512;
/// Hard ceiling for every handle to reach a terminal outcome.
const RESOLVE_TIMEOUT: Duration = Duration::from_secs(240);

fn args_for(ctx: &Context, nparams: usize, items: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let b = ctx.create_buffer(items + 16);
            let data: Vec<i32> =
                (0..items + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
            b.write(&data);
            SubmitArg::Buffer(b)
        })
        .collect()
}

fn main() {
    let spec = reference_overlay();
    let host = Device {
        spec: spec.clone(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&host);

    let smalls = [&BENCHMARKS[0], &BENCHMARKS[4], &BENCHMARKS[5]]; // chebyshev, poly1, poly2
    let nparams: Vec<usize> = BENCHMARKS
        .iter()
        .map(|b| {
            overlay_jit::frontend::parse_kernel(b.source)
                .expect("benchmark parses")
                .params
                .len()
        })
        .collect();
    let nparams_of = |name: &str| {
        BENCHMARKS
            .iter()
            .position(|b| b.name == name)
            .map(|i| nparams[i])
            .expect("known benchmark")
    };

    println!(
        "# §E13 — cluster routing ({} rounds, wide {} + small {} items, \
         2 partitions per node)\n",
        ROUNDS, WIDE_ITEMS, SMALL_ITEMS
    );
    let mut table = TextTable::new(vec![
        "cluster",
        "wall s",
        "Mitems/s",
        "affinity",
        "spills",
        "failovers",
        "failed typed",
        "routed per node",
    ]);

    // (label, nodes, kill one node halfway?)
    let shapes: [(&str, usize, bool); 3] = [
        ("1 node", 1, false),
        ("3 nodes", 3, false),
        ("3 nodes + death", 3, true),
    ];

    for (label, nodes, kill) in shapes {
        let mut node_cfg = CoordinatorConfig::sim_fleet(spec.clone(), 2);
        node_cfg.verify = false; // throughput measurement, not a correctness run
        let cluster =
            ClusterFrontend::new(ClusterConfig::sim_cluster(nodes, node_cfg)).expect("cluster");
        let mut rng = XorShiftRng::new(0xF1EE7);
        // the death scenario kills chebyshev's home mid-stream
        let victim = cluster.home_of(BENCHMARKS[0].source);

        let t0 = Instant::now();
        let mut handles = Vec::new();
        for round in 0..ROUNDS {
            if kill && round == ROUNDS / 2 {
                cluster.kill_node(victim).expect("scripted kill");
            }
            let wide = &BENCHMARKS[round % BENCHMARKS.len()];
            let wargs = args_for(&ctx, nparams_of(wide.name), WIDE_ITEMS, &mut rng);
            handles.push(
                cluster
                    .submit(wide.source, &wargs, WIDE_ITEMS, Priority::Batch)
                    .expect("wide submit"),
            );
            for s in &smalls {
                let sargs = args_for(&ctx, nparams_of(s.name), SMALL_ITEMS, &mut rng);
                handles.push(
                    cluster
                        .submit(s.source, &sargs, SMALL_ITEMS, Priority::Interactive)
                        .expect("small submit"),
                );
            }
        }

        // resolve every handle (typed failures are expected in the
        // death scenario; a hang is not)
        let mut failed_typed = 0usize;
        let mut open = handles;
        let deadline = Instant::now() + RESOLVE_TIMEOUT;
        while !open.is_empty() {
            assert!(Instant::now() <= deadline, "{label}: {} handles hung", open.len());
            let mut still = Vec::with_capacity(open.len());
            for h in open {
                match h.try_wait_typed() {
                    Some(Ok(_)) => {}
                    Some(Err(_)) => failed_typed += 1,
                    None => still.push(h),
                }
            }
            open = still;
            if !open.is_empty() {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        let stats = cluster.stats();
        let routed: Vec<String> = stats
            .per_node
            .iter()
            .map(|n| format!("{}={}", n.name, n.routed))
            .collect();
        table.row(vec![
            label.to_string(),
            format!("{wall:.2}"),
            format!("{:.2}", stats.merged.total_items as f64 / wall / 1e6),
            format!("{:.0}%", 100.0 * stats.affinity_rate()),
            format!("{}", stats.spills),
            format!("{}", stats.failovers),
            format!("{failed_typed}"),
            routed.join(" "),
        ]);
        cluster.shutdown();
    }

    println!("{}", table.render());
    println!(
        "the 3-node tier keeps each kernel's compiled variants on one home\n\
         node (affinity ~100% while everyone lives); killing a node re-routes\n\
         its range to ring successors with typed failures only for work\n\
         already queued on it — nothing hangs."
    );
}
