//! Bench: coordinator serving hot path vs the single-device
//! synchronous baseline (EXPERIMENTS.md §E8).
//!
//! Three measurements:
//! * **baseline** — one device, `Program::build` once, synchronous
//!   `enqueue_nd_range` loop (the pre-coordinator serving story);
//! * **cache-hit dispatch** — the coordinator hot path: every request
//!   after the first hits the compile cache and an already-configured
//!   partition; reported as dispatches/s and Mitems/s for 1 and 2
//!   partitions;
//! * **reconfiguration churn** — the worst case: two kernels
//!   alternating on one partition force a bitstream load per dispatch,
//!   while two partitions absorb the same stream with exactly two
//!   loads. Reported with the modeled µs spent reconfiguring.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::time::Instant;

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::coordinator::{wait_all, Coordinator, CoordinatorConfig, Priority, SubmitArg};
use overlay_jit::metrics::TextTable;
use overlay_jit::prelude::*;
use overlay_jit::util::XorShiftRng;

const DISPATCHES: usize = 64;
const ITEMS: usize = 4096;

fn buffers_for(ctx: &Context, nparams: usize, rng: &mut XorShiftRng) -> Vec<SubmitArg> {
    (0..nparams)
        .map(|_| {
            let b = ctx.create_buffer(ITEMS + 16);
            let data: Vec<i32> =
                (0..ITEMS + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
            b.write(&data);
            SubmitArg::Buffer(b)
        })
        .collect()
}

fn main() {
    let spec = reference_overlay();
    let cheb = &BENCHMARKS[0];
    let poly1 = &BENCHMARKS[4];
    let mut rng = XorShiftRng::new(0xBE7C);

    // host-side context for buffer allocation
    let host = Device {
        spec: spec.clone(),
        backend: Backend::CycleSim,
        name: "host".into(),
    };
    let ctx = Context::new(&host);

    println!(
        "# §E8 — serving hot path ({} dispatches x {} items, chebyshev)\n",
        DISPATCHES, ITEMS
    );
    let mut table = TextTable::new(vec![
        "path",
        "disp/s",
        "Mitems/s",
        "hit rate",
        "reconfigs",
        "reconfig us",
    ]);

    // --- baseline: single device, synchronous ----------------------
    {
        let platform = Platform::with_device(spec.clone(), Backend::CycleSim);
        let bctx = Context::new(&platform.devices()[0]);
        let mut program = Program::from_source(&bctx, cheb.source);
        program.build().expect("baseline build");
        let kernel = program.create_kernel(cheb.name).expect("kernel");
        let bufs: Vec<Buffer> = (0..2).map(|_| bctx.create_buffer(ITEMS + 16)).collect();
        let data: Vec<i32> = (0..ITEMS + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
        bufs[0].write(&data);
        kernel.set_arg(0, &bufs[0]).unwrap();
        kernel.set_arg(1, &bufs[1]).unwrap();
        let queue = CommandQueue::new(&bctx);
        let t0 = Instant::now();
        for _ in 0..DISPATCHES {
            queue.enqueue_nd_range(&kernel, ITEMS).expect("dispatch");
        }
        let s = t0.elapsed().as_secs_f64();
        table.row(vec![
            "sync 1-dev baseline".to_string(),
            format!("{:.0}", DISPATCHES as f64 / s),
            format!("{:.2}", DISPATCHES as f64 * ITEMS as f64 / s / 1e6),
            "-".to_string(),
            "1".to_string(),
            "-".to_string(),
        ]);
    }

    // --- coordinator cache-hit hot path, 1 and 2 partitions --------
    for partitions in [1usize, 2] {
        let mut cfg = CoordinatorConfig::sim_fleet(spec.clone(), partitions);
        cfg.verify = false; // hot-path measurement, not a correctness run
        let coord = Coordinator::new(cfg).expect("coordinator");
        // warm the cache + the partition configuration
        let args = buffers_for(&ctx, 2, &mut rng);
        coord
            .submit(cheb.source, &args, ITEMS, Priority::Interactive)
            .expect("warm submit")
            .wait()
            .expect("warm dispatch");
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(DISPATCHES);
        for _ in 0..DISPATCHES {
            handles.push(coord.submit(cheb.source, &args, ITEMS, Priority::Interactive).expect("submit"));
        }
        let results = wait_all(handles).expect("serve");
        let s = t0.elapsed().as_secs_f64();
        assert!(results.iter().all(|r| r.cache_hit));
        let stats = coord.stats();
        table.row(vec![
            format!("coordinator x{partitions} (hot)"),
            format!("{:.0}", DISPATCHES as f64 / s),
            format!("{:.2}", DISPATCHES as f64 * ITEMS as f64 / s / 1e6),
            format!("{:.0}%", 100.0 * stats.cache.hit_rate()),
            format!("{}", stats.reconfig_count),
            format!("{:.1}", stats.reconfig_seconds * 1e6),
        ]);
    }

    // --- reconfiguration churn worst case ---------------------------
    for partitions in [1usize, 2] {
        let mut cfg = CoordinatorConfig::sim_fleet(spec.clone(), partitions);
        cfg.verify = false;
        let coord = Coordinator::new(cfg).expect("coordinator");
        let cheb_args = buffers_for(&ctx, 2, &mut rng);
        let poly_args = buffers_for(&ctx, 2, &mut rng);
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(DISPATCHES);
        for i in 0..DISPATCHES {
            let (b, args) = if i % 2 == 0 {
                (cheb, &cheb_args)
            } else {
                (poly1, &poly_args)
            };
            handles.push(coord.submit(b.source, args, ITEMS, Priority::Interactive).expect("submit"));
        }
        wait_all(handles).expect("serve");
        let s = t0.elapsed().as_secs_f64();
        let stats = coord.stats();
        table.row(vec![
            format!("alternating x{partitions} (churn)"),
            format!("{:.0}", DISPATCHES as f64 / s),
            format!("{:.2}", DISPATCHES as f64 * ITEMS as f64 / s / 1e6),
            format!("{:.0}%", 100.0 * stats.cache.hit_rate()),
            format!("{}", stats.reconfig_count),
            format!("{:.1}", stats.reconfig_seconds * 1e6),
        ]);
    }

    println!("{}", table.render());
    println!(
        "baseline pays one modeled config per queue creation; the coordinator's\n\
         hot path pays zero after warm-up, and the churn rows show the fleet\n\
         absorbing an alternating working set ({} loads on 1 partition vs 2 on 2).",
        DISPATCHES
    );
}
