//! Bench: §Perf hot-path microbenchmarks.
//!
//! Times the individual JIT pipeline stages and the execution backends
//! so the EXPERIMENTS.md §Perf before/after table can be regenerated:
//!
//! * full JIT compile per benchmark (median/min of N);
//! * placement and routing isolated (the PAR hot loops);
//! * cycle-sim and PJRT dispatch throughput (work-items/s).
//!
//! Run: `cargo bench --bench jit_stages`

use std::time::Instant;

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::metrics::TextTable;
use overlay_jit::netlist::build_netlist;
use overlay_jit::overlay::RoutingGraph;
use overlay_jit::place::place;
use overlay_jit::prelude::*;
use overlay_jit::route::{bind_nets, route, RouterOptions};
use overlay_jit::sim;
use overlay_jit::util::XorShiftRng;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    let spec = reference_overlay();
    let jit = JitCompiler::new(spec.clone());
    let rrg = RoutingGraph::build(&spec);

    println!("# §Perf — JIT pipeline stage times (ms, median of 7)\n");
    let mut t = TextTable::new(vec![
        "benchmark", "frontend", "place", "route", "latency+cfg", "total JIT",
    ]);
    for b in &BENCHMARKS {
        let mut frontend = Vec::new();
        let mut place_ms = Vec::new();
        let mut route_ms = Vec::new();
        let mut rest = Vec::new();
        let mut total = Vec::new();
        for seed in 0..7u64 {
            let jit = JitCompiler::with_options(
                spec.clone(),
                CompileOptions { seed: seed + 1, ..Default::default() },
            );
            let k = jit.compile(b.source).expect("compile");
            let ms = |n: &str| k.report.get(n).map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0);
            frontend.push(k.report.frontend_time().as_secs_f64() * 1e3);
            place_ms.push(ms("place"));
            route_ms.push(ms("route"));
            rest.push(ms("latency") + ms("configgen"));
            total.push(k.report.total().as_secs_f64() * 1e3);
        }
        t.row(vec![
            b.name.to_string(),
            format!("{:.2}", median(frontend)),
            format!("{:.2}", median(place_ms)),
            format!("{:.2}", median(route_ms)),
            format!("{:.3}", median(rest)),
            format!("{:.2}", median(total)),
        ]);
    }
    println!("{}", t.render());

    // isolated PAR on the largest mapped kernel (chebyshev x16)
    let k = jit.compile(BENCHMARKS[0].source).unwrap();
    let nl = build_netlist(&k.fg);
    let mut p_times = Vec::new();
    let mut r_times = Vec::new();
    for seed in 1..=9u64 {
        let t0 = Instant::now();
        let pl = place(&nl, &spec, &rrg, seed).unwrap();
        p_times.push(t0.elapsed().as_secs_f64() * 1e3);
        let bound = bind_nets(&k.fg, &nl, &pl, &rrg).unwrap();
        let t1 = Instant::now();
        route(&rrg, &bound.route_nets, &RouterOptions::default()).unwrap();
        r_times.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "isolated PAR (chebyshev x16): place {:.2} ms, route {:.2} ms (median of 9)\n",
        median(p_times),
        median(r_times)
    );

    // execution backends
    println!("# §Perf — execution backends (chebyshev x16)\n");
    let items = 64 * 1024;
    let streams: Vec<Vec<i32>> = {
        let mut rng = XorShiftRng::new(5);
        (0..k.schedule.num_inputs)
            .map(|_| (0..items / 16).map(|_| rng.gen_i64(-40, 40) as i32).collect())
            .collect()
    };
    let n = items / 16;
    let mut sim_times = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        sim::execute(&k.schedule, &streams, n).unwrap();
        sim_times.push(t0.elapsed().as_secs_f64());
    }
    let sim_s = median(sim_times);
    println!(
        "cycle-sim : {:.1} ms per {} items = {:.2} Mitems/s",
        sim_s * 1e3,
        items,
        items as f64 / sim_s / 1e6
    );
    match overlay_jit::runtime::PjrtRuntime::new("artifacts") {
        Ok(rt) => {
            // warm up (compile cached once)
            rt.execute_overlay(&k.schedule, &streams, n).unwrap();
            let mut times = Vec::new();
            for _ in 0..5 {
                let t0 = Instant::now();
                rt.execute_overlay(&k.schedule, &streams, n).unwrap();
                times.push(t0.elapsed().as_secs_f64());
            }
            let s = median(times);
            println!(
                "pjrt      : {:.1} ms per {} items = {:.2} Mitems/s",
                s * 1e3,
                items,
                items as f64 / s / 1e6
            );
        }
        Err(e) => println!("pjrt      : unavailable ({e})"),
    }
}
