//! Bench: regenerate the **§IV configuration-time comparison** —
//! overlay configuration (1061 B, 42.4 µs) vs full-fabric
//! reconfiguration (4 MB, 31.6 ms), ≈750×.
//!
//! Also measures the real wall time of bitstream serialization +
//! deserialization (the host-side cost of a context switch) across
//! overlay sizes.
//! Run: `cargo bench --bench config_time`

use std::time::Instant;

use overlay_jit::bench_kernels::CHEBYSHEV;
use overlay_jit::metrics::TextTable;
use overlay_jit::overlay::{ConfigSizeModel, OverlayBitstream};
use overlay_jit::prelude::*;

fn main() {
    println!("# §IV — configuration size & time\n");
    let mut t = TextTable::new(vec![
        "overlay", "config bytes", "load time (model)", "serialize+parse (meas)",
    ]);
    for spec in OverlaySpec::size_sweep(FuType::Dsp2) {
        let jit = JitCompiler::new(spec.clone());
        let k = jit.compile(CHEBYSHEV).expect("compile");
        let bytes = k.bitstream.byte_size();
        let model_s = ConfigSizeModel::overlay_config_seconds(&spec, bytes);
        // measured host serialization round-trip (median of 101)
        let mut times = Vec::new();
        for _ in 0..101 {
            let t0 = Instant::now();
            let b = k.bitstream.to_bytes();
            let back = OverlayBitstream::from_bytes(&b).unwrap();
            times.push(t0.elapsed().as_secs_f64());
            assert_eq!(back.byte_size(), bytes);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        t.row(vec![
            spec.name(),
            bytes.to_string(),
            format!("{:.1} us", model_s * 1e6),
            format!("{:.1} us", times[50] * 1e6),
        ]);
    }
    println!("{}", t.render());

    let spec = OverlaySpec::zynq_default();
    let overlay_s = ConfigSizeModel::overlay_config_seconds(&spec, 1061);
    let fpga_s = ConfigSizeModel::fpga_config_seconds();
    println!(
        "full-fabric reconfiguration: {} bytes @ {:.1} ms (PCAP)\n\
         overlay reconfiguration:     1061 bytes @ {:.1} us\n\
         ratio: {:.0}x   (paper: ~750x)",
        ConfigSizeModel::FPGA_BITSTREAM_BYTES,
        fpga_s * 1e3,
        overlay_s * 1e6,
        fpga_s / overlay_s
    );
}
