//! Bench: regenerate **Fig. 6** — performance scaling by Chebyshev
//! kernel replication on different overlays (both FU types).
//!
//! Emits the two GOPS-vs-size series of the figure (blue = 2 DSP/FU,
//! red = 1 DSP/FU) from the analytic model the paper uses
//! (copies × ops × Fmax), cross-checked against the cycle-level
//! timing model on a million-item dispatch.
//! Run: `cargo bench --bench fig6_throughput`

use overlay_jit::bench_kernels::CHEBYSHEV;
use overlay_jit::metrics::{self, TextTable};
use overlay_jit::prelude::*;
use overlay_jit::sim;

fn main() {
    println!("# Fig. 6 — Chebyshev throughput vs overlay size\n");
    let mut t = TextTable::new(vec![
        "overlay", "FU type", "copies", "GOPS (model)", "GOPS (cycle sim)", "peak", "util",
    ]);
    for fu_type in [FuType::Dsp2, FuType::Dsp1] {
        for spec in OverlaySpec::size_sweep(fu_type) {
            let jit = JitCompiler::new(spec.clone());
            let Ok(k) = jit.compile(CHEBYSHEV) else {
                t.row(vec![
                    spec.name(),
                    format!("{} DSP/FU", fu_type.dsps_per_fu()),
                    "-".into(),
                    "does not fit".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            let model = metrics::throughput(&spec, &k);
            let timing =
                sim::timing(&spec, &k.latency, k.copies(), k.ops_per_copy(), 1_000_000);
            t.row(vec![
                spec.name(),
                format!("{} DSP/FU", fu_type.dsps_per_fu()),
                k.copies().to_string(),
                format!("{:.2}", model.gops),
                format!("{:.2}", timing.gops),
                format!("{:.1}", model.peak_gops),
                format!("{:.0}%", 100.0 * model.utilization),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "paper: blue curve 2.45 -> ~35 GOPS (30% of 115 GOPS peak at 16\n\
         copies); red curve 2.66 -> ~28 GOPS (43% of 65 GOPS at 12 copies)."
    );
}
