//! Bench: regenerate **Fig. 7** — PAR time comparison across the six
//! benchmarks and three scenarios.
//!
//! * `fine-PAR` — measured: the same SA+PathFinder algorithm family at
//!   LUT/bit-lane granularity on the XC7Z020-sized fabric model (the
//!   Vivado stand-in; the paper's published Vivado seconds are printed
//!   alongside for reference — Vivado additionally runs synthesis and
//!   timing-driven optimization, so its absolute numbers are higher);
//! * `overlay-x86` — measured: our JIT PAR (place+route+latency+config);
//! * `overlay-Zynq` — modeled: x86 time × the published 4× Cortex-A9
//!   slowdown (Fig. 7's third bar, 0.88 s vs 0.22 s).
//!
//! Run: `cargo bench --bench fig7_par_time` (add an effort argument to
//! scale the fine-grained annealing, default 1.0).

use overlay_jit::bench_kernels::{reference_overlay, BENCHMARKS};
use overlay_jit::fpga::{self, FpgaParOptions};
use overlay_jit::metrics::{TextTable, ZYNQ_ARM_SLOWDOWN};
use overlay_jit::prelude::*;
use overlay_jit::replicate::replicate_dfg;

fn main() {
    let effort: f64 = std::env::args()
        .skip(1)
        .find(|a| a.parse::<f64>().is_ok())
        .and_then(|a| a.parse().ok())
        .unwrap_or(0.1);
    let spec = reference_overlay();
    let jit = JitCompiler::new(spec.clone());

    println!("# Fig. 7 — PAR times in seconds (fine effort {effort})\n");
    let mut t = TextTable::new(vec![
        "benchmark",
        "fine-PAR meas",
        "Vivado paper",
        "ovl-x86 meas",
        "ovl-x86 paper",
        "ovl-Zynq model",
        "ovl-Zynq paper",
        "speedup meas",
        "speedup paper",
    ]);
    let mut ratios = Vec::new();
    let (mut sum_fine, mut sum_ovl) = (0.0, 0.0);
    for b in &BENCHMARKS {
        // median of 3 overlay JIT compiles
        let mut ovl = Vec::new();
        let mut kept = None;
        for seed in 1..=3 {
            let jit = JitCompiler::with_options(
                spec.clone(),
                CompileOptions { seed, ..Default::default() },
            );
            let k = jit.compile(b.source).expect("compile");
            ovl.push(k.report.par_time().as_secs_f64());
            kept = Some(k);
        }
        ovl.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let overlay_par = ovl[1];
        let k = kept.unwrap();

        let gates = fpga::techmap(&replicate_dfg(&k.dfg, b.paper.replication)).unwrap();
        let fine = fpga::par(&gates, &FpgaParOptions { effort, ..Default::default() })
            .unwrap();
        let fine_par = fine.par_time.as_secs_f64();
        let speedup = fine_par / overlay_par;
        ratios.push(speedup);
        sum_fine += fine_par;
        sum_ovl += overlay_par;

        t.row(vec![
            format!("{}({})", b.name, b.paper.replication),
            format!("{fine_par:.2}"),
            format!("{:.0}", b.paper.vivado_par_s),
            format!("{overlay_par:.4}"),
            format!("{:.2}", b.paper.overlay_par_s),
            format!("{:.4}", overlay_par * ZYNQ_ARM_SLOWDOWN),
            format!("{:.2}", b.paper.overlay_par_s * ZYNQ_ARM_SLOWDOWN),
            format!("{speedup:.0}x"),
            format!("{:.0}x", b.paper.vivado_par_s / b.paper.overlay_par_s),
        ]);
    }
    println!("{}", t.render());
    let _ = ratios;
    println!(
        "averages: fine-PAR {:.2} s, overlay-PAR {:.4} s -> {:.0}x same-algorithm\n\
         granularity speedup (paper: 275 s vs 0.22 s ≈ 1250x; the remainder of\n\
         the paper's ratio is Vivado's synthesis + timing-driven effort, which\n\
         the fine model intentionally omits — see DESIGN.md §Hardware-Adaptation)",
        sum_fine / 6.0,
        sum_ovl / 6.0,
        (sum_fine / 6.0) / (sum_ovl / 6.0)
    );
}
