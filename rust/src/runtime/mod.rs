//! XLA/PJRT execution backend.
//!
//! Loads the AOT-compiled overlay-datapath emulator
//! (`artifacts/overlay_exec_i32.hlo.txt`, produced once by
//! `make artifacts` from the JAX/Pallas build path) and executes
//! JIT-compiled kernels on it. The emulator's *configuration* —
//! opcodes, operand routing, immediates — is a runtime input tensor,
//! so a single compiled PJRT executable serves every kernel and every
//! replication factor, exactly how the physical overlay decouples
//! 42 µs configuration from offline fabric compilation.
//!
//! HLO **text** is the interchange format (not serialized protos):
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids. See DESIGN.md.
//!
//! Python never runs here: this module is pure Rust + the PJRT C API.
//!
//! **Feature gate:** the PJRT path needs the `xla` crate, which is
//! vendored only in the original AOT build environment. Without the
//! `pjrt` cargo feature this module compiles an API-compatible stub
//! whose constructor returns an error, so every caller (CLI `--backend
//! pjrt`, `Platform::with_pjrt`, the benches) degrades gracefully and
//! the cycle-simulator backend — which the coordinator serves from —
//! remains fully functional.

use std::path::Path;

use anyhow::{Context, Result};

use crate::configgen::EmuGeometry;
use crate::util::JsonValue;

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    use anyhow::{bail, Context, Result};

    use crate::configgen::{EmuGeometry, SlotSchedule};

    /// The PJRT-backed overlay emulator.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        artifacts_dir: PathBuf,
        pub geometry: EmuGeometry,
        executables: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
        /// Reusable host staging buffer for the value table.
        table_scratch: Mutex<Vec<i32>>,
    }

    impl std::fmt::Debug for PjrtRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjrtRuntime")
                .field("artifacts_dir", &self.artifacts_dir)
                .field("geometry", &self.geometry)
                .finish()
        }
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client and validate `artifacts/geometry.json`
        /// against the compiled-in [`EmuGeometry`].
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Arc<Self>> {
            let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
            let geometry = super::read_geometry(&artifacts_dir.join("geometry.json"))
                .context("reading artifacts/geometry.json (run `make artifacts`)")?;
            if geometry != EmuGeometry::DEFAULT {
                bail!(
                    "AOT geometry {:?} does not match the compiled-in {:?} — \
                     regenerate artifacts or rebuild",
                    geometry,
                    EmuGeometry::DEFAULT
                );
            }
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Arc::new(PjrtRuntime {
                client,
                artifacts_dir,
                geometry,
                executables: Mutex::new(HashMap::new()),
                table_scratch: Mutex::new(Vec::new()),
            }))
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile (once, cached) an artifact by stem, e.g.
        /// `overlay_exec_i32`.
        pub fn load(&self, stem: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            let mut cache = self.executables.lock().unwrap();
            if let Some(e) = cache.get(stem) {
                return Ok(e.clone());
            }
            let path = self.artifacts_dir.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("loading HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT-compiling {stem}"))?;
            let exe = Arc::new(exe);
            cache.insert(stem.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute a JIT-compiled kernel configuration over input streams.
        ///
        /// `inputs[p]` is the stream for emulator input column `p`; all
        /// must share a length. Work-items are processed in BATCH-row
        /// chunks (the emulator's static geometry); the tail chunk is
        /// zero-padded and trimmed.
        pub fn execute_overlay(
            &self,
            schedule: &SlotSchedule,
            inputs: &[Vec<i32>],
            n_items: usize,
        ) -> Result<Vec<Vec<i32>>> {
            let geom = self.geometry;
            if inputs.len() != schedule.num_inputs {
                bail!(
                    "kernel has {} input streams, got {}",
                    schedule.num_inputs,
                    inputs.len()
                );
            }
            for (p, v) in inputs.iter().enumerate() {
                if v.len() != n_items {
                    bail!("input stream {p} length {} != {}", v.len(), n_items);
                }
            }

            let exe = self.load("overlay_exec_i32")?;

            // static config literals (shared across chunks)
            let pad = |v: &[i32]| -> Vec<i32> {
                let mut out = vec![0i32; geom.max_fus];
                out[..v.len()].copy_from_slice(v);
                out
            };
            let ops_l = xla::Literal::vec1(&pad(&schedule.ops));
            let sa_l = xla::Literal::vec1(&pad(&schedule.src_a));
            let sb_l = xla::Literal::vec1(&pad(&schedule.src_b));
            let sc_l = xla::Literal::vec1(&pad(&schedule.src_c));

            let n_out = schedule.out_col.len();
            let mut outs: Vec<Vec<i32>> = vec![Vec::with_capacity(n_items); n_out];
            let slots = geom.num_slots();

            let mut table = self.table_scratch.lock().unwrap();
            table.clear();
            table.resize(geom.batch * slots, 0);

            let mut done = 0usize;
            while done < n_items {
                let chunk = (n_items - done).min(geom.batch);
                // build the value table: inputs + immediate pool
                table.iter_mut().for_each(|v| *v = 0);
                for row in 0..chunk {
                    let base = row * slots;
                    for (p, stream) in inputs.iter().enumerate() {
                        table[base + p] = stream[done + row];
                    }
                    for &(col, v) in &schedule.imm_pool {
                        table[base + col] = v;
                    }
                }
                // pad rows still need immediates (harmless but keeps the
                // emulator's semantics identical across rows)
                for row in chunk..geom.batch {
                    let base = row * slots;
                    for &(col, v) in &schedule.imm_pool {
                        table[base + col] = v;
                    }
                }
                let table_l = xla::Literal::vec1(&table[..])
                    .reshape(&[geom.batch as i64, slots as i64])?;

                let result = exe
                    .execute::<xla::Literal>(&[
                        ops_l.clone(),
                        sa_l.clone(),
                        sb_l.clone(),
                        sc_l.clone(),
                        table_l,
                    ])
                    .context("PJRT execute")?[0][0]
                    .to_literal_sync()?;
                let out = result.to_tuple1()?;
                let flat = out.to_vec::<i32>()?; // [batch, max_fus] row-major
                for row in 0..chunk {
                    let base = row * geom.max_fus;
                    for (o, &col) in schedule.out_col.iter().enumerate() {
                        outs[o].push(flat[base + (col - geom.out_base())]);
                    }
                }
                done += chunk;
            }
            Ok(outs)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{bail, Result};

    use crate::configgen::{EmuGeometry, SlotSchedule};

    const UNAVAILABLE: &str = "PJRT backend unavailable: overlay-jit was built without the \
         `pjrt` cargo feature (it requires the vendored `xla` crate); use the cycle-sim \
         backend instead";

    /// API-compatible stub of the PJRT runtime for builds without the
    /// `xla` crate. Construction always fails with a clear message.
    #[derive(Debug)]
    pub struct PjrtRuntime {
        pub geometry: EmuGeometry,
    }

    impl PjrtRuntime {
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Arc<Self>> {
            let _ = artifacts_dir;
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "pjrt-unavailable".to_string()
        }

        pub fn execute_overlay(
            &self,
            schedule: &SlotSchedule,
            inputs: &[Vec<i32>],
            n_items: usize,
        ) -> Result<Vec<Vec<i32>>> {
            let _ = (schedule, inputs, n_items);
            bail!("{UNAVAILABLE}")
        }
    }
}

pub use imp::PjrtRuntime;

#[cfg_attr(not(any(feature = "pjrt", test)), allow(dead_code))]
fn read_geometry(path: &Path) -> Result<EmuGeometry> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let v = JsonValue::parse(&text)?;
    let get = |k: &str| -> Result<usize> {
        v.get(k)
            .and_then(JsonValue::as_i64)
            .map(|x| x as usize)
            .ok_or_else(|| anyhow::anyhow!("geometry.json missing '{k}'"))
    };
    Ok(EmuGeometry {
        num_inputs: get("num_inputs")?,
        max_fus: get("max_fus")?,
        batch: get("batch")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_json_parses_and_matches() {
        // artifacts are produced by `make artifacts` (needs the Python
        // AOT toolchain); skip rather than fail when they are absent.
        if !Path::new("artifacts/geometry.json").exists() {
            eprintln!("skipping geometry_json_parses_and_matches: artifacts not built");
            return;
        }
        let g = read_geometry(Path::new("artifacts/geometry.json")).unwrap();
        assert_eq!(g, EmuGeometry::DEFAULT);
    }

    #[test]
    fn missing_geometry_is_a_clear_error() {
        let err = read_geometry(Path::new("/nonexistent/geometry.json"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("reading"), "{err}");
    }

    #[test]
    fn stub_backend_reports_unavailability() {
        if cfg!(feature = "pjrt") {
            return;
        }
        let err = PjrtRuntime::new("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
