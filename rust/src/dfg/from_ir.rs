//! Optimized IR → DFG extraction (the paper's "IR parser", §III-A.2).
//!
//! Recognized access patterns:
//! * `buf[gid]`        — elementwise stream (input port per buffer);
//! * `buf[gid ± c]`    — stencil tap: each distinct offset becomes its
//!   own input stream (the host runtime aligns the tap when packing
//!   the value table, exactly how streaming overlays realise stencils);
//! * scalar params     — broadcast input streams;
//! * constants         — FU immediates bound to operand ports.
//!
//! Extraction is demand-driven from the `StoreGlobal` roots: address
//! arithmetic (`gid + 1` feeding a GEP) never becomes a dataflow node,
//! matching the paper's DFGs where indexing is absorbed into the
//! stream abstraction.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::ir::{Function, IrBinOp, Op, ValueId};

use super::graph::{Dfg, DfgOp, ImmValue, NodeId, NodeKind};

/// Key identifying one input stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum StreamKey {
    /// (buffer param index, element offset from gid)
    Buffer(usize, i64),
    /// scalar param index
    Scalar(usize),
}

struct Extractor<'f> {
    f: &'f Function,
    g: Dfg,
    streams: HashMap<StreamKey, NodeId>,
    memo: HashMap<ValueId, NodeId>,
}

/// Extract the DFG of an optimized kernel.
pub fn extract_dfg(f: &Function) -> Result<Dfg> {
    let mut ex = Extractor {
        f,
        g: Dfg::new(f.name.clone()),
        streams: HashMap::new(),
        memo: HashMap::new(),
    };
    let mut out_ports: HashMap<(usize, i64), NodeId> = HashMap::new();

    for (i, instr) in f.instrs.iter().enumerate() {
        let Op::StoreGlobal { val, addr } = &instr.op else { continue };
        let (param, off) = ex
            .addr_of(*addr)
            .with_context(|| format!("store %{i} in kernel '{}'", f.name))?;
        let driver = ex
            .node_for(*val)
            .with_context(|| format!("store %{i} in kernel '{}'", f.name))?;
        match out_ports.get(&(param, off)) {
            Some(&existing) => {
                // straight-line overwrite: last store wins
                ex.g.edges.retain(|e| e.dst != existing);
                ex.g.add_edge(driver, existing, 0);
            }
            None => {
                let port = ex.g.output_names.len();
                let pname = &f.params[param].name;
                ex.g.output_names.push(if off == 0 {
                    pname.clone()
                } else {
                    format!("{pname}[{off:+}]")
                });
                ex.g.output_meta.push(crate::dfg::StreamMeta::buffer(param, off));
                let out = ex.g.add_node(NodeKind::OutVar { port });
                ex.g.add_edge(driver, out, 0);
                out_ports.insert((param, off), out);
            }
        }
    }

    if out_ports.is_empty() {
        bail!("kernel '{}' has no global store", f.name);
    }
    let g = ex.g.pruned();
    g.validate()?;
    Ok(g)
}

impl<'f> Extractor<'f> {
    /// Decode a GEP address into (buffer param, gid offset).
    fn addr_of(&self, v: ValueId) -> Result<(usize, i64)> {
        let f = self.f;
        let Op::Gep { base, idx } = f.op(v) else {
            bail!("global access through a non-GEP address");
        };
        let Op::ParamPtr { index } = f.op(*base) else {
            bail!("GEP base is not a kernel buffer parameter");
        };
        let off = match f.op(*idx) {
            Op::GlobalId => 0i64,
            Op::Bin { op: IrBinOp::Add, lhs, rhs } => match (f.op(*lhs), f.op(*rhs)) {
                (Op::GlobalId, Op::ConstInt(c)) => *c,
                (Op::ConstInt(c), Op::GlobalId) => *c,
                _ => bail!(
                    "unsupported index expression: only gid ± const stencil \
                     taps map to overlay streams"
                ),
            },
            Op::Bin { op: IrBinOp::Sub, lhs, rhs } => match (f.op(*lhs), f.op(*rhs)) {
                (Op::GlobalId, Op::ConstInt(c)) => -*c,
                _ => bail!("unsupported index expression"),
            },
            _ => bail!(
                "unsupported index expression: only gid ± const stencil taps \
                 map to overlay streams (data-dependent addressing cannot \
                 stream through the overlay)"
            ),
        };
        Ok((*index, off))
    }

    fn stream(&mut self, key: StreamKey, name: String) -> NodeId {
        if let Some(&n) = self.streams.get(&key) {
            return n;
        }
        let port = self.g.input_names.len();
        self.g.input_names.push(name);
        self.g.input_meta.push(match key {
            StreamKey::Buffer(param, offset) => {
                super::graph::StreamMeta::buffer(param, offset)
            }
            StreamKey::Scalar(param) => super::graph::StreamMeta::scalar(param),
        });
        let n = self.g.add_node(NodeKind::InVar { port });
        self.streams.insert(key, n);
        n
    }

    fn imm_of(&self, v: ValueId) -> Option<ImmValue> {
        match self.f.op(v) {
            Op::ConstInt(c) => Some(ImmValue::Int(*c)),
            Op::ConstFloat(c) => Some(ImmValue::Float(*c)),
            _ => None,
        }
    }

    /// DFG node carrying IR value `v` (built on demand, memoized).
    fn node_for(&mut self, v: ValueId) -> Result<NodeId> {
        if let Some(&n) = self.memo.get(&v) {
            return Ok(n);
        }
        let node = match self.f.op(v).clone() {
            Op::LoadGlobal { addr } => {
                let (param, off) = self.addr_of(addr)?;
                let pname = self.f.params[param].name.clone();
                let name = if off == 0 { pname } else { format!("{pname}[{off:+}]") };
                self.stream(StreamKey::Buffer(param, off), name)
            }
            Op::ParamVal { index } => {
                let name = self.f.params[index].name.clone();
                self.stream(StreamKey::Scalar(index), name)
            }
            Op::Bin { op, lhs, rhs } => self.build_bin(op, lhs, rhs)?,
            // a bare constant used as data (e.g. `B[i] = 5`)
            Op::ConstInt(c) => self.g.add_node(NodeKind::Op {
                op: DfgOp::Nop,
                imm: [Some(ImmValue::Int(c)), None, None],
            }),
            Op::ConstFloat(c) => self.g.add_node(NodeKind::Op {
                op: DfgOp::Nop,
                imm: [Some(ImmValue::Float(c)), None, None],
            }),
            Op::GlobalId => bail!(
                "get_global_id used as a data value — the overlay streams \
                 data, not indices; pass an index buffer instead"
            ),
            other => bail!("value {other:?} has no DFG representation"),
        };
        self.memo.insert(v, node);
        Ok(node)
    }

    fn build_bin(&mut self, op: IrBinOp, lhs: ValueId, rhs: ValueId) -> Result<NodeId> {
        let (dfg_op, l, r) = match op {
            IrBinOp::Add => (DfgOp::Add, lhs, rhs),
            IrBinOp::Mul => (DfgOp::Mul, lhs, rhs),
            IrBinOp::Min => (DfgOp::Min, lhs, rhs),
            IrBinOp::Max => (DfgOp::Max, lhs, rhs),
            IrBinOp::Sub => {
                // Sub(const, x) -> RSUB(a=x, b=const): keeps the streamed
                // operand on a routable port, constant as immediate.
                if self.imm_of(lhs).is_some() && self.imm_of(rhs).is_none() {
                    (DfgOp::Rsub, rhs, lhs)
                } else {
                    (DfgOp::Sub, lhs, rhs)
                }
            }
            IrBinOp::Shl => bail!("unlowered shift-left reached DFG extraction"),
            IrBinOp::Shr => bail!(
                "right shift is not supported: the DSP-block FU has no barrel \
                 shifter (pre-scale on the host)"
            ),
        };

        // Resolve operands *before* allocating the node so the memo sees
        // producers first (keeps node ids topologically friendly).
        let mut imm = [None, None, None];
        let mut edges: Vec<(NodeId, u8)> = Vec::new();
        for (port, v) in [(0u8, l), (1u8, r)] {
            if let Some(c) = self.imm_of(v) {
                imm[port as usize] = Some(c);
            } else {
                edges.push((self.node_for(v)?, port));
            }
        }
        let node = self.g.add_node(NodeKind::Op { op: dfg_op, imm });
        for (src, port) in edges {
            self.g.add_edge(src, node, port);
        }
        Ok(node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, optimize};

    fn dfg_of(src: &str) -> Dfg {
        let f = lower_kernel(&parse_kernel(src).unwrap()).unwrap();
        let (opt, _) = optimize(&f);
        extract_dfg(&opt).unwrap()
    }

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    #[test]
    fn paper_example_has_7_op_nodes() {
        // Table II(a) / Fig 3(a): 5 mul + 1 sub + 1 add, 1 invar, 1 outvar
        let g = dfg_of(PAPER);
        assert_eq!(g.num_ops(), 7);
        assert_eq!(g.num_inputs(), 1);
        assert_eq!(g.num_outputs(), 1);
        let muls = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { op: DfgOp::Mul, .. }))
            .count();
        assert_eq!(muls, 5);
    }

    #[test]
    fn imm_lands_on_mul_node() {
        let g = dfg_of(PAPER);
        // exactly one mul carries Imm_16 (canonicalized to port 1)
        let imm16 = g
            .nodes
            .iter()
            .filter(|n| match &n.kind {
                NodeKind::Op { op: DfgOp::Mul, imm } => {
                    imm.iter().flatten().any(|v| matches!(v, ImmValue::Int(16)))
                }
                _ => false,
            })
            .count();
        assert_eq!(imm16, 1);
    }

    #[test]
    fn stencil_taps_become_distinct_streams() {
        let g = dfg_of(
            "__kernel void stencil(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] + A[i+1] + A[i+2];
             }",
        );
        assert_eq!(g.num_inputs(), 3);
        let mut names = g.input_names.clone();
        names.sort();
        assert_eq!(names, vec!["A", "A[+1]", "A[+2]"]);
    }

    #[test]
    fn same_tap_is_shared() {
        let g = dfg_of(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] * A[i];
             }",
        );
        assert_eq!(g.num_inputs(), 1);
    }

    #[test]
    fn scalar_param_becomes_broadcast_stream() {
        let g = dfg_of(
            "__kernel void k(__global int *A, const int n, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] * n;
             }",
        );
        assert_eq!(g.num_inputs(), 2);
        assert!(g.input_names.contains(&"n".to_string()));
    }

    #[test]
    fn const_minus_x_uses_rsub() {
        let g = dfg_of(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = 100 - A[i];
             }",
        );
        let rsubs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { op: DfgOp::Rsub, .. }))
            .count();
        assert_eq!(rsubs, 1);
    }

    #[test]
    fn constant_store_materializes_nop() {
        let g = dfg_of(
            "__kernel void k(__global int *B) {
                int i = get_global_id(0);
                B[i] = 42;
             }",
        );
        assert_eq!(g.num_ops(), 1);
        assert!(matches!(
            g.nodes[g.op_nodes()[0]].kind,
            NodeKind::Op { op: DfgOp::Nop, .. }
        ));
    }

    #[test]
    fn two_output_buffers_two_ports() {
        let g = dfg_of(
            "__kernel void k(__global int *A, __global int *B, __global int *C) {
                int i = get_global_id(0);
                B[i] = A[i] + 1;
                C[i] = A[i] * 2;
             }",
        );
        assert_eq!(g.num_outputs(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn overwriting_store_keeps_last() {
        let g = dfg_of(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] + 1;
                B[i] = A[i] * 3;
             }",
        );
        assert_eq!(g.num_outputs(), 1);
        g.validate().unwrap();
        // the overwritten add chain is pruned; only the mul survives
        assert_eq!(g.num_ops(), 1);
    }

    #[test]
    fn shared_subexpression_is_one_node() {
        let g = dfg_of(
            "__kernel void k(__global int *A, __global int *B, __global int *C) {
                int i = get_global_id(0);
                int t = A[i] * A[i];
                B[i] = t + 1;
                C[i] = t - 1;
             }",
        );
        let muls = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { op: DfgOp::Mul, .. }))
            .count();
        assert_eq!(muls, 1);
    }

    #[test]
    fn rejects_right_shift() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *A, __global int *B) {
                    int i = get_global_id(0);
                    B[i] = A[i] >> 2;
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let (opt, _) = optimize(&f);
        assert!(extract_dfg(&opt).is_err());
    }

    #[test]
    fn rejects_data_dependent_index() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *A, __global int *B) {
                    int i = get_global_id(0);
                    B[i] = A[A[i]];
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let (opt, _) = optimize(&f);
        assert!(extract_dfg(&opt).is_err());
    }

    #[test]
    fn rejects_gid_as_data() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *B) {
                    int i = get_global_id(0);
                    B[i] = i;
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let (opt, _) = optimize(&f);
        let err = extract_dfg(&opt).unwrap_err().to_string();
        assert!(err.contains("store"), "{err}");
    }
}
