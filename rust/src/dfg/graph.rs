//! DFG data structures and invariants.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Node index within a [`Dfg`].
pub type NodeId = usize;

/// An immediate (compile-time constant) bound to an FU operand port.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ImmValue {
    Int(i64),
    Float(f64),
}

impl ImmValue {
    /// Bit pattern as stored in the value-table immediate column.
    pub fn to_bits_i32(self) -> i32 {
        match self {
            ImmValue::Int(v) => v as i32,
            ImmValue::Float(v) => (v as f32).to_bits() as i32,
        }
    }

    pub fn label(&self) -> String {
        match self {
            ImmValue::Int(v) => format!("{v}"),
            ImmValue::Float(v) => format!("{v}"),
        }
    }
}

/// Operation kinds, 1:1 with the AOT emulator's opcode table
/// (`python/compile/kernels/geometry.py`) and the DSP-block FU modes.
/// `MulAdd`/`MulSub` only appear after the FU-aware transform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DfgOp {
    Nop,
    Add,
    Sub,
    Mul,
    MulAdd,
    MulSub,
    /// `b - a` (subtract with the streamed operand on the right).
    Rsub,
    Max,
    Min,
}

impl DfgOp {
    /// Opcode in the emulator's instruction encoding.
    pub fn opcode(self) -> i32 {
        match self {
            DfgOp::Nop => 0,
            DfgOp::Add => 1,
            DfgOp::Sub => 2,
            DfgOp::Mul => 3,
            DfgOp::MulAdd => 4,
            DfgOp::MulSub => 5,
            DfgOp::Rsub => 6,
            DfgOp::Max => 7,
            DfgOp::Min => 8,
        }
    }

    /// Number of operand ports.
    pub fn arity(self) -> usize {
        match self {
            DfgOp::Nop => 1,
            DfgOp::MulAdd | DfgOp::MulSub => 3,
            _ => 2,
        }
    }

    /// DSP blocks consumed by this op on the physical overlay.
    pub fn dsp_cost(self) -> usize {
        match self {
            // multiply-accumulate fits one DSP48 (the fusion target)
            DfgOp::Mul | DfgOp::MulAdd | DfgOp::MulSub => 1,
            // ALU-mode DSP (add/sub/min/max/pass)
            _ => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DfgOp::Nop => "nop",
            DfgOp::Add => "add",
            DfgOp::Sub => "sub",
            DfgOp::Mul => "mul",
            DfgOp::MulAdd => "mul_add",
            DfgOp::MulSub => "mul_sub",
            DfgOp::Rsub => "rsub",
            DfgOp::Max => "max",
            DfgOp::Min => "min",
        }
    }

    pub fn from_name(s: &str) -> Option<DfgOp> {
        Some(match s {
            "nop" => DfgOp::Nop,
            "add" => DfgOp::Add,
            "sub" => DfgOp::Sub,
            "mul" => DfgOp::Mul,
            "mul_add" => DfgOp::MulAdd,
            "mul_sub" => DfgOp::MulSub,
            "rsub" => DfgOp::Rsub,
            "max" => DfgOp::Max,
            "min" => DfgOp::Min,
            _ => return None,
        })
    }
}

/// Node kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// Kernel input stream (`I<port>` in Table II labels).
    InVar { port: usize },
    /// Kernel output stream (`O<port>`).
    OutVar { port: usize },
    /// FU operation with up to 3 operand ports; a port is fed either by
    /// an edge or by an immediate, never both.
    Op { op: DfgOp, imm: [Option<ImmValue>; 3] },
}

/// A DFG node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub kind: NodeKind,
}

/// A directed edge `src → dst` into operand port `dst_port` of `dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub dst_port: u8,
}

/// Where a stream's data lives in the host's argument list: which
/// kernel parameter it reads/writes and at what element offset from
/// the work-item id (stencil tap). Scalars broadcast one value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamMeta {
    pub param: usize,
    pub offset: i64,
    pub is_scalar: bool,
}

impl StreamMeta {
    pub fn buffer(param: usize, offset: i64) -> Self {
        StreamMeta { param, offset, is_scalar: false }
    }

    pub fn scalar(param: usize) -> Self {
        StreamMeta { param, offset: 0, is_scalar: true }
    }
}

/// The dataflow graph of one kernel (pre-replication).
#[derive(Debug, Clone, Default)]
pub struct Dfg {
    pub name: String,
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    /// Human names of input streams, indexed by `InVar::port`.
    pub input_names: Vec<String>,
    /// Human names of output streams, indexed by `OutVar::port`.
    pub output_names: Vec<String>,
    /// Host binding of each input stream (parallel to `input_names`;
    /// empty for DFGs without host bindings, e.g. parsed from DOT).
    pub input_meta: Vec<StreamMeta>,
    /// Host binding of each output stream.
    pub output_meta: Vec<StreamMeta>,
}

impl Dfg {
    pub fn new(name: impl Into<String>) -> Self {
        Dfg { name: name.into(), ..Default::default() }
    }

    pub fn add_node(&mut self, kind: NodeKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, kind });
        id
    }

    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, dst_port: u8) {
        self.edges.push(Edge { src, dst, dst_port });
    }

    /// Incoming edges of `id`, sorted by destination port.
    pub fn preds(&self, id: NodeId) -> Vec<Edge> {
        let mut v: Vec<Edge> = self.edges.iter().filter(|e| e.dst == id).copied().collect();
        v.sort_by_key(|e| e.dst_port);
        v
    }

    /// Outgoing edges of `id`.
    pub fn succs(&self, id: NodeId) -> Vec<Edge> {
        self.edges.iter().filter(|e| e.src == id).copied().collect()
    }

    /// Ids of operation nodes.
    pub fn op_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { .. }))
            .map(|n| n.id)
            .collect()
    }

    pub fn num_ops(&self) -> usize {
        self.op_nodes().len()
    }

    pub fn num_inputs(&self) -> usize {
        self.input_names.len()
    }

    pub fn num_outputs(&self) -> usize {
        self.output_names.len()
    }

    /// Total I/O streams (the replication limiter next to FU count).
    pub fn num_io(&self) -> usize {
        self.num_inputs() + self.num_outputs()
    }

    /// Table II style label for a node, e.g. `mul_Imm_16_N4`, `I0_N1`.
    pub fn label(&self, id: NodeId) -> String {
        let n = &self.nodes[id];
        match &n.kind {
            NodeKind::InVar { port } => format!("I{port}_N{id}"),
            NodeKind::OutVar { port } => format!("O{port}_N{id}"),
            NodeKind::Op { op, imm } => {
                let imms: Vec<String> = imm
                    .iter()
                    .flatten()
                    .map(|v| format!("Imm_{}", v.label()))
                    .collect();
                if imms.is_empty() {
                    format!("{}_N{id}", op.name())
                } else {
                    format!("{}_{}_N{id}", op.name(), imms.join("_"))
                }
            }
        }
    }

    /// Topological order over all nodes; fails on cycles.
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for e in &self.edges {
            indeg[e.dst] += 1;
            adj[e.src].push(e.dst);
        }
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        queue.sort();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &adj[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() != n {
            bail!("DFG '{}' contains a cycle", self.name);
        }
        Ok(order)
    }

    /// Longest op-path depth (pipeline latency proxy).
    pub fn depth(&self) -> usize {
        let order = self.topo_order().expect("depth of cyclic DFG");
        let mut d: HashMap<NodeId, usize> = HashMap::new();
        let mut max = 0;
        for id in order {
            let is_op = matches!(self.nodes[id].kind, NodeKind::Op { .. });
            let base = self
                .preds(id)
                .iter()
                .map(|e| d.get(&e.src).copied().unwrap_or(0))
                .max()
                .unwrap_or(0);
            let here = base + usize::from(is_op);
            d.insert(id, here);
            max = max.max(here);
        }
        max
    }

    /// Rebuild the graph keeping only nodes with a path to an output
    /// stream (dead op nodes appear when a later store overwrites an
    /// earlier one, or when stencil taps are partially consumed).
    /// Input ports are renumbered densely.
    pub fn pruned(&self) -> Dfg {
        let n = self.nodes.len();
        let mut live = vec![false; n];
        for node in &self.nodes {
            if matches!(node.kind, NodeKind::OutVar { .. }) {
                live[node.id] = true;
            }
        }
        // reverse reachability (iterate: edges are unordered)
        let mut changed = true;
        while changed {
            changed = false;
            for e in &self.edges {
                if live[e.dst] && !live[e.src] {
                    live[e.src] = true;
                    changed = true;
                }
            }
        }

        let mut g = Dfg::new(self.name.clone());
        let mut remap: Vec<Option<NodeId>> = vec![None; n];
        for node in &self.nodes {
            if !live[node.id] {
                continue;
            }
            let kind = match &node.kind {
                NodeKind::InVar { port } => {
                    let new_port = g.input_names.len();
                    g.input_names.push(self.input_names[*port].clone());
                    if let Some(m) = self.input_meta.get(*port) {
                        g.input_meta.push(*m);
                    }
                    NodeKind::InVar { port: new_port }
                }
                NodeKind::OutVar { port } => {
                    let new_port = g.output_names.len();
                    g.output_names.push(self.output_names[*port].clone());
                    if let Some(m) = self.output_meta.get(*port) {
                        g.output_meta.push(*m);
                    }
                    NodeKind::OutVar { port: new_port }
                }
                op => op.clone(),
            };
            remap[node.id] = Some(g.add_node(kind));
        }
        for e in &self.edges {
            if let (Some(s), Some(d)) = (remap[e.src], remap[e.dst]) {
                g.add_edge(s, d, e.dst_port);
            }
        }
        g
    }

    /// Structural validation: port/arity discipline, no dangling edges,
    /// in/out degree rules, acyclicity.
    pub fn validate(&self) -> Result<()> {
        for e in &self.edges {
            if e.src >= self.nodes.len() || e.dst >= self.nodes.len() {
                bail!("dangling edge {:?}", e);
            }
            if matches!(self.nodes[e.dst].kind, NodeKind::InVar { .. }) {
                bail!("edge into invar node N{}", e.dst);
            }
            if matches!(self.nodes[e.src].kind, NodeKind::OutVar { .. }) {
                bail!("edge out of outvar node N{}", e.src);
            }
        }
        for node in &self.nodes {
            match &node.kind {
                NodeKind::InVar { .. } => {}
                NodeKind::OutVar { .. } => {
                    let p = self.preds(node.id);
                    if p.len() != 1 {
                        bail!(
                            "outvar N{} must have exactly one driver (has {})",
                            node.id,
                            p.len()
                        );
                    }
                }
                NodeKind::Op { op, imm } => {
                    let arity = op.arity();
                    let mut covered = vec![false; arity];
                    for e in self.preds(node.id) {
                        let p = e.dst_port as usize;
                        if p >= arity {
                            bail!("N{}: port {} out of range for {}", node.id, p, op.name());
                        }
                        if covered[p] {
                            bail!("N{}: port {} driven twice", node.id, p);
                        }
                        if imm[p].is_some() {
                            bail!("N{}: port {} has both edge and immediate", node.id, p);
                        }
                        covered[p] = true;
                    }
                    for (p, c) in covered.iter().enumerate() {
                        if !c && imm[p].is_none() {
                            bail!("N{}: port {} of {} undriven", node.id, p, op.name());
                        }
                    }
                    for (p, v) in imm.iter().enumerate() {
                        if p >= arity && v.is_some() {
                            bail!("N{}: immediate on out-of-range port {}", node.id, p);
                        }
                    }
                }
            }
        }
        self.topo_order()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build the paper's Fig. 3(a)-equivalent fused DFG (Fig. 3(b)).
    pub(crate) fn paper_fuaware_dfg() -> Dfg {
        let mut g = Dfg::new("example_kernel");
        g.input_names.push("A".into());
        g.output_names.push("B".into());
        let x = g.add_node(NodeKind::InVar { port: 0 });
        let n4 = g.add_node(NodeKind::Op {
            op: DfgOp::Mul,
            imm: [None, Some(ImmValue::Int(16)), None],
        });
        let n5 = g.add_node(NodeKind::Op {
            op: DfgOp::MulSub,
            imm: [None, None, Some(ImmValue::Int(20))],
        });
        let n3 = g.add_node(NodeKind::Op { op: DfgOp::Mul, imm: [None, None, None] });
        let n6 = g.add_node(NodeKind::Op {
            op: DfgOp::MulAdd,
            imm: [None, None, Some(ImmValue::Int(5))],
        });
        let n2 = g.add_node(NodeKind::Op { op: DfgOp::Mul, imm: [None, None, None] });
        let out = g.add_node(NodeKind::OutVar { port: 0 });
        g.add_edge(x, n4, 0); // 16*x
        g.add_edge(n4, n5, 0); // (16x)*x - 20
        g.add_edge(x, n5, 1);
        g.add_edge(n5, n3, 0); // (...)*x
        g.add_edge(x, n3, 1);
        g.add_edge(n3, n6, 0); // (...)*x + 5
        g.add_edge(x, n6, 1);
        g.add_edge(n6, n2, 0); // x*(...)
        g.add_edge(x, n2, 1);
        g.add_edge(n2, out, 0);
        g
    }

    #[test]
    fn paper_dfg_validates() {
        let g = paper_fuaware_dfg();
        g.validate().unwrap();
        assert_eq!(g.num_ops(), 5);
        assert_eq!(g.num_io(), 2);
        assert_eq!(g.depth(), 5);
    }

    #[test]
    fn labels_match_table2_style() {
        let g = paper_fuaware_dfg();
        assert_eq!(g.label(0), "I0_N0");
        assert_eq!(g.label(1), "mul_Imm_16_N1");
        assert_eq!(g.label(2), "mul_sub_Imm_20_N2");
        assert_eq!(g.label(6), "O0_N6");
    }

    #[test]
    fn cycle_is_detected() {
        let mut g = Dfg::new("cyclic");
        let a = g.add_node(NodeKind::Op { op: DfgOp::Add, imm: [None, None, None] });
        let b = g.add_node(NodeKind::Op { op: DfgOp::Add, imm: [None, None, None] });
        g.add_edge(a, b, 0);
        g.add_edge(b, a, 0);
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn undriven_port_is_rejected() {
        let mut g = Dfg::new("bad");
        let x = g.add_node(NodeKind::InVar { port: 0 });
        let n = g.add_node(NodeKind::Op { op: DfgOp::Add, imm: [None, None, None] });
        g.add_edge(x, n, 0);
        // port 1 undriven
        assert!(g.validate().is_err());
    }

    #[test]
    fn double_driven_port_is_rejected() {
        let mut g = Dfg::new("bad2");
        let x = g.add_node(NodeKind::InVar { port: 0 });
        let n = g.add_node(NodeKind::Op {
            op: DfgOp::Add,
            imm: [None, Some(ImmValue::Int(1)), None],
        });
        g.add_edge(x, n, 0);
        g.add_edge(x, n, 1); // collides with immediate
        assert!(g.validate().is_err());
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = paper_fuaware_dfg();
        let order = g.topo_order().unwrap();
        let pos: std::collections::HashMap<_, _> =
            order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for e in &g.edges {
            assert!(pos[&e.src] < pos[&e.dst]);
        }
    }
}
