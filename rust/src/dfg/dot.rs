//! Table II DOT interchange: export and (for tooling/tests) import.
//!
//! Export matches the paper's format:
//! ```text
//! digraph example_kernel {
//!  N1 [ntype="invar", label="I0_N1"];
//!  N4 [ntype="operation", label="mul_Imm_16_N4"];
//!  N9 [ntype="outvar", label="O0_N9"];
//!  N1 -> N4;
//! }
//! ```
//! Port information is carried in an explicit `port` edge attribute on
//! import/export (the paper's figures disambiguate ports visually).

use anyhow::{anyhow, bail, Result};

use super::graph::{Dfg, DfgOp, ImmValue, NodeKind};

/// Render `g` in the Table II DOT dialect.
pub fn to_dot(g: &Dfg) -> String {
    let mut out = String::new();
    out.push_str(&format!("digraph {} {{\n", g.name));
    for node in &g.nodes {
        let ntype = match node.kind {
            NodeKind::InVar { .. } => "invar",
            NodeKind::OutVar { .. } => "outvar",
            NodeKind::Op { .. } => "operation",
        };
        out.push_str(&format!(
            " N{} [ntype=\"{}\", label=\"{}\"];\n",
            node.id,
            ntype,
            g.label(node.id)
        ));
    }
    for e in &g.edges {
        out.push_str(&format!(" N{} -> N{} [port={}];\n", e.src, e.dst, e.dst_port));
    }
    out.push_str("}\n");
    out
}

/// Parse the dialect produced by [`to_dot`].
pub fn parse_dot(text: &str) -> Result<Dfg> {
    let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
    let header = lines.next().ok_or_else(|| anyhow!("empty document"))?;
    let name = header
        .strip_prefix("digraph ")
        .and_then(|s| s.strip_suffix('{'))
        .ok_or_else(|| anyhow!("missing 'digraph <name> {{' header"))?
        .trim()
        .to_string();

    let mut g = Dfg::new(name);
    // collected (id, kind) pairs; node ids in the file may be sparse
    let mut decls: Vec<(usize, NodeKind)> = Vec::new();
    let mut edges: Vec<(usize, usize, u8)> = Vec::new();

    for line in lines {
        if line == "}" {
            break;
        }
        let line = line.trim_end_matches(';');
        if let Some((from, rest)) = line.split_once("->") {
            let src = parse_node_id(from.trim())?;
            let (to, attrs) = match rest.find('[') {
                Some(i) => (&rest[..i], Some(&rest[i..])),
                None => (rest, None),
            };
            let dst = parse_node_id(to.trim())?;
            let port = attrs
                .and_then(|a| a.split("port=").nth(1))
                .and_then(|a| {
                    a.trim_end_matches([']', ' '])
                        .chars()
                        .take_while(|c| c.is_ascii_digit())
                        .collect::<String>()
                        .parse::<u8>()
                        .ok()
                })
                .unwrap_or(0);
            edges.push((src, dst, port));
        } else if let Some(i) = line.find('[') {
            let id = parse_node_id(line[..i].trim())?;
            let attrs = &line[i..];
            let ntype = attr(attrs, "ntype").ok_or_else(|| anyhow!("missing ntype"))?;
            let label = attr(attrs, "label").ok_or_else(|| anyhow!("missing label"))?;
            let kind = kind_from(&ntype, &label, &mut g)?;
            decls.push((id, kind));
        } else {
            bail!("unparseable line: '{line}'");
        }
    }

    // build with dense ids, remembering the file's sparse ids
    let mut remap = std::collections::HashMap::new();
    decls.sort_by_key(|(id, _)| *id);
    for (fid, kind) in decls {
        let nid = g.add_node(kind);
        remap.insert(fid, nid);
    }
    for (s, d, p) in edges {
        let s = *remap.get(&s).ok_or_else(|| anyhow!("edge from undeclared N{s}"))?;
        let d = *remap.get(&d).ok_or_else(|| anyhow!("edge to undeclared N{d}"))?;
        g.add_edge(s, d, p);
    }
    Ok(g)
}

fn parse_node_id(s: &str) -> Result<usize> {
    s.strip_prefix('N')
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| anyhow!("bad node id '{s}'"))
}

fn attr(attrs: &str, key: &str) -> Option<String> {
    let pat = format!("{key}=\"");
    let start = attrs.find(&pat)? + pat.len();
    let end = attrs[start..].find('"')? + start;
    Some(attrs[start..end].to_string())
}

/// Reconstruct a node kind from its `ntype` + Table II label.
fn kind_from(ntype: &str, label: &str, g: &mut Dfg) -> Result<NodeKind> {
    match ntype {
        "invar" => {
            // label I<port>_N<id>
            let port: usize = label
                .strip_prefix('I')
                .and_then(|s| s.split('_').next())
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow!("bad invar label '{label}'"))?;
            while g.input_names.len() <= port {
                g.input_names.push(format!("I{}", g.input_names.len()));
            }
            Ok(NodeKind::InVar { port })
        }
        "outvar" => {
            let port: usize = label
                .strip_prefix('O')
                .and_then(|s| s.split('_').next())
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow!("bad outvar label '{label}'"))?;
            while g.output_names.len() <= port {
                g.output_names.push(format!("O{}", g.output_names.len()));
            }
            Ok(NodeKind::OutVar { port })
        }
        "operation" => {
            // label: <op>(_Imm_<v>)*_N<id>; op names may contain '_'
            let body = label
                .rfind("_N")
                .map(|i| &label[..i])
                .ok_or_else(|| anyhow!("bad op label '{label}'"))?;
            let (op_str, imms) = match body.find("_Imm_") {
                Some(i) => (&body[..i], Some(&body[i..])),
                None => (body, None),
            };
            let op = DfgOp::from_name(op_str)
                .ok_or_else(|| anyhow!("unknown op '{op_str}' in label '{label}'"))?;
            let mut imm = [None, None, None];
            if let Some(imm_str) = imms {
                // immediates bind to the highest free port downward:
                // export writes them in port order; reconstruct to the
                // canonical positions (port 1 for binary, port 2 for FMA
                // unless two imms).
                let values: Vec<ImmValue> = imm_str
                    .split("_Imm_")
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        if s.contains('.') {
                            ImmValue::Float(s.parse().unwrap_or(0.0))
                        } else {
                            ImmValue::Int(s.parse().unwrap_or(0))
                        }
                    })
                    .collect();
                let slots: &[usize] = match (op.arity(), values.len()) {
                    (1, _) => &[0],
                    (2, _) => &[1, 0],
                    (3, 1) => &[2],
                    (3, _) => &[1, 2],
                    _ => &[1],
                };
                for (v, &s) in values.iter().zip(slots.iter()) {
                    imm[s] = Some(*v);
                }
            }
            Ok(NodeKind::Op { op, imm })
        }
        other => bail!("unknown ntype '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, optimize};

    fn paper_dfg() -> Dfg {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void example_kernel(__global int *A, __global int *B) {
                    int idx = get_global_id(0);
                    int x = A[idx];
                    B[idx] = (x*(x*(16*x*x-20)*x+5));
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        super::super::extract_dfg(&optimize(&f).0).unwrap()
    }

    #[test]
    fn export_has_table2_shape() {
        let g = paper_dfg();
        let dot = to_dot(&g);
        assert!(dot.starts_with("digraph example_kernel {"));
        assert!(dot.contains("ntype=\"invar\""));
        assert!(dot.contains("ntype=\"outvar\""));
        assert!(dot.contains("ntype=\"operation\""));
        assert!(dot.contains("mul_Imm_16"));
        assert!(dot.contains("->"));
        assert!(dot.trim_end().ends_with('}'));
    }

    #[test]
    fn dot_round_trip_preserves_structure() {
        let g = paper_dfg();
        let g2 = parse_dot(&to_dot(&g)).unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
        assert_eq!(g.edges.len(), g2.edges.len());
        assert_eq!(g.num_ops(), g2.num_ops());
        assert_eq!(g.num_io(), g2.num_io());
        g2.validate().unwrap();
        // labels survive (up to identical ids after dense rebuild)
        for n in &g.nodes {
            assert_eq!(g.label(n.id), g2.label(n.id));
        }
    }

    #[test]
    fn parses_paper_table2b_style_document() {
        let doc = r#"digraph example_kernel {
             N7 [ntype="outvar", label="O0_N7"];
             N1 [ntype="invar", label="I0_N1"];
             N2 [ntype="operation", label="mul_N2"];
             N4 [ntype="operation", label="mul_Imm_16_N4"];
             N5 [ntype="operation", label="mul_sub_Imm_20_N5"];
             N6 [ntype="operation", label="mul_add_Imm_5_N6"];
             N1 -> N5;
             N1 -> N6 [port=1];
             N1 -> N2 [port=1];
             N1 -> N4;
             N2 -> N7;
             N4 -> N5 [port=1];
             N5 -> N6;
             N6 -> N2;
            }"#;
        let g = parse_dot(doc).unwrap();
        assert_eq!(g.num_ops(), 4);
        assert_eq!(g.num_inputs(), 1);
        assert_eq!(g.num_outputs(), 1);
        let fma = g
            .nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.kind,
                    NodeKind::Op { op: DfgOp::MulAdd, .. } | NodeKind::Op { op: DfgOp::MulSub, .. }
                )
            })
            .count();
        assert_eq!(fma, 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_dot("not a graph").is_err());
        assert!(parse_dot("digraph g {\n N1 -> N2;\n}").is_err()); // undeclared
    }
}
