//! Dataflow-graph extraction — Table II / Fig. 3(a) of the paper.
//!
//! The DFG is the compiler's central structure: nodes are kernel input
//! streams (`invar`), output streams (`outvar`) and FU operations;
//! edges carry one 32-bit value per kernel iteration (the paper's
//! overlay uses 16-bit channels; we model the 32-bit variant the DSP48
//! natively supports — see DESIGN.md). Constants become FU *immediates*
//! (`mul_Imm_16`), not nodes, exactly as in Table II(a).
//!
//! [`extract_dfg`] consumes optimized IR; [`to_dot`]/[`parse_dot`]
//! round-trip the Table II DOT interchange format.

mod dot;
mod from_ir;
mod graph;

pub use dot::{parse_dot, to_dot};
pub use from_ir::extract_dfg;
pub use graph::{Dfg, DfgOp, Edge, ImmValue, Node, NodeId, NodeKind, StreamMeta};
