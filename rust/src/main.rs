//! `overlay-jit` CLI — the leader entry point.
//!
//! ```text
//! overlay-jit info
//! overlay-jit compile <benchmark|file.cl> [--overlay RxC-dspN] [--copies N]
//!                     [--dump-ir] [--dump-dfg] [--emit-netlist] [--seed S]
//! overlay-jit run <benchmark|file.cl> [--overlay ...] [--backend sim|pjrt]
//!                 [--items N] [--artifacts DIR]
//! ```
//!
//! (Hand-rolled argument parsing: the offline build environment only
//! vendors the `xla` crate's dependency closure — no clap.)

use std::process::ExitCode;

use anyhow::{bail, Context as AnyhowContext, Result};

use overlay_jit::bench_kernels;
use overlay_jit::compiler::{CompileOptions, JitCompiler, Replication};
use overlay_jit::dfg::to_dot;
use overlay_jit::ir::print_function;
use overlay_jit::metrics;
use overlay_jit::netlist::emit_netlist;
use overlay_jit::overlay::{FuType, OverlaySpec};
use overlay_jit::prelude::*;
use overlay_jit::util::XorShiftRng;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("info") => cmd_info(),
        Some("compile") => cmd_compile(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("help") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}' (try 'overlay-jit help')"),
    }
}

fn print_usage() {
    println!(
        "overlay-jit — resource-aware JIT OpenCL compiler for coarse-grained \
         FPGA overlays\n\n\
         USAGE:\n  overlay-jit info\n  overlay-jit compile <benchmark|file.cl> \
         [--overlay 8x8-dsp2] [--copies N] [--dump-ir] [--dump-dfg] \
         [--emit-netlist] [--seed S]\n  overlay-jit run <benchmark|file.cl> \
         [--overlay 8x8-dsp2] [--backend sim|pjrt] [--items N] [--artifacts DIR]"
    );
}

/// Parse `8x8-dsp2` style overlay names.
fn parse_overlay(name: &str) -> Result<OverlaySpec> {
    let (grid, fu) = name
        .rsplit_once('-')
        .ok_or_else(|| anyhow::anyhow!("overlay must look like 8x8-dsp2"))?;
    let (r, c) = grid
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("bad grid '{grid}'"))?;
    let fu_type = match fu {
        "dsp1" => FuType::Dsp1,
        "dsp2" => FuType::Dsp2,
        other => bail!("unknown FU type '{other}' (dsp1|dsp2)"),
    };
    Ok(OverlaySpec::new(r.parse()?, c.parse()?, fu_type))
}

fn load_source(what: &str) -> Result<String> {
    if let Some(b) = bench_kernels::by_name(what) {
        return Ok(b.source.to_string());
    }
    if what.ends_with(".cl") {
        return std::fs::read_to_string(what)
            .with_context(|| format!("reading {what}"));
    }
    bail!(
        "'{what}' is neither a benchmark ({}) nor a .cl file",
        bench_kernels::BENCHMARKS
            .iter()
            .map(|b| b.name)
            .collect::<Vec<_>>()
            .join(", ")
    )
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn cmd_info() -> Result<()> {
    println!("benchmarks (replication on 8x8-dsp2, paper Fig. 7):");
    for b in &bench_kernels::BENCHMARKS {
        println!(
            "  {:<10} x{:<3} Vivado {:>5.0} s  overlay {:>5.2} s",
            b.name, b.paper.replication, b.paper.vivado_par_s, b.paper.overlay_par_s
        );
    }
    println!("\noverlay presets: NxM-dsp1 | NxM-dsp2  (2 <= N,M <= 8)");
    let spec = OverlaySpec::zynq_default();
    println!(
        "default: {} — {} FUs, {} DSPs, {} I/O pads, {:.0} MHz, peak {:.1} GOPS, \
         {} slices",
        spec.name(),
        spec.fu_count(),
        spec.dsp_count(),
        spec.io_pads(),
        spec.fmax_mhz(),
        spec.peak_gops(),
        metrics::overlay_slices(&spec),
    );
    Ok(())
}

fn cmd_compile(args: &[String]) -> Result<()> {
    let what = args.first().ok_or_else(|| anyhow::anyhow!("missing kernel"))?;
    let source = load_source(what)?;
    let spec = parse_overlay(flag_value(args, "--overlay").unwrap_or("8x8-dsp2"))?;
    let mut options = CompileOptions::default();
    if let Some(n) = flag_value(args, "--copies") {
        options.replication = Replication::Fixed(n.parse()?);
    }
    if let Some(s) = flag_value(args, "--seed") {
        options.seed = s.parse()?;
    }

    if has_flag(args, "--dump-ir") {
        let ast = overlay_jit::frontend::parse_kernel(&source)?;
        let naive = overlay_jit::ir::lower_kernel(&ast)?;
        println!("; ---- naive IR (Table I(b)) ----\n{}", print_function(&naive));
        let (opt, _) = overlay_jit::ir::optimize(&naive);
        println!("; ---- optimized IR (Table I(c)) ----\n{}", print_function(&opt));
    }

    let jit = JitCompiler::with_options(spec.clone(), options);
    let k = jit.compile(&source)?;

    if has_flag(args, "--dump-dfg") {
        println!("// ---- DFG (Table II(a)) ----\n{}", to_dot(&k.dfg));
        println!("// ---- replicated FU-aware DFG ----\n{}", to_dot(&k.fg.dfg));
    }
    if has_flag(args, "--emit-netlist") {
        println!("{}", emit_netlist(&k.netlist));
    }

    println!("kernel        : {}", k.name);
    println!("overlay       : {}", spec.name());
    println!(
        "replication   : x{} ({}; {} FUs/copy, {} I/O/copy)",
        k.copies(),
        k.plan.limit.name(),
        k.plan.fus_per_copy,
        k.plan.io_per_copy
    );
    println!(
        "mapped        : {} FUs, {} op slots, {} routed wires, {} route iters",
        k.fg.num_fus(),
        k.schedule.n_slots(),
        k.routes.wire_count,
        k.report.route_iterations
    );
    println!(
        "latency       : {} cycles fill, max delay-chain {} (cap {})",
        k.latency.pipeline_depth, k.latency.max_delay_used, spec.delay_chain_max
    );
    println!(
        "bitstream     : {} bytes -> {:.1} us config",
        k.bitstream.byte_size(),
        overlay_jit::overlay::ConfigSizeModel::overlay_config_seconds(
            &spec,
            k.bitstream.byte_size()
        ) * 1e6
    );
    let t = metrics::throughput(&spec, &k);
    println!(
        "throughput    : {:.2} GOPS ({:.0}% of {:.1} GOPS peak)",
        t.gops,
        100.0 * t.utilization,
        t.peak_gops
    );
    println!("-- compile stages --");
    for (name, d) in &k.report.stages {
        println!("  {:<10} {:>10.3} ms", name, d.as_secs_f64() * 1e3);
    }
    println!(
        "  total      {:>10.3} ms (PAR {:.3} ms)",
        k.report.total().as_secs_f64() * 1e3,
        k.report.par_time().as_secs_f64() * 1e3
    );
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let what = args.first().ok_or_else(|| anyhow::anyhow!("missing kernel"))?;
    let source = load_source(what)?;
    let spec = parse_overlay(flag_value(args, "--overlay").unwrap_or("8x8-dsp2"))?;
    let items: usize = flag_value(args, "--items").unwrap_or("65536").parse()?;
    let backend = flag_value(args, "--backend").unwrap_or("sim");
    let artifacts = flag_value(args, "--artifacts").unwrap_or("artifacts");

    let platform = match backend {
        "sim" => Platform::with_device(spec.clone(), Backend::CycleSim),
        "pjrt" => Platform::with_pjrt(artifacts, spec.clone())?,
        other => bail!("unknown backend '{other}' (sim|pjrt)"),
    };
    let ctx = Context::new(&platform.devices()[0]);
    let mut program = Program::from_source(&ctx, &source);
    program.build()?;
    let report = program.build_report.clone().unwrap();
    let name = overlay_jit::frontend::parse_kernel(&source)?.name;
    let kernel = program.create_kernel(&name)?;

    let nparams = kernel.compiled.params.len();
    let mut rng = XorShiftRng::new(7);
    let mut buffers = Vec::new();
    for p in 0..nparams {
        let buf = ctx.create_buffer(items + 16);
        let data: Vec<i32> = (0..items + 16).map(|_| rng.gen_i64(-40, 40) as i32).collect();
        buf.write(&data);
        kernel.set_arg(p, &buf)?;
        buffers.push(buf);
    }
    let queue = CommandQueue::new(&ctx);
    let ev = queue.enqueue_nd_range(&kernel, items)?;

    println!("kernel    : {name} on {} [{backend}]", spec.name());
    println!("items     : {items}");
    println!("build     : {:.3} ms (PAR {:.3} ms)",
        report.total().as_secs_f64() * 1e3,
        report.par_time().as_secs_f64() * 1e3);
    println!("config    : {:.1} us", ev.config_seconds * 1e6);
    println!(
        "exec      : {:.3} ms wall; modeled {} cycles @ {:.0} MHz = {:.3} ms, {:.2} GOPS",
        ev.wall.as_secs_f64() * 1e3,
        ev.modeled.total_cycles,
        spec.fmax_mhz(),
        ev.modeled.seconds * 1e3,
        ev.modeled.gops
    );
    let sample = buffers.last().unwrap().read();
    println!("out[..8]  : {:?}", &sample[..8.min(sample.len())]);
    Ok(())
}
