//! Per-(kernel, spec) load signals: what the scale policy sees.
//!
//! Each (kernel, overlay-spec) pair the fleet serves gets one
//! [`LoadSignal`]: a pair of bounded sliding windows fed from the two
//! ends of a dispatch's life. The **submit side** records the copy
//! demand (`ceil(global_size / target_chunk)`, the router's quantity)
//! and the queue depth observed at routing time; the **completion
//! side** records end-to-end latency and the modeled execution time.
//! A [`SignalSnapshot`] freezes all of it at evaluation time and rides
//! along in the [`crate::autoscale::ScaleEvent`] audit log, so every
//! scaling decision can be replayed from the numbers it was made on.

use crate::metrics::SlidingWindow;

/// Sliding-window load aggregator for one (kernel, spec) pair.
#[derive(Debug, Clone)]
pub struct LoadSignal {
    /// Copies wanted per dispatch (router demand), submit-fed.
    demand: SlidingWindow,
    /// Spec queue depth observed at submit time.
    queue: SlidingWindow,
    /// End-to-end latency (enqueue → completion), milliseconds.
    latency_ms: SlidingWindow,
    /// Modeled II=1 execution time per dispatch, milliseconds — the
    /// "achieved vs. modeled" denominator.
    modeled_ms: SlidingWindow,
    submits: u64,
    completions: u64,
    /// Submits the admission gate refused for this pair — demand the
    /// fleet failed to absorb.
    rejects: u64,
}

/// Frozen view of a [`LoadSignal`] at one evaluation instant.
#[derive(Debug, Clone, Copy)]
pub struct SignalSnapshot {
    /// Submit-side samples currently in the window.
    pub samples: usize,
    /// Mean copies wanted over the window (the hysteresis input).
    pub mean_demand: f64,
    /// Maximum copies wanted over the window (the scale target input —
    /// using the max makes targets a function of the workload phase,
    /// not of how the window straddles a phase boundary).
    pub max_demand: usize,
    /// Mean queue depth observed at submit time.
    pub mean_queue: f64,
    /// Completion-side latency percentiles (0.0 until completions
    /// arrive — completions race submits by design).
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Mean modeled execution milliseconds per dispatch.
    pub mean_modeled_ms: f64,
    /// Lifetime submit / completion counts (not windowed).
    pub submits: u64,
    pub completions: u64,
    /// Lifetime admission rejections fed back into this signal.
    pub rejects: u64,
    /// Fleet-wide interactive windowed p99 at evaluation time,
    /// milliseconds (0.0 when no SLO engine feeds the autoscaler —
    /// the signal plane itself never populates these; the
    /// [`crate::autoscale::Autoscaler`] injects them at evaluation).
    pub slo_p99_ms: f64,
    /// The declared latency-SLO target, milliseconds. A zero target
    /// means "no SLO signal": the policy falls back to demand bands.
    pub slo_target_ms: f64,
}

impl LoadSignal {
    /// A signal whose submit-side windows hold `window` samples (the
    /// policy's evaluation horizon); completion-side windows keep a
    /// few multiples for stabler percentiles.
    pub fn new(window: usize) -> LoadSignal {
        let window = window.max(1);
        LoadSignal {
            demand: SlidingWindow::new(window),
            queue: SlidingWindow::new(window),
            latency_ms: SlidingWindow::new(window * 8),
            modeled_ms: SlidingWindow::new(window * 8),
            submits: 0,
            completions: 0,
            rejects: 0,
        }
    }

    /// Record one routed dispatch (submit side).
    pub fn record_submit(&mut self, demand_copies: usize, queue_depth: usize) {
        self.demand.push(demand_copies as f64);
        self.queue.push(queue_depth as f64);
        self.submits += 1;
    }

    /// Record one submit the admission gate refused. The rejected
    /// demand and the queue depth that provoked the rejection still
    /// enter the windows — refused load is load the fleet failed to
    /// absorb, and it should push scale-up decisions exactly like
    /// admitted load does.
    pub fn record_reject(&mut self, demand_copies: usize, queue_depth: usize) {
        self.demand.push(demand_copies as f64);
        self.queue.push(queue_depth as f64);
        self.rejects += 1;
    }

    /// Record one completed dispatch (worker side).
    pub fn record_complete(&mut self, latency_ms: f64, modeled_ms: f64) {
        self.latency_ms.push(latency_ms);
        self.modeled_ms.push(modeled_ms);
        self.completions += 1;
    }

    /// Whether the submit window is full — the policy never evaluates
    /// a partially observed workload.
    pub fn warmed_up(&self) -> bool {
        self.demand.is_full()
    }

    /// Lifetime admission rejections fed into this signal.
    pub fn rejects(&self) -> u64 {
        self.rejects
    }

    pub fn snapshot(&self) -> SignalSnapshot {
        SignalSnapshot {
            samples: self.demand.len(),
            mean_demand: self.demand.mean(),
            max_demand: self.demand.max().round() as usize,
            mean_queue: self.queue.mean(),
            p50_ms: self.latency_ms.percentile(0.50),
            p99_ms: self.latency_ms.percentile(0.99),
            mean_modeled_ms: self.modeled_ms.mean(),
            submits: self.submits,
            completions: self.completions,
            rejects: self.rejects,
            slo_p99_ms: 0.0,
            slo_target_ms: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_the_window_not_the_lifetime() {
        let mut s = LoadSignal::new(4);
        assert!(!s.warmed_up());
        // 8 submits of demand 16, then 4 of demand 1: the window only
        // sees the last 4
        for _ in 0..8 {
            s.record_submit(16, 2);
        }
        for _ in 0..4 {
            s.record_submit(1, 0);
        }
        assert!(s.warmed_up());
        let snap = s.snapshot();
        assert_eq!(snap.samples, 4);
        assert!((snap.mean_demand - 1.0).abs() < 1e-12);
        assert_eq!(snap.max_demand, 1);
        assert_eq!(snap.mean_queue, 0.0);
        assert_eq!(snap.submits, 12);
        assert_eq!(snap.completions, 0);
    }

    #[test]
    fn completions_feed_latency_percentiles() {
        let mut s = LoadSignal::new(4);
        s.record_submit(2, 1);
        for i in 1..=10 {
            s.record_complete(i as f64, 0.5);
        }
        let snap = s.snapshot();
        assert_eq!(snap.completions, 10);
        assert!(snap.p50_ms >= 5.0 && snap.p50_ms <= 6.0, "{}", snap.p50_ms);
        assert_eq!(snap.p99_ms, 10.0);
        assert!((snap.mean_modeled_ms - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rejections_feed_the_windows_without_counting_as_submits() {
        let mut s = LoadSignal::new(4);
        for _ in 0..4 {
            s.record_reject(8, 3);
        }
        // rejected demand warms the window like admitted demand does
        assert!(s.warmed_up());
        let snap = s.snapshot();
        assert_eq!(snap.rejects, 4);
        assert_eq!(snap.submits, 0);
        assert!((snap.mean_demand - 8.0).abs() < 1e-12);
        assert!((snap.mean_queue - 3.0).abs() < 1e-12);
    }

    #[test]
    fn window_straddling_a_phase_boundary_keeps_the_max() {
        let mut s = LoadSignal::new(4);
        for _ in 0..3 {
            s.record_submit(1, 0);
        }
        s.record_submit(16, 0);
        let snap = s.snapshot();
        // mean is diluted, max is not — targets stay phase-accurate
        assert!(snap.mean_demand < 5.0);
        assert_eq!(snap.max_demand, 16);
    }
}
