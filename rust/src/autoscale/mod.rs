//! Adaptive runtime performance scaling: the feedback loop from
//! observed serving metrics back into the JIT compiler.
//!
//! The paper's premise is that overlay JIT compilation is fast enough
//! to manage kernels *at run time*; everything below
//! [`crate::coordinator`] nevertheless froze each kernel's replication
//! factor at first compile. This module closes the loop:
//!
//! ```text
//!  submit ──▶ router ──▶ shard ──▶ partitions ──▶ completions
//!    │                                                │
//!    │  demand, queue depth                 latency, modeled time
//!    ▼                                                ▼
//!  [LoadSignal per (kernel, spec)]  ◀─────────────────┘
//!    │ window full, cooldown elapsed
//!    ▼
//!  [AutoscalePolicy] — hysteresis bands + queue floors (provably
//!    │                 oscillation-free; see `policy` docs)
//!    ▼ ScaleProposal
//!  background lane ──▶ JitCompiler::compile_at_factor (cache-keyed
//!    │                 per factor: scale-backs are cache **hits**)
//!    ▼
//!  atomic variant swap — in-flight dispatches keep their Arc'd
//!  kernel; the next submit routes, schedules and reconfigures for
//!  the new factor. Every decision lands in the bounded ScaleEvent
//!  audit log, mirroring the fleet's RouteRecord.
//! ```
//!
//! The [`Autoscaler`] owns the signals, the policy state (cooldowns,
//! queue floors, pending flags) and the audit log; the
//! [`crate::coordinator::Coordinator`] owns the background compile
//! lane and calls in from both ends of the dispatch path. Nothing
//! here spawns threads or touches devices, which keeps every scaling
//! decision unit-testable.

mod policy;
mod rescaler;
mod signal;

pub use policy::{AutoscalePolicy, QueueFloor, ScaleDecision, ScaleDirection};
pub use rescaler::{BgTask, Rescaler};
pub use signal::{LoadSignal, SignalSnapshot};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::compiler::ServableKernel;
use crate::coordinator::CacheKey;
use crate::metrics::AutoscaleStats;
use crate::util::BoundedLog;

/// (kernel, spec) pairs tracked at once. Signals are tiny, but the
/// serving layer's memory must stay flat however many distinct
/// sources a long-running fleet sees; past the bound new kernels
/// simply serve at their frozen plan (mirrors the fleet's profile
/// cache bound).
const MAX_TRACKED: usize = 1024;

/// The non-default replication variant currently serving one
/// (kernel, spec) pair. In-flight dispatches hold their own `Arc`, so
/// installing a new variant never invalidates running work.
#[derive(Debug, Clone)]
pub struct ActiveVariant {
    pub factor: usize,
    /// Kernel-cache key of the variant (its options fingerprint embeds
    /// the fixed factor, so per-factor bitstreams coexist in the cache
    /// and per-factor residency is tracked by the slot scheduler).
    pub key: CacheKey,
    pub servable: Arc<ServableKernel>,
}

/// A policy-approved rescale awaiting its background compile.
#[derive(Debug, Clone)]
pub struct ScaleProposal {
    pub kernel: String,
    pub source: String,
    pub source_hash: u64,
    pub spec: String,
    pub spec_fp: u64,
    pub from_factor: usize,
    pub to_factor: usize,
    /// Resource-aware replication bound on this spec; a target equal
    /// to it reverts the kernel to its default (plan-factor) artifact.
    pub ceiling: usize,
    pub direction: ScaleDirection,
    /// Whether queue pressure (not demand alone) drove the decision.
    pub queue_triggered: bool,
    /// The signal the decision was made from.
    pub trigger: SignalSnapshot,
}

/// Terminal outcome of a proposal.
#[derive(Debug, Clone)]
pub enum ScaleOutcome {
    /// The variant compiled (or was already cached) and now serves.
    Applied {
        /// The target factor's artifact was already resident in the
        /// kernel cache — no JIT was paid.
        cache_hit: bool,
        /// Wall seconds the background lane spent on this rescale.
        compile_seconds: f64,
    },
    /// The background compile failed; the previous factor keeps
    /// serving and the cooldown delays a retry.
    Failed { error: String },
}

/// One audited scaling decision — the autoscaler's analogue of the
/// fleet's [`crate::fleet::RouteRecord`].
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    /// Monotone sequence number (gaps impossible; the log is bounded
    /// but `dropped` says how many events fell off the end).
    pub seq: u64,
    pub kernel: String,
    pub source_hash: u64,
    pub spec: String,
    pub spec_fp: u64,
    pub from_factor: usize,
    pub to_factor: usize,
    pub direction: ScaleDirection,
    pub queue_triggered: bool,
    /// The load signal the policy evaluated.
    pub trigger: SignalSnapshot,
    pub outcome: ScaleOutcome,
}

/// Submit-side observation handed to [`Autoscaler::note_submit`].
#[derive(Debug, Clone, Copy)]
pub struct SubmitObservation<'a> {
    pub kernel: &'a str,
    pub source: &'a str,
    pub source_hash: u64,
    pub spec: &'a str,
    pub spec_fp: u64,
    /// Copies this dispatch wants (the router's demand).
    pub demand: usize,
    /// Shallowest queue among the serving spec's partitions.
    pub queue_depth: usize,
    /// Factor the dispatch is actually served at.
    pub factor: usize,
    /// Resource-aware replication ceiling on the serving spec.
    pub ceiling: usize,
}

struct KernelScaleState {
    source: String,
    kernel: String,
    signal: LoadSignal,
    active: Option<ActiveVariant>,
    /// A proposal is in the background lane; suppress re-evaluation
    /// until it lands.
    pending: bool,
    /// Submits since the last applied/failed event (`None` before the
    /// first event — the first evaluation is gated by the window
    /// alone).
    since_event: Option<usize>,
    floor: Option<QueueFloor>,
}

struct EventLog {
    events: BoundedLog<ScaleEvent>,
    seq: u64,
    ups: u64,
    downs: u64,
    failed: u64,
    cache_hits: u64,
    compile_seconds: f64,
}

impl EventLog {
    fn new(capacity: usize) -> EventLog {
        EventLog {
            events: BoundedLog::new(capacity),
            seq: 0,
            ups: 0,
            downs: 0,
            failed: 0,
            cache_hits: 0,
            compile_seconds: 0.0,
        }
    }
}

/// The feedback-driven autoscaler. Shared (`Arc`) between the
/// coordinator's submit path, its partition workers and its
/// background rescale lane.
pub struct Autoscaler {
    policy: AutoscalePolicy,
    state: Mutex<HashMap<(u64, u64), KernelScaleState>>,
    log: Mutex<EventLog>,
    /// Fleet-wide SLO burn rate (`f64` bits), pushed by
    /// [`crate::coordinator::Coordinator::slo_tick`]. A burn ≥ 1.0
    /// means an objective is spending its error budget faster than it
    /// accrues; `note_submit` then treats every warmed-up kernel as
    /// queue-bound so the existing queue-triggered scale-up path (and
    /// its anti-flap floor machinery) fires even when raw queue
    /// depths look shallow.
    slo_burn_bits: AtomicU64,
    /// Fleet-wide interactive windowed p99 (`f64` bits), pushed by
    /// [`crate::coordinator::Coordinator::slo_tick`] from the SLO
    /// engine. Injected into every evaluation snapshot so the policy
    /// runs in SLO-targeted mode (see
    /// [`AutoscalePolicy::slo_clear_ratio`]).
    slo_p99_bits: AtomicU64,
    /// The declared latency-SLO target (`f64` bits); zero disarms
    /// SLO-targeted mode and the demand bands rule as before.
    slo_target_bits: AtomicU64,
}

impl std::fmt::Debug for Autoscaler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // lock order everywhere is state → log (see `stats`)
        let tracked = self.state.lock().unwrap().len();
        let log = self.log.lock().unwrap();
        f.debug_struct("Autoscaler")
            .field("tracked", &tracked)
            .field("events", &(log.ups + log.downs + log.failed))
            .finish()
    }
}

impl Autoscaler {
    /// Build an autoscaler around a validated policy (the coordinator
    /// calls [`AutoscalePolicy::validate`] first).
    pub fn new(policy: AutoscalePolicy) -> Autoscaler {
        let log = Mutex::new(EventLog::new(policy.max_events));
        Autoscaler {
            policy,
            state: Mutex::new(HashMap::new()),
            log,
            slo_burn_bits: AtomicU64::new(0.0f64.to_bits()),
            slo_p99_bits: AtomicU64::new(0.0f64.to_bits()),
            slo_target_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Update the fleet-wide SLO burn rate. Non-finite and negative
    /// values are treated as "not burning" so a pathological objective
    /// can never wedge the autoscaler into permanent scale-up.
    pub fn set_slo_burn(&self, burn: f64) {
        let burn = if burn.is_finite() { burn.max(0.0) } else { 0.0 };
        self.slo_burn_bits.store(burn.to_bits(), Ordering::Relaxed);
    }

    /// The last SLO burn rate pushed via [`Autoscaler::set_slo_burn`].
    pub fn slo_burn(&self) -> f64 {
        f64::from_bits(self.slo_burn_bits.load(Ordering::Relaxed))
    }

    /// Update the latency control signal: the fleet-wide interactive
    /// windowed p99 and the declared SLO target, both in milliseconds.
    /// A non-finite or non-positive target disarms SLO-targeted mode
    /// (the policy falls back to demand bands); a non-finite p99 is
    /// treated as 0.0 (healthy) so a pathological histogram can never
    /// wedge the fleet into permanent scale-up.
    pub fn set_slo_latency(&self, p99_ms: f64, target_ms: f64) {
        let p99 = if p99_ms.is_finite() { p99_ms.max(0.0) } else { 0.0 };
        let target = if target_ms.is_finite() { target_ms.max(0.0) } else { 0.0 };
        self.slo_p99_bits.store(p99.to_bits(), Ordering::Relaxed);
        self.slo_target_bits.store(target.to_bits(), Ordering::Relaxed);
    }

    /// The last latency control signal pushed via
    /// [`Autoscaler::set_slo_latency`]: `(p99_ms, target_ms)`.
    pub fn slo_latency(&self) -> (f64, f64) {
        (
            f64::from_bits(self.slo_p99_bits.load(Ordering::Relaxed)),
            f64::from_bits(self.slo_target_bits.load(Ordering::Relaxed)),
        )
    }

    pub fn policy(&self) -> &AutoscalePolicy {
        &self.policy
    }

    /// The variant currently serving a (kernel, spec), if the factor
    /// has been moved off the frozen plan.
    pub fn active(&self, source_hash: u64, spec_fp: u64) -> Option<ActiveVariant> {
        self.state
            .lock()
            .unwrap()
            .get(&(source_hash, spec_fp))
            .and_then(|s| s.active.clone())
    }

    /// [`Autoscaler::active`] for every spec of a fleet in one lock
    /// acquisition — the submit hot path calls this once per dispatch
    /// instead of once per shard.
    pub fn active_all(&self, source_hash: u64, spec_fps: &[u64]) -> Vec<Option<ActiveVariant>> {
        let state = self.state.lock().unwrap();
        spec_fps
            .iter()
            .map(|&fp| state.get(&(source_hash, fp)).and_then(|s| s.active.clone()))
            .collect()
    }

    /// Record one routed dispatch and evaluate the policy. Returns a
    /// proposal when the load has persistently crossed a hysteresis
    /// band; the caller owns executing it (background compile + an
    /// eventual [`Autoscaler::install`] / [`Autoscaler::fail`]).
    pub fn note_submit(&self, obs: &SubmitObservation) -> Option<ScaleProposal> {
        let mut state = self.state.lock().unwrap();
        let key = (obs.source_hash, obs.spec_fp);
        if !state.contains_key(&key) && state.len() >= MAX_TRACKED {
            return None;
        }
        let st = state.entry(key).or_insert_with(|| KernelScaleState {
            source: obs.source.to_string(),
            kernel: obs.kernel.to_string(),
            signal: LoadSignal::new(self.policy.window),
            active: None,
            pending: false,
            since_event: None,
            floor: None,
        });
        st.signal.record_submit(obs.demand, obs.queue_depth);
        if let Some(n) = st.since_event.as_mut() {
            *n += 1;
        }
        if st.pending || !st.signal.warmed_up() {
            return None;
        }
        if st.since_event.is_some_and(|n| n < self.policy.cooldown) {
            return None;
        }
        let mut snapshot = st.signal.snapshot();
        // arm SLO-targeted mode: the policy sees the fleet-wide
        // windowed p99 vs target next to the per-kernel load windows
        snapshot.slo_p99_ms =
            f64::from_bits(self.slo_p99_bits.load(Ordering::Relaxed));
        snapshot.slo_target_ms =
            f64::from_bits(self.slo_target_bits.load(Ordering::Relaxed));
        let burn = f64::from_bits(self.slo_burn_bits.load(Ordering::Relaxed));
        if burn >= 1.0 && snapshot.mean_queue < self.policy.queue_hi {
            // burning error budget == latency objective failing: act
            // as if the queue crossed `queue_hi` so the queue-up path
            // (at-least-doubling toward the ceiling) takes over even
            // while per-kernel queues still look shallow
            snapshot.mean_queue = self.policy.queue_hi;
        }
        let decision =
            self.policy
                .evaluate(&snapshot, obs.factor, obs.ceiling, &mut st.floor)?;
        // (the queue floor a queue-triggered up ratchets is recorded
        // in `install`, once the rescale actually lands — a failed
        // compile must not leave a floor that blocks scale-downs)
        st.pending = true;
        Some(ScaleProposal {
            kernel: st.kernel.clone(),
            source: st.source.clone(),
            source_hash: obs.source_hash,
            spec: obs.spec.to_string(),
            spec_fp: obs.spec_fp,
            from_factor: obs.factor,
            to_factor: decision.target,
            ceiling: obs.ceiling,
            direction: decision.direction,
            queue_triggered: decision.queue_triggered,
            trigger: snapshot,
        })
    }

    /// Record one submit the admission gate refused. Rejected demand
    /// feeds the same load signal as admitted demand — a kernel hot
    /// enough to be turned away is exactly the kernel re-replication
    /// should relieve — but never proposes a rescale itself: proposals
    /// stay on the admitted path, where the cooldown accounting lives.
    pub fn note_reject(&self, obs: &SubmitObservation) {
        let mut state = self.state.lock().unwrap();
        let key = (obs.source_hash, obs.spec_fp);
        if !state.contains_key(&key) && state.len() >= MAX_TRACKED {
            return;
        }
        let st = state.entry(key).or_insert_with(|| KernelScaleState {
            source: obs.source.to_string(),
            kernel: obs.kernel.to_string(),
            signal: LoadSignal::new(self.policy.window),
            active: None,
            pending: false,
            since_event: None,
            floor: None,
        });
        st.signal.record_reject(obs.demand, obs.queue_depth);
    }

    /// Record one completed dispatch (worker side): end-to-end latency
    /// and the modeled execution time.
    pub fn note_complete(
        &self,
        source_hash: u64,
        spec_fp: u64,
        latency_ms: f64,
        modeled_ms: f64,
    ) {
        if let Some(st) = self.state.lock().unwrap().get_mut(&(source_hash, spec_fp)) {
            st.signal.record_complete(latency_ms, modeled_ms);
        }
    }

    /// Atomically swap the served variant after a successful
    /// background compile. A target equal to the spec's plan ceiling
    /// reverts to the default artifact (no variant entry — the base
    /// cache key serves again). In-flight dispatches are untouched:
    /// they hold their own `Arc` to whatever kernel they were bound
    /// to.
    pub fn install(
        &self,
        proposal: &ScaleProposal,
        servable: Arc<ServableKernel>,
        key: CacheKey,
        cache_hit: bool,
        compile_seconds: f64,
    ) {
        {
            let mut state = self.state.lock().unwrap();
            if let Some(st) = state.get_mut(&(proposal.source_hash, proposal.spec_fp)) {
                st.active = if proposal.to_factor == proposal.ceiling {
                    None
                } else {
                    Some(ActiveVariant {
                        factor: proposal.to_factor,
                        key,
                        servable,
                    })
                };
                if proposal.queue_triggered {
                    // the pre-scale factor was observed queue-bound:
                    // ratchet the anti-flap floor, tagged with the
                    // demand regime the queueing belonged to
                    st.floor = Some(QueueFloor {
                        min_factor: proposal.from_factor + 1,
                        demand_at_set: proposal.trigger.mean_demand,
                    });
                }
                st.pending = false;
                st.since_event = Some(0);
            }
        }
        let mut log = self.log.lock().unwrap();
        match proposal.direction {
            ScaleDirection::Up => log.ups += 1,
            ScaleDirection::Down => log.downs += 1,
        }
        if cache_hit {
            log.cache_hits += 1;
        }
        log.compile_seconds += compile_seconds;
        let outcome = ScaleOutcome::Applied { cache_hit, compile_seconds };
        Self::push_event(&mut log, proposal, outcome);
    }

    /// Record a failed background compile: the previous factor keeps
    /// serving, the cooldown delays a retry.
    pub fn fail(&self, proposal: &ScaleProposal, error: &str) {
        {
            let mut state = self.state.lock().unwrap();
            if let Some(st) = state.get_mut(&(proposal.source_hash, proposal.spec_fp)) {
                st.pending = false;
                st.since_event = Some(0);
            }
        }
        let mut log = self.log.lock().unwrap();
        log.failed += 1;
        let outcome = ScaleOutcome::Failed { error: error.to_string() };
        Self::push_event(&mut log, proposal, outcome);
    }

    fn push_event(log: &mut EventLog, p: &ScaleProposal, outcome: ScaleOutcome) {
        let seq = log.seq;
        log.seq += 1;
        log.events.push(ScaleEvent {
            seq,
            kernel: p.kernel.clone(),
            source_hash: p.source_hash,
            spec: p.spec.clone(),
            spec_fp: p.spec_fp,
            from_factor: p.from_factor,
            to_factor: p.to_factor,
            direction: p.direction,
            queue_triggered: p.queue_triggered,
            trigger: p.trigger,
            outcome,
        });
    }

    /// The retained scale events (oldest first, bounded by
    /// [`AutoscalePolicy::max_events`]).
    pub fn events(&self) -> Vec<ScaleEvent> {
        self.log.lock().unwrap().events.items().to_vec()
    }

    pub fn stats(&self) -> AutoscaleStats {
        let state = self.state.lock().unwrap();
        let log = self.log.lock().unwrap();
        AutoscaleStats {
            scale_ups: log.ups,
            scale_downs: log.downs,
            failed_rescales: log.failed,
            rescale_cache_hits: log.cache_hits,
            rescale_compile_seconds: log.compile_seconds,
            active_variants: state.values().filter(|s| s.active.is_some()).count(),
            tracked_kernels: state.len(),
            events_dropped: log.events.dropped(),
            admission_rejects: state.values().map(|s| s.signal.rejects()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::JitCompiler;
    use crate::overlay::{FuType, OverlaySpec};

    fn servable() -> Arc<ServableKernel> {
        let jit = JitCompiler::new(OverlaySpec::new(4, 4, FuType::Dsp2));
        Arc::new(jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap().servable())
    }

    fn obs(demand: usize, factor: usize) -> SubmitObservation<'static> {
        SubmitObservation {
            kernel: "chebyshev",
            source: crate::bench_kernels::CHEBYSHEV,
            source_hash: 7,
            spec: "8x8-dsp2",
            spec_fp: 0xA,
            demand,
            queue_depth: 0,
            factor,
            ceiling: 16,
        }
    }

    fn policy4() -> AutoscalePolicy {
        AutoscalePolicy { window: 4, cooldown: 4, ..Default::default() }
    }

    #[test]
    fn proposals_wait_for_a_full_window_and_respect_pending() {
        let a = Autoscaler::new(policy4());
        // three under-provisioned submits: window not full yet
        for _ in 0..3 {
            assert!(a.note_submit(&obs(1, 16)).is_none());
        }
        let p = a.note_submit(&obs(1, 16)).expect("fourth submit fills the window");
        assert_eq!(p.direction, ScaleDirection::Down);
        assert_eq!((p.from_factor, p.to_factor), (16, 1));
        assert_eq!(p.trigger.samples, 4);
        // pending: no second proposal until the first lands
        assert!(a.note_submit(&obs(1, 16)).is_none());
        let k = CacheKey { source: 7, spec: 0xA, options: 1 };
        a.install(&p, servable(), k, false, 0.25);
        let v = a.active(7, 0xA).expect("variant active after install");
        assert_eq!(v.factor, 1);
        assert_eq!(v.key, k);
        // the batched lookup agrees with the per-spec one
        let all = a.active_all(7, &[0xA, 0xB]);
        assert_eq!(all[0].as_ref().map(|v| v.factor), Some(1));
        assert!(all[1].is_none());
        let s = a.stats();
        assert_eq!(s.scale_downs, 1);
        assert_eq!(s.active_variants, 1);
        assert!((s.rescale_compile_seconds - 0.25).abs() < 1e-12);
        // cooldown: the next 3 submits cannot re-propose
        for _ in 0..3 {
            assert!(a.note_submit(&obs(1, 1)).is_none());
        }
    }

    #[test]
    fn slo_burn_promotes_a_scale_up_that_load_alone_would_not() {
        let a = Autoscaler::new(policy4());
        // steady demand exactly at the provisioned factor, empty
        // queues: a fixed point for the pure load policy
        for _ in 0..4 {
            assert!(a.note_submit(&obs(4, 4)).is_none());
        }
        assert_eq!(a.slo_burn(), 0.0);
        // an objective burning budget at 2x flips the same load to
        // the queue-triggered up path (at-least-doubling)
        a.set_slo_burn(2.0);
        assert_eq!(a.slo_burn(), 2.0);
        let p = a.note_submit(&obs(4, 4)).expect("burning SLO proposes a scale-up");
        assert_eq!(p.direction, ScaleDirection::Up);
        assert!(p.queue_triggered);
        assert_eq!((p.from_factor, p.to_factor), (4, 8));
        // non-finite / negative burns are sanitized to "not burning"
        a.set_slo_burn(f64::NAN);
        assert_eq!(a.slo_burn(), 0.0);
        a.set_slo_burn(-3.0);
        assert_eq!(a.slo_burn(), 0.0);
    }

    #[test]
    fn installing_the_ceiling_factor_reverts_to_the_default_artifact() {
        let a = Autoscaler::new(policy4());
        for _ in 0..4 {
            let _ = a.note_submit(&obs(1, 16));
        }
        let down = a.events(); // no events yet — proposals aren't events
        assert!(down.is_empty());
        let p = ScaleProposal {
            kernel: "chebyshev".into(),
            source: crate::bench_kernels::CHEBYSHEV.into(),
            source_hash: 7,
            spec: "8x8-dsp2".into(),
            spec_fp: 0xA,
            from_factor: 1,
            to_factor: 16,
            ceiling: 16,
            direction: ScaleDirection::Up,
            queue_triggered: false,
            trigger: LoadSignal::new(4).snapshot(),
        };
        let k = CacheKey { source: 7, spec: 0xA, options: 0 };
        a.install(&p, servable(), k, true, 0.0);
        assert!(a.active(7, 0xA).is_none(), "ceiling install clears the variant");
        let s = a.stats();
        assert_eq!(s.scale_ups, 1);
        assert_eq!(s.rescale_cache_hits, 1);
        assert_eq!(s.active_variants, 0);
    }

    #[test]
    fn failed_rescales_keep_serving_and_audit_the_error() {
        let a = Autoscaler::new(policy4());
        let mut p = None;
        for _ in 0..4 {
            p = a.note_submit(&obs(1, 16));
        }
        let p = p.unwrap();
        a.fail(&p, "placement exploded");
        assert!(a.active(7, 0xA).is_none());
        let events = a.events();
        assert_eq!(events.len(), 1);
        assert!(matches!(
            &events[0].outcome,
            ScaleOutcome::Failed { error } if error.contains("placement")
        ));
        assert_eq!(a.stats().failed_rescales, 1);
        // the cooldown now gates a retry
        assert!(a.note_submit(&obs(1, 16)).is_none());
    }

    #[test]
    fn event_log_is_bounded_with_monotone_sequence_numbers() {
        let mut policy = policy4();
        policy.max_events = 2;
        let a = Autoscaler::new(policy);
        let k = CacheKey { source: 7, spec: 0xA, options: 1 };
        for round in 0..5usize {
            // alternate factors so a proposal fires each round
            let (factor, _want) = if round % 2 == 0 { (16, 1) } else { (1, 16) };
            let demand = if round % 2 == 0 { 1 } else { 16 };
            let mut p = None;
            for _ in 0..8 {
                if let Some(got) = a.note_submit(&obs(demand, factor)) {
                    p = Some(got);
                }
            }
            let p = p.expect("each phase crosses a band");
            a.install(&p, servable(), k, round > 0, 0.0);
        }
        let events = a.events();
        assert_eq!(events.len(), 2, "log bounded at max_events");
        assert_eq!(events[0].seq, 0);
        assert_eq!(events[1].seq, 1);
        let s = a.stats();
        assert_eq!(s.events_dropped, 3);
        assert_eq!(s.applied(), 5);
    }
}
