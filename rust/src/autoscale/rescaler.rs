//! The background compile lane: rescales (and periodic snapshots)
//! happen off the dispatch path.
//!
//! A [`super::ScaleProposal`] costs a JIT compile — seconds-class, per
//! the paper — so executing it inline would stall the very dispatch
//! stream that triggered it. The [`Rescaler`] owns one background
//! thread and a closeable task queue: the coordinator pushes
//! [`BgTask::Rescale`] when the policy fires and [`BgTask::Snapshot`]
//! on the [`crate::coordinator::CoordinatorConfig::snapshot_every`]
//! cadence; the thread compiles the variant on the owning shard
//! (scale-backs to a previously compiled factor are kernel-cache
//! **hits**) and atomically installs it through
//! [`super::Autoscaler::install`]. Serving never blocks: until the
//! install lands, dispatches keep riding the previous factor.
//!
//! [`Rescaler::drain`] blocks until the lane is empty *and* idle —
//! the hook tests and phase-shifting drivers use to make swap timing
//! deterministic.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use crate::fleet::Fleet;

use super::{Autoscaler, ScaleProposal};

/// Work items of the background lane.
#[derive(Debug)]
pub enum BgTask {
    /// Compile `to_factor` on the owning shard and swap it in.
    Rescale(ScaleProposal),
    /// Flush every shard's kernel cache to the snapshot directory.
    Snapshot,
}

struct BgState {
    queue: VecDeque<BgTask>,
    busy: bool,
    closed: bool,
}

struct BgQueue {
    state: Mutex<BgState>,
    cv: Condvar,
    /// Signalled whenever the lane becomes empty and idle.
    idle_cv: Condvar,
}

/// The background worker: one thread, one task queue, shared counters.
pub struct Rescaler {
    queue: Arc<BgQueue>,
    join: Option<thread::JoinHandle<()>>,
    snapshots_written: Arc<AtomicU64>,
    snapshot_errors: Arc<AtomicU64>,
}

impl std::fmt::Debug for Rescaler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Rescaler")
            .field("queued", &self.queue.state.lock().unwrap().queue.len())
            .finish()
    }
}

impl Rescaler {
    /// Spawn the lane. `autoscaler` handles rescale installs (may be
    /// absent when the lane only snapshots); `snapshot_dir` receives
    /// [`BgTask::Snapshot`] flushes.
    pub fn spawn(
        fleet: Arc<Fleet>,
        autoscaler: Option<Arc<Autoscaler>>,
        snapshot_dir: Option<PathBuf>,
    ) -> Rescaler {
        let queue = Arc::new(BgQueue {
            state: Mutex::new(BgState {
                queue: VecDeque::new(),
                busy: false,
                closed: false,
            }),
            cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let snapshots_written = Arc::new(AtomicU64::new(0));
        let snapshot_errors = Arc::new(AtomicU64::new(0));
        let worker_queue = queue.clone();
        let written = snapshots_written.clone();
        let errors = snapshot_errors.clone();
        let join = thread::Builder::new()
            .name("overlay-rescale".into())
            .spawn(move || {
                bg_loop(worker_queue, fleet, autoscaler, snapshot_dir, written, errors)
            })
            .expect("spawning background rescale thread");
        Rescaler { queue, join: Some(join), snapshots_written, snapshot_errors }
    }

    /// Enqueue a task; silently dropped after close, and anything
    /// still queued when the lane closes is discarded unrun (shutdown
    /// is in progress — there is nothing useful left to rescale, and
    /// a final snapshot is the caller's explicit
    /// [`crate::coordinator::Coordinator::save_snapshot`]).
    pub fn push(&self, task: BgTask) {
        let mut s = self.queue.state.lock().unwrap();
        if s.closed {
            return;
        }
        s.queue.push_back(task);
        drop(s);
        self.queue.cv.notify_one();
    }

    /// Block until the lane is empty and idle — every pushed rescale
    /// has installed (or failed) and every snapshot has flushed.
    pub fn drain(&self) {
        let mut s = self.queue.state.lock().unwrap();
        while !s.queue.is_empty() || s.busy {
            s = self.queue.idle_cv.wait(s).unwrap();
        }
    }

    /// Snapshot flushes completed by the lane.
    pub fn snapshots_written(&self) -> u64 {
        self.snapshots_written.load(Ordering::Relaxed)
    }

    /// Snapshot flushes that errored (disk trouble; serving is
    /// unaffected).
    pub fn snapshot_errors(&self) -> u64 {
        self.snapshot_errors.load(Ordering::Relaxed)
    }
}

impl Drop for Rescaler {
    fn drop(&mut self) {
        {
            let mut s = self.queue.state.lock().unwrap();
            s.closed = true;
        }
        self.queue.cv.notify_all();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn bg_loop(
    queue: Arc<BgQueue>,
    fleet: Arc<Fleet>,
    autoscaler: Option<Arc<Autoscaler>>,
    snapshot_dir: Option<PathBuf>,
    snapshots_written: Arc<AtomicU64>,
    snapshot_errors: Arc<AtomicU64>,
) {
    loop {
        let task = {
            let mut s = queue.state.lock().unwrap();
            loop {
                // closed is checked BEFORE popping: whatever is still
                // queued at shutdown is discarded, not compiled — a
                // seconds-class rescale whose result nobody will ever
                // serve must not stall Coordinator::drop
                if s.closed {
                    return;
                }
                if let Some(t) = s.queue.pop_front() {
                    s.busy = true;
                    break t;
                }
                s = queue.cv.wait(s).unwrap();
            }
        };
        match task {
            BgTask::Rescale(p) => run_rescale(&fleet, autoscaler.as_deref(), p),
            BgTask::Snapshot => {
                if let Some(dir) = &snapshot_dir {
                    match fleet.save_snapshot(dir) {
                        Ok(_) => {
                            snapshots_written.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            snapshot_errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
        let mut s = queue.state.lock().unwrap();
        s.busy = false;
        if s.queue.is_empty() {
            queue.idle_cv.notify_all();
        }
    }
}

/// Execute one rescale: cache-or-compile the target factor on the
/// owning shard, then swap. A target equal to the spec's plan ceiling
/// compiles through the shard's default path, so "scale back up to
/// the plan" hits the very first artifact the kernel ever compiled.
fn run_rescale(fleet: &Fleet, autoscaler: Option<&Autoscaler>, p: ScaleProposal) {
    let Some(autoscaler) = autoscaler else {
        return;
    };
    let t0 = Instant::now();
    let result = match fleet.shard_index(p.spec_fp) {
        None => Err(anyhow::anyhow!(
            "no shard with spec fingerprint {:#018x}",
            p.spec_fp
        )),
        Some(si) => {
            let shard = &fleet.shards()[si];
            if p.to_factor == p.ceiling {
                shard.get_or_compile(&p.source)
            } else {
                shard.get_or_compile_at(&p.source, p.to_factor)
            }
        }
    };
    match result {
        Ok((servable, cache_hit, key)) => {
            autoscaler.install(&p, servable, key, cache_hit, t0.elapsed().as_secs_f64());
        }
        Err(e) => autoscaler.fail(&p, &format!("{e:#}")),
    }
}
