//! The scale policy: when to re-replicate, and why it cannot
//! oscillate.
//!
//! A kernel's replication factor starts at the resource-aware ceiling
//! ([`crate::replicate::plan`]'s factor — the FU/IO bound the paper's
//! §III-C computes). The policy proposes a different factor only when
//! the observed load persistently disagrees with it:
//!
//! * **Scale down** when the windowed *mean* copy demand sits at or
//!   below `factor × down_ratio` — the kernel is over-provisioned and
//!   its extra copies idle on short streams while hogging FUs and
//!   inflating every reconfiguration of its (larger) bitstream.
//! * **Scale up** when the windowed mean demand reaches
//!   `factor × up_ratio`, or the spec's queues are persistently deep
//!   (`mean_queue ≥ queue_hi`) — the kernel is queue-bound and wider
//!   replication shortens every dispatch.
//!
//! The proposed **target** is `ceil(max demand over the window)`
//! clamped to `[1, ceiling]` (queue-triggered scale-ups take at least
//! a doubling). Using the window *max* for the target and the window
//! *mean* for the trigger makes targets a function of the workload
//! phase rather than of how the sliding window happens to straddle a
//! phase boundary — which is what keeps rescale targets (and hence
//! kernel-cache keys) deterministic per phase.
//!
//! # Why this provably cannot oscillate
//!
//! Consider a constant workload: every dispatch wants `d` copies and
//! the queue signal is stationary. Then:
//!
//! 1. A demand-driven event moves the factor to `t = clamp(⌈d⌉, 1,
//!    ceiling)`, which is a **fixed point**: the up trigger needs
//!    `d ≥ t × up_ratio > t ≥ d` (impossible, since `up_ratio > 1`),
//!    and the down trigger needs `d ≤ t × down_ratio < t − ½ < d` for
//!    every `t ≥ 2` (impossible, since `down_ratio < ½` and
//!    `d > t − 1`), while from `t = 1` there is nowhere down to go.
//!    [`AutoscalePolicy::validate`] rejects bands that violate these
//!    inequalities, so the two trigger conditions can never overlap.
//! 2. A queue-driven scale-up from factor `f` records a **floor** of
//!    `f + 1` tagged with the demand regime it was observed under.
//!    While the regime holds (mean demand within `regime_band` of the
//!    recorded value), no scale-down may go below the floor — so a
//!    kernel proven queue-bound at `f` can never return to `f`, which
//!    removes the classic down/up flap where added capacity drains
//!    the queue, tempts a scale-down, and immediately re-queues.
//!    Under a constant workload the regime never changes, the floor
//!    never clears, and queue-driven ups are monotone and bounded by
//!    the ceiling.
//! 3. A **cooldown** of at least one full window between events means
//!    every evaluation sees only post-event samples — no decision is
//!    ever made on a window polluted by pre-scale queue depths.
//!
//! Together: under any constant workload the factor sequence is a
//! (possibly empty) run of monotone queue-driven ups followed by at
//! most one demand-driven move to a fixed point — finitely many
//! events, then **zero** forever. The property test in
//! `rust/tests/autoscale.rs` asserts exactly that, and the unit tests
//! below sweep the fixed-point inequalities. When the workload *does*
//! shift phase, the regime tag no longer matches, floors clear, and
//! the policy converges on the new phase by the same argument.
//!
//! # SLO-targeted mode
//!
//! When the snapshot carries a latency SLO (`slo_target_ms > 0` —
//! injected by the autoscaler from the coordinator's SLO engine), the
//! **demand band is no longer the scale-up trigger**: the policy
//! scales up while the fleet-wide interactive windowed p99 is at or
//! above the target (`slo_p99_ms ≥ slo_target_ms`), taking at least a
//! doubling per event like queue-driven ups, and **holds** — refuses
//! to scale down — until the p99 clears a hysteresis band *below* the
//! target (`slo_p99_ms ≤ slo_clear_ratio × slo_target_ms`). The gap
//! between the up trigger (at the target) and the down gate (at
//! `slo_clear_ratio` of it) is what prevents oscillation: a factor
//! that just cleared the SLO cannot immediately tempt a scale-down,
//! because clearing the up trigger does not clear the hold band. The
//! queue-up trigger stays armed in SLO mode (deep queues predict a
//! p99 miss one window later; reacting early is strictly better), and
//! the cooldown ≥ window rule means every SLO evaluation sees only
//! post-event windows — the same proof structure as the demand bands.

use anyhow::{bail, Result};

use super::signal::SignalSnapshot;

/// Direction of a proposed or applied rescale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

impl ScaleDirection {
    pub fn name(self) -> &'static str {
        match self {
            ScaleDirection::Up => "up",
            ScaleDirection::Down => "down",
        }
    }
}

/// Anti-flap floor recorded by a queue-driven scale-up: the factor
/// below it was observed queue-bound, so scale-downs must not return
/// there while the demand regime that produced the queueing persists.
#[derive(Debug, Clone, Copy)]
pub struct QueueFloor {
    /// Scale-downs may not go below this factor.
    pub min_factor: usize,
    /// Mean demand when the floor was set — the regime tag.
    pub demand_at_set: f64,
}

/// What the policy decided for one evaluation.
#[derive(Debug, Clone, Copy)]
pub struct ScaleDecision {
    pub target: usize,
    pub direction: ScaleDirection,
    /// Whether deep queues (rather than demand alone) drove the
    /// decision — such scale-ups record a [`QueueFloor`].
    pub queue_triggered: bool,
}

/// Tunable knobs of the feedback loop. Construct, adjust, then let
/// [`crate::coordinator::Coordinator::new`] call
/// [`AutoscalePolicy::validate`].
#[derive(Debug, Clone)]
pub struct AutoscalePolicy {
    /// Submit-side samples required (and retained) per evaluation —
    /// the sliding-window length.
    pub window: usize,
    /// Submits after an applied (or failed) rescale before the next
    /// evaluation. Must be ≥ `window` so every decision is made on a
    /// fully post-event window.
    pub cooldown: usize,
    /// Scale up when mean demand ≥ `factor × up_ratio` (> 1.0).
    pub up_ratio: f64,
    /// Scale down when mean demand ≤ `factor × down_ratio` (< 0.5 —
    /// see the module docs for why ½ is the oscillation bound).
    pub down_ratio: f64,
    /// Scale up (toward at least a doubling) when the mean queue
    /// depth observed at submit time reaches this.
    pub queue_hi: f64,
    /// Fractional demand shift that counts as a regime change and
    /// clears queue floors (e.g. 0.5 = mean demand moved ±50%).
    pub regime_band: f64,
    /// SLO-mode hysteresis: scale-downs are held until the windowed
    /// p99 drops to this fraction of the SLO target (must lie in
    /// (0, 1)). Only consulted when the snapshot carries an SLO
    /// signal (`slo_target_ms > 0`).
    pub slo_clear_ratio: f64,
    /// Scale events retained verbatim in the audit log; counters keep
    /// counting after the buffer fills (mirrors
    /// [`crate::fleet::RoutingPolicy::max_records`]).
    pub max_events: usize,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            window: 8,
            cooldown: 8,
            up_ratio: 1.5,
            down_ratio: 0.45,
            queue_hi: 4.0,
            regime_band: 0.5,
            slo_clear_ratio: 0.8,
            max_events: 1024,
        }
    }
}

impl AutoscalePolicy {
    /// Check the hysteresis invariants the no-oscillation argument
    /// rests on (module docs). The coordinator refuses to start an
    /// autoscaler whose bands could overlap.
    pub fn validate(&self) -> Result<()> {
        if self.window == 0 {
            bail!("autoscale window must be at least 1 sample");
        }
        if self.cooldown < self.window {
            bail!(
                "autoscale cooldown ({}) must cover the window ({}) so \
                 evaluations never see pre-event samples",
                self.cooldown,
                self.window
            );
        }
        if self.up_ratio <= 1.0 {
            bail!("up_ratio must exceed 1.0, got {}", self.up_ratio);
        }
        if !(0.0..0.5).contains(&self.down_ratio) {
            bail!(
                "down_ratio must lie in [0, 0.5) for the hysteresis bands \
                 to be disjoint at every factor, got {}",
                self.down_ratio
            );
        }
        if self.queue_hi <= 0.0 {
            bail!("queue_hi must be positive, got {}", self.queue_hi);
        }
        if self.regime_band <= 0.0 {
            bail!("regime_band must be positive, got {}", self.regime_band);
        }
        if !(self.slo_clear_ratio > 0.0 && self.slo_clear_ratio < 1.0) {
            bail!(
                "slo_clear_ratio must lie in (0, 1) so the SLO hold band \
                 sits strictly below the up trigger, got {}",
                self.slo_clear_ratio
            );
        }
        if self.max_events == 0 {
            bail!("max_events must be at least 1");
        }
        Ok(())
    }

    /// Evaluate one warmed-up snapshot against the current factor.
    /// `ceiling` is the resource-aware replication bound for this
    /// (kernel, spec); `floor` is the kernel's queue floor, cleared
    /// here when the demand regime has shifted and (re)set by a
    /// queue-triggered decision's caller. Returns `None` at a fixed
    /// point.
    pub fn evaluate(
        &self,
        s: &SignalSnapshot,
        factor: usize,
        ceiling: usize,
        floor: &mut Option<QueueFloor>,
    ) -> Option<ScaleDecision> {
        // a shifted demand regime invalidates queue floors: the
        // queueing they memorialized belonged to a different workload
        if let Some(f) = *floor {
            if (s.mean_demand - f.demand_at_set).abs()
                > self.regime_band * f.demand_at_set.max(1.0)
            {
                *floor = None;
            }
        }

        // SLO mode: a declared latency target replaces the demand band
        // as the scale-up trigger (module docs, "SLO-targeted mode")
        let slo_mode = s.slo_target_ms > 0.0;
        let slo_up = slo_mode && s.slo_p99_ms >= s.slo_target_ms;
        let slo_hold = slo_mode && s.slo_p99_ms > self.slo_clear_ratio * s.slo_target_ms;
        let demand_up = !slo_mode && s.mean_demand >= factor as f64 * self.up_ratio;
        let queue_up = s.mean_queue >= self.queue_hi;
        if (demand_up || queue_up || slo_up) && factor < ceiling {
            let mut target = s.max_demand.max(1).min(ceiling);
            if queue_up || slo_up {
                // queue-bound or SLO-missing: take at least a doubling
                // toward the ceiling even when per-dispatch demand
                // looks small
                target = target.max((factor * 2).min(ceiling));
            }
            if target > factor {
                return Some(ScaleDecision {
                    target,
                    direction: ScaleDirection::Up,
                    queue_triggered: (queue_up || slo_up) && !demand_up,
                });
            }
        }

        if !slo_hold && s.mean_demand <= factor as f64 * self.down_ratio {
            let mut target = s.max_demand.max(1);
            if let Some(f) = *floor {
                target = target.max(f.min_factor);
            }
            let target = target.min(ceiling);
            if target < factor {
                return Some(ScaleDecision {
                    target,
                    direction: ScaleDirection::Down,
                    queue_triggered: false,
                });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(mean_demand: f64, max_demand: usize, mean_queue: f64) -> SignalSnapshot {
        SignalSnapshot {
            samples: 8,
            mean_demand,
            max_demand,
            mean_queue,
            p50_ms: 0.0,
            p99_ms: 0.0,
            mean_modeled_ms: 0.0,
            submits: 8,
            completions: 8,
            rejects: 0,
            slo_p99_ms: 0.0,
            slo_target_ms: 0.0,
        }
    }

    fn slo_snap(p99_ms: f64, target_ms: f64, mean_queue: f64) -> SignalSnapshot {
        SignalSnapshot {
            slo_p99_ms: p99_ms,
            slo_target_ms: target_ms,
            ..snap(1.0, 1, mean_queue)
        }
    }

    #[test]
    fn defaults_validate_and_bad_bands_are_rejected() {
        AutoscalePolicy::default().validate().unwrap();
        let overlap = AutoscalePolicy { down_ratio: 0.6, ..Default::default() };
        assert!(overlap.validate().is_err());
        let inverted = AutoscalePolicy { up_ratio: 0.9, ..Default::default() };
        assert!(inverted.validate().is_err());
        let short = AutoscalePolicy { cooldown: 2, window: 8, ..Default::default() };
        assert!(short.validate().is_err());
        let hold_at_trigger =
            AutoscalePolicy { slo_clear_ratio: 1.0, ..Default::default() };
        assert!(hold_at_trigger.validate().is_err());
        let hold_zero = AutoscalePolicy { slo_clear_ratio: 0.0, ..Default::default() };
        assert!(hold_zero.validate().is_err());
    }

    #[test]
    fn slo_miss_scales_up_at_least_doubling_and_demand_band_is_disarmed() {
        let p = AutoscalePolicy::default();
        let mut floor = None;
        // p99 at the target: scale up even though demand is tiny
        let d = p.evaluate(&slo_snap(600.0, 500.0, 0.0), 2, 16, &mut floor).unwrap();
        assert_eq!(d.direction, ScaleDirection::Up);
        assert_eq!(d.target, 4, "SLO-triggered up doubles");
        assert!(d.queue_triggered, "SLO ups record a floor like queue ups");
        // in SLO mode the demand band no longer triggers on its own:
        // huge demand with a healthy p99 proposes nothing upward
        let mut s = slo_snap(100.0, 500.0, 0.0);
        s.mean_demand = 40.0;
        s.max_demand = 40;
        assert!(p.evaluate(&s, 2, 16, &mut floor).is_none());
        // ...but deep queues still do (they predict the next p99 miss)
        let q = p.evaluate(&slo_snap(100.0, 500.0, 6.0), 2, 16, &mut floor).unwrap();
        assert_eq!(q.direction, ScaleDirection::Up);
        assert_eq!(q.target, 4);
    }

    #[test]
    fn slo_hold_band_blocks_scale_down_until_p99_clears_it() {
        let p = AutoscalePolicy::default(); // slo_clear_ratio 0.8
        let mut floor = None;
        // p99 under the target but above 0.8×target: down is held even
        // though the demand band says over-provisioned
        assert!(
            p.evaluate(&slo_snap(450.0, 500.0, 0.0), 8, 16, &mut floor).is_none(),
            "inside the hold band nothing may scale down"
        );
        // p99 well inside the clear band: the demand-band down fires
        let d = p.evaluate(&slo_snap(100.0, 500.0, 0.0), 8, 16, &mut floor).unwrap();
        assert_eq!(d.direction, ScaleDirection::Down);
        assert_eq!(d.target, 1);
    }

    #[test]
    fn slo_up_trigger_and_hold_band_never_overlap() {
        // the SLO analogue of the fixed-point sweep: once the p99
        // clears the up trigger, a down is only possible after it also
        // clears the hold band — so no single p99 value can fire both
        let p = AutoscalePolicy::default();
        for p99 in [0.0, 100.0, 399.0, 400.0, 450.0, 499.0, 500.0, 900.0] {
            let mut floor = None;
            let verdict = p.evaluate(&slo_snap(p99, 500.0, 0.0), 8, 16, &mut floor);
            if let Some(d) = verdict {
                let both = d.direction == ScaleDirection::Up && p99 < 500.0
                    || d.direction == ScaleDirection::Down && p99 > 0.8 * 500.0;
                assert!(!both, "p99 {p99} produced a band-violating {d:?}");
            }
        }
    }

    #[test]
    fn fixed_points_are_silent_at_every_demand() {
        // the inequality sweep behind the no-oscillation proof: after
        // converging to t = clamp(ceil(d)), neither band re-fires
        let p = AutoscalePolicy::default();
        for ceiling in [1usize, 5, 16, 64] {
            for d in 1..=80usize {
                let t = d.clamp(1, ceiling);
                let mut floor = None;
                let verdict = p.evaluate(&snap(d as f64, d, 0.0), t, ceiling, &mut floor);
                assert!(
                    verdict.is_none(),
                    "demand {d} at factor {t} (ceiling {ceiling}) proposed {verdict:?}"
                );
            }
        }
    }

    #[test]
    fn over_provisioned_kernels_scale_down_to_the_window_max() {
        let p = AutoscalePolicy::default();
        let mut floor = None;
        let d = p.evaluate(&snap(1.0, 1, 0.0), 16, 16, &mut floor).unwrap();
        assert_eq!(d.direction, ScaleDirection::Down);
        assert_eq!(d.target, 1);
        // a window still holding one wide sample keeps the target at
        // the phase max — no event, because target == factor
        assert!(p.evaluate(&snap(2.9, 16, 0.0), 16, 16, &mut floor).is_none());
    }

    #[test]
    fn queue_bound_kernels_scale_up_and_record_a_floor() {
        let p = AutoscalePolicy::default();
        let mut floor = None;
        // demand alone would not trigger (mean 1 < 2 * 1.5) but the
        // queue is deep
        let d = p.evaluate(&snap(1.0, 1, 6.0), 2, 16, &mut floor).unwrap();
        assert_eq!(d.direction, ScaleDirection::Up);
        assert_eq!(d.target, 4, "queue-triggered up doubles");
        assert!(d.queue_triggered);
        // the caller records the floor; a later down proposal honors it
        floor = Some(QueueFloor { min_factor: 3, demand_at_set: 1.0 });
        let down = p.evaluate(&snap(1.0, 1, 0.0), 8, 16, &mut floor).unwrap();
        assert_eq!(down.direction, ScaleDirection::Down);
        assert_eq!(down.target, 3, "scale-down clamped to the queue floor");
        // a regime shift clears the floor and frees the full range
        let down2 = p.evaluate(&snap(4.0, 4, 0.0), 16, 16, &mut floor);
        assert!(floor.is_none(), "regime shift must clear the floor");
        let down2 = down2.unwrap();
        assert_eq!(down2.target, 4);
    }

    #[test]
    fn demand_up_targets_the_phase_max_and_respects_the_ceiling() {
        let p = AutoscalePolicy::default();
        let mut floor = None;
        let d = p.evaluate(&snap(4.0, 16, 0.0), 1, 16, &mut floor).unwrap();
        assert_eq!(d.direction, ScaleDirection::Up);
        assert_eq!(d.target, 16);
        assert!(!d.queue_triggered);
        // already at the ceiling: queue pressure proposes nothing
        assert!(p.evaluate(&snap(40.0, 64, 9.0), 16, 16, &mut floor).is_none());
    }
}
