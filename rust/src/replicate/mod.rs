//! Resource-aware kernel replication (paper §III-C / §IV).
//!
//! The OpenCL runtime exposes the overlay's size and FU type; the
//! compiler replicates the kernel's FU-aware DFG as many times as the
//! *binding* resource allows. On the 8×8 two-DSP overlay the paper
//! reports exactly the limits this module computes: Chebyshev is
//! I/O-limited at 16 copies (32 pads / 2 streams), while with one-DSP
//! FUs it is FU-limited at 12 copies (64 / 5).

use anyhow::{bail, Result};

use crate::dfg::{Dfg, NodeKind};
use crate::fuaware::FuGraph;
use crate::overlay::OverlaySpec;

/// Which resource capped the replication factor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LimitReason {
    /// Overlay FU count.
    Fu,
    /// Perimeter I/O pads.
    Io,
    /// AOT emulator op-slot budget (execution backend).
    EmuSlots,
    /// AOT emulator input-column budget (execution backend).
    EmuInputs,
}

impl LimitReason {
    pub fn name(self) -> &'static str {
        match self {
            LimitReason::Fu => "FU-limited",
            LimitReason::Io => "I/O-limited",
            LimitReason::EmuSlots => "emulator-slot-limited",
            LimitReason::EmuInputs => "emulator-input-limited",
        }
    }

    /// Compact stable identifier used by the kernel-cache snapshot
    /// format and routing reports.
    pub fn short_name(self) -> &'static str {
        match self {
            LimitReason::Fu => "fu",
            LimitReason::Io => "io",
            LimitReason::EmuSlots => "emu-slots",
            LimitReason::EmuInputs => "emu-inputs",
        }
    }

    /// Inverse of [`LimitReason::short_name`].
    pub fn from_short_name(s: &str) -> Option<LimitReason> {
        match s {
            "fu" => Some(LimitReason::Fu),
            "io" => Some(LimitReason::Io),
            "emu-slots" => Some(LimitReason::EmuSlots),
            "emu-inputs" => Some(LimitReason::EmuInputs),
            _ => None,
        }
    }
}

/// Resource arithmetic of a replication decision.
#[derive(Debug, Clone)]
pub struct ReplicationPlan {
    pub factor: usize,
    pub limit: LimitReason,
    pub fus_per_copy: usize,
    pub io_per_copy: usize,
    pub ops_per_copy: usize,
    pub fu_capacity: usize,
    pub io_capacity: usize,
}

/// Optional execution-backend limits (op slots, input columns) from
/// the AOT emulator geometry.
#[derive(Debug, Clone, Copy)]
pub struct BackendLimits {
    pub max_op_slots: usize,
    pub max_inputs: usize,
}

/// Decide the replication factor for one kernel copy described by `fg`.
pub fn plan(
    fg: &FuGraph,
    spec: &OverlaySpec,
    backend: Option<BackendLimits>,
) -> Result<ReplicationPlan> {
    let fus_per_copy = fg.num_fus();
    let io_per_copy = fg.dfg.num_io();
    let ops_per_copy = fg.dfg.num_ops();
    if fus_per_copy == 0 {
        bail!("kernel has no FUs");
    }

    let fu_capacity = spec.fu_count();
    let io_capacity = spec.io_pads();
    let mut factor = fu_capacity / fus_per_copy;
    let mut limit = LimitReason::Fu;

    let by_io = io_capacity / io_per_copy.max(1);
    if by_io < factor {
        factor = by_io;
        limit = LimitReason::Io;
    }
    if let Some(b) = backend {
        let by_slots = b.max_op_slots / ops_per_copy.max(1);
        if by_slots < factor {
            factor = by_slots;
            limit = LimitReason::EmuSlots;
        }
        let by_inputs = b.max_inputs / fg.dfg.num_inputs().max(1);
        if by_inputs < factor {
            factor = by_inputs;
            limit = LimitReason::EmuInputs;
        }
    }

    if factor == 0 {
        bail!(
            "kernel does not fit the {} overlay: needs {} FUs / {} I/O \
             (capacity {} / {})",
            spec.name(),
            fus_per_copy,
            io_per_copy,
            fu_capacity,
            io_capacity
        );
    }
    Ok(ReplicationPlan {
        factor,
        limit,
        fus_per_copy,
        io_per_copy,
        ops_per_copy,
        fu_capacity,
        io_capacity,
    })
}

/// Build a DFG with `factor` disjoint copies of `dfg`. Stream ports are
/// renumbered copy-major: copy `r`'s input `i` becomes port
/// `r * inputs_per_copy + i` (and likewise for outputs), which is also
/// the layout the host runtime packs value-table columns in.
pub fn replicate_dfg(dfg: &Dfg, factor: usize) -> Dfg {
    let mut out = Dfg::new(dfg.name.clone());
    let n_in = dfg.num_inputs();
    let n_out = dfg.num_outputs();
    for r in 0..factor {
        for name in &dfg.input_names {
            out.input_names.push(if factor == 1 {
                name.clone()
            } else {
                format!("{name}#{r}")
            });
        }
        for name in &dfg.output_names {
            out.output_names.push(if factor == 1 {
                name.clone()
            } else {
                format!("{name}#{r}")
            });
        }
        out.input_meta.extend(dfg.input_meta.iter().copied());
        out.output_meta.extend(dfg.output_meta.iter().copied());
    }
    for r in 0..factor {
        let base = out.nodes.len();
        for node in &dfg.nodes {
            let kind = match &node.kind {
                NodeKind::InVar { port } => NodeKind::InVar { port: r * n_in + port },
                NodeKind::OutVar { port } => NodeKind::OutVar { port: r * n_out + port },
                op => op.clone(),
            };
            out.add_node(kind);
        }
        for e in &dfg.edges {
            out.add_edge(base + e.src, base + e.dst, e.dst_port);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::fuaware::to_fu_graph;
    use crate::ir::{lower_kernel, optimize};
    use crate::overlay::FuType;

    const CHEB: &str = "__kernel void chebyshev(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn cheb_fg(dsps: usize) -> FuGraph {
        let f = lower_kernel(&parse_kernel(CHEB).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        to_fu_graph(&dfg, dsps).unwrap()
    }

    #[test]
    fn chebyshev_16_copies_io_limited_on_8x8_dsp2() {
        // §IV: "16 copies of the Chebyshev benchmark … limited only by
        // the available I/O"
        let fg = cheb_fg(2);
        let spec = OverlaySpec::new(8, 8, FuType::Dsp2);
        let p = plan(&fg, &spec, None).unwrap();
        assert_eq!(p.factor, 16);
        assert_eq!(p.limit, LimitReason::Io);
        assert_eq!(p.fus_per_copy, 3);
        assert_eq!(p.io_per_copy, 2);
    }

    #[test]
    fn chebyshev_12_copies_fu_limited_on_8x8_dsp1() {
        // Fig. 6 (red curve): 12 instances on the 1-DSP/FU overlay
        let fg = cheb_fg(1);
        let spec = OverlaySpec::new(8, 8, FuType::Dsp1);
        let p = plan(&fg, &spec, None).unwrap();
        assert_eq!(p.factor, 12);
        assert_eq!(p.limit, LimitReason::Fu);
        assert_eq!(p.fus_per_copy, 5);
    }

    #[test]
    fn single_copy_on_2x2_fig5a() {
        // Fig. 5(a): 2×2 overlay fits exactly one Chebyshev copy
        let fg = cheb_fg(2);
        let spec = OverlaySpec::new(2, 2, FuType::Dsp2);
        let p = plan(&fg, &spec, None).unwrap();
        assert_eq!(p.factor, 1);
    }

    #[test]
    fn size_sweep_matches_fig5_replication_counts() {
        // Fig. 5(a)-(g): copies on 2x2..8x8 with 2-DSP FUs.
        // FU-capacity 4,9,16,25,36,49,64 / 3 FUs per copy, capped by
        // I/O pads (8,12,16,20,24,28,32) / 2 per copy.
        let fg = cheb_fg(2);
        let expect = [1, 3, 5, 8, 12, 14, 16];
        for (spec, want) in OverlaySpec::size_sweep(FuType::Dsp2).iter().zip(expect) {
            let p = plan(&fg, spec, None).unwrap();
            assert_eq!(p.factor, want, "overlay {}", spec.name());
        }
    }

    #[test]
    fn backend_limits_can_bind() {
        let fg = cheb_fg(2);
        let spec = OverlaySpec::new(8, 8, FuType::Dsp2);
        let p = plan(&fg, &spec, Some(BackendLimits { max_op_slots: 20, max_inputs: 32 }))
            .unwrap();
        // 20 slots / 5 ops per copy = 4 copies
        assert_eq!(p.factor, 4);
        assert_eq!(p.limit, LimitReason::EmuSlots);
    }

    #[test]
    fn too_large_kernel_errors() {
        let fg = cheb_fg(1); // 5 FUs
        let spec = OverlaySpec::new(2, 2, FuType::Dsp1); // 4 FUs
        assert!(plan(&fg, &spec, None).is_err());
    }

    #[test]
    fn replicated_dfg_is_disjoint_and_valid() {
        let fg = cheb_fg(2);
        let rep = replicate_dfg(&fg.dfg, 16);
        rep.validate().unwrap();
        assert_eq!(rep.num_ops(), 16 * fg.dfg.num_ops());
        assert_eq!(rep.num_inputs(), 16);
        assert_eq!(rep.num_outputs(), 16);
        // port numbering dense and unique
        let mut in_ports: Vec<usize> = rep
            .nodes
            .iter()
            .filter_map(|n| match n.kind {
                NodeKind::InVar { port } => Some(port),
                _ => None,
            })
            .collect();
        in_ports.sort();
        assert_eq!(in_ports, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn factor_one_keeps_names() {
        let fg = cheb_fg(2);
        let rep = replicate_dfg(&fg.dfg, 1);
        assert_eq!(rep.input_names, fg.dfg.input_names);
    }

    #[test]
    fn limit_reason_short_names_round_trip() {
        for r in [
            LimitReason::Fu,
            LimitReason::Io,
            LimitReason::EmuSlots,
            LimitReason::EmuInputs,
        ] {
            assert_eq!(LimitReason::from_short_name(r.short_name()), Some(r));
        }
        assert_eq!(LimitReason::from_short_name("nope"), None);
    }
}
