//! SSA intermediate representation — the LLVM stand-in.
//!
//! [`lower_kernel`] produces the *naive* memory-form IR of Table I(b):
//! an `alloca` per local variable and per parameter, with every use
//! going through a load/store pair, exactly as Clang emits at `-O0`.
//! The pass pipeline ([`optimize`]) then reproduces Table I(c):
//! `mem2reg` promotes the allocas, constant folding / algebraic
//! simplification / CSE / DCE clean the rest, leaving the pure dataflow
//! the DFG extractor consumes.
//!
//! Everything is a single basic block: the frontend rejects control
//! flow (an II=1 spatial overlay executes straight-line dataflow).

mod build;
mod instr;
pub mod passes;
mod printer;

pub use build::lower_kernel;
pub use instr::{Function, Instr, IrBinOp, IrType, Op, ValueId};
pub use passes::{optimize, PassStats};
pub use printer::print_function;
