//! Alloca promotion. With a single basic block this is plain forward
//! store→load forwarding: track the last value stored to each slot and
//! substitute it at each load. Allocas, their stores and loads all
//! disappear from the instruction stream.

use std::collections::HashMap;

use crate::ir::instr::{Function, Op, ValueId};

use super::Rewriter;

/// Returns the rewritten function and the number of allocas promoted.
pub fn mem2reg(f: &Function) -> (Function, usize) {
    let mut rw = Rewriter::new(f.instrs.len());
    // old alloca id → current (new-id-space) value
    let mut current: HashMap<ValueId, ValueId> = HashMap::new();
    let mut promoted = 0usize;

    for (i, instr) in f.instrs.iter().enumerate() {
        let old = ValueId(i as u32);
        match &instr.op {
            Op::Alloca { .. } => {
                promoted += 1;
                // slot itself produces no value; loads are forwarded.
            }
            Op::Store { val, slot } => {
                let new_val = rw.lookup(*val);
                current.insert(*slot, new_val);
            }
            Op::Load { slot } => {
                let cur = *current
                    .get(slot)
                    .expect("load of uninitialized slot (sema guarantees init)");
                rw.forward(old, cur);
            }
            _ => {
                rw.copy(old, instr);
            }
        }
    }
    (rw.finish(f), promoted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::lower_kernel;

    #[test]
    fn no_memory_ops_survive() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *A, __global int *B) {
                    int i = get_global_id(0);
                    int x = A[i];
                    x = x + 1;
                    B[i] = x * x;
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let (g, promoted) = mem2reg(&f);
        assert_eq!(promoted, 4); // A, B, i, x
        assert_eq!(g.count(|o| matches!(o, Op::Alloca { .. })), 0);
        assert_eq!(g.count(|o| matches!(o, Op::Load { .. })), 0);
        assert_eq!(g.count(|o| matches!(o, Op::Store { .. })), 0);
        // reassignment respected: the store's value feeds the multiply
        assert_eq!(g.count(|o| matches!(o, Op::StoreGlobal { .. })), 1);
    }

    #[test]
    fn reassignment_uses_latest_value() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *B) {
                    int i = get_global_id(0);
                    int x = 3;
                    x = 5;
                    B[i] = x;
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let (g, _) = mem2reg(&f);
        // find the StoreGlobal and check its value is the constant 5
        let store = g
            .instrs
            .iter()
            .find_map(|ins| match &ins.op {
                Op::StoreGlobal { val, .. } => Some(*val),
                _ => None,
            })
            .unwrap();
        assert!(matches!(g.op(store), Op::ConstInt(5)));
    }
}
