//! Algebraic simplification and strength rewrites.
//!
//! Integer identities: `x+0`, `0+x`, `x-0`, `x*1`, `1*x` → `x`;
//! `x*0`, `0*x`, `x-x` → `0`; `x<<c` → `x * 2^c` (the overlay FU has a
//! multiplier but no barrel shifter, so shifts become DSP multiplies —
//! the same choice Vivado HLS makes when a shifter is unavailable).
//!
//! Float identities are applied only where IEEE-safe for the f32
//! emulated datapath: `x*1.0` → x. (`x+0.0` is kept: it is not an
//! identity for −0.0.)

use crate::ir::instr::{Function, Instr, IrBinOp, IrType, Op, ValueId};

use super::{const_of, Rewriter};

/// Returns the rewritten function and the number of rewrites applied.
pub fn algebraic(f: &Function) -> (Function, usize) {
    let mut rw = Rewriter::new(f.instrs.len());
    let mut n = 0usize;

    for (i, instr) in f.instrs.iter().enumerate() {
        let old = ValueId(i as u32);
        let Op::Bin { op, lhs, rhs } = &instr.op else {
            rw.copy(old, instr);
            continue;
        };
        let is_int = instr.ty == IrType::Int;
        let lc = const_of(f, *lhs);
        let rc = const_of(f, *rhs);

        // x - x -> 0 (int only; float NaN semantics)
        if is_int && *op == IrBinOp::Sub && lhs == rhs {
            rw.emit(old, Instr { op: Op::ConstInt(0), ty: instr.ty });
            n += 1;
            continue;
        }
        // identities returning an operand
        let forwarded = match (op, lc, rc) {
            (IrBinOp::Add, _, Some(Op::ConstInt(0))) if is_int => Some(*lhs),
            (IrBinOp::Add, Some(Op::ConstInt(0)), _) if is_int => Some(*rhs),
            (IrBinOp::Sub, _, Some(Op::ConstInt(0))) if is_int => Some(*lhs),
            (IrBinOp::Mul, _, Some(Op::ConstInt(1))) if is_int => Some(*lhs),
            (IrBinOp::Mul, Some(Op::ConstInt(1)), _) if is_int => Some(*rhs),
            (IrBinOp::Mul, _, Some(Op::ConstFloat(c))) if *c == 1.0 => Some(*lhs),
            (IrBinOp::Mul, Some(Op::ConstFloat(c)), _) if *c == 1.0 => Some(*rhs),
            (IrBinOp::Shl, _, Some(Op::ConstInt(0))) if is_int => Some(*lhs),
            (IrBinOp::Shr, _, Some(Op::ConstInt(0))) if is_int => Some(*lhs),
            _ => None,
        };
        if let Some(v) = forwarded {
            let new = rw.lookup(v);
            rw.forward(old, new);
            n += 1;
            continue;
        }
        // x * 0 -> 0
        if is_int
            && *op == IrBinOp::Mul
            && (matches!(lc, Some(Op::ConstInt(0))) || matches!(rc, Some(Op::ConstInt(0))))
        {
            rw.emit(old, Instr { op: Op::ConstInt(0), ty: instr.ty });
            n += 1;
            continue;
        }
        // x << c -> x * 2^c
        if is_int && *op == IrBinOp::Shl {
            if let Some(Op::ConstInt(c)) = rc {
                if (0..31).contains(c) {
                    let pow = rw.emit_fresh(Instr {
                        op: Op::ConstInt(1i64 << c),
                        ty: IrType::Int,
                    });
                    let l = rw.lookup(*lhs);
                    rw.emit(
                        old,
                        Instr { op: Op::Bin { op: IrBinOp::Mul, lhs: l, rhs: pow }, ty: instr.ty },
                    );
                    n += 1;
                    continue;
                }
            }
        }
        rw.copy(old, instr);
    }
    (rw.finish(f), n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, passes::mem2reg};

    fn prep(src: &str) -> Function {
        mem2reg(&lower_kernel(&parse_kernel(src).unwrap()).unwrap()).0
    }

    #[test]
    fn float_add_zero_is_preserved() {
        let f = prep(
            "__kernel void k(__global float *A, __global float *B) {
                int i = get_global_id(0);
                B[i] = A[i] + 0.0f;
             }",
        );
        let (g, n) = algebraic(&f);
        assert_eq!(n, 0);
        assert_eq!(g.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })), 1);
    }

    #[test]
    fn float_mul_one_is_removed() {
        let f = prep(
            "__kernel void k(__global float *A, __global float *B) {
                int i = get_global_id(0);
                B[i] = A[i] * 1.0f;
             }",
        );
        let (g, n) = algebraic(&f);
        assert_eq!(n, 1);
        assert_eq!(g.count(|o| matches!(o, Op::Bin { .. })), 0);
    }

    #[test]
    fn shl_rewrite_preserves_operand_order() {
        let f = prep(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] << 3;
             }",
        );
        let (g, _) = algebraic(&f);
        let found = g.instrs.iter().any(|ins| match &ins.op {
            Op::Bin { op: IrBinOp::Mul, rhs, .. } => {
                matches!(g.op(*rhs), Op::ConstInt(8))
            }
            _ => false,
        });
        assert!(found);
    }
}
