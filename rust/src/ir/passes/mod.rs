//! The optimization pipeline — LLVM `opt` stand-in.
//!
//! [`optimize`] takes the naive memory-form IR of Table I(b) to the
//! clean dataflow of Table I(c):
//!
//! 1. [`mem2reg`] — promote allocas to SSA values (single block, so a
//!    simple forward store/load forwarding suffices);
//! 2. [`constfold`] — fold constant expressions, canonicalize constants
//!    to the right operand of commutative ops;
//! 3. [`algebraic`] — identities (`x*1`, `x+0`, `x-x`, `x*0`) and
//!    strength rewrites (`x << c` → `x * 2^c`: the DSP FU multiplies in
//!    one slot; there is no barrel shifter in the overlay);
//! 4. [`cse`] — hash-based common-subexpression elimination;
//! 5. [`dce`] — mark/sweep from `StoreGlobal` roots.
//!
//! 2–5 iterate to a fixpoint (bounded), matching `opt -O2`'s effect on
//! these straight-line kernels.

mod algebraic;
mod constfold;
mod cse;
mod dce;
mod mem2reg;

pub use algebraic::algebraic;
pub use constfold::constfold;
pub use cse::cse;
pub use dce::dce;
pub use mem2reg::mem2reg;

use super::instr::{Function, Instr, Op, ValueId};

/// Counters reported by [`optimize`] (used by `CompileReport`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PassStats {
    pub allocas_promoted: usize,
    pub consts_folded: usize,
    pub algebraic_rewrites: usize,
    pub cse_removed: usize,
    pub dce_removed: usize,
    pub iterations: usize,
}

/// Run the full pipeline to a fixpoint.
pub fn optimize(f: &Function) -> (Function, PassStats) {
    let mut stats = PassStats::default();
    let (mut cur, promoted) = mem2reg(f);
    stats.allocas_promoted = promoted;

    for _ in 0..8 {
        stats.iterations += 1;
        let mut changed = false;

        let (next, n) = constfold(&cur);
        stats.consts_folded += n;
        changed |= n > 0;
        cur = next;

        let (next, n) = algebraic(&cur);
        stats.algebraic_rewrites += n;
        changed |= n > 0;
        cur = next;

        let (next, n) = cse(&cur);
        stats.cse_removed += n;
        changed |= n > 0;
        cur = next;

        let (next, n) = dce(&cur);
        stats.dce_removed += n;
        changed |= n > 0;
        cur = next;

        if !changed {
            break;
        }
    }
    (cur, stats)
}

/// Shared rebuild helper: passes emit instructions into a fresh
/// function while maintaining an old→new value map. Dropping an
/// instruction means mapping its result to an existing new value.
pub(crate) struct Rewriter {
    pub instrs: Vec<Instr>,
    remap: Vec<Option<ValueId>>,
}

impl Rewriter {
    pub fn new(old_len: usize) -> Self {
        Self { instrs: Vec::with_capacity(old_len), remap: vec![None; old_len] }
    }

    /// New id for an old operand (must already be mapped).
    pub fn lookup(&self, old: ValueId) -> ValueId {
        self.remap[old.0 as usize].expect("operand used before definition")
    }

    /// Emit `instr` (with operands already in new-id space) as the
    /// translation of old value `old`.
    pub fn emit(&mut self, old: ValueId, instr: Instr) -> ValueId {
        self.instrs.push(instr);
        let new = ValueId((self.instrs.len() - 1) as u32);
        self.remap[old.0 as usize] = Some(new);
        new
    }

    /// Emit an instruction with no old counterpart.
    pub fn emit_fresh(&mut self, instr: Instr) -> ValueId {
        self.instrs.push(instr);
        ValueId((self.instrs.len() - 1) as u32)
    }

    /// Map old value `old` to existing new value `new` (drop + forward).
    pub fn forward(&mut self, old: ValueId, new: ValueId) {
        self.remap[old.0 as usize] = Some(new);
    }

    /// Copy an instruction verbatim, renaming operands.
    pub fn copy(&mut self, old: ValueId, instr: &Instr) -> ValueId {
        let mut op = instr.op.clone();
        op.map_operands(|v| self.lookup(v));
        self.emit(old, Instr { op, ty: instr.ty })
    }

    pub fn finish(self, f: &Function) -> Function {
        Function { name: f.name.clone(), params: f.params.clone(), instrs: self.instrs }
    }
}

/// Is this op a compile-time constant, and which?
pub(crate) fn const_of(f: &Function, v: ValueId) -> Option<&Op> {
    match f.op(v) {
        c @ (Op::ConstInt(_) | Op::ConstFloat(_)) => Some(c),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, IrBinOp};

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn optimized(src: &str) -> Function {
        let f = lower_kernel(&parse_kernel(src).unwrap()).unwrap();
        optimize(&f).0
    }

    #[test]
    fn paper_example_reaches_table1c_form() {
        let f = optimized(PAPER);
        // Table I(c): no allocas / stack traffic survive
        assert_eq!(f.count(|o| matches!(o, Op::Alloca { .. })), 0);
        assert_eq!(f.count(|o| matches!(o, Op::Load { .. })), 0);
        assert_eq!(f.count(|o| matches!(o, Op::Store { .. })), 0);
        // dataflow: 1 gid call, 2 geps, 1 load, 1 store, 5 mul, 1 sub, 1 add
        assert_eq!(f.count(|o| matches!(o, Op::GlobalId)), 1);
        assert_eq!(f.count(|o| matches!(o, Op::Gep { .. })), 2);
        assert_eq!(f.count(|o| matches!(o, Op::LoadGlobal { .. })), 1);
        assert_eq!(f.count(|o| matches!(o, Op::StoreGlobal { .. })), 1);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })), 5);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Sub, .. })), 1);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })), 1);
    }

    #[test]
    fn duplicate_loads_are_cse_d() {
        let f = optimized(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] * A[i] + A[i];
             }",
        );
        // one load feeds all three uses
        assert_eq!(f.count(|o| matches!(o, Op::LoadGlobal { .. })), 1);
    }

    #[test]
    fn constant_expression_folds_completely() {
        let f = optimized(
            "__kernel void k(__global int *B) {
                int i = get_global_id(0);
                B[i] = (3 + 4) * (10 - 2);
             }",
        );
        assert_eq!(f.count(|o| matches!(o, Op::Bin { .. })), 0);
        assert_eq!(f.count(|o| matches!(o, Op::ConstInt(56))), 1);
    }

    #[test]
    fn mul_by_one_and_add_zero_vanish() {
        let f = optimized(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] * 1 + 0;
             }",
        );
        assert_eq!(f.count(|o| matches!(o, Op::Bin { .. })), 0);
    }

    #[test]
    fn shift_becomes_multiply() {
        let f = optimized(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = A[i] << 4;
             }",
        );
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Shl, .. })), 0);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })), 1);
        assert_eq!(f.count(|o| matches!(o, Op::ConstInt(16))), 1);
    }

    #[test]
    fn dead_local_is_removed() {
        let f = optimized(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                int unused = A[i] * 99;
                B[i] = A[i] + 1;
             }",
        );
        assert_eq!(f.count(|o| matches!(o, Op::ConstInt(99))), 0);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })), 0);
    }

    #[test]
    fn x_minus_x_folds_to_zero() {
        let f = optimized(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = (A[i] - A[i]) + 7;
             }",
        );
        assert_eq!(f.count(|o| matches!(o, Op::Bin { .. })), 0);
        assert_eq!(f.count(|o| matches!(o, Op::ConstInt(7))), 1);
    }

    #[test]
    fn float_kernel_optimizes_too() {
        let f = optimized(
            "__kernel void k(__global float *A, __global float *B) {
                int i = get_global_id(0);
                float x = A[i];
                B[i] = x * 2.0f + 0.0f;
             }",
        );
        assert_eq!(f.count(|o| matches!(o, Op::Alloca { .. })), 0);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })), 1);
        // + 0.0f is NOT removed for floats (−0.0/NaN semantics)… unless
        // we allowed it; we keep float identities conservative.
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })), 1);
    }
}
