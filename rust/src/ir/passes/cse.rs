//! Common-subexpression elimination by hash-consing.
//!
//! Two instructions are congruent if they have the same opcode and
//! (order-normalized for commutative binops) operands. Loads from
//! global memory are congruent when their addresses are: the kernels
//! are straight-line with no intervening stores to the same buffer
//! from the same work-item — the OpenCL execution model makes cross-
//! work-item interference undefined anyway. `GlobalId` is pure, so
//! duplicate calls collapse (Table I(c) has exactly one).

use std::collections::HashMap;

use crate::ir::instr::{Function, Op, ValueId};

use super::Rewriter;

/// Hashable congruence key for pure instructions.
#[derive(Hash, PartialEq, Eq)]
enum Key {
    ParamPtr(usize),
    ParamVal(usize),
    Gep(ValueId, ValueId),
    LoadGlobal(ValueId),
    GlobalId,
    ConstInt(i64),
    ConstFloat(u64), // bit pattern
    Bin(u8, ValueId, ValueId),
}

fn key_of(op: &Op) -> Option<Key> {
    Some(match op {
        Op::ParamPtr { index } => Key::ParamPtr(*index),
        Op::ParamVal { index } => Key::ParamVal(*index),
        Op::Gep { base, idx } => Key::Gep(*base, *idx),
        Op::LoadGlobal { addr } => Key::LoadGlobal(*addr),
        Op::GlobalId => Key::GlobalId,
        Op::ConstInt(v) => Key::ConstInt(*v),
        Op::ConstFloat(v) => Key::ConstFloat(v.to_bits()),
        Op::Bin { op, lhs, rhs } => {
            let (a, b) = if op.is_commutative() && rhs < lhs {
                (*rhs, *lhs)
            } else {
                (*lhs, *rhs)
            };
            Key::Bin(*op as u8, a, b)
        }
        _ => return None,
    })
}

/// Returns the rewritten function and the number of duplicates removed.
pub fn cse(f: &Function) -> (Function, usize) {
    let mut rw = Rewriter::new(f.instrs.len());
    let mut seen: HashMap<Key, ValueId> = HashMap::new();
    let mut n = 0usize;

    for (i, instr) in f.instrs.iter().enumerate() {
        let old = ValueId(i as u32);
        // Build the key in *new* id space so transitively-identical
        // chains collapse in one pass.
        let mut renamed = instr.op.clone();
        renamed.map_operands(|v| rw.lookup(v));
        match key_of(&renamed) {
            Some(key) => {
                if let Some(&existing) = seen.get(&key) {
                    rw.forward(old, existing);
                    n += 1;
                } else {
                    let new = rw.emit(old, crate::ir::Instr { op: renamed, ty: instr.ty });
                    seen.insert(key, new);
                }
            }
            None => {
                rw.emit(old, crate::ir::Instr { op: renamed, ty: instr.ty });
            }
        }
    }
    (rw.finish(f), n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, passes::mem2reg, IrBinOp};

    fn prep(src: &str) -> Function {
        mem2reg(&lower_kernel(&parse_kernel(src).unwrap()).unwrap()).0
    }

    #[test]
    fn commutative_duplicates_collapse() {
        let f = prep(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                int x = A[i];
                B[i] = (x + 3) * (3 + x);
             }",
        );
        let (g, n) = cse(&f);
        assert!(n >= 1, "expected x+3 / 3+x to collapse");
        assert_eq!(g.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })), 1);
    }

    #[test]
    fn repeated_gid_calls_collapse() {
        let f = prep(
            "__kernel void k(__global int *A, __global int *B) {
                B[get_global_id(0)] = A[get_global_id(0)];
             }",
        );
        let (g, _) = cse(&f);
        assert_eq!(g.count(|o| matches!(o, Op::GlobalId)), 1);
        // the two geps (A and B bases differ) must NOT collapse
        assert_eq!(g.count(|o| matches!(o, Op::Gep { .. })), 2);
    }

    #[test]
    fn transitive_chains_collapse_in_one_pass() {
        let f = prep(
            "__kernel void k(__global int *A, __global int *B) {
                int i = get_global_id(0);
                B[i] = (A[i] * 2 + 1) - (A[i] * 2 + 1);
             }",
        );
        let (g, _) = cse(&f);
        assert_eq!(g.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })), 1);
        assert_eq!(g.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })), 1);
    }
}
