//! Constant folding + canonicalization.
//!
//! * `Bin(const, const)` → const (int wrapping, matching the 32-bit
//!   datapath; float in f32 to match the emulated overlay numerics).
//! * Commutative ops with a constant on the left get their operands
//!   swapped so immediates always sit on the right — this is the form
//!   the DFG labels (`mul_Imm_16`) and the FU immediate ports expect.

use crate::ir::instr::{Function, Instr, IrBinOp, Op, ValueId};

use super::{const_of, Rewriter};

/// Returns the rewritten function and the number of rewrites applied.
pub fn constfold(f: &Function) -> (Function, usize) {
    let mut rw = Rewriter::new(f.instrs.len());
    let mut n = 0usize;

    for (i, instr) in f.instrs.iter().enumerate() {
        let old = ValueId(i as u32);
        let Op::Bin { op, lhs, rhs } = &instr.op else {
            rw.copy(old, instr);
            continue;
        };
        match (const_of(f, *lhs), const_of(f, *rhs)) {
            (Some(Op::ConstInt(a)), Some(Op::ConstInt(b))) => {
                let v = eval_int(*op, *a, *b);
                rw.emit(old, Instr { op: Op::ConstInt(v), ty: instr.ty });
                n += 1;
            }
            (Some(Op::ConstFloat(a)), Some(Op::ConstFloat(b))) => {
                if let Some(v) = eval_float(*op, *a, *b) {
                    rw.emit(old, Instr { op: Op::ConstFloat(v), ty: instr.ty });
                    n += 1;
                } else {
                    rw.copy(old, instr);
                }
            }
            (Some(_), None) if op.is_commutative() => {
                // canonicalize: constant to the right
                let l = rw.lookup(*lhs);
                let r = rw.lookup(*rhs);
                rw.emit(old, Instr { op: Op::Bin { op: *op, lhs: r, rhs: l }, ty: instr.ty });
                n += 1;
            }
            _ => {
                rw.copy(old, instr);
            }
        }
    }
    (rw.finish(f), n)
}

/// Integer evaluation with the 32-bit wrap-around semantics of the
/// emulated datapath (matches the Pallas kernel and the cycle sim).
fn eval_int(op: IrBinOp, a: i64, b: i64) -> i64 {
    let (a, b) = (a as i32, b as i32);
    let v = match op {
        IrBinOp::Add => a.wrapping_add(b),
        IrBinOp::Sub => a.wrapping_sub(b),
        IrBinOp::Mul => a.wrapping_mul(b),
        IrBinOp::Shl => a.wrapping_shl(b as u32 & 31),
        IrBinOp::Shr => a.wrapping_shr(b as u32 & 31),
        IrBinOp::Min => a.min(b),
        IrBinOp::Max => a.max(b),
    };
    v as i64
}

/// f32 evaluation (None for ops floats don't support).
fn eval_float(op: IrBinOp, a: f64, b: f64) -> Option<f64> {
    let (a, b) = (a as f32, b as f32);
    let v = match op {
        IrBinOp::Add => a + b,
        IrBinOp::Sub => a - b,
        IrBinOp::Mul => a * b,
        IrBinOp::Min => a.min(b),
        IrBinOp::Max => a.max(b),
        IrBinOp::Shl | IrBinOp::Shr => return None,
    };
    Some(v as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_folding_wraps_at_32_bits() {
        assert_eq!(eval_int(IrBinOp::Mul, i32::MAX as i64, 2), -2);
        assert_eq!(eval_int(IrBinOp::Add, 1, 2), 3);
        assert_eq!(eval_int(IrBinOp::Shl, 1, 4), 16);
        assert_eq!(eval_int(IrBinOp::Min, -5, 3), -5);
    }

    #[test]
    fn float_folding_uses_f32() {
        let v = eval_float(IrBinOp::Add, 0.1, 0.2).unwrap();
        assert_eq!(v, (0.1f32 + 0.2f32) as f64);
        assert!(eval_float(IrBinOp::Shl, 1.0, 1.0).is_none());
    }
}
