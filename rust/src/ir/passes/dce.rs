//! Dead-code elimination: mark live from `StoreGlobal` roots, sweep
//! everything else. Allocas/stores/loads left by earlier passes (there
//! should be none after mem2reg) are conservatively kept if referenced.

use crate::ir::instr::{Function, ValueId};

use super::Rewriter;

/// Returns the rewritten function and the number of instructions removed.
pub fn dce(f: &Function) -> (Function, usize) {
    let n = f.instrs.len();
    let mut live = vec![false; n];

    // mark
    for (i, instr) in f.instrs.iter().enumerate().rev() {
        if instr.op.is_root() {
            live[i] = true;
        }
        if live[i] {
            for v in instr.op.operands() {
                live[v.0 as usize] = true;
            }
        }
    }
    // a reverse scan handles straight-line defs-before-uses in one pass,
    // but operands of late-marked instrs may precede them; iterate to fix.
    let mut changed = true;
    while changed {
        changed = false;
        for (i, instr) in f.instrs.iter().enumerate().rev() {
            if live[i] {
                for v in instr.op.operands() {
                    if !live[v.0 as usize] {
                        live[v.0 as usize] = true;
                        changed = true;
                    }
                }
            }
        }
    }

    // sweep
    let mut rw = Rewriter::new(n);
    let mut removed = 0usize;
    for (i, instr) in f.instrs.iter().enumerate() {
        if live[i] {
            rw.copy(ValueId(i as u32), instr);
        } else {
            removed += 1;
        }
    }
    (rw.finish(f), removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, passes::mem2reg, Op};

    #[test]
    fn unreferenced_chain_is_swept() {
        let f = mem2reg(
            &lower_kernel(
                &parse_kernel(
                    "__kernel void k(__global int *A, __global int *B) {
                        int i = get_global_id(0);
                        int dead = A[i] * 1234;
                        int dead2 = dead + 1;
                        B[i] = 7;
                     }",
                )
                .unwrap(),
            )
            .unwrap(),
        )
        .0;
        let (g, removed) = dce(&f);
        assert!(removed >= 3);
        assert_eq!(g.count(|o| matches!(o, Op::ConstInt(1234))), 0);
        assert_eq!(g.count(|o| matches!(o, Op::StoreGlobal { .. })), 1);
        // the B-gep chain must survive
        assert!(g.count(|o| matches!(o, Op::Gep { .. })) >= 1);
    }

    #[test]
    fn everything_live_means_no_removal() {
        let f = mem2reg(
            &lower_kernel(
                &parse_kernel(
                    "__kernel void k(__global int *A, __global int *B) {
                        int i = get_global_id(0);
                        B[i] = A[i] + 1;
                     }",
                )
                .unwrap(),
            )
            .unwrap(),
        )
        .0;
        let before = f.instrs.len();
        let (g, removed) = dce(&f);
        assert_eq!(removed, 0);
        assert_eq!(g.instrs.len(), before);
    }
}
