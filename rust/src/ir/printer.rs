//! LLVM-flavoured textual IR printer (diagnostics, docs, golden tests).
//! The output shape mirrors Table I(b)/(c) of the paper.

use super::instr::{Function, IrType, Op};

fn ty_str(ty: IrType) -> &'static str {
    match ty {
        IrType::Int => "i32",
        IrType::Float => "f32",
        IrType::Ptr => "i32*",
        IrType::StackPtr => "i32**",
        IrType::Void => "void",
    }
}

/// Render `f` as LLVM-ish text.
pub fn print_function(f: &Function) -> String {
    let mut out = String::new();
    let params: Vec<String> = f
        .params
        .iter()
        .map(|p| {
            let star = if matches!(p.kind, crate::frontend::ParamKind::GlobalPtr) {
                "*"
            } else {
                ""
            };
            format!("{:?}{} %{}", p.ty, star, p.name).to_lowercase()
        })
        .collect();
    out.push_str(&format!("define void @{}({}) {{\n", f.name, params.join(", ")));
    for (i, instr) in f.instrs.iter().enumerate() {
        let line = match &instr.op {
            Op::Alloca { name } => format!("%{i} = alloca i32, align 4 ; {name}"),
            Op::Store { val, slot } => format!("store {} {}, {}", ty_str(f.value_ty(*val)), val, slot),
            Op::Load { slot } => format!("%{i} = load {}", slot),
            Op::ParamPtr { index } => {
                format!("%{i} = param.ptr {} ; %{}", index, f.params[*index].name)
            }
            Op::ParamVal { index } => {
                format!("%{i} = param.val {} ; %{}", index, f.params[*index].name)
            }
            Op::Gep { base, idx } =>

                format!("%{i} = getelementptr inbounds i32* {base}, i32 {idx}"),
            Op::LoadGlobal { addr } => format!("%{i} = load i32* {addr}"),
            Op::StoreGlobal { val, addr } => format!("store i32 {val}, i32* {addr}"),
            Op::GlobalId => format!("%{i} = call i32 @get_global_id(i32 0)"),
            Op::ConstInt(v) => format!("%{i} = i32 {v}"),
            Op::ConstFloat(v) => format!("%{i} = f32 {v}"),
            Op::Bin { op, lhs, rhs } => {
                let nsw = if instr.ty == IrType::Int { " nsw" } else { "" };
                format!("%{i} = {}{nsw} {} {}, {}", op.name(), ty_str(instr.ty), lhs, rhs)
            }
        };
        out.push_str("  ");
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, optimize};

    #[test]
    fn prints_optimized_paper_kernel() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void example_kernel(__global int *A, __global int *B) {
                    int idx = get_global_id(0);
                    int x = A[idx];
                    B[idx] = (x*(x*(16*x*x-20)*x+5));
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let (g, _) = optimize(&f);
        let text = print_function(&g);
        assert!(text.contains("@example_kernel"));
        assert!(text.contains("get_global_id"));
        assert!(text.contains("getelementptr inbounds"));
        assert!(text.contains("mul nsw"));
        // Table I(c) ends with the global store
        assert!(text.trim_end().ends_with("}"));
        assert!(text.contains("store i32"));
    }
}
