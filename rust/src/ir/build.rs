//! AST → naive memory-form IR (the Clang `-O0` stand-in).
//!
//! Reproduces the shape of Table I(b): an `alloca` per parameter and
//! local, stores of the incoming parameter values, and a load before
//! every use. `mad(a,b,c)` lowers to `mul`+`add` (re-fused later by the
//! FU-aware transform); `-x` lowers to `0 - x`; `min`/`max` lower to
//! dedicated binops (the DSP-block FU exposes a compare-select mode).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::frontend::{BinOp, Expr, Kernel, ParamKind, Stmt};

use super::instr::{Function, Instr, IrBinOp, IrType, Op, ValueId};

struct Builder<'k> {
    kernel: &'k Kernel,
    instrs: Vec<Instr>,
    /// variable name → its alloca slot
    slots: HashMap<String, (ValueId, IrType)>,
}

/// Lower a semantically-checked kernel to naive IR.
pub fn lower_kernel(kernel: &Kernel) -> Result<Function> {
    let mut b = Builder { kernel, instrs: Vec::new(), slots: HashMap::new() };

    // Parameter allocas + stores, mirroring Clang -O0 prologue.
    for (i, p) in kernel.params.iter().enumerate() {
        match p.kind {
            ParamKind::GlobalPtr => {
                let slot = b.push(Op::Alloca { name: p.name.clone() }, IrType::StackPtr);
                let val = b.push(Op::ParamPtr { index: i }, IrType::Ptr);
                b.push(Op::Store { val, slot }, IrType::Void);
                b.slots.insert(p.name.clone(), (slot, IrType::Ptr));
            }
            ParamKind::Scalar => {
                let ty: IrType = p.ty.into();
                let slot = b.push(Op::Alloca { name: p.name.clone() }, IrType::StackPtr);
                let val = b.push(Op::ParamVal { index: i }, ty);
                b.push(Op::Store { val, slot }, IrType::Void);
                b.slots.insert(p.name.clone(), (slot, ty));
            }
        }
    }

    for stmt in &kernel.body {
        b.stmt(stmt)?;
    }

    Ok(Function {
        name: kernel.name.clone(),
        params: kernel.params.clone(),
        instrs: b.instrs,
    })
}

impl<'k> Builder<'k> {
    fn push(&mut self, op: Op, ty: IrType) -> ValueId {
        self.instrs.push(Instr { op, ty });
        ValueId((self.instrs.len() - 1) as u32)
    }

    fn stmt(&mut self, stmt: &Stmt) -> Result<()> {
        match stmt {
            Stmt::Decl { ty, name, init } => {
                let slot = self.push(Op::Alloca { name: name.clone() }, IrType::StackPtr);
                let v = self.expr(init)?;
                self.push(Op::Store { val: v, slot }, IrType::Void);
                self.slots.insert(name.clone(), (slot, (*ty).into()));
                Ok(())
            }
            Stmt::AssignVar { name, expr } => {
                let v = self.expr(expr)?;
                let (slot, _) = self.slots[name.as_str()];
                self.push(Op::Store { val: v, slot }, IrType::Void);
                Ok(())
            }
            Stmt::AssignIndex { array, index, expr } => {
                let v = self.expr(expr)?;
                let idx = self.expr(index)?;
                let base = self.load_var(array)?;
                let addr = self.push(Op::Gep { base, idx }, IrType::Ptr);
                self.push(Op::StoreGlobal { val: v, addr }, IrType::Void);
                Ok(())
            }
        }
    }

    fn load_var(&mut self, name: &str) -> Result<ValueId> {
        let Some(&(slot, ty)) = self.slots.get(name) else {
            bail!("internal: unknown variable '{name}' survived sema");
        };
        Ok(self.push(Op::Load { slot }, ty))
    }

    fn expr(&mut self, e: &Expr) -> Result<ValueId> {
        match e {
            Expr::IntLit(v) => Ok(self.push(Op::ConstInt(*v), IrType::Int)),
            Expr::FloatLit(v) => Ok(self.push(Op::ConstFloat(*v), IrType::Float)),
            Expr::Var(name) => self.load_var(name),
            Expr::Index(array, idx) => {
                let idx = self.expr(idx)?;
                let base = self.load_var(array)?;
                let addr = self.push(Op::Gep { base, idx }, IrType::Ptr);
                let ty: IrType = self
                    .kernel
                    .param(array)
                    .map(|p| p.ty.into())
                    .unwrap_or(IrType::Int);
                Ok(self.push(Op::LoadGlobal { addr }, ty))
            }
            Expr::Neg(inner) => {
                let v = self.expr(inner)?;
                let ty = self.instrs[v.0 as usize].ty;
                let zero = match ty {
                    IrType::Float => self.push(Op::ConstFloat(0.0), ty),
                    _ => self.push(Op::ConstInt(0), ty),
                };
                Ok(self.push(Op::Bin { op: IrBinOp::Sub, lhs: zero, rhs: v }, ty))
            }
            Expr::Binary(op, l, r) => {
                let lv = self.expr(l)?;
                let rv = self.expr(r)?;
                let ty = self.instrs[lv.0 as usize].ty;
                let ir_op = match op {
                    BinOp::Add => IrBinOp::Add,
                    BinOp::Sub => IrBinOp::Sub,
                    BinOp::Mul => IrBinOp::Mul,
                    BinOp::Shl => IrBinOp::Shl,
                    BinOp::Shr => IrBinOp::Shr,
                };
                Ok(self.push(Op::Bin { op: ir_op, lhs: lv, rhs: rv }, ty))
            }
            Expr::Call(name, args) => match name.as_str() {
                "get_global_id" => Ok(self.push(Op::GlobalId, IrType::Int)),
                "min" | "max" => {
                    let lv = self.expr(&args[0])?;
                    let rv = self.expr(&args[1])?;
                    let ty = self.instrs[lv.0 as usize].ty;
                    let op = if name == "min" { IrBinOp::Min } else { IrBinOp::Max };
                    Ok(self.push(Op::Bin { op, lhs: lv, rhs: rv }, ty))
                }
                "mad" => {
                    let a = self.expr(&args[0])?;
                    let bv = self.expr(&args[1])?;
                    let c = self.expr(&args[2])?;
                    let ty = self.instrs[a.0 as usize].ty;
                    let m = self.push(Op::Bin { op: IrBinOp::Mul, lhs: a, rhs: bv }, ty);
                    Ok(self.push(Op::Bin { op: IrBinOp::Add, lhs: m, rhs: c }, ty))
                }
                other => bail!("internal: unknown builtin '{other}' survived sema"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    #[test]
    fn naive_ir_has_table1b_shape() {
        let f = lower_kernel(&parse_kernel(PAPER).unwrap()).unwrap();
        // Table I(b): allocas for 2 params + 2 locals, loads around uses.
        assert_eq!(f.count(|o| matches!(o, Op::Alloca { .. })), 4);
        assert!(f.count(|o| matches!(o, Op::Load { .. })) >= 7);
        assert_eq!(f.count(|o| matches!(o, Op::StoreGlobal { .. })), 1);
        assert_eq!(f.count(|o| matches!(o, Op::GlobalId)), 1);
        // 5 multiplies, 1 sub, 1 add as written
        assert_eq!(
            f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })),
            5
        );
        assert_eq!(
            f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Sub, .. })),
            1
        );
        assert_eq!(
            f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })),
            1
        );
    }

    #[test]
    fn mad_lowers_to_mul_add() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *A, __global int *B) {
                    int i = get_global_id(0);
                    B[i] = mad(A[i], 3, 4);
                }",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Mul, .. })), 1);
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Add, .. })), 1);
    }

    #[test]
    fn neg_lowers_to_zero_sub() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void k(__global int *A, __global int *B) {
                    int i = get_global_id(0);
                    B[i] = -A[i];
                }",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(f.count(|o| matches!(o, Op::Bin { op: IrBinOp::Sub, .. })), 1);
        assert!(f.count(|o| matches!(o, Op::ConstInt(0))) >= 1);
    }
}
