//! IR data structures.

use crate::frontend::{Param, Type};

/// A value in the function: the result of the instruction with the same
/// index in [`Function::instrs`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub u32);

impl std::fmt::Display for ValueId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "%{}", self.0)
    }
}

/// Scalar IR types. `Short` is widened to `Int` semantics on the
/// emulated 32-bit datapath but retained for resource modelling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IrType {
    Int,
    Float,
    /// Pointer to global memory (buffer parameters, GEP results).
    Ptr,
    /// Alloca result (stack slot address).
    StackPtr,
    Void,
}

impl From<Type> for IrType {
    fn from(t: Type) -> Self {
        match t {
            Type::Int | Type::Short => IrType::Int,
            Type::Float => IrType::Float,
        }
    }
}

/// Binary operations. `Min`/`Max` come from the OpenCL builtins; the
/// rest from operators. Division never reaches the IR (rejected at
/// parse time).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IrBinOp {
    Add,
    Sub,
    Mul,
    Shl,
    Shr,
    Min,
    Max,
}

impl IrBinOp {
    pub fn name(self) -> &'static str {
        match self {
            IrBinOp::Add => "add",
            IrBinOp::Sub => "sub",
            IrBinOp::Mul => "mul",
            IrBinOp::Shl => "shl",
            IrBinOp::Shr => "ashr",
            IrBinOp::Min => "min",
            IrBinOp::Max => "max",
        }
    }

    pub fn is_commutative(self) -> bool {
        matches!(self, IrBinOp::Add | IrBinOp::Mul | IrBinOp::Min | IrBinOp::Max)
    }
}

/// Instruction opcodes.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Stack slot for a local variable or by-value parameter (pre-mem2reg).
    Alloca { name: String },
    /// Store to an alloca.
    Store { val: ValueId, slot: ValueId },
    /// Load from an alloca.
    Load { slot: ValueId },
    /// Address of a kernel buffer parameter (by parameter index).
    ParamPtr { index: usize },
    /// Value of a scalar kernel parameter.
    ParamVal { index: usize },
    /// `getelementptr inbounds base, idx`.
    Gep { base: ValueId, idx: ValueId },
    /// Load through a global pointer.
    LoadGlobal { addr: ValueId },
    /// Store through a global pointer. The IR's only side effect.
    StoreGlobal { val: ValueId, addr: ValueId },
    /// `call get_global_id(0)`.
    GlobalId,
    ConstInt(i64),
    ConstFloat(f64),
    Bin { op: IrBinOp, lhs: ValueId, rhs: ValueId },
}

impl Op {
    /// Does this op have an observable side effect (a DCE root)?
    pub fn is_root(&self) -> bool {
        matches!(self, Op::StoreGlobal { .. })
    }

    /// Operands read by this op.
    pub fn operands(&self) -> Vec<ValueId> {
        match self {
            Op::Store { val, slot } => vec![*val, *slot],
            Op::Load { slot } => vec![*slot],
            Op::Gep { base, idx } => vec![*base, *idx],
            Op::LoadGlobal { addr } => vec![*addr],
            Op::StoreGlobal { val, addr } => vec![*val, *addr],
            Op::Bin { lhs, rhs, .. } => vec![*lhs, *rhs],
            _ => vec![],
        }
    }

    /// Rewrite operands through `f` (used by passes when renaming).
    pub fn map_operands(&mut self, f: impl Fn(ValueId) -> ValueId) {
        match self {
            Op::Store { val, slot } => {
                *val = f(*val);
                *slot = f(*slot);
            }
            Op::Load { slot } => *slot = f(*slot),
            Op::Gep { base, idx } => {
                *base = f(*base);
                *idx = f(*idx);
            }
            Op::LoadGlobal { addr } => *addr = f(*addr),
            Op::StoreGlobal { val, addr } => {
                *val = f(*val);
                *addr = f(*addr);
            }
            Op::Bin { lhs, rhs, .. } => {
                *lhs = f(*lhs);
                *rhs = f(*rhs);
            }
            _ => {}
        }
    }
}

/// One instruction: an opcode plus its result type.
#[derive(Debug, Clone, PartialEq)]
pub struct Instr {
    pub op: Op,
    pub ty: IrType,
}

/// A lowered kernel: straight-line SSA over one basic block.
#[derive(Debug, Clone)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub instrs: Vec<Instr>,
}

impl Function {
    pub fn value_ty(&self, v: ValueId) -> IrType {
        self.instrs[v.0 as usize].ty
    }

    pub fn op(&self, v: ValueId) -> &Op {
        &self.instrs[v.0 as usize].op
    }

    /// Count of instructions with a given predicate (test/report helper).
    pub fn count(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.instrs.iter().filter(|i| pred(&i.op)).count()
    }
}
