//! The paper's six benchmark kernels (Fig. 7 / Table III) as OpenCL-C
//! sources, plus the published measurements they are compared against.
//!
//! The paper names the benchmarks and their replication factors —
//! chebyshev(16), sgfilter(10), mibench(7), qspline(3), poly1(9),
//! poly2(10) — but not their sources; the kernels here follow the
//! workload descriptions of the same group's overlay papers
//! (FCCM'15 [13], DATE'16 [14], DeCO/FCCM'16 [15]): polynomial and
//! filter arithmetic over streamed operands. Each source is shaped so
//! the FU-aware mapping on the 8×8 two-DSP overlay reproduces the
//! paper's replication factor exactly (checked by tests).

use crate::overlay::{FuType, OverlaySpec};

/// Published Table III row (direct-FPGA implementation) + Fig. 7 data.
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    /// Replication factor in Fig. 5/7/Table III, e.g. chebyshev(16).
    pub replication: usize,
    /// Vivado PAR time, seconds (Table III).
    pub vivado_par_s: f64,
    /// Direct-FPGA Fmax, MHz.
    pub fpga_fmax_mhz: f64,
    /// Direct-FPGA resources.
    pub fpga_dsp: usize,
    pub fpga_slices: usize,
    /// Overlay PAR time on the x86 workstation, seconds (Table III).
    pub overlay_par_s: f64,
}

/// One benchmark: name, source, paper-reported numbers.
#[derive(Debug, Clone, Copy)]
pub struct Benchmark {
    pub name: &'static str,
    pub source: &'static str,
    pub paper: PaperRow,
}

/// The paper's example kernel (§III, Table I) — also the Chebyshev
/// benchmark: B = x·(x·(16·x·x−20)·x+5) = T₅(x).
pub const CHEBYSHEV: &str = r#"
__kernel void chebyshev(__global int *A, __global int *B)
{
    int idx = get_global_id(0);
    int x = A[idx];
    B[idx] = (x*(x*(16*x*x-20)*x+5));
}
"#;

/// Savitzky–Golay-style smoothing: a quartic response in the sample
/// stream combined with a quadratic in the weight stream.
pub const SGFILTER: &str = r#"
__kernel void sgfilter(__global int *x, __global int *w, __global int *y)
{
    int i = get_global_id(0);
    int a = x[i];
    int b = w[i];
    int p = (((-3*a + 12)*a + 17)*a + 12)*a - 3;
    int q = (5*b - 2)*b + 9;
    y[i] = p*q + a*b;
}
"#;

/// MiBench-style integer kernel (bit-exact select/accumulate mix).
pub const MIBENCH: &str = r#"
__kernel void mibench(__global int *a, __global int *b, __global int *out)
{
    int i = get_global_id(0);
    int x = a[i];
    int y = b[i];
    int t1 = max(x, y);
    int t2 = min(x, y);
    int u = (t1*3 + 5)*t2;
    int v = (t2*7 - 9)*t1;
    int w1 = u*v + t1;
    int w2 = u - v;
    int z1 = w1*w1;
    int z2 = (w2*11 + 2)*w1;
    out[i] = max(z1, z2) * (w1 + w2);
}
"#;

/// Quadratic-spline evaluation: three knot polynomials blended with
/// the weight stream (the largest kernel of the set).
pub const QSPLINE: &str = r#"
__kernel void qspline(__global int *t, __global int *u, __global int *y)
{
    int i = get_global_id(0);
    int x = t[i];
    int w = u[i];
    int s0 = (x*3 + 2)*x + 7;
    int s1 = (x*5 - 4)*x + 11;
    int s2 = (x*7 + 6)*x - 13;
    int b0 = (w*2 + 1)*w + 3;
    int b1 = (w*4 - 3)*w + 5;
    int b2 = (w*6 + 5)*w - 7;
    int p0 = s0*b0 + x;
    int p1 = s1*b1 + w;
    int p2 = s2*b2 - x;
    int m0 = max(p0, p1);
    int m1 = min(p1, p2);
    int d0 = (p0 - p1)*(p1 - p2);
    int d1 = (m0*9 + 8)*m1;
    int e0 = d0*d1 + p2;
    int e1 = (d0 + d1)*(m0 - m1);
    int f0 = e0*3 - e1;
    int f1 = (e1*5 + 2)*e0;
    y[i] = max(f0, f1)*(e0 + e1) + m0*m1;
}
"#;

/// Degree-8 even polynomial with shared powers (poly1).
pub const POLY1: &str = r#"
__kernel void poly1(__global int *a, __global int *y)
{
    int i = get_global_id(0);
    int x = a[i];
    int x2 = x*x;
    int x4 = x2*x2;
    int p = (x4*3 + 2)*x4;
    int q = (x2*7 - 5)*x2;
    int r = p + q;
    int s = max(p, q);
    y[i] = (r*9 + 4)*r + x2 + s;
}
"#;

/// Two-stream quartic blend (poly2).
pub const POLY2: &str = r#"
__kernel void poly2(__global int *a, __global int *b, __global int *y)
{
    int i = get_global_id(0);
    int x = a[i];
    int z = b[i];
    int p = ((x*6 + 1)*x - 8)*x;
    int q = (z*4 - 3)*z + 2;
    y[i] = p*q + (x + z)*(x - z);
}
"#;

/// All six benchmarks with their paper-reported measurements
/// (Table III; Vivado-x86 / Overlay-PAR-x86 times also plotted in
/// Fig. 7).
pub const BENCHMARKS: [Benchmark; 6] = [
    Benchmark {
        name: "chebyshev",
        source: CHEBYSHEV,
        paper: PaperRow {
            replication: 16,
            vivado_par_s: 240.0,
            fpga_fmax_mhz: 225.0,
            fpga_dsp: 48,
            fpga_slices: 251,
            overlay_par_s: 0.2,
        },
    },
    Benchmark {
        name: "sgfilter",
        source: SGFILTER,
        paper: PaperRow {
            replication: 10,
            vivado_par_s: 396.0,
            fpga_fmax_mhz: 185.0,
            fpga_dsp: 100,
            fpga_slices: 797,
            overlay_par_s: 0.29,
        },
    },
    Benchmark {
        name: "mibench",
        source: MIBENCH,
        paper: PaperRow {
            replication: 7,
            vivado_par_s: 245.0,
            fpga_fmax_mhz: 230.0,
            fpga_dsp: 21,
            fpga_slices: 403,
            overlay_par_s: 0.27,
        },
    },
    Benchmark {
        name: "qspline",
        source: QSPLINE,
        paper: PaperRow {
            replication: 3,
            vivado_par_s: 242.0,
            fpga_fmax_mhz: 165.0,
            fpga_dsp: 36,
            fpga_slices: 307,
            overlay_par_s: 0.17,
        },
    },
    Benchmark {
        name: "poly1",
        source: POLY1,
        paper: PaperRow {
            replication: 9,
            vivado_par_s: 256.0,
            fpga_fmax_mhz: 175.0,
            fpga_dsp: 36,
            fpga_slices: 425,
            overlay_par_s: 0.18,
        },
    },
    Benchmark {
        name: "poly2",
        source: POLY2,
        paper: PaperRow {
            replication: 10,
            vivado_par_s: 270.0,
            fpga_fmax_mhz: 172.0,
            fpga_dsp: 40,
            fpga_slices: 453,
            overlay_par_s: 0.23,
        },
    },
];

/// Look a benchmark up by name.
pub fn by_name(name: &str) -> Option<&'static Benchmark> {
    BENCHMARKS.iter().find(|b| b.name == name)
}

/// The paper's reference overlay for Fig. 7 / Table III.
pub fn reference_overlay() -> OverlaySpec {
    OverlaySpec::new(8, 8, FuType::Dsp2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::JitCompiler;

    #[test]
    fn all_benchmarks_compile_on_the_reference_overlay() {
        let jit = JitCompiler::new(reference_overlay());
        for b in &BENCHMARKS {
            let k = jit
                .compile(b.source)
                .unwrap_or_else(|e| panic!("{}: {e:#}", b.name));
            assert_eq!(k.name, b.name);
        }
    }

    #[test]
    fn replication_factors_match_the_paper() {
        // Fig. 7 brackets: chebyshev(16), sgfilter(10), mibench(7),
        // qspline(3), poly1(9), poly2(10)
        let jit = JitCompiler::new(reference_overlay());
        let mut got = Vec::new();
        for b in &BENCHMARKS {
            let k = jit.compile(b.source).unwrap();
            got.push((b.name, k.copies(), k.single.num_fus(), k.dfg.num_io()));
        }
        let factors: Vec<usize> = got.iter().map(|&(_, f, _, _)| f).collect();
        let want: Vec<usize> = BENCHMARKS.iter().map(|b| b.paper.replication).collect();
        assert_eq!(factors, want, "details: {got:?}");
    }

    #[test]
    fn by_name_finds_all() {
        for b in &BENCHMARKS {
            assert!(by_name(b.name).is_some());
        }
        assert!(by_name("nope").is_none());
    }
}
