//! Multi-window burn-rate SLO engine on a deterministic clock.
//!
//! Objectives are declared per traffic slice — `(tenant, priority)`
//! selectors with wildcards — in the Google-SRE style: an error
//! *budget* (the tolerated bad fraction: 1% of interactive requests
//! may exceed the latency SLO, 10% of submits may be rejected under a
//! 90% availability target) and a *burn rate*, the ratio of observed
//! bad fraction to that budget. Burning at 1.0 spends exactly the
//! budget; sustained burn above it exhausts the budget early.
//!
//! Alerting uses the classic two-window rule: an alert **fires** when
//! both a fast window (reacts in one tick) and a slow window (filters
//! blips) burn above their thresholds, and **clears** when the fast
//! window drops back below — fast detection, hysteretic clearing, no
//! flapping on a single bad window. Transitions are typed
//! [`SloAlert`]s appended to a [`BoundedLog`], and the instantaneous
//! worst-case burn is exported as a `[0, ∞)` gauge the admission
//! pressure fold and the autoscaler consume
//! ([`SloCollector::burn`]).
//!
//! Nothing here reads a wall clock. Windows close only when the owner
//! calls [`SloCollector::tick`] with an explicit nanosecond stamp, so
//! a scripted test can pin the *exact tick* an alert fires and
//! clears — and does, below.

use std::sync::{Arc, Mutex};

use super::hist::LatencyHist;
use super::timeseries::TimeSeries;
use crate::util::BoundedLog;

/// What an objective bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloKind {
    /// "p99 latency ≤ `target` ms": a completion slower than `target`
    /// spends error budget.
    LatencyP99,
    /// "availability ≥ `target`": a rejected / shed / errored submit
    /// spends error budget.
    Availability,
}

impl SloKind {
    pub fn name(&self) -> &'static str {
        match self {
            SloKind::LatencyP99 => "latency_p99",
            SloKind::Availability => "availability",
        }
    }
}

/// One declared objective over a traffic slice.
#[derive(Debug, Clone)]
pub struct SloObjective {
    /// Stable alert label, e.g. `"interactive-p99"`.
    pub name: String,
    /// Tenant selector (`None` = every tenant).
    pub tenant: Option<String>,
    /// Priority-class selector (`None` = both classes).
    pub interactive: Option<bool>,
    pub kind: SloKind,
    /// `LatencyP99`: the SLO in milliseconds. `Availability`: the
    /// target fraction, e.g. `0.9`.
    pub target: f64,
    /// Tolerated bad fraction (the error budget). For availability
    /// objectives this is `1 - target`.
    pub budget: f64,
}

impl SloObjective {
    /// "Interactive p99 ≤ `slo_ms`" with a 1% budget.
    pub fn interactive_p99(slo_ms: f64) -> SloObjective {
        SloObjective {
            name: "interactive-p99".to_string(),
            tenant: None,
            interactive: Some(true),
            kind: SloKind::LatencyP99,
            target: slo_ms,
            budget: 0.01,
        }
    }

    /// "Availability ≥ `target`" over all traffic.
    pub fn availability(target: f64) -> SloObjective {
        SloObjective {
            name: "availability".to_string(),
            tenant: None,
            interactive: None,
            kind: SloKind::Availability,
            target,
            budget: (1.0 - target).max(1e-6),
        }
    }

    fn matches(&self, tenant: &str, interactive: bool) -> bool {
        if let Some(t) = &self.tenant {
            if t != tenant {
                return false;
            }
        }
        if let Some(i) = self.interactive {
            if i != interactive {
                return false;
            }
        }
        true
    }
}

/// The declared objectives plus the shared burn-rate alert rule.
#[derive(Debug, Clone)]
pub struct SloPolicy {
    pub objectives: Vec<SloObjective>,
    /// Fast-window width in ticks (reacts quickly).
    pub fast_windows: usize,
    /// Slow-window width in ticks (filters blips).
    pub slow_windows: usize,
    /// Fast-window burn threshold; firing requires both.
    pub fast_burn: f64,
    /// Slow-window burn threshold.
    pub slow_burn: f64,
    /// Windows retained per objective ring.
    pub capacity: usize,
    /// Alert-log bound.
    pub max_alerts: usize,
}

impl Default for SloPolicy {
    fn default() -> SloPolicy {
        SloPolicy {
            objectives: Vec::new(),
            fast_windows: 1,
            slow_windows: 6,
            fast_burn: 2.0,
            slow_burn: 1.0,
            capacity: 64,
            max_alerts: 256,
        }
    }
}

impl SloPolicy {
    /// The common serving policy: interactive p99 plus a fleet
    /// availability floor.
    pub fn serving(slo_ms: f64, availability: f64) -> SloPolicy {
        SloPolicy {
            objectives: vec![
                SloObjective::interactive_p99(slo_ms),
                SloObjective::availability(availability),
            ],
            ..SloPolicy::default()
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.fast_windows == 0 || self.slow_windows < self.fast_windows {
            anyhow::bail!(
                "slo: need 1 <= fast_windows ({}) <= slow_windows ({})",
                self.fast_windows,
                self.slow_windows
            );
        }
        if self.capacity < self.slow_windows {
            anyhow::bail!(
                "slo: ring capacity {} cannot cover slow window {}",
                self.capacity,
                self.slow_windows
            );
        }
        for o in &self.objectives {
            if !(o.budget > 0.0 && o.budget <= 1.0) {
                anyhow::bail!("slo '{}': budget {} outside (0, 1]", o.name, o.budget);
            }
            if o.target <= 0.0 {
                anyhow::bail!("slo '{}': target {} must be positive", o.name, o.target);
            }
        }
        Ok(())
    }
}

/// Alert transition direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertState {
    Firing,
    Cleared,
}

impl AlertState {
    pub fn name(&self) -> &'static str {
        match self {
            AlertState::Firing => "firing",
            AlertState::Cleared => "cleared",
        }
    }
}

/// One burn-rate alert transition.
#[derive(Debug, Clone, PartialEq)]
pub struct SloAlert {
    pub objective: String,
    pub kind: SloKind,
    pub state: AlertState,
    /// 1-based tick index at which the transition happened.
    pub tick: u64,
    /// The caller clock at that tick.
    pub now_ns: u64,
    /// Fast-window burn at the transition.
    pub fast_burn: f64,
    /// Slow-window burn at the transition.
    pub slow_burn: f64,
}

/// Counters accumulated inside one open window for one objective.
#[derive(Debug, Clone, Default)]
struct WindowCounts {
    good: u64,
    bad: u64,
    submits: u64,
    completions: u64,
    hist: LatencyHist,
}

struct ObjectiveState {
    objective: SloObjective,
    cur: WindowCounts,
    series: TimeSeries<WindowCounts>,
    firing: bool,
}

/// Cheap copyable summary for `ServingStats` / `prometheus()`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SloStats {
    pub objectives: usize,
    /// Objectives currently in the firing state.
    pub firing: usize,
    /// Alert transitions emitted since creation.
    pub alerts_total: u64,
    pub alerts_dropped: u64,
    /// Worst fast-window burn across objectives at the last tick.
    pub burn: f64,
    /// Windows closed so far.
    pub ticks: u64,
}

struct SloEngine {
    policy: SloPolicy,
    states: Vec<ObjectiveState>,
    alerts: BoundedLog<SloAlert>,
    tick_no: u64,
    burn: f64,
    alerts_total: u64,
}

impl SloEngine {
    fn new(policy: SloPolicy) -> SloEngine {
        let states = policy
            .objectives
            .iter()
            .map(|o| ObjectiveState {
                objective: o.clone(),
                cur: WindowCounts::default(),
                series: TimeSeries::new(policy.capacity),
                firing: false,
            })
            .collect();
        let max_alerts = policy.max_alerts;
        SloEngine {
            policy,
            states,
            alerts: BoundedLog::new(max_alerts),
            tick_no: 0,
            burn: 0.0,
            alerts_total: 0,
        }
    }

    fn admitted(&mut self, tenant: &str, interactive: bool) {
        for st in &mut self.states {
            if !st.objective.matches(tenant, interactive) {
                continue;
            }
            st.cur.submits += 1;
            if st.objective.kind == SloKind::Availability {
                st.cur.good += 1;
            }
        }
    }

    fn rejected(&mut self, tenant: &str, interactive: bool) {
        for st in &mut self.states {
            if !st.objective.matches(tenant, interactive) {
                continue;
            }
            st.cur.submits += 1;
            if st.objective.kind == SloKind::Availability {
                st.cur.bad += 1;
            }
        }
    }

    fn completed(&mut self, tenant: &str, interactive: bool, latency_ms: f64, ok: bool) {
        for st in &mut self.states {
            if !st.objective.matches(tenant, interactive) {
                continue;
            }
            st.cur.completions += 1;
            st.cur.hist.record_ms(latency_ms);
            match st.objective.kind {
                SloKind::LatencyP99 => {
                    if ok && latency_ms <= st.objective.target {
                        st.cur.good += 1;
                    } else {
                        st.cur.bad += 1;
                    }
                }
                SloKind::Availability => {
                    if !ok {
                        st.cur.bad += 1;
                    }
                }
            }
        }
    }

    fn burn_over(st: &ObjectiveState, n: usize) -> f64 {
        let bad = st.series.windowed_sum(n, |w| w.bad);
        let total = bad + st.series.windowed_sum(n, |w| w.good);
        if total == 0 {
            return 0.0;
        }
        (bad as f64 / total as f64) / st.objective.budget
    }

    fn tick(&mut self, now_ns: u64) -> Vec<SloAlert> {
        self.tick_no += 1;
        let mut out = Vec::new();
        let mut worst = 0.0f64;
        for st in &mut self.states {
            let closed = std::mem::take(&mut st.cur);
            st.series.push(now_ns, closed);
            let fast = Self::burn_over(st, self.policy.fast_windows);
            let slow = Self::burn_over(st, self.policy.slow_windows);
            worst = worst.max(fast);
            let transition = if !st.firing
                && fast >= self.policy.fast_burn
                && slow >= self.policy.slow_burn
            {
                st.firing = true;
                Some(AlertState::Firing)
            } else if st.firing && fast < self.policy.fast_burn {
                st.firing = false;
                Some(AlertState::Cleared)
            } else {
                None
            };
            if let Some(state) = transition {
                let alert = SloAlert {
                    objective: st.objective.name.clone(),
                    kind: st.objective.kind,
                    state,
                    tick: self.tick_no,
                    now_ns,
                    fast_burn: fast,
                    slow_burn: slow,
                };
                self.alerts.push(alert.clone());
                self.alerts_total += 1;
                out.push(alert);
            }
        }
        self.burn = worst;
        out
    }

    fn stats(&self) -> SloStats {
        SloStats {
            objectives: self.states.len(),
            firing: self.states.iter().filter(|s| s.firing).count(),
            alerts_total: self.alerts_total,
            alerts_dropped: self.alerts.dropped(),
            burn: self.burn,
            ticks: self.tick_no,
        }
    }

    /// Merged per-window histogram over the last `n` closed windows of
    /// the objective named `name` — "p99 over the last N windows".
    fn windowed_hist(&self, name: &str, n: usize) -> Option<LatencyHist> {
        let st = self.states.iter().find(|s| s.objective.name == name)?;
        let mut h = LatencyHist::new();
        for (_, w) in st.series.window(n) {
            h.merge(&w.hist);
        }
        Some(h)
    }

    /// The autoscaler's latency control signal: `(windowed_p99_ms,
    /// target_ms)` for the first declared latency objective, with the
    /// p99 merged over the policy's slow window — the same horizon
    /// the burn alert filters on, so scale decisions and alerts agree
    /// on what "sustained" means. `None` when the policy declares no
    /// latency objective or no window has closed yet.
    fn latency_control_signal(&self) -> Option<(f64, f64)> {
        let obj = self
            .states
            .iter()
            .map(|s| &s.objective)
            .find(|o| o.kind == SloKind::LatencyP99)?;
        let target = obj.target;
        let name = obj.name.clone();
        let h = self.windowed_hist(&name, self.policy.slow_windows)?;
        if h.count() == 0 {
            return None;
        }
        Some((h.p99_ms(), target))
    }
}

/// Thread-safe front of the engine, shared `Arc`-style by the submit
/// path (admission outcomes), the worker path (completions, via
/// [`SloProbe`]), and the owner driving the clock.
pub struct SloCollector {
    inner: Mutex<SloEngine>,
}

impl SloCollector {
    pub fn new(policy: SloPolicy) -> Arc<SloCollector> {
        Arc::new(SloCollector { inner: Mutex::new(SloEngine::new(policy)) })
    }

    pub fn admitted(&self, tenant: &str, interactive: bool) {
        self.inner.lock().unwrap().admitted(tenant, interactive);
    }

    pub fn rejected(&self, tenant: &str, interactive: bool) {
        self.inner.lock().unwrap().rejected(tenant, interactive);
    }

    pub fn completed(&self, tenant: &str, interactive: bool, latency_ms: f64, ok: bool) {
        self.inner.lock().unwrap().completed(tenant, interactive, latency_ms, ok);
    }

    /// Close the current window at caller time `now_ns`, evaluate
    /// every objective's fast+slow burn, and return the alert
    /// transitions this tick produced.
    pub fn tick(&self, now_ns: u64) -> Vec<SloAlert> {
        self.inner.lock().unwrap().tick(now_ns)
    }

    /// Worst fast-window burn across objectives at the last tick.
    pub fn burn(&self) -> f64 {
        self.inner.lock().unwrap().burn
    }

    pub fn stats(&self) -> SloStats {
        self.inner.lock().unwrap().stats()
    }

    /// Every retained alert transition, oldest first.
    pub fn alerts(&self) -> Vec<SloAlert> {
        self.inner.lock().unwrap().alerts.items().to_vec()
    }

    /// The latency control signal for SLO-targeted autoscaling: see
    /// [`SloEngine::latency_control_signal`].
    pub fn latency_control_signal(&self) -> Option<(f64, f64)> {
        self.inner.lock().unwrap().latency_control_signal()
    }

    /// "p99 over the last `n` windows" for the named objective.
    pub fn windowed_p99_ms(&self, objective: &str, n: usize) -> Option<f64> {
        self.inner
            .lock()
            .unwrap()
            .windowed_hist(objective, n)
            .map(|h| h.p99_ms())
    }
}

/// Per-job completion hook carried on a queued job (mirrors
/// `JobTrace`): lets the worker loop report the completion into the
/// SLO engine without knowing about tenants.
#[derive(Clone)]
pub struct SloProbe {
    pub collector: Arc<SloCollector>,
    pub tenant: Arc<str>,
    pub interactive: bool,
}

impl SloProbe {
    pub fn complete(&self, latency_ms: f64, ok: bool) {
        self.collector
            .completed(&self.tenant, self.interactive, latency_ms, ok);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scripted_policy() -> SloPolicy {
        SloPolicy {
            objectives: vec![SloObjective::availability(0.9)],
            fast_windows: 1,
            slow_windows: 3,
            fast_burn: 2.0,
            slow_burn: 1.0,
            capacity: 16,
            max_alerts: 16,
        }
    }

    fn feed(slo: &SloCollector, good: u64, bad: u64) {
        for _ in 0..good {
            slo.admitted("t", false);
        }
        for _ in 0..bad {
            slo.rejected("t", false);
        }
    }

    /// The scripted-clock pin: with budget 0.1, fast=1 window @ burn
    /// ≥ 2 and slow=3 windows @ burn ≥ 1, two healthy windows then a
    /// 50%-bad flood window burns fast = (10/20)/0.1 = 5.0 ≥ 2 and
    /// slow = (10/60)/0.1 ≈ 1.67 ≥ 1 — so the alert must fire at
    /// exactly tick 3 and clear at exactly tick 5 (first healthy
    /// window after the flood drops the fast burn to 0).
    #[test]
    fn burn_alert_fires_and_clears_at_the_exact_scripted_tick() {
        let slo = SloCollector::new(scripted_policy());
        // Ticks 1-2: healthy traffic. slow burn 0.
        for t in 1..=2u64 {
            feed(&slo, 20, 0);
            assert!(slo.tick(t * 1_000).is_empty(), "healthy tick {t}");
        }
        // Tick 3: first flood window crosses both thresholds.
        feed(&slo, 10, 10);
        let a3 = slo.tick(3_000);
        assert_eq!(a3.len(), 1, "fires on the first flood window");
        assert_eq!(a3[0].state, AlertState::Firing);
        assert_eq!(a3[0].tick, 3);
        assert!(a3[0].fast_burn >= 2.0);
        assert!(a3[0].slow_burn >= 1.0);
        // Tick 4: flood continues; still firing, no new transition.
        feed(&slo, 10, 10);
        assert!(slo.tick(4_000).is_empty(), "no re-fire while firing");
        assert_eq!(slo.stats().firing, 1);
        assert!(slo.burn() >= 2.0);
        // Tick 5: recovery window. fast burn 0 → clears exactly here.
        feed(&slo, 20, 0);
        let a5 = slo.tick(5_000);
        assert_eq!(a5.len(), 1, "clears on the first healthy window");
        assert_eq!(a5[0].state, AlertState::Cleared);
        assert_eq!(a5[0].tick, 5);
        assert_eq!(slo.stats().firing, 0);
        let st = slo.stats();
        assert_eq!(st.alerts_total, 2);
        assert_eq!(st.ticks, 5);
        let alerts = slo.alerts();
        assert_eq!(alerts.len(), 2);
        assert_eq!(alerts[0].state, AlertState::Firing);
        assert_eq!(alerts[1].state, AlertState::Cleared);
        assert_eq!(alerts[1].now_ns, 5_000);
    }

    /// A single bad blip must NOT fire: the fast window crosses its
    /// threshold but the slow window filters it.
    #[test]
    fn slow_window_filters_a_single_blip() {
        let mut p = scripted_policy();
        p.slow_windows = 3;
        p.slow_burn = 3.0; // demand sustained burn
        let slo = SloCollector::new(p);
        for t in 1..=2u64 {
            feed(&slo, 20, 0);
            slo.tick(t);
        }
        // One blip: fast = 5 ≥ 2, slow = (10/60)/0.1 = 1.67 < 3.
        feed(&slo, 10, 10);
        assert!(slo.tick(3).is_empty(), "blip filtered by the slow window");
        assert_eq!(slo.stats().firing, 0);
    }

    #[test]
    fn latency_objective_burns_on_slow_completions() {
        let p = SloPolicy {
            objectives: vec![SloObjective::interactive_p99(100.0)],
            fast_windows: 1,
            slow_windows: 1,
            fast_burn: 1.0,
            slow_burn: 1.0,
            capacity: 8,
            max_alerts: 8,
        };
        let slo = SloCollector::new(p);
        // Batch traffic does not match the interactive selector.
        slo.completed("t", false, 5_000.0, true);
        // 9 fast + 1 slow interactive: bad frac 0.1 / budget 0.01 = 10.
        for _ in 0..9 {
            slo.completed("t", true, 10.0, true);
        }
        slo.completed("t", true, 250.0, true);
        let alerts = slo.tick(1);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, SloKind::LatencyP99);
        assert_eq!(alerts[0].state, AlertState::Firing);
        // Windowed p99 comes from the merged per-window histograms.
        let p99 = slo.windowed_p99_ms("interactive-p99", 4).unwrap();
        assert!(p99 > 100.0, "windowed p99 sees the tail: {p99}");
    }

    #[test]
    fn empty_windows_and_empty_policy_are_inert() {
        let slo = SloCollector::new(SloPolicy::default());
        assert!(slo.tick(1).is_empty());
        assert_eq!(slo.burn(), 0.0);
        let st = slo.stats();
        assert_eq!(st.objectives, 0);
        assert_eq!(st.firing, 0);
        assert_eq!(st.ticks, 1);
        // An objective with zero traffic never divides by zero.
        let slo = SloCollector::new(scripted_policy());
        for t in 1..=5 {
            assert!(slo.tick(t).is_empty());
        }
        assert_eq!(slo.burn(), 0.0);
    }

    #[test]
    fn policy_validation_rejects_bad_windows_and_budgets() {
        assert!(SloPolicy::serving(250.0, 0.99).validate().is_ok());
        let mut p = SloPolicy::serving(250.0, 0.99);
        p.fast_windows = 0;
        assert!(p.validate().is_err());
        let mut p = SloPolicy::serving(250.0, 0.99);
        p.slow_windows = 0;
        assert!(p.validate().is_err());
        let mut p = SloPolicy::serving(250.0, 0.99);
        p.capacity = 1;
        assert!(p.validate().is_err());
        let mut p = SloPolicy::serving(250.0, 0.99);
        p.objectives[0].budget = 0.0;
        assert!(p.validate().is_err());
    }
}
