//! Fixed-capacity time-series rings on a caller-advanced clock.
//!
//! [`TimeSeries<T>`] holds the last N periodic snapshots of anything —
//! counter deltas, per-window [`LatencyHist`]s, full stat structs —
//! each stamped with the caller-supplied nanosecond clock at which the
//! window closed. Nothing in here reads a wall clock: the serving
//! stack advances time explicitly (`Coordinator::slo_tick`, the
//! cluster heartbeat clock, scripted test clocks), which is what makes
//! the SLO burn-rate tests fully deterministic.
//!
//! Windowed rates are derived on read: [`TimeSeries::rate_per_sec`]
//! divides a counter delta by the covered wall span, and
//! [`TimeSeries::ratio`] forms hit-rate / shed-rate style quotients
//! over the last N windows. Per-node, per-tenant and per-priority
//! series are just separate rings — the SLO engine in
//! [`crate::obs::slo`] keeps one per objective.
//!
//! [`LatencyHist`]: crate::obs::hist::LatencyHist

use std::collections::VecDeque;

/// A bounded ring of `(closed_at_ns, snapshot)` pairs, oldest evicted
/// first. Capacity is fixed at construction; pushing never grows the
/// ring past it.
#[derive(Debug, Clone)]
pub struct TimeSeries<T> {
    capacity: usize,
    slots: VecDeque<(u64, T)>,
}

impl<T> TimeSeries<T> {
    pub fn new(capacity: usize) -> TimeSeries<T> {
        let capacity = capacity.max(1);
        TimeSeries { capacity, slots: VecDeque::with_capacity(capacity) }
    }

    /// Close a window: append `sample` stamped `now_ns`, evicting the
    /// oldest window once the ring is full.
    pub fn push(&mut self, now_ns: u64, sample: T) {
        if self.slots.len() == self.capacity {
            self.slots.pop_front();
        }
        self.slots.push_back((now_ns, sample));
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn latest(&self) -> Option<&(u64, T)> {
        self.slots.back()
    }

    pub fn oldest(&self) -> Option<&(u64, T)> {
        self.slots.front()
    }

    /// All retained windows, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &(u64, T)> {
        self.slots.iter()
    }

    /// The last `n` windows, oldest first (fewer if the ring holds
    /// fewer).
    pub fn window(&self, n: usize) -> impl Iterator<Item = &(u64, T)> {
        let skip = self.slots.len().saturating_sub(n.max(1));
        self.slots.iter().skip(skip)
    }

    /// Sum `f` over the last `n` windows.
    pub fn windowed_sum(&self, n: usize, f: impl Fn(&T) -> u64) -> u64 {
        self.window(n).map(|(_, t)| f(t)).sum()
    }

    /// `num / den` over the last `n` windows (0.0 when the denominator
    /// is empty) — hit rate, shed rate, error rate.
    pub fn ratio(&self, n: usize, num: impl Fn(&T) -> u64, den: impl Fn(&T) -> u64) -> f64 {
        let d = self.windowed_sum(n, den);
        if d == 0 {
            return 0.0;
        }
        self.windowed_sum(n, num) as f64 / d as f64
    }

    /// Events per second over the last `n` windows: the summed counter
    /// divided by the wall span from the window *before* the oldest
    /// counted one (its close stamp is when the oldest counted window
    /// opened) to the latest close. 0.0 until two windows exist.
    pub fn rate_per_sec(&self, n: usize, f: impl Fn(&T) -> u64) -> f64 {
        if self.slots.len() < 2 {
            return 0.0;
        }
        // Count over the last n windows, but never more than len-1 so
        // an opening stamp always exists.
        let n = n.clamp(1, self.slots.len() - 1);
        let opened = self.slots[self.slots.len() - 1 - n].0;
        let closed = self.slots[self.slots.len() - 1].0;
        let span_ns = closed.saturating_sub(opened);
        if span_ns == 0 {
            return 0.0;
        }
        let events: u64 = self.window(n).map(|(_, t)| f(t)).sum();
        events as f64 / (span_ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_evicts_oldest() {
        let mut ts = TimeSeries::new(3);
        for i in 0..5u64 {
            ts.push(i * 1_000, i);
        }
        assert_eq!(ts.len(), 3);
        assert_eq!(ts.capacity(), 3);
        assert_eq!(ts.oldest(), Some(&(2_000, 2)));
        assert_eq!(ts.latest(), Some(&(4_000, 4)));
        let kept: Vec<u64> = ts.iter().map(|&(_, v)| v).collect();
        assert_eq!(kept, vec![2, 3, 4]);
    }

    #[test]
    fn windowed_rates_use_the_caller_clock() {
        let mut ts = TimeSeries::new(8);
        // One window per second, 10 events each.
        for i in 0..5u64 {
            ts.push((i + 1) * 1_000_000_000, 10u64);
        }
        let qps = ts.rate_per_sec(2, |&c| c);
        assert!((qps - 10.0).abs() < 1e-9, "2-window rate: {qps}");
        let qps_all = ts.rate_per_sec(100, |&c| c);
        assert!((qps_all - 10.0).abs() < 1e-9, "clamped rate: {qps_all}");
    }

    #[test]
    fn ratio_and_degenerate_windows() {
        let mut ts: TimeSeries<(u64, u64)> = TimeSeries::new(4);
        assert_eq!(ts.rate_per_sec(4, |&(a, _)| a), 0.0, "empty ring");
        assert_eq!(ts.ratio(4, |&(a, _)| a, |&(_, b)| b), 0.0, "empty den");
        ts.push(1_000, (3, 10));
        assert_eq!(ts.rate_per_sec(4, |&(a, _)| a), 0.0, "one window");
        ts.push(2_000, (1, 10));
        let r = ts.ratio(1, |&(a, _)| a, |&(_, b)| b);
        assert!((r - 0.1).abs() < 1e-12);
        let r2 = ts.ratio(2, |&(a, _)| a, |&(_, b)| b);
        assert!((r2 - 0.2).abs() < 1e-12);
        // Zero-capacity request clamps to 1.
        let z = TimeSeries::<u64>::new(0);
        assert_eq!(z.capacity(), 1);
    }
}
