//! Log-bucketed latency histograms — the canonical latency carrier.
//!
//! [`LatencyHist`] is an HDR-style histogram with two buckets per
//! octave spanning 1 ns .. ~2 minutes: bucket `i` covers
//! `[2^(i/2), 2^((i+1)/2))` nanoseconds, so every bucket's relative
//! width is `sqrt(2) - 1` (~41%) and a percentile read is exact to
//! within one bucket. Memory is a fixed 75-slot count array — no
//! sampling, no decimation, no allocation after construction.
//!
//! The property the stride-aligned reservoirs it replaces never had:
//! **merge is lossless bucket-wise addition**. Merging shard A into
//! shard B, or node stats in any order, adds count arrays — it is
//! commutative, associative, and drops nothing, so cluster-merged
//! percentiles are computed over *every* recorded completion rather
//! than a thinned sample. `ServeLog` shards, `ServingStats`, and
//! `ServingStats::merge` all carry one of these.
//!
//! Bucket selection is pure integer math (floor log2 via
//! `leading_zeros`, half-octave test via a `u128` square compare), so
//! identical streams always land in identical buckets on every
//! platform — the determinism the scripted SLO tests lean on.

/// Sub-buckets per octave (factor-of-two range).
const SUB: usize = 2;
/// Octaves covered before overflow: 1 ns .. 2^37 ns (~137 s).
const OCTAVES: usize = 37;
/// Finite buckets; index `OVERFLOW` catches everything ≥ 2^37 ns.
const FINITE: usize = SUB * OCTAVES;
const OVERFLOW: usize = FINITE;
/// Total bucket slots (finite + overflow).
pub const HIST_BUCKETS: usize = FINITE + 1;

/// Fixed-memory log-bucketed latency histogram (see module docs).
///
/// Records are nanosecond-resolution; the public API speaks
/// milliseconds because every call site in the serving stack does.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHist {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum_ms: f64,
    max_ms: f64,
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist::new()
    }
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum_ms: 0.0,
            max_ms: 0.0,
        }
    }

    /// Bucket index for a nanosecond value. Pure integer math: floor
    /// log2 via `leading_zeros`, then a half-octave test comparing
    /// `v^2` against `2^(2k+1)` in `u128` (exact — no float rounding
    /// at bucket edges).
    fn bucket_of_ns(ns: u64) -> usize {
        if ns <= 1 {
            return 0;
        }
        let k = (63 - ns.leading_zeros()) as usize;
        let sub = usize::from((ns as u128) * (ns as u128) >= 1u128 << (2 * k + 1));
        (SUB * k + sub).min(OVERFLOW)
    }

    /// Record one latency in milliseconds. Negative and NaN inputs
    /// count as zero-latency (bucket 0) rather than poisoning sums.
    pub fn record_ms(&mut self, ms: f64) {
        let ms = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        let ns = ms * 1e6;
        let bucket = if ns >= u64::MAX as f64 {
            OVERFLOW
        } else {
            Self::bucket_of_ns(ns as u64)
        };
        self.counts[bucket] += 1;
        self.count += 1;
        self.sum_ms += ms;
        if ms > self.max_ms {
            self.max_ms = ms;
        }
    }

    /// Record one latency in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket_of_ns(ns)] += 1;
        self.count += 1;
        let ms = ns as f64 / 1e6;
        self.sum_ms += ms;
        if ms > self.max_ms {
            self.max_ms = ms;
        }
    }

    /// Lossless merge: bucket-wise addition. Commutative and
    /// associative — merge order never changes any percentile.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ms += other.sum_ms;
        if other.max_ms > self.max_ms {
            self.max_ms = other.max_ms;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_ms
    }

    pub fn max_ms(&self) -> f64 {
        self.max_ms
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }

    /// Upper edge of bucket `i` in milliseconds (`f64::INFINITY` for
    /// the overflow bucket). These are the Prometheus `le` edges.
    pub fn bucket_upper_ms(i: usize) -> f64 {
        if i >= OVERFLOW {
            f64::INFINITY
        } else {
            2f64.powf((i + 1) as f64 * 0.5) / 1e6
        }
    }

    /// Representative (geometric-midpoint) value of bucket `i` in ms.
    fn bucket_mid_ms(i: usize) -> f64 {
        if i >= OVERFLOW {
            // No upper edge; report the lower one.
            2f64.powf(FINITE as f64 * 0.5) / 1e6
        } else {
            2f64.powf(i as f64 * 0.5 + 0.25) / 1e6
        }
    }

    /// Percentile within bucket resolution. Rank semantics match the
    /// sorted-sample `metrics::percentile`: the value at index
    /// `round((count - 1) * p)`. Returns the bucket midpoint, clamped
    /// to the observed max so p100 never exceeds a real sample.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 1.0);
        let rank = ((self.count - 1) as f64 * p).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::bucket_mid_ms(i).min(self.max_ms);
            }
        }
        self.max_ms
    }

    pub fn p50_ms(&self) -> f64 {
        self.percentile_ms(0.50)
    }

    pub fn p99_ms(&self) -> f64 {
        self.percentile_ms(0.99)
    }

    pub fn p999_ms(&self) -> f64 {
        self.percentile_ms(0.999)
    }

    /// Cumulative `(le_ms, count)` pairs for every bucket that
    /// actually holds samples, in ascending edge order — exactly the
    /// non-trivial Prometheus `_bucket{le="..."}` series (the caller
    /// adds the `+Inf` edge from [`LatencyHist::count`]).
    pub fn cumulative_buckets_ms(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            out.push((Self::bucket_upper_ms(i), cum));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_hist_is_all_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
        assert_eq!(h.p50_ms(), 0.0);
        assert_eq!(h.p99_ms(), 0.0);
        assert_eq!(h.max_ms(), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
        assert!(h.cumulative_buckets_ms().is_empty());
    }

    #[test]
    fn bucket_edges_are_exact_integer_math() {
        // 2^k lands exactly on the lower edge of bucket 2k.
        for k in 0..37usize {
            assert_eq!(LatencyHist::bucket_of_ns(1u64 << k), SUB * k, "2^{k}");
        }
        // The half-octave edge: floor(2^(k+0.5)) is below the edge
        // (its square < 2^(2k+1)), the next integer is at or above.
        for k in 2..37usize {
            let edge_sq = 1u128 << (2 * k + 1);
            let below = (2f64.powf(k as f64 + 0.5)).floor() as u64;
            let below = if (below as u128 * below as u128) >= edge_sq { below - 1 } else { below };
            assert_eq!(LatencyHist::bucket_of_ns(below), SUB * k, "below edge k={k}");
            assert_eq!(LatencyHist::bucket_of_ns(below + 1), SUB * k + 1, "above edge k={k}");
        }
        // Overflow: anything at or past 2^37 ns pools in the last slot.
        assert_eq!(LatencyHist::bucket_of_ns(1u64 << 37), OVERFLOW);
        assert_eq!(LatencyHist::bucket_of_ns(u64::MAX), OVERFLOW);
        // Sub-nanosecond pools in slot 0.
        assert_eq!(LatencyHist::bucket_of_ns(0), 0);
        assert_eq!(LatencyHist::bucket_of_ns(1), 0);
    }

    #[test]
    fn percentile_is_within_one_bucket_of_exact() {
        let mut h = LatencyHist::new();
        let mut exact: Vec<f64> = Vec::new();
        // A deterministic long-tailed stream: 1..400 scaled unevenly.
        for i in 1..=400u64 {
            let ms = (i as f64) * 0.37 + ((i * i) % 97) as f64 * 0.11;
            h.record_ms(ms);
            exact.push(ms);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &p in &[0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let idx = ((exact.len() - 1) as f64 * p).round() as usize;
            let want = exact[idx];
            let got = h.percentile_ms(p);
            // One bucket of resolution: a factor of sqrt(2) either way.
            let ratio = got / want;
            assert!(
                (0.70..=1.42).contains(&ratio),
                "p{p}: hist {got} vs exact {want} (ratio {ratio})"
            );
        }
    }

    #[test]
    fn merge_is_commutative_and_lossless() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        for i in 0..1000u64 {
            a.record_ms(0.01 * (i + 1) as f64);
        }
        for i in 0..10u64 {
            b.record_ms(100.0 * (i + 1) as f64);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge(a,b) == merge(b,a)");
        assert_eq!(ab.count(), 1010, "no thinning: every sample survives");
        assert_eq!(ab.max_ms(), 1000.0);
        // The merged p999 reflects b's tail even though b is tiny —
        // a thinned reservoir merge would have decimated it.
        assert!(ab.p999_ms() > 50.0, "tail survives merge: {}", ab.p999_ms());
    }

    #[test]
    fn degenerate_inputs_do_not_poison() {
        let mut h = LatencyHist::new();
        h.record_ms(f64::NAN);
        h.record_ms(-5.0);
        h.record_ms(0.0);
        h.record_ms(f64::INFINITY);
        assert_eq!(h.count(), 4);
        assert!(h.sum_ms().is_finite());
        assert!(h.p50_ms().is_finite());
        assert!(h.max_ms().is_finite());
    }

    #[test]
    fn cumulative_buckets_end_at_total_count() {
        let mut h = LatencyHist::new();
        for ms in [0.1, 0.1, 1.0, 10.0, 500.0] {
            h.record_ms(ms);
        }
        let cum = h.cumulative_buckets_ms();
        assert!(!cum.is_empty());
        // Edges ascend, counts ascend, final count is the total.
        for w in cum.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert_eq!(cum.last().unwrap().1, h.count());
    }
}
