//! End-to-end dispatch tracing, flight recorder, and telemetry export.
//!
//! Every gated submit is assigned a stable [`TraceId`] and leaves a
//! tree of phase [`Span`]s behind as it crosses the serving layers:
//! admission triage, route ranking, cache lookup / JIT compile, slot
//! pick on the submit path; queue wait, pack, exec, scatter, verify on
//! the worker path; retry spans for fault-recovery requeues; and hop
//! spans when the cluster frontend spills or fails a dispatch over to
//! a sibling node (the trace context propagates `ClusterFrontend` →
//! `Node` → `Coordinator`, so one trace covers the whole journey).
//!
//! Spans land in per-worker ring buffers in the sharded-log style of
//! the dispatch data plane: each shard is an independently locked,
//! pre-sized ring (a [`Span`] is `Copy` — recording never allocates),
//! and shards are merged only when a reader asks. A disabled sink
//! ([`TraceSink::disabled`]) owns no rings at all and every recording
//! helper bails on one branch — the tracing-off hot path is a no-op
//! recorder, pinned by `rust/tests/obs.rs`.
//!
//! The [`FlightRecorder`] additionally pins one exemplar trace per
//! anomaly class — each admission [`RejectReason`] kind, each injected
//! [`FaultKind`], partition quarantines, and the slowest (p99-tail)
//! completion — so a postmortem dump after an overload or node-death
//! run shows *why* the slow or failed dispatches were slow.
//!
//! Exporters: [`chrome_trace`] renders the merged spans as
//! Chrome-trace-event JSON (load `trace.json` in Perfetto / about:
//! tracing), and `ServingStats::prometheus` (in [`crate::metrics`])
//! emits the Prometheus text exposition. `examples/e2e_serve -- trace`
//! writes both (`TRACE_OUT` / `METRICS_OUT` env override the paths)
//! and re-parses them as part of its acceptance check.
//!
//! High-QPS deployments arm **head-based sampling**: a
//! [`Sampler`] on the sink admits a deterministic
//! (hash-of-candidate-trace-id) subset of submits at trace-begin time.
//! A sampled-out submit runs completely untraced — no spans, no span
//! ids, no exemplar pins — but still lands in every latency histogram
//! and counter, so sampling thins the *trace* stream, never the
//! *metrics* stream. Flight-recorder exemplars are pinned only from
//! sampled-in traces, so a pinned trace id can always be looked up in
//! the rings.
//!
//! The continuous-telemetry layer lives in submodules: [`hist`]
//! (log-bucketed mergeable latency histograms — the canonical latency
//! carrier in `ServeLog` / `ServingStats`), [`timeseries`]
//! (caller-advanced-clock snapshot rings for windowed rates), and
//! [`slo`] (multi-window burn-rate alerting feeding admission and the
//! autoscaler).
//!
//! [`RejectReason`]: crate::admission::RejectReason
//! [`FaultKind`]: crate::admission::FaultKind

pub mod hist;
pub mod slo;
pub mod timeseries;

pub use hist::LatencyHist;
pub use slo::{
    AlertState, SloAlert, SloCollector, SloKind, SloObjective, SloPolicy, SloProbe, SloStats,
};
pub use timeseries::TimeSeries;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::JsonValue;

/// SplitMix64 finalizer: a cheap, well-mixed 64-bit permutation used
/// to decorrelate sequential trace ids before the sampling modulus.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Head-based trace sampling decision. `ratio(N)` admits a
/// deterministic ~1/N subset of traces by hashing the candidate trace
/// id — the same id always gets the same verdict, on every node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sampler {
    denom: u64,
}

impl Default for Sampler {
    fn default() -> Sampler {
        Sampler::always()
    }
}

impl Sampler {
    /// Trace every submit (the pre-sampling behavior).
    pub fn always() -> Sampler {
        Sampler { denom: 1 }
    }

    /// Trace ~1 in `denom` submits (clamped to ≥ 1).
    pub fn ratio(denom: u64) -> Sampler {
        Sampler { denom: denom.max(1) }
    }

    pub fn denom(&self) -> u64 {
        self.denom
    }

    /// Deterministic verdict for a candidate trace id.
    pub fn admits(&self, candidate: u64) -> bool {
        self.denom <= 1 || mix64(candidate) % self.denom == 0
    }
}

/// Stable identifier of one submit's end-to-end trace (1-based; 0
/// means "not traced").
pub type TraceId = u64;

/// Marker worker index for spans recorded off the worker path (the
/// submit front door, the cluster frontend).
pub const NO_WORKER: i32 = -1;

/// Marker node id for spans recorded by the cluster front door itself
/// (rendered as the `frontend` process in the Chrome trace).
pub const FRONTEND_NODE: u32 = u32::MAX;

/// The phase a span measures. `name()` doubles as the Chrome-trace
/// event name and the flight-recorder dump label.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Root of a cluster-front-door trace (one per cluster submit).
    Frontend,
    /// Root of a coordinator trace; child of [`Phase::Frontend`] when
    /// the submit arrived through the cluster tier.
    Submit,
    /// Admission triage (token bucket, deadline, shed pressure).
    Admission,
    /// Fleet route ranking across spec shards.
    Route,
    /// Kernel-cache lookup that hit.
    CacheLookup,
    /// Kernel-cache miss paying the seconds-class JIT compile.
    Compile,
    /// Slot-aware scheduler pick (including any reconfiguration cost).
    SlotPick,
    /// Queue residency between submit and the worker starting the job.
    QueueWait,
    /// Stream-arena pack on the worker.
    Pack,
    /// Backend execution.
    Exec,
    /// Scatter of results back into the argument buffers.
    Scatter,
    /// Cycle-simulator verification.
    Verify,
    /// A fault-recovery requeue hop to a sibling partition.
    Retry,
    /// A cluster spill/failover hop to a sibling node.
    Hop,
    /// A chunk-boundary preemption of a batch run: the un-run
    /// remainder is requeued as a typed continuation.
    Preempt,
}

/// Every phase, for exhaustive export/report loops.
pub const ALL_PHASES: [Phase; 15] = [
    Phase::Frontend,
    Phase::Submit,
    Phase::Admission,
    Phase::Route,
    Phase::CacheLookup,
    Phase::Compile,
    Phase::SlotPick,
    Phase::QueueWait,
    Phase::Pack,
    Phase::Exec,
    Phase::Scatter,
    Phase::Verify,
    Phase::Retry,
    Phase::Hop,
    Phase::Preempt,
];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Frontend => "frontend",
            Phase::Submit => "submit",
            Phase::Admission => "admission",
            Phase::Route => "route",
            Phase::CacheLookup => "cache_lookup",
            Phase::Compile => "compile",
            Phase::SlotPick => "slot_pick",
            Phase::QueueWait => "queue_wait",
            Phase::Pack => "pack",
            Phase::Exec => "exec",
            Phase::Scatter => "scatter",
            Phase::Verify => "verify",
            Phase::Retry => "retry",
            Phase::Hop => "hop",
            Phase::Preempt => "preempt",
        }
    }
}

/// One recorded phase span. `Copy` on purpose: recording a span into
/// a ring moves 80-odd bytes and never touches the heap.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub trace_id: TraceId,
    /// 1-based, unique within the sink.
    pub span_id: u64,
    /// Parent span id, or 0 for a trace root.
    pub parent: u64,
    pub phase: Phase,
    /// Static detail tag: a reject kind, fault name, spill reason…
    /// Empty when the phase needs none.
    pub tag: &'static str,
    /// Cluster node id ([`FRONTEND_NODE`] for the front door;
    /// 0 for a standalone coordinator).
    pub node: u32,
    /// Worker / partition index, [`NO_WORKER`] off the worker path.
    pub worker: i32,
    /// Start, microseconds since the sink epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Phase-specific payload (e.g. hop: `a0` = home node, `a1` =
    /// chosen sibling; retry: `a0` = attempt, `a1` = sibling
    /// partition; exec: `a0` = batch size).
    pub a0: u64,
    pub a1: u64,
}

/// One shard of the span store: an independently locked, pre-sized
/// ring. New spans overwrite the oldest once full (overwrites are
/// counted sink-wide).
struct ShardRing {
    ring: Mutex<RingInner>,
}

struct RingInner {
    buf: Vec<Span>,
    /// Next overwrite position once `buf` reached capacity.
    head: usize,
}

/// Counters describing a sink's state; all cheap atomic reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSinkStats {
    /// Ring shards owned (0 for a disabled sink).
    pub shards: usize,
    /// Per-shard ring capacity in spans.
    pub capacity: usize,
    /// Spans pre-allocated across all rings (0 for a disabled sink —
    /// the no-op recorder owns no ring memory at all).
    pub allocated_spans: usize,
    /// Spans recorded since creation.
    pub recorded: u64,
    /// Spans overwritten by ring wrap-around (lost to readers).
    pub overwritten: u64,
    /// Traces started (sampled-in only — a sampled-out submit opens
    /// no trace).
    pub traces: u64,
    /// Submits the [`Sampler`] declined to trace.
    pub sampled_out: u64,
}

/// The lock-light span store: N independently locked pre-sized rings
/// plus the [`FlightRecorder`]. Shared via `Arc` by every layer that
/// records (frontend, coordinator submit path, workers, recovery).
pub struct TraceSink {
    enabled: bool,
    epoch: Instant,
    sampler: Sampler,
    next_trace: AtomicU64,
    sampled_out: AtomicU64,
    next_span: AtomicU64,
    recorded: AtomicU64,
    overwritten: AtomicU64,
    capacity: usize,
    shards: Vec<ShardRing>,
    flight: Mutex<FlightRecorder>,
}

impl TraceSink {
    /// An enabled sink with `shards` rings of `capacity` spans each,
    /// tracing every submit. Ring memory is allocated up front so the
    /// record path never grows a buffer.
    pub fn new(shards: usize, capacity: usize) -> Arc<TraceSink> {
        Self::sampled(shards, capacity, Sampler::always())
    }

    /// An enabled sink that head-samples: only submits the `sampler`
    /// admits open a trace; the rest run untraced (but still fully
    /// counted in histograms and stats).
    pub fn sampled(shards: usize, capacity: usize, sampler: Sampler) -> Arc<TraceSink> {
        let shards = shards.max(1);
        let capacity = capacity.max(1);
        Arc::new(TraceSink {
            enabled: true,
            epoch: Instant::now(),
            sampler,
            next_trace: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
            next_span: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            overwritten: AtomicU64::new(0),
            capacity,
            shards: (0..shards)
                .map(|_| ShardRing {
                    ring: Mutex::new(RingInner {
                        buf: Vec::with_capacity(capacity),
                        head: 0,
                    }),
                })
                .collect(),
            flight: Mutex::new(FlightRecorder::new()),
        })
    }

    /// The no-op recorder: owns zero rings, never allocates, and every
    /// recording entry point returns on its first branch. This is what
    /// "tracing off" costs.
    pub fn disabled() -> Arc<TraceSink> {
        Arc::new(TraceSink {
            enabled: false,
            epoch: Instant::now(),
            sampler: Sampler::always(),
            next_trace: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
            next_span: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            overwritten: AtomicU64::new(0),
            capacity: 0,
            shards: Vec::new(),
            flight: Mutex::new(FlightRecorder::new()),
        })
    }

    pub fn sampler(&self) -> Sampler {
        self.sampler
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds since the sink epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Start a new trace; 0 when disabled or when the [`Sampler`]
    /// declines this submit (the candidate id is consumed either way,
    /// so the sampling decision is a stable function of submit order).
    pub fn begin_trace(&self) -> TraceId {
        if !self.enabled {
            return 0;
        }
        let candidate = self.next_trace.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.sampler.admits(candidate) {
            self.sampled_out.fetch_add(1, Ordering::Relaxed);
            return 0;
        }
        candidate
    }

    /// Reserve a span id (so a root can be handed to children before
    /// the root span itself is recorded); 0 when disabled.
    pub fn next_span_id(&self) -> u64 {
        if !self.enabled {
            return 0;
        }
        self.next_span.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record one span into its shard ring. Worker-path spans land in
    /// the worker's shard; front-door spans spread by trace id.
    pub fn record(&self, span: Span) {
        if !self.enabled {
            return;
        }
        let shard = if span.worker >= 0 {
            span.worker as usize % self.shards.len()
        } else {
            span.trace_id as usize % self.shards.len()
        };
        let mut inner = self.shards[shard].ring.lock().unwrap();
        if inner.buf.len() < self.capacity {
            inner.buf.push(span);
        } else {
            let at = inner.head;
            inner.buf[at] = span;
            inner.head = (at + 1) % self.capacity;
            self.overwritten.fetch_add(1, Ordering::Relaxed);
        }
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Merge every shard's retained spans, ordered by
    /// (trace, start, span id). This is the only cross-shard read.
    pub fn spans(&self) -> Vec<Span> {
        let mut all = Vec::new();
        for s in &self.shards {
            all.extend_from_slice(&s.ring.lock().unwrap().buf);
        }
        all.sort_by_key(|s| (s.trace_id, s.start_us, s.span_id));
        all
    }

    pub fn stats(&self) -> TraceSinkStats {
        let sampled_out = self.sampled_out.load(Ordering::Relaxed);
        TraceSinkStats {
            shards: self.shards.len(),
            capacity: self.capacity,
            allocated_spans: self.shards.len() * self.capacity,
            recorded: self.recorded.load(Ordering::Relaxed),
            overwritten: self.overwritten.load(Ordering::Relaxed),
            traces: self.next_trace.load(Ordering::Relaxed) - sampled_out,
            sampled_out,
        }
    }

    /// Pin `trace_id` as the exemplar for an anomaly `(class, kind)`.
    /// Keep-first per key, except [`CLASS_TAIL`] which keeps the
    /// largest `weight` (latency) seen — the slowest completion is by
    /// construction in the p99 tail.
    pub fn pin(&self, class: &'static str, kind: &'static str, trace_id: TraceId, weight: u64) {
        if !self.enabled || trace_id == 0 {
            return;
        }
        self.flight.lock().unwrap().pin(class, kind, trace_id, weight);
    }

    /// The pinned exemplars, sorted by (class, kind).
    pub fn exemplars(&self) -> Vec<Exemplar> {
        self.flight.lock().unwrap().exemplars()
    }

    /// The exemplar pinned for `(class, kind)`, if any.
    pub fn exemplar(&self, class: &str, kind: &str) -> Option<Exemplar> {
        self.flight
            .lock()
            .unwrap()
            .entries
            .iter()
            .find(|e| e.class == class && e.kind == kind)
            .copied()
    }
}

/// Flight-recorder class for admission rejections (kind =
/// `RejectReason::kind()`).
pub const CLASS_REJECT: &str = "reject";
/// Flight-recorder class for injected faults (kind =
/// `FaultKind::name()`).
pub const CLASS_FAULT: &str = "fault";
/// Flight-recorder class for partition quarantines.
pub const CLASS_QUARANTINE: &str = "quarantine";
/// Flight-recorder class for the slowest (p99-tail) completion.
pub const CLASS_TAIL: &str = "tail";
/// Flight-recorder class for chunk-boundary batch preemptions.
pub const CLASS_PREEMPT: &str = "preempt";

/// Hard bound on distinct pinned anomaly keys. The key space is tiny
/// by construction (3 reject kinds + 4 fault kinds + quarantine +
/// tail + preempt), so hitting the bound means a new anomaly class
/// forgot to budget here.
pub const MAX_EXEMPLARS: usize = 64;

/// One pinned exemplar trace for an anomaly class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Exemplar {
    pub class: &'static str,
    pub kind: &'static str,
    /// The pinned trace.
    pub trace_id: TraceId,
    /// Occurrences of this (class, kind) since creation (including
    /// ones that did not replace the pin).
    pub count: u64,
    /// The pin's weight (tail: latency in µs; others: 0).
    pub weight: u64,
}

/// Bounded map (class, kind) → exemplar. Tiny and cold — a plain Vec
/// behind the sink's flight mutex.
struct FlightRecorder {
    entries: Vec<Exemplar>,
    dropped: u64,
}

impl FlightRecorder {
    fn new() -> FlightRecorder {
        FlightRecorder { entries: Vec::new(), dropped: 0 }
    }

    fn pin(&mut self, class: &'static str, kind: &'static str, trace_id: TraceId, weight: u64) {
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| e.class == class && e.kind == kind)
        {
            e.count += 1;
            if class == CLASS_TAIL && weight > e.weight {
                e.trace_id = trace_id;
                e.weight = weight;
            }
            return;
        }
        if self.entries.len() >= MAX_EXEMPLARS {
            self.dropped += 1;
            return;
        }
        self.entries.push(Exemplar { class, kind, trace_id, count: 1, weight });
    }

    fn exemplars(&self) -> Vec<Exemplar> {
        let mut out = self.entries.clone();
        out.sort_by_key(|e| (e.class, e.kind));
        out
    }
}

/// The cheap per-layer handle: the shared sink plus the cluster node
/// id this layer records under. Cloning is an `Arc` bump.
#[derive(Clone)]
pub struct TraceHandle {
    pub sink: Arc<TraceSink>,
    pub node: u32,
}

impl TraceHandle {
    pub fn new(sink: Arc<TraceSink>, node: u32) -> TraceHandle {
        TraceHandle { sink, node }
    }

    /// A standalone-coordinator handle (node 0) over a fresh sink.
    pub fn local(shards: usize, capacity: usize) -> TraceHandle {
        TraceHandle { sink: TraceSink::new(shards, capacity), node: 0 }
    }

    /// A handle over the no-op recorder.
    pub fn disabled() -> TraceHandle {
        TraceHandle { sink: TraceSink::disabled(), node: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.sink.enabled()
    }
}

/// Trace context a caller threads into a deeper layer so the deeper
/// layer's spans join the caller's tree instead of rooting a new one.
#[derive(Debug, Clone, Copy)]
pub struct ParentCtx {
    pub trace_id: TraceId,
    pub parent_span: u64,
}

/// Live trace state of one submit crossing the coordinator: the trace
/// id, a pre-reserved root span id children parent to, and the root's
/// start time. Built by [`SubmitTrace::begin`] (returns `None` when
/// tracing is off, so the hot path carries nothing), finished by
/// [`SubmitTrace::finish_root`] on every exit path.
#[derive(Clone)]
pub struct SubmitTrace {
    pub handle: TraceHandle,
    pub trace_id: TraceId,
    /// The reserved root span id.
    pub root: u64,
    /// The caller's span this root parents to (0 = this is the top).
    pub parent: u64,
    /// Root start, µs since the sink epoch.
    pub t0: u64,
}

impl SubmitTrace {
    pub fn begin(handle: &TraceHandle, parent: Option<ParentCtx>) -> Option<SubmitTrace> {
        if !handle.enabled() {
            return None;
        }
        let trace_id = match parent {
            Some(p) if p.trace_id != 0 => p.trace_id,
            _ => handle.sink.begin_trace(),
        };
        if trace_id == 0 {
            // Head-sampled out: this submit runs completely untraced
            // (its latency still reaches every histogram and counter).
            return None;
        }
        Some(SubmitTrace {
            handle: handle.clone(),
            trace_id,
            root: handle.sink.next_span_id(),
            parent: parent.map_or(0, |p| p.parent_span),
            t0: handle.sink.now_us(),
        })
    }

    pub fn now(&self) -> u64 {
        self.handle.sink.now_us()
    }

    /// Record a child phase span running from `start_us` to now.
    pub fn child(&self, phase: Phase, tag: &'static str, start_us: u64, a0: u64, a1: u64) {
        let now = self.now();
        self.handle.sink.record(Span {
            trace_id: self.trace_id,
            span_id: self.handle.sink.next_span_id(),
            parent: self.root,
            phase,
            tag,
            node: self.handle.node,
            worker: NO_WORKER,
            start_us,
            dur_us: now.saturating_sub(start_us),
            a0,
            a1,
        });
    }

    /// Record the reserved root span, covering begin → now. Call
    /// exactly once, on the submit's exit path (admitted, rejected or
    /// errored — a trace must always gain its root).
    pub fn finish_root(&self, phase: Phase, tag: &'static str, a0: u64) {
        let now = self.now();
        self.handle.sink.record(Span {
            trace_id: self.trace_id,
            span_id: self.root,
            parent: self.parent,
            phase,
            tag,
            node: self.handle.node,
            worker: NO_WORKER,
            start_us: self.t0,
            dur_us: now.saturating_sub(self.t0),
            a0,
            a1: 0,
        });
    }

    /// Pin this trace as an anomaly exemplar.
    pub fn pin(&self, class: &'static str, kind: &'static str) {
        self.handle.sink.pin(class, kind, self.trace_id, 0);
    }

    /// The slimmed context a queued job carries to the worker path.
    pub fn job_trace(&self) -> JobTrace {
        JobTrace {
            handle: self.handle.clone(),
            trace_id: self.trace_id,
            root: self.root,
            enq_us: self.now(),
        }
    }
}

/// Trace context carried by a queued job: lets the worker path attach
/// queue-wait / pack / exec / scatter / verify / retry spans to the
/// submit's tree. An `Arc` bump to clone; absent entirely when
/// tracing is off.
#[derive(Clone)]
pub struct JobTrace {
    pub handle: TraceHandle,
    pub trace_id: TraceId,
    /// The submit root span these worker spans parent to.
    pub root: u64,
    /// Enqueue time, µs since the sink epoch (queue-wait span start).
    pub enq_us: u64,
}

impl JobTrace {
    pub fn now(&self) -> u64 {
        self.handle.sink.now_us()
    }

    /// Record a worker-path span with explicit timing.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        phase: Phase,
        tag: &'static str,
        worker: i32,
        start_us: u64,
        dur_us: u64,
        a0: u64,
        a1: u64,
    ) {
        self.handle.sink.record(Span {
            trace_id: self.trace_id,
            span_id: self.handle.sink.next_span_id(),
            parent: self.root,
            phase,
            tag,
            node: self.handle.node,
            worker,
            start_us,
            dur_us,
            a0,
            a1,
        });
    }

    /// Pin this trace as an anomaly exemplar (weight: tail latency µs).
    pub fn pin(&self, class: &'static str, kind: &'static str, weight: u64) {
        self.handle.sink.pin(class, kind, self.trace_id, weight);
    }
}

/// Per-trace structural report from [`check_traces`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCheck {
    /// Distinct traces seen.
    pub traces: usize,
    /// Traces with exactly one root span (parent == 0).
    pub rooted: usize,
    /// Spans whose parent id is absent from their trace.
    pub orphans: usize,
}

/// Structural completeness over a merged span set: every trace must
/// have exactly one root and every parent reference must resolve
/// within its trace.
pub fn check_traces(spans: &[Span]) -> TraceCheck {
    use std::collections::{HashMap, HashSet};
    let mut ids: HashMap<TraceId, HashSet<u64>> = HashMap::new();
    for s in spans {
        ids.entry(s.trace_id).or_default().insert(s.span_id);
    }
    let mut roots: HashMap<TraceId, usize> = HashMap::new();
    let mut orphans = 0usize;
    for s in spans {
        if s.parent == 0 {
            *roots.entry(s.trace_id).or_insert(0) += 1;
        } else if !ids[&s.trace_id].contains(&s.parent) {
            orphans += 1;
        }
    }
    TraceCheck {
        traces: ids.len(),
        rooted: roots.values().filter(|&&n| n == 1).count(),
        orphans,
    }
}

/// Render spans as a Chrome-trace-event JSON document (the Perfetto /
/// `about:tracing` format): one complete (`"ph":"X"`) event per span,
/// `pid` = node, `tid` = worker (+1 so the front door renders as tid
/// 0), span/trace/parent ids and the phase payload under `args`.
///
/// `id_offset` shifts trace and span ids, letting multiple sinks merge
/// into one document without collisions.
pub fn chrome_trace(spans: &[Span], id_offset: u64) -> JsonValue {
    use std::collections::BTreeMap;
    let mut events = Vec::with_capacity(spans.len());
    for s in spans {
        let mut args = BTreeMap::new();
        args.insert("trace_id".to_string(), JsonValue::Number((s.trace_id + id_offset) as f64));
        args.insert("span_id".to_string(), JsonValue::Number((s.span_id + id_offset) as f64));
        let parent = if s.parent == 0 { 0 } else { s.parent + id_offset };
        args.insert("parent".to_string(), JsonValue::Number(parent as f64));
        if !s.tag.is_empty() {
            args.insert("tag".to_string(), JsonValue::String(s.tag.to_string()));
        }
        args.insert("a0".to_string(), JsonValue::Number(s.a0 as f64));
        args.insert("a1".to_string(), JsonValue::Number(s.a1 as f64));
        let mut ev = BTreeMap::new();
        ev.insert("name".to_string(), JsonValue::String(s.phase.name().to_string()));
        ev.insert("cat".to_string(), JsonValue::String("dispatch".to_string()));
        ev.insert("ph".to_string(), JsonValue::String("X".to_string()));
        ev.insert("ts".to_string(), JsonValue::Number(s.start_us as f64));
        ev.insert("dur".to_string(), JsonValue::Number(s.dur_us as f64));
        ev.insert("pid".to_string(), JsonValue::Number(s.node as f64));
        ev.insert("tid".to_string(), JsonValue::Number((s.worker + 1) as f64));
        ev.insert("args".to_string(), JsonValue::Object(args));
        events.push(JsonValue::Object(ev));
    }
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), JsonValue::Array(events));
    doc.insert(
        "displayTimeUnit".to_string(),
        JsonValue::String("ms".to_string()),
    );
    JsonValue::Object(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, id: u64, parent: u64, phase: Phase) -> Span {
        Span {
            trace_id: trace,
            span_id: id,
            parent,
            phase,
            tag: "",
            node: 0,
            worker: NO_WORKER,
            start_us: id * 10,
            dur_us: 5,
            a0: 0,
            a1: 0,
        }
    }

    #[test]
    fn disabled_sink_is_a_true_noop() {
        let sink = TraceSink::disabled();
        assert!(!sink.enabled());
        assert_eq!(sink.begin_trace(), 0);
        assert_eq!(sink.next_span_id(), 0);
        sink.record(span(1, 1, 0, Phase::Submit));
        sink.pin(CLASS_TAIL, "", 1, 9);
        let st = sink.stats();
        assert_eq!(st.shards, 0);
        assert_eq!(st.allocated_spans, 0);
        assert_eq!(st.recorded, 0);
        assert_eq!(st.traces, 0);
        assert!(sink.spans().is_empty());
        assert!(sink.exemplars().is_empty());
    }

    #[test]
    fn rings_are_bounded_and_count_overwrites() {
        let sink = TraceSink::new(1, 4);
        for i in 1..=7 {
            sink.record(span(1, i, 0, Phase::Exec));
        }
        let st = sink.stats();
        assert_eq!(st.recorded, 7);
        assert_eq!(st.overwritten, 3);
        let spans = sink.spans();
        assert_eq!(spans.len(), 4);
        // the oldest three were overwritten
        assert!(spans.iter().all(|s| s.span_id >= 4));
    }

    #[test]
    fn sampler_is_deterministic_and_stats_count_sampled_in_only() {
        // Verdicts are a pure function of the candidate id.
        let s = Sampler::ratio(4);
        for id in 1..=64u64 {
            assert_eq!(s.admits(id), Sampler::ratio(4).admits(id));
        }
        assert!((1..=64u64).all(|id| Sampler::always().admits(id)));
        assert_eq!(Sampler::ratio(0).denom(), 1, "ratio clamps to always");

        let sink = TraceSink::sampled(2, 4096, Sampler::ratio(4));
        let mut sampled_in = 0u64;
        for _ in 0..256 {
            let t = sink.begin_trace();
            if t != 0 {
                sampled_in += 1;
                sink.record(span(t, sink.next_span_id(), 0, Phase::Submit));
                sink.pin(CLASS_TAIL, "e2e", t, 1);
            }
        }
        let st = sink.stats();
        assert_eq!(st.traces, sampled_in, "traces counts sampled-in only");
        assert_eq!(st.traces + st.sampled_out, 256);
        assert!(sampled_in > 0, "a 1/4 sampler admits some of 256");
        assert!(st.sampled_out > 0, "a 1/4 sampler declines some of 256");
        // The span store agrees with the counter: one trace per
        // sampled-in submit, all rooted, and the tail exemplar points
        // at a sampled-in (recorded) trace.
        let chk = check_traces(&sink.spans());
        assert_eq!(chk.traces as u64, sampled_in);
        assert_eq!(chk.rooted, chk.traces);
        let tail = sink.exemplar(CLASS_TAIL, "e2e").expect("tail pinned");
        assert!(tail.trace_id != 0);
        assert_eq!(tail.count, sampled_in);
    }

    #[test]
    fn spans_merge_across_shards_in_trace_order() {
        let sink = TraceSink::new(4, 16);
        let t1 = sink.begin_trace();
        let t2 = sink.begin_trace();
        assert_eq!((t1, t2), (1, 2));
        let mut w0 = span(t2, sink.next_span_id(), 0, Phase::Submit);
        w0.worker = 3;
        sink.record(w0);
        let mut w1 = span(t1, sink.next_span_id(), 0, Phase::Submit);
        w1.worker = 0;
        sink.record(w1);
        let spans = sink.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].trace_id, t1);
        assert_eq!(spans[1].trace_id, t2);
    }

    #[test]
    fn check_traces_flags_orphans_and_multiple_roots() {
        let good = vec![
            span(1, 1, 0, Phase::Submit),
            span(1, 2, 1, Phase::Route),
            span(1, 3, 1, Phase::Exec),
        ];
        let c = check_traces(&good);
        assert_eq!(c, TraceCheck { traces: 1, rooted: 1, orphans: 0 });

        let orphan = vec![span(2, 4, 0, Phase::Submit), span(2, 5, 99, Phase::Exec)];
        let c = check_traces(&orphan);
        assert_eq!(c.orphans, 1);

        let two_roots = vec![span(3, 6, 0, Phase::Submit), span(3, 7, 0, Phase::Submit)];
        let c = check_traces(&two_roots);
        assert_eq!(c.rooted, 0);
    }

    #[test]
    fn flight_recorder_keeps_first_except_tail_keeps_slowest() {
        let sink = TraceSink::new(1, 8);
        sink.pin(CLASS_REJECT, "quota", 1, 0);
        sink.pin(CLASS_REJECT, "quota", 2, 0);
        sink.pin(CLASS_TAIL, "", 3, 100);
        sink.pin(CLASS_TAIL, "", 4, 900);
        sink.pin(CLASS_TAIL, "", 5, 50);
        let q = sink.exemplar(CLASS_REJECT, "quota").unwrap();
        assert_eq!((q.trace_id, q.count), (1, 2));
        let t = sink.exemplar(CLASS_TAIL, "").unwrap();
        assert_eq!((t.trace_id, t.weight, t.count), (4, 900, 3));
        assert!(sink.exemplar(CLASS_FAULT, "worker_kill").is_none());
    }

    #[test]
    fn chrome_trace_round_trips_through_the_json_reader() {
        let sink = TraceSink::new(2, 8);
        let t = sink.begin_trace();
        let root = sink.next_span_id();
        sink.record(span(t, root, 0, Phase::Submit));
        let mut hop = span(t, sink.next_span_id(), root, Phase::Hop);
        hop.tag = "home_down";
        hop.a0 = 1;
        hop.a1 = 2;
        sink.record(hop);
        let doc = chrome_trace(&sink.spans(), 1000);
        let text = doc.render();
        let back = JsonValue::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(events.len(), 2);
        let names: Vec<_> = events
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert!(names.contains(&"submit") && names.contains(&"hop"));
        for e in events {
            let args = e.get("args").unwrap();
            assert_eq!(args.get("trace_id").unwrap().as_i64(), Some((t + 1000) as i64));
        }
        let hop_ev = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("hop"))
            .unwrap();
        assert_eq!(hop_ev.get("args").unwrap().get("tag").unwrap().as_str(), Some("home_down"));
        assert_eq!(hop_ev.get("args").unwrap().get("a1").unwrap().as_i64(), Some(2));
    }
}
