//! Throughput / resource / host-speed models behind Figs. 6–7 and
//! Table III.
//!
//! * **GOPS model** — the paper's Fig. 6 metric is
//!   `copies × ops-per-kernel × Fmax`: a spatially configured II=1
//!   overlay retires every mapped op once per cycle. Peak is the
//!   overlay's total DSP op capacity ([`OverlaySpec::peak_gops`]).
//! * **Slice model** — the full 8×8 two-DSP overlay occupies 12,617
//!   Zynq slices (Table III): 197 per tile + 9 fixed.
//! * **Host-speed model** — Fig. 7's third bar (Overlay-PAR-Zynq) is
//!   the x86 measurement scaled by the published 667 MHz Cortex-A9 vs
//!   3.5 GHz Xeon slowdown (0.88 s / 0.22 s = 4.0×).

use crate::compiler::CompiledKernel;
use crate::obs::{LatencyHist, SloStats};
use crate::overlay::OverlaySpec;

/// Slices of overlay fabric per tile (calibrated to Table III's 12617
/// for the 8×8 two-DSP overlay).
pub const SLICES_PER_TILE: usize = 197;
/// Fixed overlay infrastructure slices (config controller, AXI).
pub const SLICES_FIXED: usize = 9;

/// Fig. 7 Zynq-ARM / x86-Xeon PAR slowdown (0.88 / 0.22).
pub const ZYNQ_ARM_SLOWDOWN: f64 = 4.0;

/// Achieved throughput of `copies` replicas of a kernel with
/// `ops_per_copy` DFG operations at `fmax_mhz` — in GOPS.
pub fn achieved_gops(copies: usize, ops_per_copy: usize, fmax_mhz: f64) -> f64 {
    (copies * ops_per_copy) as f64 * fmax_mhz / 1000.0
}

/// Overlay slice footprint (constant per overlay, independent of the
/// kernel mapped — the whole point of Table III's fixed 12617).
pub fn overlay_slices(spec: &OverlaySpec) -> usize {
    spec.fu_count() * SLICES_PER_TILE + SLICES_FIXED
}

/// One Fig. 6 sample point.
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    pub overlay: String,
    pub fu_count: usize,
    pub copies: usize,
    pub gops: f64,
    pub peak_gops: f64,
    pub utilization: f64,
}

/// Evaluate a compiled kernel's throughput on its overlay.
pub fn throughput(spec: &OverlaySpec, k: &CompiledKernel) -> ThroughputPoint {
    let gops = achieved_gops(k.copies(), k.ops_per_copy(), spec.fmax_mhz());
    let peak = spec.peak_gops();
    ThroughputPoint {
        overlay: spec.name(),
        fu_count: spec.fu_count(),
        copies: k.copies(),
        gops,
        peak_gops: peak,
        utilization: gops / peak,
    }
}

/// Percentile of a **sorted** sample slice (nearest-rank with
/// round-half-up, matching the bench harnesses). Returns 0.0 for an
/// empty slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// A bounded sliding window of scalar samples — the building block of
/// the autoscaler's [`crate::autoscale::LoadSignal`]. Pushing past the
/// capacity drops the oldest sample, so every summary reflects only
/// the most recent `capacity` observations.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    buf: std::collections::VecDeque<f64>,
    capacity: usize,
}

impl SlidingWindow {
    /// A window retaining the last `capacity` samples (clamped ≥ 1).
    pub fn new(capacity: usize) -> SlidingWindow {
        let capacity = capacity.max(1);
        SlidingWindow { buf: std::collections::VecDeque::with_capacity(capacity), capacity }
    }

    /// Append a sample, evicting the oldest once full.
    pub fn push(&mut self, v: f64) {
        if self.buf.len() == self.capacity {
            self.buf.pop_front();
        }
        self.buf.push_back(v);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Whether the window holds `capacity` samples.
    pub fn is_full(&self) -> bool {
        self.buf.len() == self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Mean of the retained samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.buf.iter().sum::<f64>() / self.buf.len() as f64
        }
    }

    /// Maximum of the retained samples (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.buf.iter().copied().fold(0.0, f64::max)
    }

    /// Percentile (nearest-rank, [`percentile`]) of the retained
    /// samples; 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut sorted: Vec<f64> = self.buf.iter().copied().collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&sorted, p)
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

/// Latency distribution summary (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub count: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub mean_ms: f64,
}

impl LatencyStats {
    /// Summarize a sample set (takes ownership to sort in place).
    pub fn from_samples_ms(mut samples: Vec<f64>) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = samples.len();
        let mean = samples.iter().sum::<f64>() / count as f64;
        LatencyStats {
            count,
            p50_ms: percentile(&samples, 0.50),
            p99_ms: percentile(&samples, 0.99),
            max_ms: *samples.last().unwrap(),
            mean_ms: mean,
        }
    }

    /// Summarize a log-bucketed histogram — the canonical path since
    /// [`LatencyHist`] replaced the sampling reservoirs. Percentiles
    /// are exact to within one bucket (~41% relative width); count,
    /// max and mean are exact.
    pub fn from_hist(h: &LatencyHist) -> LatencyStats {
        LatencyStats {
            count: h.count() as usize,
            p50_ms: h.p50_ms(),
            p99_ms: h.p99_ms(),
            max_ms: h.max_ms(),
            mean_ms: h.mean_ms(),
        }
    }
}

/// Kernel-cache counters (produced by
/// [`crate::coordinator::KernelCache::stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
}

impl CacheStats {
    /// Hit rate in `[0, 1]` (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Per-partition serving counters (one overlay instance in the
/// coordinator's fleet).
#[derive(Debug, Clone)]
pub struct PartitionServingStats {
    pub partition: usize,
    pub overlay: String,
    /// Dispatches routed to this partition.
    pub dispatches: u64,
    /// Times the partition had to load a different kernel bitstream.
    pub reconfigs: u64,
    /// Modeled overlay-busy seconds (execution + reconfiguration).
    pub busy_seconds: f64,
    /// `busy_seconds` / coordinator wall uptime.
    pub utilization: f64,
}

/// Per-spec serving counters: one compilation shard of a
/// (possibly heterogeneous) fleet — its kernel cache, its share of
/// the routing decisions, and the replication factors it served at.
#[derive(Debug, Clone)]
pub struct SpecServingStats {
    /// Overlay name, e.g. `"8x8-dsp2"`.
    pub spec: String,
    /// [`OverlaySpec::fingerprint`] keying the shard.
    pub fingerprint: u64,
    /// Partitions built from this spec.
    pub partitions: usize,
    /// This shard's kernel-cache counters (per-spec hit rates).
    pub cache: CacheStats,
    /// Wall seconds of JIT compilation this shard paid.
    pub compile_seconds: f64,
    /// Dispatches the router placed on this spec.
    pub routed: u64,
    /// …of which via the small-kernel best-fit path.
    pub best_fit: u64,
    /// …of which via the wide-data-parallel path.
    pub widest: u64,
    /// …of which because no other spec fit the kernel.
    pub only_fit: u64,
    /// Dispatches that landed here after a compile failure on a
    /// higher-ranked spec.
    pub fallbacks: u64,
    /// Cache hits whose artifact geometry didn't match this shard's
    /// overlay grid — the shard-isolation invariant; must be 0 (such
    /// an entry is never dispatched: it is recompiled instead).
    pub cross_spec_hits: u64,
    /// Replication factor → dispatches served at that factor.
    pub replication_histogram: Vec<(usize, u64)>,
}

/// Counters of the feedback-driven autoscaler
/// ([`crate::autoscale::Autoscaler`]): how often kernels were
/// re-replicated at run time and what the rescales cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct AutoscaleStats {
    /// Applied rescales that raised the replication factor.
    pub scale_ups: u64,
    /// Applied rescales that lowered the replication factor.
    pub scale_downs: u64,
    /// Rescales whose background compile failed (the previous factor
    /// keeps serving).
    pub failed_rescales: u64,
    /// Rescales whose target factor was already resident in the
    /// kernel cache — scaling back to a previously compiled factor
    /// pays no JIT.
    pub rescale_cache_hits: u64,
    /// Wall seconds the background lane spent compiling variants.
    pub rescale_compile_seconds: f64,
    /// (kernel, spec) pairs currently served by a non-default factor.
    pub active_variants: usize,
    /// (kernel, spec) pairs with live load signals.
    pub tracked_kernels: usize,
    /// Scale events beyond the bounded audit log.
    pub events_dropped: u64,
    /// Admission rejections fed back into load signals (refused demand
    /// still pushes scale-ups).
    pub admission_rejects: u64,
}

impl AutoscaleStats {
    /// Applied scale events (ups + downs).
    pub fn applied(&self) -> u64 {
        self.scale_ups + self.scale_downs
    }
}

/// Aggregate serving statistics reported by the coordinator: the
/// quantities that decide whether run-time kernel management is
/// actually paying off (paper's premise — seconds-class JIT + µs-class
/// reconfiguration make the overlay fleet a schedulable cache).
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    /// Kernel-cache counters summed across every spec shard
    /// (`capacity` and `entries` sum too).
    pub cache: CacheStats,
    /// Times any partition had to load a different kernel bitstream.
    pub reconfig_count: u64,
    /// Modeled seconds spent loading bitstreams.
    pub reconfig_seconds: f64,
    /// End-to-end dispatch latency (enqueue → completion), summarized
    /// from `latency_hist`.
    pub latency: LatencyStats,
    /// The log-bucketed histogram `latency` was summarized from — the
    /// canonical latency carrier. Every completed dispatch lands here
    /// (no sampling, no decimation), and [`ServingStats::merge`]
    /// combines nodes by lossless bucket addition.
    pub latency_hist: LatencyHist,
    pub partitions: Vec<PartitionServingStats>,
    /// Per-spec shard breakdown (cache isolation, routing decisions,
    /// replication-factor histograms).
    pub per_spec: Vec<SpecServingStats>,
    pub total_dispatches: u64,
    pub total_items: u64,
    /// Failed simulator cross-checks (0 when verification is on and
    /// every dispatch agreed with the cycle simulator).
    pub verify_failures: u64,
    /// Dispatches that errored before producing a result.
    pub dispatch_errors: u64,
    /// Worker batches in which ≥ 2 same-kernel dispatches were fused
    /// into one backend invocation.
    pub fused_batches: u64,
    /// Wall seconds of JIT compilation spent on cache misses.
    pub compile_seconds: f64,
    /// Dispatch-scratch pool counters (arena reuse; warm-up-only heap
    /// growth — the zero-copy data plane's allocation evidence).
    pub scratch_pool: crate::arena::PoolStats,
    /// Run-time rescale counters; `None` when the coordinator runs
    /// with frozen replication plans (no autoscaler configured).
    pub autoscale: Option<AutoscaleStats>,
    /// Submits refused by the admission gate (quota + unmeetable
    /// deadline). Zero when no gate is configured.
    pub rejected_submits: u64,
    /// Batch submits shed under pressure to protect interactive p99.
    pub shed_submits: u64,
    /// Dispatches the recovery plane re-placed onto a sibling
    /// partition after a worker death, failed reconfiguration or
    /// corrupted verify.
    pub retried_dispatches: u64,
    /// Batch runs checkpointed at a chunk boundary to yield to
    /// interactive work (each may cover several fused jobs).
    pub preempted_runs: u64,
    /// Preempted jobs whose un-run remainder was requeued as a typed
    /// continuation (and later completed elsewhere).
    pub preempted_continuations: u64,
    /// Times any partition entered quarantine after repeated failures.
    pub quarantine_events: u64,
    /// Partitions currently sitting out in quarantine.
    pub quarantined_partitions: usize,
    /// The admission gate's live counters; `None` when every submit is
    /// admitted ungated.
    pub admission: Option<crate::admission::AdmissionStats>,
    /// Injected-fault tallies; `None` when no fault plan is armed.
    pub faults: Option<crate::admission::FaultTally>,
    /// Poisoned (kernel, spec) pairs: currently withheld, re-probes
    /// offered, recoveries (probe compiled clean).
    pub poison: crate::fleet::PoisonStats,
    /// SLO burn-rate engine summary; `None` when no [`SloPolicy`] is
    /// configured.
    ///
    /// [`SloPolicy`]: crate::obs::SloPolicy
    pub slo: Option<SloStats>,
}

impl ServingStats {
    /// Merge node-level snapshots into one cluster-wide view.
    ///
    /// Counters sum; partition rows concatenate with re-numbered
    /// indices; per-spec rows merge by spec fingerprint (histograms
    /// included). Latency merges by **bucket-wise histogram
    /// addition** ([`LatencyHist::merge`]): lossless, commutative and
    /// associative, so the merged percentiles are computed over every
    /// recorded completion regardless of merge order — no stride
    /// thinning, no idle-node bias.
    ///
    /// Caveats, by construction: `admission.pressure` is the maximum
    /// across nodes (pressure is a level, not a count),
    /// `admission.tenants` is the per-node maximum (tenants served by
    /// several nodes cannot be de-duplicated from counters alone),
    /// `slo.burn` is the worst node's burn, and `faults` stays `None`
    /// (injected-fault tallies are per-node diagnostics; read them
    /// off the node's own stats).
    pub fn merge(nodes: &[ServingStats]) -> ServingStats {
        let mut out = ServingStats::default();

        // lossless latency merge: bucket-wise histogram addition
        let mut hist = LatencyHist::new();
        for n in nodes {
            hist.merge(&n.latency_hist);
        }
        out.latency = LatencyStats::from_hist(&hist);
        out.latency_hist = hist;

        let mut specs: std::collections::BTreeMap<u64, SpecServingStats> =
            std::collections::BTreeMap::new();
        let mut histograms: std::collections::BTreeMap<
            u64,
            std::collections::BTreeMap<usize, u64>,
        > = std::collections::BTreeMap::new();
        let mut partition_offset = 0usize;
        for n in nodes {
            out.cache.hits += n.cache.hits;
            out.cache.misses += n.cache.misses;
            out.cache.evictions += n.cache.evictions;
            out.cache.entries += n.cache.entries;
            out.cache.capacity += n.cache.capacity;
            out.reconfig_count += n.reconfig_count;
            out.reconfig_seconds += n.reconfig_seconds;
            out.total_dispatches += n.total_dispatches;
            out.total_items += n.total_items;
            out.verify_failures += n.verify_failures;
            out.dispatch_errors += n.dispatch_errors;
            out.fused_batches += n.fused_batches;
            out.compile_seconds += n.compile_seconds;
            out.rejected_submits += n.rejected_submits;
            out.shed_submits += n.shed_submits;
            out.retried_dispatches += n.retried_dispatches;
            out.preempted_runs += n.preempted_runs;
            out.preempted_continuations += n.preempted_continuations;
            out.quarantine_events += n.quarantine_events;
            out.quarantined_partitions += n.quarantined_partitions;
            out.scratch_pool.created += n.scratch_pool.created;
            out.scratch_pool.checkouts += n.scratch_pool.checkouts;
            out.scratch_pool.reuses += n.scratch_pool.reuses;
            out.scratch_pool.pooled += n.scratch_pool.pooled;
            out.scratch_pool.grow_events += n.scratch_pool.grow_events;
            out.poison.active += n.poison.active;
            out.poison.probes += n.poison.probes;
            out.poison.recoveries += n.poison.recoveries;

            for p in &n.partitions {
                let mut p = p.clone();
                p.partition += partition_offset;
                out.partitions.push(p);
            }
            partition_offset += n.partitions.len();

            for s in &n.per_spec {
                let e = specs.entry(s.fingerprint).or_insert_with(|| SpecServingStats {
                    spec: s.spec.clone(),
                    fingerprint: s.fingerprint,
                    partitions: 0,
                    cache: CacheStats::default(),
                    compile_seconds: 0.0,
                    routed: 0,
                    best_fit: 0,
                    widest: 0,
                    only_fit: 0,
                    fallbacks: 0,
                    cross_spec_hits: 0,
                    replication_histogram: Vec::new(),
                });
                e.partitions += s.partitions;
                e.cache.hits += s.cache.hits;
                e.cache.misses += s.cache.misses;
                e.cache.evictions += s.cache.evictions;
                e.cache.entries += s.cache.entries;
                e.cache.capacity += s.cache.capacity;
                e.compile_seconds += s.compile_seconds;
                e.routed += s.routed;
                e.best_fit += s.best_fit;
                e.widest += s.widest;
                e.only_fit += s.only_fit;
                e.fallbacks += s.fallbacks;
                e.cross_spec_hits += s.cross_spec_hits;
                let h = histograms.entry(s.fingerprint).or_default();
                for &(factor, count) in &s.replication_histogram {
                    *h.entry(factor).or_insert(0) += count;
                }
            }

            if let Some(a) = &n.autoscale {
                let m = out.autoscale.get_or_insert_with(AutoscaleStats::default);
                m.scale_ups += a.scale_ups;
                m.scale_downs += a.scale_downs;
                m.failed_rescales += a.failed_rescales;
                m.rescale_cache_hits += a.rescale_cache_hits;
                m.rescale_compile_seconds += a.rescale_compile_seconds;
                m.active_variants += a.active_variants;
                m.tracked_kernels += a.tracked_kernels;
                m.events_dropped += a.events_dropped;
                m.admission_rejects += a.admission_rejects;
            }
            if let Some(a) = &n.admission {
                let m = out
                    .admission
                    .get_or_insert_with(crate::admission::AdmissionStats::default);
                m.admitted += a.admitted;
                m.rejected_quota += a.rejected_quota;
                m.rejected_deadline += a.rejected_deadline;
                m.shed += a.shed;
                m.pressure = m.pressure.max(a.pressure);
                m.tenants = m.tenants.max(a.tenants);
            }
            if let Some(s) = &n.slo {
                let m = out.slo.get_or_insert_with(SloStats::default);
                m.objectives += s.objectives;
                m.firing += s.firing;
                m.alerts_total += s.alerts_total;
                m.alerts_dropped += s.alerts_dropped;
                m.burn = m.burn.max(s.burn);
                m.ticks = m.ticks.max(s.ticks);
            }
        }
        for (fp, s) in specs {
            let mut s = s;
            s.replication_histogram = histograms
                .remove(&fp)
                .map_or_else(Vec::new, |h| h.into_iter().collect());
            out.per_spec.push(s);
        }
        out
    }

    /// A compact multi-line report for examples and benches.
    pub fn render(&self) -> String {
        let mut out = format!(
            "cache      : {} hits / {} misses ({:.0}% hit rate), {} evictions, {} resident\n\
             reconfig   : {} loads, {:.1} us modeled\n\
             compile    : {:.1} ms total on misses\n\
             fusion     : {} fused batches\n\
             latency    : p50 {:.3} ms, p99 {:.3} ms, max {:.3} ms over {} dispatches\n",
            self.cache.hits,
            self.cache.misses,
            100.0 * self.cache.hit_rate(),
            self.cache.evictions,
            self.cache.entries,
            self.reconfig_count,
            self.reconfig_seconds * 1e6,
            self.compile_seconds * 1e3,
            self.fused_batches,
            self.latency.p50_ms,
            self.latency.p99_ms,
            self.latency.max_ms,
            self.latency.count,
        );
        out.push_str(&format!(
            "scratch    : {} checkouts over {} scratches ({} pooled), {} heap growths\n",
            self.scratch_pool.checkouts,
            self.scratch_pool.created,
            self.scratch_pool.pooled,
            self.scratch_pool.grow_events,
        ));
        if let Some(a) = &self.admission {
            out.push_str(&format!(
                "admission  : {} admitted, {} rejected ({} quota / {} deadline), \
                 {} shed, pressure {:.2}, {} tenants\n",
                a.admitted,
                self.rejected_submits,
                a.rejected_quota,
                a.rejected_deadline,
                self.shed_submits,
                a.pressure,
                a.tenants,
            ));
        }
        if self.retried_dispatches > 0
            || self.quarantine_events > 0
            || self.faults.is_some()
        {
            out.push_str(&format!(
                "recovery   : {} retried dispatches, {} quarantine events \
                 ({} partitions out now)\n",
                self.retried_dispatches,
                self.quarantine_events,
                self.quarantined_partitions,
            ));
        }
        if self.preempted_runs > 0 || self.preempted_continuations > 0 {
            out.push_str(&format!(
                "preemption : {} batch runs checkpointed, {} continuations requeued\n",
                self.preempted_runs, self.preempted_continuations,
            ));
        }
        if let Some(f) = &self.faults {
            out.push_str(&format!(
                "faults     : {} injected / {} recovered\n",
                f.total_injected(),
                f.total_recovered(),
            ));
        }
        if self.poison.active > 0 || self.poison.probes > 0 || self.poison.recoveries > 0 {
            out.push_str(&format!(
                "poison     : {} active pairs, {} re-probes, {} recoveries\n",
                self.poison.active, self.poison.probes, self.poison.recoveries,
            ));
        }
        if let Some(a) = &self.autoscale {
            out.push_str(&format!(
                "autoscale  : {} up / {} down ({} failed), {} rescale cache hits, \
                 {:.1} ms variant compiles, {} active variants\n",
                a.scale_ups,
                a.scale_downs,
                a.failed_rescales,
                a.rescale_cache_hits,
                a.rescale_compile_seconds * 1e3,
                a.active_variants,
            ));
        }
        for s in &self.per_spec {
            let histogram: Vec<String> = s
                .replication_histogram
                .iter()
                .map(|(f, n)| format!("x{f}:{n}"))
                .collect();
            out.push_str(&format!(
                "spec {}: {} partitions, {} routed ({} best-fit / {} widest / {} only-fit), \
                 {:.0}% cache hit rate, {} cross-spec hits, factors [{}]\n",
                s.spec,
                s.partitions,
                s.routed,
                s.best_fit,
                s.widest,
                s.only_fit,
                100.0 * s.cache.hit_rate(),
                s.cross_spec_hits,
                histogram.join(" "),
            ));
        }
        for p in &self.partitions {
            out.push_str(&format!(
                "partition {}: {} ({} dispatches, {} reconfigs, {:.1}% utilized)\n",
                p.partition,
                p.overlay,
                p.dispatches,
                p.reconfigs,
                100.0 * p.utilization,
            ));
        }
        out
    }

    /// Prometheus text exposition of the counter-shaped serving
    /// fields — the unified telemetry export written by
    /// `e2e_serve -- trace` alongside the Chrome trace. Round-trips
    /// through [`parse_prometheus`].
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut metric = |name: &str, kind: &str, help: &str, value: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        metric(
            "overlay_jit_cache_hits_total",
            "counter",
            "Kernel-cache hits across every spec shard",
            self.cache.hits as f64,
        );
        metric(
            "overlay_jit_cache_misses_total",
            "counter",
            "Kernel-cache misses (JIT compiles paid)",
            self.cache.misses as f64,
        );
        metric(
            "overlay_jit_cache_evictions_total",
            "counter",
            "Kernel-cache LRU evictions",
            self.cache.evictions as f64,
        );
        metric(
            "overlay_jit_cache_entries",
            "gauge",
            "Compiled kernels currently resident",
            self.cache.entries as f64,
        );
        metric(
            "overlay_jit_reconfigurations_total",
            "counter",
            "Partition bitstream loads",
            self.reconfig_count as f64,
        );
        metric(
            "overlay_jit_reconfig_seconds_total",
            "counter",
            "Modeled seconds spent loading bitstreams",
            self.reconfig_seconds,
        );
        metric(
            "overlay_jit_compile_seconds_total",
            "counter",
            "Wall seconds of JIT compilation on cache misses",
            self.compile_seconds,
        );
        metric(
            "overlay_jit_dispatches_total",
            "counter",
            "Completed dispatches",
            self.total_dispatches as f64,
        );
        metric(
            "overlay_jit_items_total",
            "counter",
            "Work items served",
            self.total_items as f64,
        );
        metric(
            "overlay_jit_verify_failures_total",
            "counter",
            "Dispatches that disagreed with the cycle simulator",
            self.verify_failures as f64,
        );
        metric(
            "overlay_jit_dispatch_errors_total",
            "counter",
            "Dispatches that errored before producing a result",
            self.dispatch_errors as f64,
        );
        metric(
            "overlay_jit_fused_batches_total",
            "counter",
            "Worker batches that fused 2+ same-kernel dispatches",
            self.fused_batches as f64,
        );
        metric(
            "overlay_jit_rejected_submits_total",
            "counter",
            "Submits refused by the admission gate",
            self.rejected_submits as f64,
        );
        metric(
            "overlay_jit_shed_submits_total",
            "counter",
            "Batch submits shed under pressure",
            self.shed_submits as f64,
        );
        metric(
            "overlay_jit_retried_dispatches_total",
            "counter",
            "Dispatches re-placed by the recovery plane",
            self.retried_dispatches as f64,
        );
        metric(
            "overlay_jit_preempted_runs_total",
            "counter",
            "Batch runs checkpointed at a chunk boundary to yield to interactive work",
            self.preempted_runs as f64,
        );
        metric(
            "overlay_jit_preempted_continuations_total",
            "counter",
            "Preempted batch remainders requeued as typed continuations",
            self.preempted_continuations as f64,
        );
        metric(
            "overlay_jit_quarantine_events_total",
            "counter",
            "Times any partition entered quarantine",
            self.quarantine_events as f64,
        );
        metric(
            "overlay_jit_quarantined_partitions",
            "gauge",
            "Partitions currently sitting out in quarantine",
            self.quarantined_partitions as f64,
        );
        metric(
            "overlay_jit_latency_p50_ms",
            "gauge",
            "End-to-end dispatch latency p50",
            self.latency.p50_ms,
        );
        metric(
            "overlay_jit_latency_p99_ms",
            "gauge",
            "End-to-end dispatch latency p99",
            self.latency.p99_ms,
        );
        metric(
            "overlay_jit_latency_max_ms",
            "gauge",
            "End-to-end dispatch latency max",
            self.latency.max_ms,
        );
        if let Some(f) = &self.faults {
            metric(
                "overlay_jit_faults_injected_total",
                "counter",
                "Faults injected by the seeded plan",
                f.total_injected() as f64,
            );
            metric(
                "overlay_jit_faults_recovered_total",
                "counter",
                "Injected faults the serving plane recovered from",
                f.total_recovered() as f64,
            );
        }
        if let Some(slo) = &self.slo {
            metric(
                "overlay_jit_slo_burn",
                "gauge",
                "Worst fast-window SLO burn rate across objectives",
                slo.burn,
            );
            metric(
                "overlay_jit_slo_firing",
                "gauge",
                "SLO objectives currently firing",
                slo.firing as f64,
            );
            metric(
                "overlay_jit_slo_alerts_total",
                "counter",
                "SLO burn-rate alert transitions emitted",
                slo.alerts_total as f64,
            );
        }
        // Proper histogram series from the log-bucketed carrier:
        // cumulative `_bucket{le="..."}` counts (only edges that hold
        // samples — the cumulative sequence reconstructs the rest),
        // the mandatory `+Inf` edge, `_sum` and `_count`.
        out.push_str(
            "# HELP overlay_jit_latency_ms End-to-end dispatch latency (enqueue to completion)\n\
             # TYPE overlay_jit_latency_ms histogram\n",
        );
        for (le, cum) in self.latency_hist.cumulative_buckets_ms() {
            out.push_str(&format!("overlay_jit_latency_ms_bucket{{le=\"{le}\"}} {cum}\n"));
        }
        out.push_str(&format!(
            "overlay_jit_latency_ms_bucket{{le=\"+Inf\"}} {}\n",
            self.latency_hist.count()
        ));
        out.push_str(&format!(
            "overlay_jit_latency_ms_sum {}\n",
            self.latency_hist.sum_ms()
        ));
        out.push_str(&format!(
            "overlay_jit_latency_ms_count {}\n",
            self.latency_hist.count()
        ));
        out
    }
}

/// Parse a Prometheus text-exposition page back into `(name, value)`
/// pairs — the re-parse half of the telemetry round-trip check in
/// `e2e_serve -- trace` / `-- slo`. Comment (`#`) lines — `# HELP`
/// and `# TYPE` in any order, anywhere on the page — and blank lines
/// are skipped; malformed sample lines are reported, not ignored.
///
/// Labeled samples (`name{le="0.25"} 12`, the histogram `_bucket`
/// series) keep their label block in the returned name, so two
/// buckets of the same family stay distinct. Labels must not contain
/// whitespace — true of everything this crate emits.
pub fn parse_prometheus(text: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(name), Some(value), None) =
            (parts.next(), parts.next(), parts.next())
        else {
            anyhow::bail!("malformed Prometheus sample line: {line:?}");
        };
        if name.contains('{') && !name.ends_with('}') {
            anyhow::bail!("malformed label block in Prometheus sample: {line:?}");
        }
        let value: f64 = value
            .parse()
            .map_err(|e| anyhow::anyhow!("bad value in {line:?}: {e}"))?;
        out.push((name.to_string(), value));
    }
    Ok(out)
}

/// The metric *family* a parsed sample name belongs to: labels are
/// stripped, and the histogram sample suffixes (`_bucket`, `_sum`,
/// `_count`) fold back onto the family declared by `# TYPE`.
pub fn prometheus_family(sample_name: &str) -> &str {
    let base = sample_name.split('{').next().unwrap_or(sample_name);
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(family) = base.strip_suffix(suffix) {
            return family;
        }
    }
    base
}

/// Simple fixed-width table formatter used by the bench harnesses to
/// print the paper's tables.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = width[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::JitCompiler;
    use crate::overlay::FuType;

    #[test]
    fn overlay_slice_model_matches_table3() {
        assert_eq!(overlay_slices(&OverlaySpec::zynq_default()), 12617);
    }

    #[test]
    fn fig6_endpoints_match_paper() {
        // 16 copies × 7 ops × 300 MHz = 33.6 GOPS ≈ "≈35 GOPS … 30% of
        // peak"; 12 × 7 × 338 = 28.4 ≈ "≈28 GOPS … 43% of 65 GOPS"
        let jit2 = JitCompiler::new(OverlaySpec::new(8, 8, FuType::Dsp2));
        let k2 = jit2.compile(crate::bench_kernels::CHEBYSHEV).unwrap();
        let t2 = throughput(&jit2.spec, &k2);
        assert!((t2.gops - 33.6).abs() < 0.1, "{}", t2.gops);
        assert!((t2.utilization - 0.292).abs() < 0.02);

        let jit1 = JitCompiler::new(OverlaySpec::new(8, 8, FuType::Dsp1));
        let k1 = jit1.compile(crate::bench_kernels::CHEBYSHEV).unwrap();
        let t1 = throughput(&jit1.spec, &k1);
        assert!((t1.gops - 28.4).abs() < 0.1, "{}", t1.gops);
        assert!((t1.utilization - 0.437).abs() < 0.02);
    }

    #[test]
    fn single_copy_point_matches_fig6_left_edge() {
        // one instance on 2×2 dsp2: 7 ops × 300 MHz = 2.1 GOPS (paper
        // reads ≈2.45); utilization ≈ 30%
        let jit = JitCompiler::new(OverlaySpec::new(2, 2, FuType::Dsp2));
        let k = jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap();
        let t = throughput(&jit.spec, &k);
        assert!((t.gops - 2.1).abs() < 0.05);
        assert!((t.utilization - 0.29).abs() < 0.03);
    }

    #[test]
    fn latency_stats_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencyStats::from_samples_ms(samples);
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 51.0).abs() < 1.5, "{}", s.p50_ms);
        assert!(s.p99_ms >= 98.0 && s.p99_ms <= 100.0, "{}", s.p99_ms);
        assert_eq!(s.max_ms, 100.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        let empty = LatencyStats::from_samples_ms(Vec::new());
        assert_eq!(empty.count, 0);
        assert_eq!(empty.p99_ms, 0.0);
    }

    fn hist_of(samples: &[f64]) -> LatencyHist {
        let mut h = LatencyHist::new();
        for &ms in samples {
            h.record_ms(ms);
        }
        h
    }

    #[test]
    fn serving_stats_hit_rate_and_render() {
        let s = ServingStats {
            cache: CacheStats { hits: 3, misses: 1, evictions: 0, entries: 1, capacity: 32 },
            reconfig_count: 2,
            reconfig_seconds: 84.8e-6,
            latency: LatencyStats::from_hist(&hist_of(&[1.0, 2.0, 3.0])),
            latency_hist: hist_of(&[1.0, 2.0, 3.0]),
            partitions: vec![PartitionServingStats {
                partition: 0,
                overlay: "8x8-dsp2".into(),
                dispatches: 4,
                reconfigs: 2,
                busy_seconds: 0.5,
                utilization: 0.5,
            }],
            per_spec: vec![SpecServingStats {
                spec: "8x8-dsp2".into(),
                fingerprint: 0xABCD,
                partitions: 1,
                cache: CacheStats { hits: 3, misses: 1, evictions: 0, entries: 1, capacity: 32 },
                compile_seconds: 0.2,
                routed: 4,
                best_fit: 3,
                widest: 1,
                only_fit: 0,
                fallbacks: 0,
                cross_spec_hits: 0,
                replication_histogram: vec![(16, 4)],
            }],
            total_dispatches: 4,
            total_items: 1000,
            verify_failures: 0,
            dispatch_errors: 0,
            fused_batches: 1,
            compile_seconds: 0.2,
            scratch_pool: crate::arena::PoolStats {
                created: 1,
                checkouts: 4,
                reuses: 3,
                pooled: 1,
                grow_events: 2,
            },
            autoscale: Some(AutoscaleStats {
                scale_ups: 1,
                scale_downs: 2,
                rescale_cache_hits: 1,
                ..Default::default()
            }),
            rejected_submits: 3,
            shed_submits: 2,
            retried_dispatches: 1,
            preempted_runs: 2,
            preempted_continuations: 3,
            quarantine_events: 1,
            quarantined_partitions: 0,
            admission: Some(crate::admission::AdmissionStats {
                admitted: 10,
                rejected_quota: 2,
                rejected_deadline: 1,
                shed: 2,
                pressure: 0.42,
                tenants: 4,
            }),
            faults: None,
            poison: crate::fleet::PoisonStats { active: 1, probes: 2, recoveries: 1 },
            slo: Some(SloStats { objectives: 2, firing: 1, ..Default::default() }),
        };
        assert!((s.cache.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let r = s.render();
        assert!(r.contains("75% hit rate"), "{r}");
        assert!(r.contains("partition 0"), "{r}");
        assert!(r.contains("spec 8x8-dsp2"), "{r}");
        assert!(r.contains("x16:4"), "{r}");
        assert!(r.contains("1 fused batches"), "{r}");
        assert!(r.contains("4 checkouts over 1 scratches"), "{r}");
        assert!(r.contains("1 up / 2 down"), "{r}");
        assert!(r.contains("3 rejected (2 quota / 1 deadline)"), "{r}");
        assert!(r.contains("2 shed"), "{r}");
        assert!(r.contains("1 retried dispatches, 1 quarantine events"), "{r}");
        assert!(
            r.contains("2 batch runs checkpointed, 3 continuations requeued"),
            "{r}"
        );
        assert!(r.contains("1 active pairs, 2 re-probes, 1 recoveries"), "{r}");
        assert_eq!(s.autoscale.unwrap().applied(), 3);
    }

    #[test]
    fn serving_stats_merge_adds_histogram_buckets_and_sums_counters() {
        // busy node: 32 slow completions, every one in the histogram
        let busy = ServingStats {
            total_dispatches: 32,
            total_items: 3200,
            cache: CacheStats { hits: 30, misses: 2, evictions: 1, entries: 2, capacity: 32 },
            latency_hist: hist_of(&[100.0; 32]),
            per_spec: vec![SpecServingStats {
                spec: "8x8-dsp2".into(),
                fingerprint: 0xABCD,
                partitions: 2,
                cache: CacheStats { hits: 30, misses: 2, evictions: 1, entries: 2, capacity: 32 },
                compile_seconds: 0.2,
                routed: 32,
                best_fit: 30,
                widest: 2,
                only_fit: 0,
                fallbacks: 0,
                cross_spec_hits: 0,
                replication_histogram: vec![(16, 30), (8, 2)],
            }],
            partitions: vec![PartitionServingStats {
                partition: 0,
                overlay: "8x8-dsp2".into(),
                dispatches: 32,
                reconfigs: 1,
                busy_seconds: 0.8,
                utilization: 0.8,
            }],
            admission: Some(crate::admission::AdmissionStats {
                admitted: 32,
                rejected_quota: 1,
                rejected_deadline: 0,
                shed: 2,
                pressure: 0.9,
                tenants: 3,
            }),
            preempted_runs: 2,
            preempted_continuations: 3,
            ..Default::default()
        };
        // idle node: 8 fast completions
        let idle = ServingStats {
            total_dispatches: 8,
            total_items: 800,
            preempted_runs: 1,
            preempted_continuations: 1,
            cache: CacheStats { hits: 6, misses: 2, evictions: 0, entries: 2, capacity: 32 },
            latency_hist: hist_of(&[1.0; 8]),
            per_spec: vec![SpecServingStats {
                spec: "8x8-dsp2".into(),
                fingerprint: 0xABCD,
                partitions: 1,
                cache: CacheStats { hits: 6, misses: 2, evictions: 0, entries: 2, capacity: 32 },
                compile_seconds: 0.1,
                routed: 8,
                best_fit: 8,
                widest: 0,
                only_fit: 0,
                fallbacks: 0,
                cross_spec_hits: 0,
                replication_histogram: vec![(16, 8)],
            }],
            partitions: vec![PartitionServingStats {
                partition: 0,
                overlay: "8x8-dsp2".into(),
                dispatches: 8,
                reconfigs: 1,
                busy_seconds: 0.1,
                utilization: 0.1,
            }],
            admission: Some(crate::admission::AdmissionStats {
                admitted: 8,
                rejected_quota: 0,
                rejected_deadline: 1,
                shed: 0,
                pressure: 0.1,
                tenants: 2,
            }),
            ..Default::default()
        };

        let m = ServingStats::merge(&[busy.clone(), idle.clone()]);
        assert_eq!(m.total_dispatches, 40);
        assert_eq!(m.total_items, 4000);
        assert_eq!(m.cache.hits, 36);
        assert_eq!(m.cache.misses, 4);

        // lossless bucket addition: every one of the 40 completions
        // survives the merge (the old reservoir discipline thinned the
        // idle node 4:1 here), and the busy node's 32 slow samples
        // dominate the merged p50 to within one bucket of 100 ms.
        assert_eq!(m.latency_hist.count(), 40);
        assert_eq!(m.latency.count, 40);
        assert!(
            (70.0..=142.0).contains(&m.latency.p50_ms),
            "p50 within one bucket of 100: {}",
            m.latency.p50_ms
        );
        assert_eq!(m.latency.max_ms, 100.0);
        // preemption counters sum like every other recovery counter
        assert_eq!(m.preempted_runs, 3);
        assert_eq!(m.preempted_continuations, 4);

        // merge order cannot matter: bucket addition commutes
        let swapped = ServingStats::merge(&[idle.clone(), busy.clone()]);
        assert_eq!(m.latency_hist, swapped.latency_hist, "merge(a,b) == merge(b,a)");
        assert_eq!(m.latency.p50_ms, swapped.latency.p50_ms);
        assert_eq!(m.latency.p99_ms, swapped.latency.p99_ms);

        // partition rows re-number instead of colliding
        assert_eq!(m.partitions.len(), 2);
        assert_eq!(m.partitions[0].partition, 0);
        assert_eq!(m.partitions[1].partition, 1);

        // per-spec rows merge by fingerprint, histograms included
        assert_eq!(m.per_spec.len(), 1);
        let spec = &m.per_spec[0];
        assert_eq!(spec.fingerprint, 0xABCD);
        assert_eq!(spec.partitions, 3);
        assert_eq!(spec.routed, 40);
        assert_eq!(spec.replication_histogram, vec![(8, 2), (16, 38)]);

        // admission: counts sum, pressure/tenants take the max
        let adm = m.admission.expect("merged admission");
        assert_eq!(adm.admitted, 40);
        assert_eq!(adm.rejected_quota, 1);
        assert_eq!(adm.rejected_deadline, 1);
        assert_eq!(adm.shed, 2);
        assert_eq!(adm.pressure, 0.9);
        assert_eq!(adm.tenants, 3);

        // faults stay per-node; merging nothing yields a default
        assert!(m.faults.is_none());
        assert_eq!(ServingStats::merge(&[]).total_dispatches, 0);
    }

    #[test]
    fn sliding_window_evicts_oldest_and_summarizes() {
        let mut w = SlidingWindow::new(4);
        assert!(w.is_empty());
        assert_eq!(w.mean(), 0.0);
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.push(v);
        }
        assert!(w.is_full());
        assert_eq!(w.len(), 4);
        assert!((w.mean() - 2.5).abs() < 1e-12);
        assert_eq!(w.max(), 4.0);
        // pushing past capacity drops the oldest sample (1.0)
        w.push(8.0);
        assert_eq!(w.len(), 4);
        assert!((w.mean() - (2.0 + 3.0 + 4.0 + 8.0) / 4.0).abs() < 1e-12);
        assert_eq!(w.max(), 8.0);
        assert_eq!(w.percentile(0.0), 2.0);
        assert_eq!(w.percentile(1.0), 8.0);
        w.clear();
        assert!(w.is_empty());
        // capacity is clamped to at least one sample
        assert_eq!(SlidingWindow::new(0).capacity(), 1);
    }

    #[test]
    fn text_table_renders_aligned() {
        let mut t = TextTable::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "22"]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn percentile_degenerate_inputs() {
        // empty slice: every percentile is 0.0, no panic
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[], 1.0), 0.0);
        // single sample: every percentile is that sample
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 0.5), 7.5);
        assert_eq!(percentile(&[7.5], 1.0), 7.5);
        // p = 0 / p = 1 hit the exact ends of a multi-sample slice
        let sorted = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&sorted, 1.0), 5.0);
        // out-of-range p clamps to the last index instead of panicking
        assert_eq!(percentile(&sorted, 2.0), 5.0);
    }

    #[test]
    fn sliding_window_degenerate_inputs() {
        // empty window: every summary is 0.0
        let w = SlidingWindow::new(4);
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.max(), 0.0);
        assert_eq!(w.percentile(0.0), 0.0);
        assert_eq!(w.percentile(0.5), 0.0);
        assert_eq!(w.percentile(1.0), 0.0);
        // single sample: every percentile collapses onto it
        let mut w = SlidingWindow::new(4);
        w.push(3.25);
        assert_eq!(w.percentile(0.0), 3.25);
        assert_eq!(w.percentile(0.5), 3.25);
        assert_eq!(w.percentile(1.0), 3.25);
        assert_eq!(w.mean(), 3.25);
        assert_eq!(w.max(), 3.25);
    }

    #[test]
    fn empty_latency_and_empty_merge_are_all_zero() {
        let empty = LatencyStats::from_samples_ms(vec![]);
        assert_eq!(empty.count, 0);
        assert_eq!(empty.p50_ms, 0.0);
        assert_eq!(empty.p99_ms, 0.0);
        assert_eq!(empty.max_ms, 0.0);
        assert_eq!(empty.mean_ms, 0.0);
        let empty_hist = LatencyHist::new();
        assert_eq!(LatencyStats::from_hist(&empty_hist).count, 0);
        let merged = ServingStats::merge(&[]);
        assert_eq!(merged.total_dispatches, 0);
        assert_eq!(merged.latency.count, 0);
        assert_eq!(merged.latency_hist.count(), 0);
        assert_eq!(merged.preempted_runs, 0);
        assert!(merged.slo.is_none());
        assert!(merged.partitions.is_empty());
        assert!(merged.per_spec.is_empty());
        assert!(merged.admission.is_none());
        assert!(merged.autoscale.is_none());
        assert!(merged.faults.is_none());
    }

    #[test]
    fn prometheus_exposition_round_trips() {
        let s = ServingStats {
            cache: CacheStats { hits: 9, misses: 3, evictions: 1, entries: 2, capacity: 32 },
            total_dispatches: 12,
            total_items: 1200,
            retried_dispatches: 2,
            preempted_runs: 3,
            preempted_continuations: 5,
            rejected_submits: 4,
            shed_submits: 1,
            quarantine_events: 1,
            latency: LatencyStats::from_hist(&hist_of(&[1.0, 2.0, 4.0])),
            latency_hist: hist_of(&[1.0, 2.0, 4.0]),
            slo: Some(crate::obs::SloStats {
                objectives: 1,
                firing: 1,
                alerts_total: 3,
                burn: 2.5,
                ..Default::default()
            }),
            faults: Some(crate::admission::FaultTally::default()),
            ..Default::default()
        };
        let page = s.prometheus();
        let parsed = parse_prometheus(&page).expect("well-formed page");
        let get = |name: &str| -> f64 {
            parsed
                .iter()
                .find(|(n, _)| n == name)
                .unwrap_or_else(|| panic!("missing metric {name}"))
                .1
        };
        assert_eq!(get("overlay_jit_cache_hits_total"), 9.0);
        assert_eq!(get("overlay_jit_cache_misses_total"), 3.0);
        assert_eq!(get("overlay_jit_dispatches_total"), 12.0);
        assert_eq!(get("overlay_jit_items_total"), 1200.0);
        assert_eq!(get("overlay_jit_retried_dispatches_total"), 2.0);
        assert_eq!(get("overlay_jit_preempted_runs_total"), 3.0);
        assert_eq!(get("overlay_jit_preempted_continuations_total"), 5.0);
        assert_eq!(get("overlay_jit_rejected_submits_total"), 4.0);
        assert_eq!(get("overlay_jit_shed_submits_total"), 1.0);
        assert_eq!(get("overlay_jit_quarantine_events_total"), 1.0);
        assert_eq!(get("overlay_jit_latency_max_ms"), 4.0);
        assert_eq!(get("overlay_jit_faults_injected_total"), 0.0);
        assert_eq!(get("overlay_jit_slo_burn"), 2.5);
        assert_eq!(get("overlay_jit_slo_firing"), 1.0);
        assert_eq!(get("overlay_jit_slo_alerts_total"), 3.0);

        // histogram exposition: cumulative buckets, +Inf, _sum, _count
        assert_eq!(get(r#"overlay_jit_latency_ms_bucket{le="+Inf"}"#), 3.0);
        assert_eq!(get("overlay_jit_latency_ms_count"), 3.0);
        assert!((get("overlay_jit_latency_ms_sum") - 7.0).abs() < 1e-9);
        let buckets: Vec<f64> = parsed
            .iter()
            .filter(|(n, _)| n.starts_with("overlay_jit_latency_ms_bucket"))
            .map(|&(_, v)| v)
            .collect();
        assert!(buckets.len() >= 2, "at least one finite bucket plus +Inf");
        assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "cumulative buckets ascend");
        assert_eq!(*buckets.last().unwrap(), 3.0, "+Inf bucket equals count");

        // every sample line names a declared family (HELP + TYPE) —
        // labelled/suffixed series map back through prometheus_family
        for (name, _) in &parsed {
            let family = prometheus_family(name);
            assert!(page.contains(&format!("# TYPE {family} ")), "undeclared {name}");
        }
        assert_eq!(prometheus_family(r#"overlay_jit_latency_ms_bucket{le="0.5"}"#), "overlay_jit_latency_ms");
        assert_eq!(prometheus_family("overlay_jit_latency_ms_sum"), "overlay_jit_latency_ms");
        assert_eq!(prometheus_family("overlay_jit_dispatches_total"), "overlay_jit_dispatches_total");

        // parsing tolerates HELP/TYPE in any order, even after samples
        let scrambled = "jit_x_total 3\n# TYPE jit_x_total counter\n# HELP jit_x_total scrambled\n";
        let p2 = parse_prometheus(scrambled).expect("order-tolerant parse");
        assert_eq!(p2, vec![("jit_x_total".to_string(), 3.0)]);

        // malformed pages are errors, not silent zeros
        assert!(parse_prometheus("metric_without_value\n").is_err());
        assert!(parse_prometheus("metric nan_oops extra\n").is_err());
        assert!(parse_prometheus("broken{le=\"0.5\" 1\n").is_err());
    }
}
