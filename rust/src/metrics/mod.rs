//! Throughput / resource / host-speed models behind Figs. 6–7 and
//! Table III.
//!
//! * **GOPS model** — the paper's Fig. 6 metric is
//!   `copies × ops-per-kernel × Fmax`: a spatially configured II=1
//!   overlay retires every mapped op once per cycle. Peak is the
//!   overlay's total DSP op capacity ([`OverlaySpec::peak_gops`]).
//! * **Slice model** — the full 8×8 two-DSP overlay occupies 12,617
//!   Zynq slices (Table III): 197 per tile + 9 fixed.
//! * **Host-speed model** — Fig. 7's third bar (Overlay-PAR-Zynq) is
//!   the x86 measurement scaled by the published 667 MHz Cortex-A9 vs
//!   3.5 GHz Xeon slowdown (0.88 s / 0.22 s = 4.0×).

use crate::compiler::CompiledKernel;
use crate::overlay::OverlaySpec;

/// Slices of overlay fabric per tile (calibrated to Table III's 12617
/// for the 8×8 two-DSP overlay).
pub const SLICES_PER_TILE: usize = 197;
/// Fixed overlay infrastructure slices (config controller, AXI).
pub const SLICES_FIXED: usize = 9;

/// Fig. 7 Zynq-ARM / x86-Xeon PAR slowdown (0.88 / 0.22).
pub const ZYNQ_ARM_SLOWDOWN: f64 = 4.0;

/// Achieved throughput of `copies` replicas of a kernel with
/// `ops_per_copy` DFG operations at `fmax_mhz` — in GOPS.
pub fn achieved_gops(copies: usize, ops_per_copy: usize, fmax_mhz: f64) -> f64 {
    (copies * ops_per_copy) as f64 * fmax_mhz / 1000.0
}

/// Overlay slice footprint (constant per overlay, independent of the
/// kernel mapped — the whole point of Table III's fixed 12617).
pub fn overlay_slices(spec: &OverlaySpec) -> usize {
    spec.fu_count() * SLICES_PER_TILE + SLICES_FIXED
}

/// One Fig. 6 sample point.
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    pub overlay: String,
    pub fu_count: usize,
    pub copies: usize,
    pub gops: f64,
    pub peak_gops: f64,
    pub utilization: f64,
}

/// Evaluate a compiled kernel's throughput on its overlay.
pub fn throughput(spec: &OverlaySpec, k: &CompiledKernel) -> ThroughputPoint {
    let gops = achieved_gops(k.copies(), k.ops_per_copy(), spec.fmax_mhz());
    let peak = spec.peak_gops();
    ThroughputPoint {
        overlay: spec.name(),
        fu_count: spec.fu_count(),
        copies: k.copies(),
        gops,
        peak_gops: peak,
        utilization: gops / peak,
    }
}

/// Simple fixed-width table formatter used by the bench harnesses to
/// print the paper's tables.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = width[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::JitCompiler;
    use crate::overlay::FuType;

    #[test]
    fn overlay_slice_model_matches_table3() {
        assert_eq!(overlay_slices(&OverlaySpec::zynq_default()), 12617);
    }

    #[test]
    fn fig6_endpoints_match_paper() {
        // 16 copies × 7 ops × 300 MHz = 33.6 GOPS ≈ "≈35 GOPS … 30% of
        // peak"; 12 × 7 × 338 = 28.4 ≈ "≈28 GOPS … 43% of 65 GOPS"
        let jit2 = JitCompiler::new(OverlaySpec::new(8, 8, FuType::Dsp2));
        let k2 = jit2.compile(crate::bench_kernels::CHEBYSHEV).unwrap();
        let t2 = throughput(&jit2.spec, &k2);
        assert!((t2.gops - 33.6).abs() < 0.1, "{}", t2.gops);
        assert!((t2.utilization - 0.292).abs() < 0.02);

        let jit1 = JitCompiler::new(OverlaySpec::new(8, 8, FuType::Dsp1));
        let k1 = jit1.compile(crate::bench_kernels::CHEBYSHEV).unwrap();
        let t1 = throughput(&jit1.spec, &k1);
        assert!((t1.gops - 28.4).abs() < 0.1, "{}", t1.gops);
        assert!((t1.utilization - 0.437).abs() < 0.02);
    }

    #[test]
    fn single_copy_point_matches_fig6_left_edge() {
        // one instance on 2×2 dsp2: 7 ops × 300 MHz = 2.1 GOPS (paper
        // reads ≈2.45); utilization ≈ 30%
        let jit = JitCompiler::new(OverlaySpec::new(2, 2, FuType::Dsp2));
        let k = jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap();
        let t = throughput(&jit.spec, &k);
        assert!((t.gops - 2.1).abs() < 0.05);
        assert!((t.utilization - 0.29).abs() < 0.03);
    }

    #[test]
    fn text_table_renders_aligned() {
        let mut t = TextTable::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "22"]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() >= 4);
    }
}
