//! The JIT compilation pipeline driver (paper Fig. 2).
//!
//! `source → lex/parse/sema → naive IR → optimized IR → DFG →
//! FU-aware DFG → resource-aware replication → FU netlist → placement
//! → routing → latency balancing → configuration generation`.
//!
//! [`JitCompiler`] owns the overlay description (what the OpenCL
//! runtime exposes) and a prebuilt routing-resource graph; each
//! [`JitCompiler::compile`] run produces a [`CompiledKernel`] holding
//! every intermediate artifact plus a per-stage timing
//! [`CompileReport`] — the quantity Fig. 7 plots.

use std::time::Duration;

use anyhow::{Context, Result};

use crate::configgen::{bitstream, slot_schedule, EmuGeometry, SlotSchedule};
use crate::dfg::{extract_dfg, Dfg};
use crate::frontend::parse_kernel;
use crate::fuaware::{cluster, fuse_muladd, FuGraph};
use crate::ir::{lower_kernel, optimize, PassStats};
use crate::latency::{balance, LatencyReport};
use crate::netlist::{build_netlist, FuNetlist};
use crate::overlay::{OverlayBitstream, OverlaySpec, RoutingGraph};
use crate::place::{place_with, Placement, PlacerOptions};
use crate::replicate::{plan, replicate_dfg, BackendLimits, ReplicationPlan};
use crate::route::{bind_nets, route, RouteResult, RouterOptions};
use crate::util::Stopwatch;

/// How many kernel copies to map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replication {
    /// Fill the overlay (the paper's resource-aware default).
    Auto,
    /// Exactly `n` copies (Fig. 5/6 sweeps).
    Fixed(usize),
}

/// Compiler options.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Seed for the stochastic passes (placement).
    pub seed: u64,
    /// Placer effort (§Perf: inner_num 0.5 halves PAR time for ~1%
    /// wirelength on these netlists; routing still converges in one
    /// PathFinder iteration).
    pub placer: PlacerOptions,
    pub replication: Replication,
    /// Execution-backend limits (AOT emulator geometry), if the kernel
    /// will run through the PJRT backend.
    pub backend_limits: Option<BackendLimits>,
    pub router: RouterOptions,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            seed: 1,
            placer: PlacerOptions { inner_num: 0.5 },
            replication: Replication::Auto,
            backend_limits: Some(BackendLimits {
                max_op_slots: EmuGeometry::DEFAULT.max_fus,
                max_inputs: EmuGeometry::DEFAULT.num_inputs,
            }),
            router: RouterOptions::default(),
        }
    }
}

impl CompileOptions {
    /// Stable fingerprint over every option that can change the
    /// compiled artifact — one third of the coordinator's compile-cache
    /// key (source hash, overlay fingerprint, options fingerprint).
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::StableHasher::new();
        h.write_u64(self.seed);
        h.write_f64(self.placer.inner_num);
        match self.replication {
            Replication::Auto => h.write_u64(0),
            Replication::Fixed(n) => {
                h.write_u64(1);
                h.write_usize(n);
            }
        }
        match &self.backend_limits {
            None => h.write_u64(0),
            Some(b) => {
                h.write_u64(1);
                h.write_usize(b.max_op_slots);
                h.write_usize(b.max_inputs);
            }
        }
        h.write_usize(self.router.max_iterations);
        h.write_f64(self.router.first_pres_fac);
        h.write_f64(self.router.pres_fac_mult);
        h.write_f64(self.router.hist_fac);
        h.write_f64(self.router.astar_fac);
        h.finish()
    }
}

/// Stable (FNV-1a) hash of a kernel source string. Unlike
/// `DefaultHasher`, the value is identical across processes and Rust
/// versions, so cache keys built from it can be logged and compared
/// across runs.
pub fn stable_source_hash(source: &str) -> u64 {
    crate::util::fnv1a_64(source.as_bytes())
}

/// Wall-clock timing of each pipeline stage.
#[derive(Debug, Clone, Default)]
pub struct CompileReport {
    pub stages: Vec<(String, Duration)>,
    pub pass_stats: Option<PassStats>,
    /// Routing iterations (PathFinder convergence metric).
    pub route_iterations: usize,
}

impl CompileReport {
    pub fn total(&self) -> Duration {
        self.stages.iter().map(|(_, d)| *d).sum()
    }

    /// Front-end time: everything before placement (Clang-equivalent).
    pub fn frontend_time(&self) -> Duration {
        self.stages
            .iter()
            .filter(|(n, _)| !matches!(n.as_str(), "place" | "route" | "latency" | "configgen"))
            .map(|(_, d)| *d)
            .sum()
    }

    /// PAR time: placement + routing (+ latency + config) — the Fig. 7
    /// metric compared against Vivado.
    pub fn par_time(&self) -> Duration {
        self.stages
            .iter()
            .filter(|(n, _)| matches!(n.as_str(), "place" | "route" | "latency" | "configgen"))
            .map(|(_, d)| *d)
            .sum()
    }

    pub fn get(&self, stage: &str) -> Option<Duration> {
        self.stages.iter().find(|(n, _)| n == stage).map(|(_, d)| *d)
    }
}

/// Everything produced by one JIT compilation.
#[derive(Debug, Clone)]
pub struct CompiledKernel {
    pub name: String,
    /// Kernel parameter list (host argument binding).
    pub params: Vec<crate::frontend::Param>,
    /// Single-copy DFG (Table II(a) form).
    pub dfg: Dfg,
    /// Single-copy FU-aware graph.
    pub single: FuGraph,
    pub plan: ReplicationPlan,
    /// Replicated + clustered graph actually mapped.
    pub fg: FuGraph,
    pub netlist: FuNetlist,
    pub placement: Placement,
    pub routes: RouteResult,
    pub latency: LatencyReport,
    pub bitstream: OverlayBitstream,
    pub schedule: SlotSchedule,
    pub report: CompileReport,
}

/// Compact cost summary of a compiled kernel — what the serving
/// coordinator needs for scheduling and reporting without dragging the
/// full artifact around.
#[derive(Debug, Clone)]
pub struct KernelCost {
    pub name: String,
    /// Replicated copies mapped.
    pub copies: usize,
    /// Arithmetic ops per copy (GOPS model input).
    pub ops_per_copy: usize,
    /// Functional units consumed on the overlay.
    pub fus: usize,
    /// Emulator op slots in the levelized schedule.
    pub op_slots: usize,
    /// Serialized configuration size — drives the modeled
    /// reconfiguration cost when a partition must swap kernels.
    pub bitstream_bytes: usize,
    /// Fill latency of the mapped pipeline, cycles.
    pub pipeline_depth: u32,
    /// Measured wall time of the whole JIT compile.
    pub compile_seconds: f64,
    /// Measured wall time of the PAR portion (the Fig. 7 metric).
    pub par_seconds: f64,
}

/// The executable slice of a [`CompiledKernel`] — exactly what the
/// serving layer needs to bind arguments, dispatch, verify and model a
/// kernel, without dragging the PAR artifacts (netlist, placement,
/// routes) around. This is also the unit the coordinator's kernel
/// cache persists to disk: schedule + bitstream + host-binding
/// metadata round-trip through the snapshot format, so a restarted
/// fleet warm-starts without re-paying the seconds-class JIT.
#[derive(Debug, Clone)]
pub struct ServableKernel {
    pub name: String,
    /// Kernel parameter list (host argument binding).
    pub params: Vec<crate::frontend::Param>,
    /// Replicated copies mapped.
    pub factor: usize,
    /// Which resource capped the replication factor.
    pub limit: crate::replicate::LimitReason,
    /// Arithmetic ops per copy (GOPS model input).
    pub ops_per_copy: usize,
    /// Functional units consumed on the overlay (all copies).
    pub fus: usize,
    /// Input streams per copy.
    pub n_inputs: usize,
    /// Output streams per copy.
    pub n_outputs: usize,
    /// Host binding of each per-copy input stream.
    pub input_meta: Vec<crate::dfg::StreamMeta>,
    /// Host binding of each per-copy output stream.
    pub output_meta: Vec<crate::dfg::StreamMeta>,
    /// Latency-balancing report (timing model input; snapshot restores
    /// keep only the stream latencies and pipeline depth).
    pub latency: LatencyReport,
    pub bitstream: OverlayBitstream,
    pub schedule: SlotSchedule,
    /// Wall seconds of the JIT compile that produced this kernel
    /// (0.0 when restored from a snapshot — nothing was compiled).
    pub compile_seconds: f64,
}

impl CompiledKernel {
    /// Replicated copies mapped.
    pub fn copies(&self) -> usize {
        self.plan.factor
    }

    /// Arithmetic ops per copy (GOPS model input).
    pub fn ops_per_copy(&self) -> usize {
        self.dfg.num_ops()
    }

    /// Extract the executable slice served by the coordinator.
    pub fn servable(&self) -> ServableKernel {
        ServableKernel {
            name: self.name.clone(),
            params: self.params.clone(),
            factor: self.plan.factor,
            limit: self.plan.limit,
            ops_per_copy: self.dfg.num_ops(),
            fus: self.fg.num_fus(),
            n_inputs: self.dfg.num_inputs(),
            n_outputs: self.dfg.num_outputs(),
            input_meta: self.dfg.input_meta.clone(),
            output_meta: self.dfg.output_meta.clone(),
            latency: self.latency.clone(),
            bitstream: self.bitstream.clone(),
            schedule: self.schedule.clone(),
            compile_seconds: self.report.total().as_secs_f64(),
        }
    }

    /// The coordinator-facing cost summary.
    pub fn cost_summary(&self) -> KernelCost {
        KernelCost {
            name: self.name.clone(),
            copies: self.copies(),
            ops_per_copy: self.ops_per_copy(),
            fus: self.fg.num_fus(),
            op_slots: self.schedule.n_slots(),
            bitstream_bytes: self.bitstream.byte_size(),
            pipeline_depth: self.latency.pipeline_depth,
            compile_seconds: self.report.total().as_secs_f64(),
            par_seconds: self.report.par_time().as_secs_f64(),
        }
    }
}

/// Result of the compile-free front-half analysis
/// ([`JitCompiler::plan_kernel`]): the replication decision the fleet
/// router scores specs with, at a tiny fraction of a full JIT run.
#[derive(Debug, Clone)]
pub struct KernelPlan {
    pub name: String,
    /// Arithmetic ops per copy (GOPS model input).
    pub ops_per_copy: usize,
    pub plan: ReplicationPlan,
}

/// Intermediate artifacts of the pipeline's front half, shared by
/// [`JitCompiler::compile`] and [`JitCompiler::plan_kernel`].
struct FrontHalf {
    ast: crate::frontend::Kernel,
    /// Single-copy DFG.
    dfg: Dfg,
    /// Single-copy DFG after multiply–add fusion.
    fused: Dfg,
    /// Single-copy FU-aware graph.
    single: FuGraph,
    plan: ReplicationPlan,
    pass_stats: PassStats,
    /// Per-stage wall times, spliced into the [`CompileReport`].
    stages: Vec<(String, Duration)>,
}

/// The JIT compiler bound to one overlay instance.
#[derive(Debug)]
pub struct JitCompiler {
    pub spec: OverlaySpec,
    pub options: CompileOptions,
    rrg: RoutingGraph,
}

impl JitCompiler {
    pub fn new(spec: OverlaySpec) -> Self {
        Self::with_options(spec, CompileOptions::default())
    }

    pub fn with_options(spec: OverlaySpec, options: CompileOptions) -> Self {
        let rrg = RoutingGraph::build(&spec);
        JitCompiler { spec, options, rrg }
    }

    pub fn rrg(&self) -> &RoutingGraph {
        &self.rrg
    }

    /// The shared front half of [`JitCompiler::compile`] and
    /// [`JitCompiler::plan_kernel`]: parse → IR → DFG → FU-aware
    /// transform → resource-aware replication decision. One code
    /// path, so the router's plans are *structurally* identical to
    /// what a full compile produces — any future pass added here
    /// changes both automatically. `replication` is usually
    /// `self.options.replication`; the autoscaler's
    /// [`JitCompiler::compile_at_factor`] passes an override.
    fn front_half(&self, source: &str, replication: Replication) -> Result<FrontHalf> {
        let mut sw = Stopwatch::new();
        let mut stages: Vec<(String, std::time::Duration)> = Vec::new();

        // front end
        let ast = parse_kernel(source).context("front end")?;
        stages.push(("parse".to_string(), sw.lap("parse")));
        let naive = lower_kernel(&ast)?;
        stages.push(("lower".to_string(), sw.lap("lower")));
        let (ir, pass_stats) = optimize(&naive);
        stages.push(("optimize".to_string(), sw.lap("optimize")));
        let dfg = extract_dfg(&ir).context("DFG extraction")?;
        stages.push(("dfg".to_string(), sw.lap("dfg")));

        // FU-aware transform
        let fused = fuse_muladd(&dfg)?;
        let single = cluster(&fused, self.spec.fu_type.dsps_per_fu())?;
        stages.push(("fuaware".to_string(), sw.lap("fuaware")));

        // resource-aware replication decision
        let mut rep_plan = plan(&single, &self.spec, self.options.backend_limits)
            .context("replication planning")?;
        if let Replication::Fixed(n) = replication {
            if n > rep_plan.factor {
                anyhow::bail!(
                    "requested {} copies but the {} overlay supports at most {} ({})",
                    n,
                    self.spec.name(),
                    rep_plan.factor,
                    rep_plan.limit.name()
                );
            }
            rep_plan.factor = n;
        }
        Ok(FrontHalf { ast, dfg, fused, single, plan: rep_plan, pass_stats, stages })
    }

    /// Run only the front half of the pipeline — parse → IR → DFG →
    /// FU-aware transform → resource-aware replication — and return
    /// the replication decision, **without** placement, routing or
    /// configuration generation. This is the µs-class analysis the
    /// fleet router uses to score overlay specs for an incoming
    /// kernel before committing to the seconds-class JIT; the factor
    /// and limit it reports are identical to what
    /// [`JitCompiler::compile`] would produce — both run the same
    /// [`JitCompiler::front_half`].
    pub fn plan_kernel(&self, source: &str) -> Result<KernelPlan> {
        let front = self.front_half(source, self.options.replication)?;
        Ok(KernelPlan {
            name: front.ast.name,
            ops_per_copy: front.dfg.num_ops(),
            plan: front.plan,
        })
    }

    /// JIT-compile an OpenCL kernel to an overlay configuration.
    pub fn compile(&self, source: &str) -> Result<CompiledKernel> {
        self.compile_with_replication(source, self.options.replication)
    }

    /// JIT-compile at an explicit replication factor — the
    /// autoscaler's entry point. Reuses this compiler's prebuilt
    /// routing-resource graph and every other option; only the copy
    /// count differs, so the artifact is exactly what
    /// [`JitCompiler::compile`] under
    /// `CompileOptions { replication: Replication::Fixed(factor), .. }`
    /// would produce (and caches under that options fingerprint).
    /// Errors when `factor` exceeds the resource-aware ceiling
    /// reported by [`JitCompiler::plan_kernel`].
    pub fn compile_at_factor(&self, source: &str, factor: usize) -> Result<CompiledKernel> {
        self.compile_with_replication(source, Replication::Fixed(factor))
    }

    fn compile_with_replication(
        &self,
        source: &str,
        replication: Replication,
    ) -> Result<CompiledKernel> {
        let FrontHalf { ast, dfg, fused, single, plan: rep_plan, pass_stats, stages } =
            self.front_half(source, replication)?;
        let mut report = CompileReport { stages, pass_stats: Some(pass_stats), ..Default::default() };
        let mut sw = Stopwatch::new();
        let lap = |sw: &mut Stopwatch, report: &mut CompileReport, name: &str| {
            let d = sw.lap(name);
            report.stages.push((name.to_string(), d));
        };

        // replication: materialize the planned copies
        let dsps = self.spec.fu_type.dsps_per_fu();
        let replicated = replicate_dfg(&fused, rep_plan.factor);
        let fg = cluster(&replicated, dsps)?;
        lap(&mut sw, &mut report, "replicate");

        // netlist
        let netlist = build_netlist(&fg);
        lap(&mut sw, &mut report, "netlist");

        // PAR
        let placement = place_with(
            &netlist,
            &self.spec,
            &self.rrg,
            self.options.seed,
            &self.options.placer,
        )
        .context("placement")?;
        lap(&mut sw, &mut report, "place");
        let bound = bind_nets(&fg, &netlist, &placement, &self.rrg)?;
        let routes = route(&self.rrg, &bound.route_nets, &self.options.router)
            .context("routing")?;
        report.route_iterations = routes.iterations;
        lap(&mut sw, &mut report, "route");

        // latency balancing
        let latency = balance(&fg, &self.spec, &self.rrg, &bound, &routes)
            .context("latency balancing")?;
        lap(&mut sw, &mut report, "latency");

        // configuration generation
        let bs = bitstream(&fg, &self.spec, &self.rrg, &placement, &routes, &latency);
        let schedule = slot_schedule(&fg.dfg, EmuGeometry::DEFAULT)?;
        lap(&mut sw, &mut report, "configgen");

        Ok(CompiledKernel {
            params: ast.params.clone(),
            name: ast.name,
            dfg,
            single,
            plan: rep_plan,
            fg,
            netlist,
            placement,
            routes,
            latency,
            bitstream: bs,
            schedule,
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overlay::FuType;

    const CHEB: &str = "__kernel void chebyshev(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    #[test]
    fn end_to_end_compile_on_8x8_dsp2() {
        let jit = JitCompiler::new(OverlaySpec::zynq_default());
        let k = jit.compile(CHEB).unwrap();
        assert_eq!(k.name, "chebyshev");
        assert_eq!(k.copies(), 16);
        assert_eq!(k.fg.num_fus(), 48);
        assert_eq!(k.schedule.n_slots(), 80);
        assert_eq!(k.bitstream.byte_size(), 1061);
        assert!(k.report.total() > Duration::ZERO);
        assert!(k.report.get("route").is_some());
    }

    #[test]
    fn fixed_replication_respected() {
        let jit = JitCompiler::with_options(
            OverlaySpec::zynq_default(),
            CompileOptions { replication: Replication::Fixed(4), ..Default::default() },
        );
        let k = jit.compile(CHEB).unwrap();
        assert_eq!(k.copies(), 4);
        assert_eq!(k.netlist.num_inputs, 4);
    }

    #[test]
    fn oversubscribed_fixed_replication_errors() {
        let jit = JitCompiler::with_options(
            OverlaySpec::zynq_default(),
            CompileOptions { replication: Replication::Fixed(17), ..Default::default() },
        );
        assert!(jit.compile(CHEB).is_err());
    }

    #[test]
    fn compiles_on_every_fig5_size() {
        for spec in OverlaySpec::size_sweep(FuType::Dsp2) {
            let jit = JitCompiler::new(spec.clone());
            let k = jit
                .compile(CHEB)
                .unwrap_or_else(|e| panic!("{}: {e:#}", spec.name()));
            assert!(k.copies() >= 1);
            // every FU placed within bounds and latency balanced
            assert!(k.latency.pipeline_depth > 0);
        }
    }

    #[test]
    fn dsp1_overlay_compiles_12_copies() {
        let jit = JitCompiler::new(OverlaySpec::new(8, 8, FuType::Dsp1));
        let k = jit.compile(CHEB).unwrap();
        assert_eq!(k.copies(), 12);
        assert_eq!(k.fg.num_fus(), 60);
    }

    #[test]
    fn report_partitions_frontend_and_par() {
        let jit = JitCompiler::new(OverlaySpec::zynq_default());
        let k = jit.compile(CHEB).unwrap();
        let total = k.report.total();
        let split = k.report.frontend_time() + k.report.par_time();
        assert!((total.as_nanos() as i128 - split.as_nanos() as i128).abs() < 1000);
    }

    #[test]
    fn fingerprints_are_stable_and_sensitive() {
        let a = CompileOptions::default();
        let b = CompileOptions::default();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = CompileOptions { seed: 2, ..Default::default() };
        assert_ne!(a.fingerprint(), c.fingerprint());
        let d = CompileOptions {
            replication: Replication::Fixed(4),
            ..Default::default()
        };
        assert_ne!(a.fingerprint(), d.fingerprint());
        // source hash is content-addressed and whitespace-sensitive
        assert_eq!(stable_source_hash(CHEB), stable_source_hash(CHEB));
        assert_ne!(stable_source_hash(CHEB), stable_source_hash("__kernel void x() {}"));
    }

    #[test]
    fn cost_summary_matches_artifacts() {
        let jit = JitCompiler::new(OverlaySpec::zynq_default());
        let k = jit.compile(CHEB).unwrap();
        let c = k.cost_summary();
        assert_eq!(c.name, "chebyshev");
        assert_eq!(c.copies, k.copies());
        assert_eq!(c.ops_per_copy, k.ops_per_copy());
        assert_eq!(c.bitstream_bytes, k.bitstream.byte_size());
        assert_eq!(c.pipeline_depth, k.latency.pipeline_depth);
        assert!(c.compile_seconds > 0.0);
        assert!(c.par_seconds <= c.compile_seconds);
    }

    #[test]
    fn compile_errors_carry_stage_context() {
        let jit = JitCompiler::new(OverlaySpec::zynq_default());
        let err = jit.compile("__kernel void bad(__global int *B) { B[0] = x; }");
        assert!(format!("{:#}", err.unwrap_err()).contains("front end"));
    }

    #[test]
    fn plan_kernel_matches_full_compile() {
        for spec in [OverlaySpec::zynq_default(), OverlaySpec::new(4, 4, FuType::Dsp2)] {
            let jit = JitCompiler::new(spec);
            let p = jit.plan_kernel(CHEB).unwrap();
            let k = jit.compile(CHEB).unwrap();
            assert_eq!(p.name, k.name);
            assert_eq!(p.plan.factor, k.plan.factor);
            assert_eq!(p.plan.limit, k.plan.limit);
            assert_eq!(p.ops_per_copy, k.ops_per_copy());
        }
    }

    #[test]
    fn compile_at_factor_matches_fixed_option_artifacts() {
        let jit = JitCompiler::new(OverlaySpec::zynq_default());
        let k4 = jit.compile_at_factor(CHEB, 4).unwrap();
        assert_eq!(k4.copies(), 4);
        // byte-identical to a compiler configured with Fixed(4) — the
        // cache-key equivalence the autoscaler's variants rely on
        let fixed = JitCompiler::with_options(
            OverlaySpec::zynq_default(),
            CompileOptions { replication: Replication::Fixed(4), ..Default::default() },
        )
        .compile(CHEB)
        .unwrap();
        assert_eq!(k4.bitstream.to_bytes(), fixed.bitstream.to_bytes());
        assert_eq!(k4.schedule, fixed.schedule);
        // the resource-aware ceiling still binds
        assert!(jit.compile_at_factor(CHEB, 17).is_err());
    }

    #[test]
    fn plan_kernel_rejects_oversubscribed_fixed_replication() {
        let jit = JitCompiler::with_options(
            OverlaySpec::zynq_default(),
            CompileOptions { replication: Replication::Fixed(17), ..Default::default() },
        );
        assert!(jit.plan_kernel(CHEB).is_err());
    }

    #[test]
    fn servable_slice_matches_compiled_kernel() {
        let jit = JitCompiler::new(OverlaySpec::zynq_default());
        let k = jit.compile(CHEB).unwrap();
        let s = k.servable();
        assert_eq!(s.name, k.name);
        assert_eq!(s.factor, k.copies());
        assert_eq!(s.ops_per_copy, k.ops_per_copy());
        assert_eq!(s.n_inputs, k.dfg.num_inputs());
        assert_eq!(s.n_outputs, k.dfg.num_outputs());
        assert_eq!(s.input_meta, k.dfg.input_meta);
        assert_eq!(s.schedule, k.schedule);
        assert_eq!(s.bitstream.byte_size(), k.bitstream.byte_size());
        assert_eq!(s.latency.pipeline_depth, k.latency.pipeline_depth);
        assert!(s.compile_seconds > 0.0);
    }
}
