//! Per-spec compilation shards.
//!
//! A compiled kernel's placement, routing and bitstream are bound to
//! one [`OverlaySpec`]; a heterogeneous fleet therefore needs one
//! complete compilation stack per distinct spec. A [`CompileShard`]
//! owns exactly that: a [`JitCompiler`] (with its prebuilt
//! routing-resource graph), a [`KernelCache`] keyed by (source, spec,
//! options) fingerprints, and the global indices of the partitions
//! built from this spec. Shards never exchange cache entries — a
//! 4×4 bitstream can't configure an 8×8 region — and the
//! `cross_spec_hits` counter proves the isolation invariant at run
//! time (it must stay 0).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::compiler::{
    stable_source_hash, CompileOptions, JitCompiler, Replication, ServableKernel,
};
use crate::coordinator::{CacheKey, KernelCache};
use crate::metrics::CacheStats;
use crate::overlay::{ConfigSizeModel, OverlayBitstream, OverlaySpec};

/// One overlay spec's compiler + kernel cache + partitions.
pub struct CompileShard {
    spec: OverlaySpec,
    fingerprint: u64,
    options_fingerprint: u64,
    pub(crate) jit: JitCompiler,
    cache: Mutex<KernelCache>,
    /// Global partition (device) indices served from this shard.
    partitions: Vec<usize>,
    /// Modeled seconds to load one bitstream on this spec — the
    /// serialized configuration size is spec-constant, so this is
    /// computed once instead of per dispatch on the hot path.
    config_seconds_estimate: f64,
    compile_seconds: Mutex<f64>,
    /// Cache hits whose **artifact** didn't match this shard's overlay
    /// geometry — a bitstream for another grid landing under our key.
    /// Structurally impossible today (keys embed the spec fingerprint
    /// and snapshot loads filter on it), so this is the tripwire that
    /// turns a future isolation regression (shared cache, snapshot
    /// pollution, fingerprint collision) into a visible non-zero
    /// counter instead of a wrong-geometry dispatch.
    cross_spec_hits: AtomicU64,
}

impl std::fmt::Debug for CompileShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompileShard")
            .field("spec", &self.spec.name())
            .field("partitions", &self.partitions)
            .finish()
    }
}

impl CompileShard {
    pub fn new(
        spec: OverlaySpec,
        options: CompileOptions,
        cache_capacity: usize,
        partitions: Vec<usize>,
    ) -> CompileShard {
        let fingerprint = spec.fingerprint();
        let options_fingerprint = options.fingerprint();
        let config_seconds_estimate = ConfigSizeModel::overlay_config_seconds(
            &spec,
            OverlayBitstream::empty(&spec).byte_size(),
        );
        let jit = JitCompiler::with_options(spec.clone(), options);
        CompileShard {
            spec,
            fingerprint,
            options_fingerprint,
            jit,
            cache: Mutex::new(KernelCache::new(cache_capacity)),
            partitions,
            config_seconds_estimate,
            compile_seconds: Mutex::new(0.0),
            cross_spec_hits: AtomicU64::new(0),
        }
    }

    /// Modeled bitstream-load seconds on this spec (configuration
    /// size is spec-constant — see §IV's 1061 B / 42.4 µs).
    pub fn config_seconds_estimate(&self) -> f64 {
        self.config_seconds_estimate
    }

    pub fn spec(&self) -> &OverlaySpec {
        &self.spec
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn options_fingerprint(&self) -> u64 {
        self.options_fingerprint
    }

    pub fn partitions(&self) -> &[usize] {
        &self.partitions
    }

    /// The cache key this shard files `source` under.
    pub fn cache_key_for_hash(&self, source_hash: u64) -> CacheKey {
        CacheKey {
            source: source_hash,
            spec: self.fingerprint,
            options: self.options_fingerprint,
        }
    }

    /// The cache key this shard files a `factor`-copy variant of
    /// `source_hash` under: the options fingerprint of this shard's
    /// options with `Replication::Fixed(factor)` — identical to what
    /// a compiler configured that way would produce, so variant
    /// entries coexist with (and never collide with) the default
    /// plan's entry.
    pub fn variant_key(&self, source_hash: u64, factor: usize) -> CacheKey {
        let mut options = self.jit.options.clone();
        options.replication = Replication::Fixed(factor);
        CacheKey {
            source: source_hash,
            spec: self.fingerprint,
            options: options.fingerprint(),
        }
    }

    /// Cache-or-compile: the shard's hot path. Returns the executable
    /// kernel, whether it came from the cache, and its key.
    pub fn get_or_compile(&self, source: &str) -> Result<(Arc<ServableKernel>, bool, CacheKey)> {
        let key = CacheKey::new(source, &self.spec, &self.jit.options);
        self.get_or_compile_keyed(source, key, None)
    }

    /// Cache-or-compile an explicit-factor variant — the autoscaler's
    /// rescale path. Scale-backs to a factor this shard compiled
    /// before are cache **hits**: the variant key is stable, so the
    /// artifact is still resident (and even survives snapshots).
    pub fn get_or_compile_at(
        &self,
        source: &str,
        factor: usize,
    ) -> Result<(Arc<ServableKernel>, bool, CacheKey)> {
        let key = self.variant_key(stable_source_hash(source), factor);
        self.get_or_compile_keyed(source, key, Some(factor))
    }

    fn get_or_compile_keyed(
        &self,
        source: &str,
        key: CacheKey,
        factor: Option<usize>,
    ) -> Result<(Arc<ServableKernel>, bool, CacheKey)> {
        if let Some(k) = self.cache.lock().unwrap().get(&key) {
            if k.bitstream.rows == self.spec.rows && k.bitstream.cols == self.spec.cols {
                return Ok((k, true, key));
            }
            // an artifact for another overlay geometry under our key:
            // count the isolation violation and recompile rather than
            // dispatch a bitstream that cannot configure this grid
            self.cross_spec_hits.fetch_add(1, Ordering::Relaxed);
        }
        // the seconds-class step — paid once per distinct
        // (source, overlay, options[, factor])
        let t0 = Instant::now();
        let compiled = match factor {
            None => self.jit.compile(source)?,
            Some(f) => self.jit.compile_at_factor(source, f)?,
        };
        *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
        let servable = Arc::new(compiled.servable());
        self.cache.lock().unwrap().insert(key, servable.clone());
        Ok((servable, false, key))
    }

    /// Cache lookup without a compile fallback (counts a hit or miss,
    /// refreshes LRU order, enforces the geometry tripwire). The
    /// coordinator's variant dispatch path uses this: the autoscaler
    /// holds its own `Arc` of the active variant, so an evicted entry
    /// is re-admitted rather than recompiled.
    pub fn get_cached(&self, key: &CacheKey) -> Option<Arc<ServableKernel>> {
        let k = self.cache.lock().unwrap().get(key)?;
        if k.bitstream.rows == self.spec.rows && k.bitstream.cols == self.spec.cols {
            return Some(k);
        }
        self.cross_spec_hits.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Residency check that does NOT touch hit/miss counters or LRU
    /// order — for peeking (e.g. fault-injection gates) where a probe
    /// must not skew cache statistics.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.cache.lock().unwrap().contains(key)
    }

    /// Re-admit an already-compiled kernel (an autoscaler variant the
    /// LRU evicted) without paying a compile.
    pub fn admit(&self, key: CacheKey, servable: Arc<ServableKernel>) {
        self.cache.lock().unwrap().insert(key, servable);
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.lock().unwrap().stats()
    }

    /// Wall seconds of JIT compilation this shard has paid.
    pub fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }

    pub fn cross_spec_hits(&self) -> u64 {
        self.cross_spec_hits.load(Ordering::Relaxed)
    }

    /// Persist this shard's cache (see [`KernelCache::save_snapshot`]).
    /// Returns the number of entries written.
    pub fn save_snapshot(&self, path: &Path) -> Result<usize> {
        self.cache.lock().unwrap().save_snapshot(path)
    }

    /// Warm-start this shard's cache from a snapshot; entries for
    /// other specs or options are skipped, and a truncated or corrupt
    /// file is logged and ignored (cold start) rather than propagated
    /// — see [`KernelCache::load_snapshot`]. Returns entries loaded.
    pub fn load_snapshot(&self, path: &Path) -> usize {
        self.cache
            .lock()
            .unwrap()
            .load_snapshot(path, self.fingerprint, &self.jit.options)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_kernels::CHEBYSHEV;
    use crate::overlay::FuType;

    #[test]
    fn shard_caches_per_spec() {
        let shard = CompileShard::new(
            OverlaySpec::new(4, 4, FuType::Dsp2),
            CompileOptions::default(),
            8,
            vec![0, 1],
        );
        let (a, hit_a, key) = shard.get_or_compile(CHEBYSHEV).unwrap();
        assert!(!hit_a);
        assert_eq!(key.spec, shard.fingerprint());
        let (b, hit_b, _) = shard.get_or_compile(CHEBYSHEV).unwrap();
        assert!(hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        let stats = shard.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!(shard.compile_seconds() > 0.0);
        assert_eq!(shard.cross_spec_hits(), 0);
        assert_eq!(shard.partitions(), &[0, 1]);
    }

    #[test]
    fn factor_variants_cache_independently_and_scale_backs_hit() {
        let shard = CompileShard::new(
            OverlaySpec::zynq_default(),
            CompileOptions::default(),
            8,
            vec![0],
        );
        let (base, _, base_key) = shard.get_or_compile(CHEBYSHEV).unwrap();
        assert_eq!(base.factor, 16);
        // scale down: a distinct key, a fresh compile
        let (v2, hit2, key2) = shard.get_or_compile_at(CHEBYSHEV, 2).unwrap();
        assert!(!hit2);
        assert_eq!(v2.factor, 2);
        assert_ne!(key2, base_key);
        assert_eq!(key2, shard.variant_key(base_key.source, 2));
        // the base artifact is untouched and still a hit
        let (_, hit_base, _) = shard.get_or_compile(CHEBYSHEV).unwrap();
        assert!(hit_base);
        // scaling back to factor 2 is a cache hit — no recompile
        let misses_before = shard.cache_stats().misses;
        let (v2b, hit2b, _) = shard.get_or_compile_at(CHEBYSHEV, 2).unwrap();
        assert!(hit2b);
        assert!(Arc::ptr_eq(&v2, &v2b));
        assert_eq!(shard.cache_stats().misses, misses_before);
        // get_cached counts a hit without compiling; admit restores an
        // evicted entry
        assert!(shard.get_cached(&key2).is_some());
        assert!(shard.get_cached(&shard.variant_key(base_key.source, 7)).is_none());
        shard.admit(shard.variant_key(base_key.source, 7), v2b);
        assert!(shard.get_cached(&shard.variant_key(base_key.source, 7)).is_some());
    }

    #[test]
    fn distinct_specs_produce_distinct_keys_and_factors() {
        let big = CompileShard::new(
            OverlaySpec::zynq_default(),
            CompileOptions::default(),
            8,
            vec![0],
        );
        let small = CompileShard::new(
            OverlaySpec::new(4, 4, FuType::Dsp2),
            CompileOptions::default(),
            8,
            vec![1],
        );
        let (kb, _, key_b) = big.get_or_compile(CHEBYSHEV).unwrap();
        let (ks, _, key_s) = small.get_or_compile(CHEBYSHEV).unwrap();
        assert_eq!(key_b.source, key_s.source);
        assert_ne!(key_b.spec, key_s.spec);
        // the paper's resource arithmetic: 16 copies on 8×8 (I/O), 5
        // on 4×4 (FU: 16 FUs / 3 per copy)
        assert_eq!(kb.factor, 16);
        assert_eq!(ks.factor, 5);
    }
}
