//! The resource-aware router: which overlay spec serves a dispatch.
//!
//! For an incoming kernel the router consults its [`KernelProfile`] —
//! the per-spec replication plans ([`crate::replicate::plan`]: factor,
//! [`LimitReason`], FU and I/O demand) computed once by the compile-
//! free front-half analysis — and a live [`SpecObservation`] per spec
//! (queue depth, bitstream residency, modeled reconfiguration cost).
//! The decision rule:
//!
//! 1. **Demand**: a dispatch of `global_size` items wants
//!    `ceil(global_size / target_chunk)` kernel copies.
//! 2. **Adequate specs** (replication factor ≥ demand) compete on
//!    `(min queue depth, peak GOPS, reconfiguration cost,
//!    fingerprint)` — the *smallest idle* adequate overlay wins, so a
//!    small kernel never occupies an 8×8 partition while a 4×4 sits
//!    idle.
//! 3. With **no adequate spec** the dispatch is wide data-parallel:
//!    specs compete on `(achieved GOPS desc, queue, reconfiguration
//!    cost, fingerprint)` — it lands where `copies × ops × Fmax` is
//!    highest.
//!
//! Every decision is recorded (bounded) with the observations it was
//! made from, so tests and operators can audit placements after the
//! fact.

use std::cmp::Ordering;
use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Result};

use crate::replicate::LimitReason;
use crate::util::BoundedLog;

use super::policy::{Priority, RoutingPolicy};

/// Per-spec outcome of the compile-free replication analysis.
#[derive(Debug, Clone, Copy)]
pub struct PlanSummary {
    pub factor: usize,
    pub limit: LimitReason,
    pub fus_per_copy: usize,
    pub io_per_copy: usize,
    /// `factor × ops_per_copy × Fmax` — the Fig. 6 quantity.
    pub gops: f64,
}

/// What the fleet knows about one kernel: its name and, per shard
/// (fleet order), whether it fits and with what replication plan.
/// `None` marks a spec the kernel does not fit. Compile failures do
/// *not* edit the profile; they poison the `(kernel, spec)` pair with
/// a decaying TTL instead (see [`crate::fleet::Fleet::poison`]) and
/// are withheld at ranking time via [`apply_poison_mask`].
#[derive(Debug, Clone)]
pub struct KernelProfile {
    pub name: String,
    pub source_hash: u64,
    pub ops_per_copy: usize,
    pub fits: Vec<Option<PlanSummary>>,
}

/// Live per-spec state sampled at routing time, combined with the
/// profile's plan for that spec. One per shard, in fleet order.
#[derive(Debug, Clone)]
pub struct SpecObservation {
    pub fingerprint: u64,
    pub spec: String,
    /// Whether the kernel fits this spec at all.
    pub fits: bool,
    /// Whether this spec's replication factor meets the dispatch's
    /// copy demand (filled in by the router).
    pub adequate: bool,
    pub factor: usize,
    pub limit: Option<LimitReason>,
    pub gops: f64,
    pub peak_gops: f64,
    /// Shallowest dispatch queue among this spec's partitions.
    pub min_queue_depth: usize,
    /// Whether some partition of this spec already holds the kernel's
    /// bitstream (an affinity dispatch pays zero reconfiguration).
    pub resident: bool,
    /// Modeled bitstream-load seconds if a partition must reconfigure.
    pub config_seconds: f64,
}

impl SpecObservation {
    /// Reconfiguration cost this dispatch would actually pay.
    fn effective_config_seconds(&self) -> f64 {
        if self.resident {
            0.0
        } else {
            self.config_seconds
        }
    }
}

/// Why a spec was chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteReason {
    /// Several specs fit; the smallest adequate one (by queue, then
    /// peak) was picked — the "small kernel → small overlay" path.
    BestFit,
    /// No spec met the copy demand; the highest-throughput spec was
    /// picked — the "wide data-parallel → widest overlay" path.
    Widest,
    /// Exactly one spec fits this kernel.
    OnlyFit,
}

impl RouteReason {
    pub fn name(self) -> &'static str {
        match self {
            RouteReason::BestFit => "best-fit",
            RouteReason::Widest => "widest",
            RouteReason::OnlyFit => "only-fit",
        }
    }
}

/// One audited routing decision.
#[derive(Debug, Clone)]
pub struct RouteRecord {
    pub kernel: String,
    /// Admission tenant the dispatch was submitted under (the
    /// coordinator's default tenant for ungated submits) — lets
    /// per-tenant traffic be attributed per spec and, at cluster
    /// scale, per node.
    pub tenant: String,
    pub source_hash: u64,
    pub global_size: usize,
    pub copies_wanted: usize,
    /// Fingerprint of the spec that actually served the dispatch.
    pub chosen: u64,
    pub chosen_spec: String,
    pub reason: RouteReason,
    /// True when the first-ranked spec failed to compile and a
    /// lower-ranked candidate took the dispatch.
    pub fallback: bool,
    pub priority: Priority,
    /// The per-spec observations the decision was made from.
    pub specs: Vec<SpecObservation>,
}

/// Aggregate routing counters for one spec.
#[derive(Debug, Clone)]
pub struct SpecRouteStats {
    pub spec: String,
    pub fingerprint: u64,
    pub routed: u64,
    pub best_fit: u64,
    pub widest: u64,
    pub only_fit: u64,
    pub fallbacks: u64,
    /// Replication factor → dispatches served at that factor.
    pub histogram: BTreeMap<usize, u64>,
}

impl SpecRouteStats {
    fn new(spec: String, fingerprint: u64) -> SpecRouteStats {
        SpecRouteStats {
            spec,
            fingerprint,
            routed: 0,
            best_fit: 0,
            widest: 0,
            only_fit: 0,
            fallbacks: 0,
            histogram: BTreeMap::new(),
        }
    }
}

/// The routing engine: pure ranking plus bounded decision history.
#[derive(Debug)]
pub struct Router {
    policy: RoutingPolicy,
    records: BoundedLog<RouteRecord>,
    per_spec: HashMap<u64, SpecRouteStats>,
}

fn f64_cmp(a: f64, b: f64) -> Ordering {
    a.partial_cmp(&b).unwrap_or(Ordering::Equal)
}

/// Copies a dispatch of `global_size` items wants under `policy`.
pub fn copies_wanted(policy: &RoutingPolicy, global_size: usize) -> usize {
    global_size.div_ceil(policy.target_chunk.max(1)).max(1)
}

/// Withhold poisoned `(kernel, spec)` pairs from ranking: any spec the
/// fleet's [`poison mask`](crate::fleet::Fleet::poison_mask) marks is
/// treated as unfit for this dispatch only — the profile itself is
/// untouched, so the spec comes back automatically when the TTL
/// expires. Returns how many otherwise-fitting specs were withheld,
/// letting the caller tell "kernel does not fit the fleet" apart from
/// "every fitting spec is temporarily poisoned".
pub fn apply_poison_mask(obs: &mut [SpecObservation], mask: &[bool]) -> usize {
    let mut withheld = 0;
    for (o, &masked) in obs.iter_mut().zip(mask) {
        if masked && o.fits {
            o.fits = false;
            withheld += 1;
        }
    }
    withheld
}

/// Rank the specs for one dispatch — the pure decision function, free
/// of any router state so the coordinator's submit path can rank
/// **without holding the router lock** (the lock guards only the
/// bounded decision history appended by [`Router::commit`]).
///
/// `obs` must be in fleet shard order with the profile-derived fields
/// (`fits`, `factor`, `limit`, `gops`) already filled; this fills
/// `adequate` and returns shard indices in preference order (the tail
/// entries are compile-failure fallbacks), the reason for the first
/// choice, and the copy demand.
pub fn rank_specs(
    policy: &RoutingPolicy,
    profile: &KernelProfile,
    obs: &mut [SpecObservation],
    global_size: usize,
) -> Result<(Vec<usize>, RouteReason, usize)> {
    let wanted = copies_wanted(policy, global_size);
    for o in obs.iter_mut() {
        o.adequate = o.fits && o.factor >= wanted;
    }
    let fitting: Vec<usize> = (0..obs.len()).filter(|&i| obs[i].fits).collect();
    if fitting.is_empty() {
        bail!(
            "kernel '{}' fits none of the fleet's overlay specs",
            profile.name
        );
    }
    if fitting.len() == 1 {
        return Ok((fitting, RouteReason::OnlyFit, wanted));
    }
    let adequate: Vec<usize> = fitting
        .iter()
        .copied()
        .filter(|&i| obs[i].adequate)
        .collect();
    if !adequate.is_empty() {
        // small-kernel path: least loaded, then smallest overlay,
        // then cheapest reconfiguration, then stable order
        let mut ranked = adequate.clone();
        ranked.sort_by(|&a, &b| {
            let (oa, ob) = (&obs[a], &obs[b]);
            oa.min_queue_depth
                .cmp(&ob.min_queue_depth)
                .then(f64_cmp(oa.peak_gops, ob.peak_gops))
                .then(f64_cmp(
                    oa.effective_config_seconds(),
                    ob.effective_config_seconds(),
                ))
                .then(oa.fingerprint.cmp(&ob.fingerprint))
        });
        // compile-failure fallbacks: the remaining fitting specs,
        // widest first
        let mut rest: Vec<usize> = fitting
            .iter()
            .copied()
            .filter(|i| !adequate.contains(i))
            .collect();
        rest.sort_by(|&a, &b| f64_cmp(obs[b].gops, obs[a].gops));
        ranked.extend(rest);
        return Ok((ranked, RouteReason::BestFit, wanted));
    }
    // wide data-parallel path: highest copies × throughput wins
    let mut ranked = fitting;
    ranked.sort_by(|&a, &b| {
        let (oa, ob) = (&obs[a], &obs[b]);
        f64_cmp(ob.gops, oa.gops)
            .then(oa.min_queue_depth.cmp(&ob.min_queue_depth))
            .then(f64_cmp(
                oa.effective_config_seconds(),
                ob.effective_config_seconds(),
            ))
            .then(oa.fingerprint.cmp(&ob.fingerprint))
    });
    Ok((ranked, RouteReason::Widest, wanted))
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Router {
        let records = BoundedLog::new(policy.max_records);
        Router { policy, records, per_spec: HashMap::new() }
    }

    pub fn policy(&self) -> &RoutingPolicy {
        &self.policy
    }

    /// Copies a dispatch of `global_size` items wants.
    pub fn copies_wanted(&self, global_size: usize) -> usize {
        copies_wanted(&self.policy, global_size)
    }

    /// Rank the specs for one dispatch (see [`rank_specs`]).
    pub fn rank(
        &self,
        profile: &KernelProfile,
        obs: &mut [SpecObservation],
        global_size: usize,
    ) -> Result<(Vec<usize>, RouteReason, usize)> {
        rank_specs(&self.policy, profile, obs, global_size)
    }

    /// Record a served dispatch: bump the chosen spec's counters and
    /// (bounded) append the decision record.
    pub fn commit(&mut self, record: RouteRecord, factor: usize) {
        let s = self
            .per_spec
            .entry(record.chosen)
            .or_insert_with(|| SpecRouteStats::new(record.chosen_spec.clone(), record.chosen));
        s.routed += 1;
        match record.reason {
            RouteReason::BestFit => s.best_fit += 1,
            RouteReason::Widest => s.widest += 1,
            RouteReason::OnlyFit => s.only_fit += 1,
        }
        if record.fallback {
            s.fallbacks += 1;
        }
        *s.histogram.entry(factor).or_insert(0) += 1;
        self.records.push(record);
    }

    /// The retained decision records (oldest first). Aggregates keep
    /// counting after the buffer fills; `dropped_records` says how
    /// many decisions are missing here.
    pub fn records(&self) -> &[RouteRecord] {
        self.records.items()
    }

    pub fn dropped_records(&self) -> u64 {
        self.records.dropped()
    }

    pub fn spec_stats(&self, fingerprint: u64) -> Option<&SpecRouteStats> {
        self.per_spec.get(&fingerprint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(fits: Vec<Option<PlanSummary>>) -> KernelProfile {
        KernelProfile {
            name: "k".into(),
            source_hash: 1,
            ops_per_copy: 7,
            fits,
        }
    }

    fn ps(factor: usize, gops: f64) -> PlanSummary {
        PlanSummary {
            factor,
            limit: LimitReason::Fu,
            fus_per_copy: 3,
            io_per_copy: 2,
            gops,
        }
    }

    /// An 8×8-class and a 4×4-class observation, both idle and cold.
    fn two_specs() -> Vec<SpecObservation> {
        vec![
            SpecObservation {
                fingerprint: 100,
                spec: "8x8".into(),
                fits: true,
                adequate: false,
                factor: 16,
                limit: Some(LimitReason::Io),
                gops: 33.6,
                peak_gops: 115.2,
                min_queue_depth: 0,
                resident: false,
                config_seconds: 42e-6,
            },
            SpecObservation {
                fingerprint: 200,
                spec: "4x4".into(),
                fits: true,
                adequate: false,
                factor: 5,
                limit: Some(LimitReason::Fu),
                gops: 10.5,
                peak_gops: 28.8,
                min_queue_depth: 0,
                resident: false,
                config_seconds: 12e-6,
            },
        ]
    }

    fn router() -> Router {
        Router::new(RoutingPolicy::default())
    }

    #[test]
    fn small_dispatch_best_fits_the_small_spec() {
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        // 256 items want 1 copy: both adequate, small peak wins
        let (ranked, reason, wanted) = router().rank(&p, &mut obs, 256).unwrap();
        assert_eq!(wanted, 1);
        assert_eq!(reason, RouteReason::BestFit);
        assert_eq!(ranked[0], 1, "small spec first");
        assert!(obs[0].adequate && obs[1].adequate);
    }

    #[test]
    fn wide_dispatch_goes_to_the_widest_spec() {
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        // 32768 items want 32 copies: nobody adequate → highest gops
        let (ranked, reason, wanted) = router().rank(&p, &mut obs, 32768).unwrap();
        assert_eq!(wanted, 32);
        assert_eq!(reason, RouteReason::Widest);
        assert_eq!(ranked[0], 0, "widest spec first");
    }

    #[test]
    fn medium_dispatch_picks_the_smallest_adequate_spec() {
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        // 8192 items want 8 copies: only the 8×8 is adequate
        let (ranked, reason, _) = router().rank(&p, &mut obs, 8192).unwrap();
        assert_eq!(reason, RouteReason::BestFit);
        assert_eq!(ranked[0], 0);
        assert!(!obs[1].adequate);
    }

    #[test]
    fn busy_small_spec_spills_to_an_idle_bigger_one() {
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        obs[1].min_queue_depth = 3; // every small partition busy
        let (ranked, reason, _) = router().rank(&p, &mut obs, 64).unwrap();
        assert_eq!(reason, RouteReason::BestFit);
        assert_eq!(ranked[0], 0, "spill to the idle big spec");
    }

    #[test]
    fn small_spec_wins_even_when_the_big_one_is_resident() {
        // residency is a tie-breaker *below* overlay size: a small
        // kernel must not park on the 8×8 just because its bitstream
        // is still loaded there
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        obs[0].resident = true;
        let (ranked, _, _) = router().rank(&p, &mut obs, 64).unwrap();
        assert_eq!(ranked[0], 1);
    }

    #[test]
    fn unfit_spec_is_only_fit_for_the_other() {
        let p = profile(vec![Some(ps(3, 6.3)), None]);
        let mut obs = two_specs();
        obs[1].fits = false;
        let (ranked, reason, _) = router().rank(&p, &mut obs, 64).unwrap();
        assert_eq!(reason, RouteReason::OnlyFit);
        assert_eq!(ranked, vec![0]);
    }

    #[test]
    fn poison_mask_withholds_fitting_specs_without_editing_the_profile() {
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        let withheld = apply_poison_mask(&mut obs, &[false, true]);
        assert_eq!(withheld, 1);
        assert!(obs[0].fits && !obs[1].fits);
        // ranking proceeds on the surviving spec
        let (ranked, reason, _) = router().rank(&p, &mut obs, 64).unwrap();
        assert_eq!(reason, RouteReason::OnlyFit);
        assert_eq!(ranked, vec![0]);
        // masking an already-unfit spec counts nothing
        let mut obs2 = two_specs();
        obs2[1].fits = false;
        assert_eq!(apply_poison_mask(&mut obs2, &[false, true]), 0);
    }

    #[test]
    fn no_fitting_spec_errors() {
        let p = profile(vec![None, None]);
        let mut obs = two_specs();
        obs[0].fits = false;
        obs[1].fits = false;
        assert!(router().rank(&p, &mut obs, 64).is_err());
    }

    #[test]
    fn commit_accumulates_stats_and_histogram() {
        let mut r = router();
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        let mut obs = two_specs();
        let (ranked, reason, wanted) = r.rank(&p, &mut obs, 256).unwrap();
        let chosen = obs[ranked[0]].fingerprint;
        r.commit(
            RouteRecord {
                kernel: "k".into(),
                tenant: "default".into(),
                source_hash: 1,
                global_size: 256,
                copies_wanted: wanted,
                chosen,
                chosen_spec: obs[ranked[0]].spec.clone(),
                reason,
                fallback: false,
                priority: Priority::Interactive,
                specs: obs.clone(),
            },
            5,
        );
        let s = r.spec_stats(chosen).unwrap();
        assert_eq!(s.routed, 1);
        assert_eq!(s.best_fit, 1);
        assert_eq!(s.histogram.get(&5), Some(&1));
        assert_eq!(r.records().len(), 1);
        assert_eq!(r.dropped_records(), 0);
    }

    #[test]
    fn record_buffer_is_bounded() {
        let mut r = Router::new(RoutingPolicy { max_records: 2, ..Default::default() });
        let p = profile(vec![Some(ps(16, 33.6)), Some(ps(5, 10.5))]);
        for i in 0..5u64 {
            let mut obs = two_specs();
            let (ranked, reason, wanted) = r.rank(&p, &mut obs, 64).unwrap();
            r.commit(
                RouteRecord {
                    kernel: format!("k{i}"),
                    tenant: format!("tenant-{i}"),
                    source_hash: i,
                    global_size: 64,
                    copies_wanted: wanted,
                    chosen: obs[ranked[0]].fingerprint,
                    chosen_spec: obs[ranked[0]].spec.clone(),
                    reason,
                    fallback: false,
                    priority: Priority::Batch,
                    specs: obs.clone(),
                },
                5,
            );
        }
        assert_eq!(r.records().len(), 2);
        assert_eq!(r.dropped_records(), 3);
    }
}
