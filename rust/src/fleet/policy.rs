//! Routing policy: the knobs that decide where a dispatch lands.
//!
//! The router's job is matching a kernel's *parallelism demand* to an
//! overlay's *parallelism supply*. Supply is the resource-aware
//! replication factor (§III-C): how many copies of the kernel the
//! spec's FU count, perimeter I/O pads and backend limits admit.
//! Demand is derived from the dispatch size: a request for
//! `global_size` work-items "wants" roughly `global_size /
//! target_chunk` kernel copies — fewer copies than that and the
//! per-copy stream grows past the target; more and the extra copies
//! idle on short streams. A spec whose factor meets the demand is
//! *adequate*; among adequate specs the router prefers the least
//! loaded, then the **smallest** (lowest peak GOPS) — small kernels
//! must not squat on the big overlays the wide data-parallel kernels
//! need.

/// Scheduling class of a dispatch (the QoS lane it queues in).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive: drains before any batch work on the same
    /// partition.
    Interactive,
    /// Throughput work: drains when the interactive lane is empty, and
    /// partitions holding only batch-class kernels are preferred
    /// reconfiguration victims.
    Batch,
}

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// Tunable routing parameters.
#[derive(Debug, Clone)]
pub struct RoutingPolicy {
    /// Work-items one kernel copy comfortably streams per dispatch.
    /// A dispatch of `global_size` items wants
    /// `ceil(global_size / target_chunk)` copies; specs whose
    /// replication factor meets that demand are *adequate* and the
    /// smallest adequate spec wins. Larger values bias toward small
    /// overlays, smaller values toward wide replication.
    pub target_chunk: usize,
    /// Routing decisions retained verbatim for inspection
    /// ([`crate::coordinator::Coordinator::routing_log`]); aggregate
    /// counters keep counting after the buffer fills.
    pub max_records: usize,
}

impl Default for RoutingPolicy {
    fn default() -> Self {
        RoutingPolicy { target_chunk: 1024, max_records: 4096 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let p = RoutingPolicy::default();
        assert!(p.target_chunk >= 1);
        assert!(p.max_records >= 1);
        assert_eq!(Priority::Interactive.name(), "interactive");
        assert_eq!(Priority::Batch.name(), "batch");
    }
}
