//! Heterogeneous overlay fleet: per-spec compilation shards plus a
//! resource-aware router.
//!
//! The paper's resource-aware replication (§III-C) sizes a kernel to
//! *one* overlay; this module scales the idea to a **fleet of
//! different overlays**. A [`Fleet`] owns one [`CompileShard`] per
//! distinct [`OverlaySpec`] — its own [`crate::compiler::JitCompiler`]
//! (routing-resource graph included) and
//! [`crate::coordinator::KernelCache`], keyed by
//! [`OverlaySpec::fingerprint`] — and a per-kernel [`KernelProfile`]
//! cache holding the replication plan the kernel gets on every spec
//! (factor, [`crate::replicate::LimitReason`], FU/IO demand, modeled
//! GOPS), computed once by the compile-free front-half analysis
//! ([`crate::compiler::JitCompiler::plan_kernel`]).
//!
//! The [`Router`] turns those profiles plus live queue/residency
//! observations into placements: small kernels onto the smallest
//! adequate overlay, wide data-parallel kernels onto the spec where
//! `copies × throughput` peaks, queue depth and modeled
//! reconfiguration cost as tie-breakers. The
//! [`crate::coordinator::Coordinator`] drives the whole thing; this
//! module deliberately knows nothing about worker threads or dispatch
//! queues, which keeps every routing decision unit-testable.

mod policy;
mod router;
mod shard;

pub use policy::{Priority, RoutingPolicy};
pub use router::{
    apply_poison_mask, rank_specs, KernelProfile, PlanSummary, RouteReason,
    RouteRecord, Router, SpecObservation, SpecRouteStats,
};
pub use shard::CompileShard;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context as _, Result};

use crate::compiler::{stable_source_hash, CompileOptions};
use crate::metrics::achieved_gops;
use crate::overlay::OverlaySpec;

/// Kernel profiles retained at once. Profiles are µs-class to
/// recompute, so past this bound new kernels are simply analyzed per
/// submit instead of cached — the serving layer's memory stays flat
/// however many distinct sources a long-running fleet sees.
const MAX_PROFILES: usize = 4096;

/// Poison TTL, in poison-clock ticks (one tick per profiled submit),
/// after the first compile failure of a `(kernel, spec)` pair.
pub const POISON_BASE_TTL: u64 = 8;

/// Ceiling on the exponentially backed-off poison TTL.
pub const POISON_MAX_TTL: u64 = 1024;

/// One poisoned `(kernel, shard)` pair: a compile failure quarantines
/// the pair for a TTL that doubles with each repeated failure, instead
/// of forever — a transient failure is not a life sentence.
#[derive(Debug, Clone, Copy)]
struct PoisonEntry {
    /// Compile failures observed for this pair.
    strikes: u32,
    /// Poison-clock tick at which the pair becomes probe-eligible.
    until: u64,
    /// Whether the expired entry has already been offered for re-probe
    /// (counted once per expiry).
    probing: bool,
}

/// Counters for the poison/decay/re-probe lifecycle.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoisonStats {
    /// Pairs currently inside their poison TTL.
    pub active: u64,
    /// Expired entries offered back to the router for a re-probe.
    pub probes: u64,
    /// Entries cleared by a successful re-probe compile.
    pub recoveries: u64,
}

/// A heterogeneous set of per-spec compilation shards.
pub struct Fleet {
    shards: Vec<CompileShard>,
    /// Kernel source hash → per-spec plans (aligned with `shards`),
    /// bounded by [`MAX_PROFILES`].
    profiles: Mutex<HashMap<u64, KernelProfile>>,
    /// `(source hash, shard index)` pairs whose JIT compile failed,
    /// with decaying TTLs.
    poisoned: Mutex<HashMap<(u64, usize), PoisonEntry>>,
    /// Advances once per [`Fleet::profile`] call — the decay clock.
    poison_clock: std::sync::atomic::AtomicU64,
    poison_probes: std::sync::atomic::AtomicU64,
    poison_recoveries: std::sync::atomic::AtomicU64,
}

impl std::fmt::Debug for Fleet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let specs: Vec<String> = self.shards.iter().map(|s| s.spec().name()).collect();
        f.debug_struct("Fleet").field("specs", &specs).finish()
    }
}

impl Fleet {
    /// Build one shard per group. Groups must carry distinct spec
    /// fingerprints (the coordinator merges duplicates before calling
    /// this) and at least one partition each.
    pub fn new(
        groups: Vec<(OverlaySpec, Vec<usize>)>,
        options: &CompileOptions,
        cache_capacity: usize,
    ) -> Result<Fleet> {
        if groups.is_empty() {
            bail!("fleet needs at least one overlay spec");
        }
        let mut shards: Vec<CompileShard> = Vec::with_capacity(groups.len());
        for (spec, partitions) in groups {
            if partitions.is_empty() {
                bail!("spec {} has no partitions", spec.name());
            }
            if shards
                .iter()
                .any(|s| s.fingerprint() == spec.fingerprint())
            {
                bail!("duplicate spec {} in fleet groups", spec.name());
            }
            shards.push(CompileShard::new(
                spec,
                options.clone(),
                cache_capacity,
                partitions,
            ));
        }
        Ok(Fleet {
            shards,
            profiles: Mutex::new(HashMap::new()),
            poisoned: Mutex::new(HashMap::new()),
            poison_clock: std::sync::atomic::AtomicU64::new(0),
            poison_probes: std::sync::atomic::AtomicU64::new(0),
            poison_recoveries: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn shards(&self) -> &[CompileShard] {
        &self.shards
    }

    /// Shard index for a spec fingerprint.
    pub fn shard_index(&self, fingerprint: u64) -> Option<usize> {
        self.shards.iter().position(|s| s.fingerprint() == fingerprint)
    }

    /// The kernel's per-spec replication profile, computed on first
    /// sight (µs-class — no placement or routing) and cached under
    /// the stable source hash. Errors only when the kernel fits no
    /// spec in the fleet.
    pub fn profile(&self, source: &str) -> Result<KernelProfile> {
        use std::sync::atomic::Ordering;
        self.poison_clock.fetch_add(1, Ordering::Relaxed);
        let hash = stable_source_hash(source);
        if let Some(p) = self.profiles.lock().unwrap().get(&hash) {
            return Ok(p.clone());
        }
        let mut fits: Vec<Option<PlanSummary>> = Vec::with_capacity(self.shards.len());
        let mut name = None;
        let mut ops_per_copy = 0;
        let mut first_err = None;
        for shard in &self.shards {
            match shard.jit.plan_kernel(source) {
                Ok(kp) => {
                    let gops =
                        achieved_gops(kp.plan.factor, kp.ops_per_copy, shard.spec().fmax_mhz());
                    if name.is_none() {
                        name = Some(kp.name.clone());
                        ops_per_copy = kp.ops_per_copy;
                    }
                    fits.push(Some(PlanSummary {
                        factor: kp.plan.factor,
                        limit: kp.plan.limit,
                        fus_per_copy: kp.plan.fus_per_copy,
                        io_per_copy: kp.plan.io_per_copy,
                        gops,
                    }));
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    fits.push(None);
                }
            }
        }
        let Some(name) = name else {
            return Err(first_err
                .expect("at least one shard was analyzed")
                .context("kernel fits no overlay spec in the fleet"));
        };
        let p = KernelProfile { name, source_hash: hash, ops_per_copy, fits };
        let mut map = self.profiles.lock().unwrap();
        if map.len() < MAX_PROFILES || map.contains_key(&hash) {
            map.insert(hash, p.clone());
        }
        Ok(p)
    }

    /// Poison a `(kernel, shard)` pair after a compile failure so the
    /// router stops offering that spec for this kernel — but only for
    /// a decaying TTL, not forever. The first failure quarantines the
    /// pair for [`POISON_BASE_TTL`] poison-clock ticks; each repeated
    /// failure doubles the TTL (capped at [`POISON_MAX_TTL`]). When the
    /// TTL expires the pair is offered back to the router exactly once
    /// per expiry (a *re-probe*); a successful compile then clears the
    /// entry via [`Fleet::clear_poison`], a failed one re-poisons it
    /// with a longer TTL. Transient environment failures (and the
    /// injected ones from [`crate::admission::FaultPlan`]) therefore
    /// heal instead of permanently shrinking the kernel's fleet.
    pub fn poison(&self, source_hash: u64, shard_index: usize) {
        use std::sync::atomic::Ordering;
        let clock = self.poison_clock.load(Ordering::Relaxed);
        let mut map = self.poisoned.lock().unwrap();
        let e = map
            .entry((source_hash, shard_index))
            .or_insert(PoisonEntry { strikes: 0, until: 0, probing: false });
        e.strikes += 1;
        let ttl = POISON_BASE_TTL
            .saturating_mul(1u64 << (e.strikes - 1).min(62))
            .min(POISON_MAX_TTL);
        e.until = clock + ttl;
        e.probing = false;
    }

    /// Clear a pair's poison after a successful compile; counts a
    /// recovery when the pair was actually poisoned and tells the
    /// caller (true) so fault tallies can credit the re-probe.
    pub fn clear_poison(&self, source_hash: u64, shard_index: usize) -> bool {
        use std::sync::atomic::Ordering;
        if self.poisoned.lock().unwrap().remove(&(source_hash, shard_index)).is_some() {
            self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Per-shard poison mask for a kernel: `true` means "do not offer
    /// this spec right now". Expired entries return `false` (the
    /// re-probe) and are counted once per expiry.
    pub fn poison_mask(&self, source_hash: u64) -> Vec<bool> {
        use std::sync::atomic::Ordering;
        let clock = self.poison_clock.load(Ordering::Relaxed);
        let mut mask = vec![false; self.shards.len()];
        let mut map = self.poisoned.lock().unwrap();
        for (i, m) in mask.iter_mut().enumerate() {
            if let Some(e) = map.get_mut(&(source_hash, i)) {
                if clock < e.until {
                    *m = true;
                } else if !e.probing {
                    e.probing = true;
                    self.poison_probes.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        mask
    }

    /// Snapshot the poison lifecycle counters.
    pub fn poison_stats(&self) -> PoisonStats {
        use std::sync::atomic::Ordering;
        let clock = self.poison_clock.load(Ordering::Relaxed);
        let active = self
            .poisoned
            .lock()
            .unwrap()
            .values()
            .filter(|e| clock < e.until)
            .count() as u64;
        PoisonStats {
            active,
            probes: self.poison_probes.load(Ordering::Relaxed),
            recoveries: self.poison_recoveries.load(Ordering::Relaxed),
        }
    }

    fn snapshot_path(&self, dir: &Path, shard: &CompileShard) -> PathBuf {
        dir.join(format!("shard-{:016x}.json", shard.fingerprint()))
    }

    /// Persist every shard's kernel cache under `dir` (one JSON file
    /// per spec fingerprint). Returns total entries written (counted
    /// by the serializer itself, so the number matches the files even
    /// under concurrent inserts).
    pub fn save_snapshot(&self, dir: &Path) -> Result<usize> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        let mut total = 0;
        for shard in &self.shards {
            total += shard.save_snapshot(&self.snapshot_path(dir, shard))?;
        }
        Ok(total)
    }

    /// Warm-start every shard whose snapshot file exists under `dir`.
    /// Missing files are fine (new spec in an existing deployment),
    /// and truncated or corrupt files are logged and cost only a cold
    /// start for that shard — a damaged snapshot must never abort a
    /// coordinator restart. Returns total entries loaded.
    pub fn load_snapshot(&self, dir: &Path) -> usize {
        let mut total = 0;
        for shard in &self.shards {
            let path = self.snapshot_path(dir, shard);
            if path.exists() {
                total += shard.load_snapshot(&path);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_kernels::{CHEBYSHEV, QSPLINE};
    use crate::overlay::FuType;
    use crate::replicate::LimitReason;

    fn mixed_fleet() -> Fleet {
        Fleet::new(
            vec![
                (OverlaySpec::zynq_default(), vec![0, 1]),
                (OverlaySpec::new(4, 4, FuType::Dsp2), vec![2, 3]),
            ],
            &CompileOptions::default(),
            16,
        )
        .unwrap()
    }

    #[test]
    fn profiles_report_per_spec_replication() {
        let fleet = mixed_fleet();
        let p = fleet.profile(CHEBYSHEV).unwrap();
        assert_eq!(p.name, "chebyshev");
        assert_eq!(p.fits.len(), 2);
        let big = p.fits[0].unwrap();
        let small = p.fits[1].unwrap();
        // §IV: 16 copies I/O-limited on 8×8; 16 FUs / 3 per copy = 5
        // FU-limited on 4×4
        assert_eq!(big.factor, 16);
        assert_eq!(big.limit, LimitReason::Io);
        assert_eq!(small.factor, 5);
        assert_eq!(small.limit, LimitReason::Fu);
        assert!(big.gops > small.gops);
        // cached: second call returns the same profile
        let q = fleet.profile(CHEBYSHEV).unwrap();
        assert_eq!(q.source_hash, p.source_hash);
    }

    #[test]
    fn kernels_may_fit_only_a_subset_of_specs() {
        let fleet = Fleet::new(
            vec![
                (OverlaySpec::zynq_default(), vec![0]),
                (OverlaySpec::new(2, 2, FuType::Dsp2), vec![1]),
            ],
            &CompileOptions::default(),
            16,
        )
        .unwrap();
        // qspline is the largest benchmark: it cannot fit a 2×2
        let p = fleet.profile(QSPLINE).unwrap();
        assert!(p.fits[0].is_some());
        assert!(p.fits[1].is_none());
    }

    #[test]
    fn poison_masks_a_spec_without_destroying_the_profile() {
        let fleet = mixed_fleet();
        let p = fleet.profile(CHEBYSHEV).unwrap();
        fleet.poison(p.source_hash, 1);
        // the mask hides the poisoned shard; the profile keeps its plan
        let mask = fleet.poison_mask(p.source_hash);
        assert_eq!(mask, vec![false, true]);
        let q = fleet.profile(CHEBYSHEV).unwrap();
        assert!(q.fits[1].is_some(), "the plan survives for the re-probe");
        assert_eq!(fleet.poison_stats().active, 1);
    }

    #[test]
    fn poison_decays_into_a_reprobe_and_clears_on_success() {
        let fleet = mixed_fleet();
        let p = fleet.profile(CHEBYSHEV).unwrap();
        fleet.poison(p.source_hash, 0);
        assert_eq!(fleet.poison_mask(p.source_hash), vec![true, false]);
        // each profile() call ticks the decay clock
        for _ in 0..POISON_BASE_TTL {
            let _ = fleet.profile(CHEBYSHEV).unwrap();
        }
        // TTL expired: the shard is offered again, counted as a probe
        assert_eq!(fleet.poison_mask(p.source_hash), vec![false, false]);
        let stats = fleet.poison_stats();
        assert_eq!(stats.active, 0);
        assert_eq!(stats.probes, 1);
        // the probe is counted once per expiry, not per mask query
        let _ = fleet.poison_mask(p.source_hash);
        assert_eq!(fleet.poison_stats().probes, 1);
        // a successful re-probe compile clears the entry
        assert!(fleet.clear_poison(p.source_hash, 0));
        assert_eq!(fleet.poison_stats().recoveries, 1);
        // clearing an unpoisoned pair is not a recovery
        assert!(!fleet.clear_poison(p.source_hash, 0));
        assert_eq!(fleet.poison_stats().recoveries, 1);
    }

    #[test]
    fn repeated_poison_backs_off_exponentially() {
        let fleet = mixed_fleet();
        let p = fleet.profile(CHEBYSHEV).unwrap();
        fleet.poison(p.source_hash, 0);
        for _ in 0..POISON_BASE_TTL {
            let _ = fleet.profile(CHEBYSHEV).unwrap();
        }
        assert_eq!(fleet.poison_mask(p.source_hash), vec![false, false]);
        // the re-probe fails: TTL doubles, so the base TTL no longer
        // clears it
        fleet.poison(p.source_hash, 0);
        for _ in 0..POISON_BASE_TTL {
            let _ = fleet.profile(CHEBYSHEV).unwrap();
        }
        assert_eq!(fleet.poison_mask(p.source_hash), vec![true, false]);
        for _ in 0..POISON_BASE_TTL {
            let _ = fleet.profile(CHEBYSHEV).unwrap();
        }
        assert_eq!(fleet.poison_mask(p.source_hash), vec![false, false]);
    }

    #[test]
    fn duplicate_or_empty_groups_are_rejected() {
        let dup = Fleet::new(
            vec![
                (OverlaySpec::zynq_default(), vec![0]),
                (OverlaySpec::zynq_default(), vec![1]),
            ],
            &CompileOptions::default(),
            4,
        );
        assert!(dup.is_err());
        assert!(Fleet::new(vec![], &CompileOptions::default(), 4).is_err());
        let no_parts = Fleet::new(
            vec![(OverlaySpec::zynq_default(), vec![])],
            &CompileOptions::default(),
            4,
        );
        assert!(no_parts.is_err());
    }

    #[test]
    fn snapshot_round_trips_across_fleets() {
        let dir = std::env::temp_dir().join(format!(
            "overlay-jit-fleet-snapshot-{}",
            std::process::id()
        ));
        let fleet = mixed_fleet();
        // populate both shards with chebyshev
        fleet.shards()[0].get_or_compile(CHEBYSHEV).unwrap();
        fleet.shards()[1].get_or_compile(CHEBYSHEV).unwrap();
        let written = fleet.save_snapshot(&dir).unwrap();
        assert_eq!(written, 2);

        let warm = mixed_fleet();
        let loaded = warm.load_snapshot(&dir);
        assert_eq!(loaded, 2);
        // both shards now serve from cache without compiling
        let (_, hit_big, _) = warm.shards()[0].get_or_compile(CHEBYSHEV).unwrap();
        let (_, hit_small, _) = warm.shards()[1].get_or_compile(CHEBYSHEV).unwrap();
        assert!(hit_big && hit_small);
        assert_eq!(warm.shards()[0].compile_seconds(), 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
