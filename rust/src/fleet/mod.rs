//! Heterogeneous overlay fleet: per-spec compilation shards plus a
//! resource-aware router.
//!
//! The paper's resource-aware replication (§III-C) sizes a kernel to
//! *one* overlay; this module scales the idea to a **fleet of
//! different overlays**. A [`Fleet`] owns one [`CompileShard`] per
//! distinct [`OverlaySpec`] — its own [`crate::compiler::JitCompiler`]
//! (routing-resource graph included) and
//! [`crate::coordinator::KernelCache`], keyed by
//! [`OverlaySpec::fingerprint`] — and a per-kernel [`KernelProfile`]
//! cache holding the replication plan the kernel gets on every spec
//! (factor, [`crate::replicate::LimitReason`], FU/IO demand, modeled
//! GOPS), computed once by the compile-free front-half analysis
//! ([`crate::compiler::JitCompiler::plan_kernel`]).
//!
//! The [`Router`] turns those profiles plus live queue/residency
//! observations into placements: small kernels onto the smallest
//! adequate overlay, wide data-parallel kernels onto the spec where
//! `copies × throughput` peaks, queue depth and modeled
//! reconfiguration cost as tie-breakers. The
//! [`crate::coordinator::Coordinator`] drives the whole thing; this
//! module deliberately knows nothing about worker threads or dispatch
//! queues, which keeps every routing decision unit-testable.

mod policy;
mod router;
mod shard;

pub use policy::{Priority, RoutingPolicy};
pub use router::{
    rank_specs, KernelProfile, PlanSummary, RouteReason, RouteRecord, Router,
    SpecObservation, SpecRouteStats,
};
pub use shard::CompileShard;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context as _, Result};

use crate::compiler::{stable_source_hash, CompileOptions};
use crate::metrics::achieved_gops;
use crate::overlay::OverlaySpec;

/// Kernel profiles retained at once. Profiles are µs-class to
/// recompute, so past this bound new kernels are simply analyzed per
/// submit instead of cached — the serving layer's memory stays flat
/// however many distinct sources a long-running fleet sees.
const MAX_PROFILES: usize = 4096;

/// A heterogeneous set of per-spec compilation shards.
pub struct Fleet {
    shards: Vec<CompileShard>,
    /// Kernel source hash → per-spec plans (aligned with `shards`),
    /// bounded by [`MAX_PROFILES`].
    profiles: Mutex<HashMap<u64, KernelProfile>>,
}

impl std::fmt::Debug for Fleet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let specs: Vec<String> = self.shards.iter().map(|s| s.spec().name()).collect();
        f.debug_struct("Fleet").field("specs", &specs).finish()
    }
}

impl Fleet {
    /// Build one shard per group. Groups must carry distinct spec
    /// fingerprints (the coordinator merges duplicates before calling
    /// this) and at least one partition each.
    pub fn new(
        groups: Vec<(OverlaySpec, Vec<usize>)>,
        options: &CompileOptions,
        cache_capacity: usize,
    ) -> Result<Fleet> {
        if groups.is_empty() {
            bail!("fleet needs at least one overlay spec");
        }
        let mut shards: Vec<CompileShard> = Vec::with_capacity(groups.len());
        for (spec, partitions) in groups {
            if partitions.is_empty() {
                bail!("spec {} has no partitions", spec.name());
            }
            if shards
                .iter()
                .any(|s| s.fingerprint() == spec.fingerprint())
            {
                bail!("duplicate spec {} in fleet groups", spec.name());
            }
            shards.push(CompileShard::new(
                spec,
                options.clone(),
                cache_capacity,
                partitions,
            ));
        }
        Ok(Fleet { shards, profiles: Mutex::new(HashMap::new()) })
    }

    pub fn shards(&self) -> &[CompileShard] {
        &self.shards
    }

    /// Shard index for a spec fingerprint.
    pub fn shard_index(&self, fingerprint: u64) -> Option<usize> {
        self.shards.iter().position(|s| s.fingerprint() == fingerprint)
    }

    /// The kernel's per-spec replication profile, computed on first
    /// sight (µs-class — no placement or routing) and cached under
    /// the stable source hash. Errors only when the kernel fits no
    /// spec in the fleet.
    pub fn profile(&self, source: &str) -> Result<KernelProfile> {
        let hash = stable_source_hash(source);
        if let Some(p) = self.profiles.lock().unwrap().get(&hash) {
            return Ok(p.clone());
        }
        let mut fits: Vec<Option<PlanSummary>> = Vec::with_capacity(self.shards.len());
        let mut name = None;
        let mut ops_per_copy = 0;
        let mut first_err = None;
        for shard in &self.shards {
            match shard.jit.plan_kernel(source) {
                Ok(kp) => {
                    let gops =
                        achieved_gops(kp.plan.factor, kp.ops_per_copy, shard.spec().fmax_mhz());
                    if name.is_none() {
                        name = Some(kp.name.clone());
                        ops_per_copy = kp.ops_per_copy;
                    }
                    fits.push(Some(PlanSummary {
                        factor: kp.plan.factor,
                        limit: kp.plan.limit,
                        fus_per_copy: kp.plan.fus_per_copy,
                        io_per_copy: kp.plan.io_per_copy,
                        gops,
                    }));
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    fits.push(None);
                }
            }
        }
        let Some(name) = name else {
            return Err(first_err
                .expect("at least one shard was analyzed")
                .context("kernel fits no overlay spec in the fleet"));
        };
        let p = KernelProfile { name, source_hash: hash, ops_per_copy, fits };
        let mut map = self.profiles.lock().unwrap();
        if map.len() < MAX_PROFILES || map.contains_key(&hash) {
            map.insert(hash, p.clone());
        }
        Ok(p)
    }

    /// Mark a (kernel, shard) pair unfit after a compile failure so
    /// the router stops offering that spec for this kernel. The
    /// compiler is a pure function of (source, spec, options), so one
    /// failure predicts all retries; a no-op when the profile was not
    /// retained (the bounded cache was full), in which case the
    /// router's compile-fallback ranking still serves the kernel.
    pub fn mark_unfit(&self, source_hash: u64, shard_index: usize) {
        if let Some(p) = self.profiles.lock().unwrap().get_mut(&source_hash) {
            if shard_index < p.fits.len() {
                p.fits[shard_index] = None;
            }
        }
    }

    fn snapshot_path(&self, dir: &Path, shard: &CompileShard) -> PathBuf {
        dir.join(format!("shard-{:016x}.json", shard.fingerprint()))
    }

    /// Persist every shard's kernel cache under `dir` (one JSON file
    /// per spec fingerprint). Returns total entries written (counted
    /// by the serializer itself, so the number matches the files even
    /// under concurrent inserts).
    pub fn save_snapshot(&self, dir: &Path) -> Result<usize> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        let mut total = 0;
        for shard in &self.shards {
            total += shard.save_snapshot(&self.snapshot_path(dir, shard))?;
        }
        Ok(total)
    }

    /// Warm-start every shard whose snapshot file exists under `dir`.
    /// Missing files are fine (new spec in an existing deployment);
    /// malformed files are errors. Returns total entries loaded.
    pub fn load_snapshot(&self, dir: &Path) -> Result<usize> {
        let mut total = 0;
        for shard in &self.shards {
            let path = self.snapshot_path(dir, shard);
            if path.exists() {
                total += shard.load_snapshot(&path)?;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_kernels::{CHEBYSHEV, QSPLINE};
    use crate::overlay::FuType;
    use crate::replicate::LimitReason;

    fn mixed_fleet() -> Fleet {
        Fleet::new(
            vec![
                (OverlaySpec::zynq_default(), vec![0, 1]),
                (OverlaySpec::new(4, 4, FuType::Dsp2), vec![2, 3]),
            ],
            &CompileOptions::default(),
            16,
        )
        .unwrap()
    }

    #[test]
    fn profiles_report_per_spec_replication() {
        let fleet = mixed_fleet();
        let p = fleet.profile(CHEBYSHEV).unwrap();
        assert_eq!(p.name, "chebyshev");
        assert_eq!(p.fits.len(), 2);
        let big = p.fits[0].unwrap();
        let small = p.fits[1].unwrap();
        // §IV: 16 copies I/O-limited on 8×8; 16 FUs / 3 per copy = 5
        // FU-limited on 4×4
        assert_eq!(big.factor, 16);
        assert_eq!(big.limit, LimitReason::Io);
        assert_eq!(small.factor, 5);
        assert_eq!(small.limit, LimitReason::Fu);
        assert!(big.gops > small.gops);
        // cached: second call returns the same profile
        let q = fleet.profile(CHEBYSHEV).unwrap();
        assert_eq!(q.source_hash, p.source_hash);
    }

    #[test]
    fn kernels_may_fit_only_a_subset_of_specs() {
        let fleet = Fleet::new(
            vec![
                (OverlaySpec::zynq_default(), vec![0]),
                (OverlaySpec::new(2, 2, FuType::Dsp2), vec![1]),
            ],
            &CompileOptions::default(),
            16,
        )
        .unwrap();
        // qspline is the largest benchmark: it cannot fit a 2×2
        let p = fleet.profile(QSPLINE).unwrap();
        assert!(p.fits[0].is_some());
        assert!(p.fits[1].is_none());
    }

    #[test]
    fn mark_unfit_removes_a_spec_from_the_profile() {
        let fleet = mixed_fleet();
        let p = fleet.profile(CHEBYSHEV).unwrap();
        fleet.mark_unfit(p.source_hash, 1);
        let q = fleet.profile(CHEBYSHEV).unwrap();
        assert!(q.fits[0].is_some());
        assert!(q.fits[1].is_none());
    }

    #[test]
    fn duplicate_or_empty_groups_are_rejected() {
        let dup = Fleet::new(
            vec![
                (OverlaySpec::zynq_default(), vec![0]),
                (OverlaySpec::zynq_default(), vec![1]),
            ],
            &CompileOptions::default(),
            4,
        );
        assert!(dup.is_err());
        assert!(Fleet::new(vec![], &CompileOptions::default(), 4).is_err());
        let no_parts = Fleet::new(
            vec![(OverlaySpec::zynq_default(), vec![])],
            &CompileOptions::default(),
            4,
        );
        assert!(no_parts.is_err());
    }

    #[test]
    fn snapshot_round_trips_across_fleets() {
        let dir = std::env::temp_dir().join(format!(
            "overlay-jit-fleet-snapshot-{}",
            std::process::id()
        ));
        let fleet = mixed_fleet();
        // populate both shards with chebyshev
        fleet.shards()[0].get_or_compile(CHEBYSHEV).unwrap();
        fleet.shards()[1].get_or_compile(CHEBYSHEV).unwrap();
        let written = fleet.save_snapshot(&dir).unwrap();
        assert_eq!(written, 2);

        let warm = mixed_fleet();
        let loaded = warm.load_snapshot(&dir).unwrap();
        assert_eq!(loaded, 2);
        // both shards now serve from cache without compiling
        let (_, hit_big, _) = warm.shards()[0].get_or_compile(CHEBYSHEV).unwrap();
        let (_, hit_small, _) = warm.shards()[1].get_or_compile(CHEBYSHEV).unwrap();
        assert!(hit_big && hit_small);
        assert_eq!(warm.shards()[0].compile_seconds(), 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
