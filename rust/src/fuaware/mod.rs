//! DFG → FU-aware DFG transform (paper §III-B, Fig. 3).
//!
//! Two stages, both driven by the DSP-block capabilities of the target
//! overlay's functional units:
//!
//! 1. **Fusion** ([`fuse_muladd`]): a multiply whose single consumer is
//!    an add/sub collapses into one `mul_add` / `mul_sub` node — the
//!    DSP48's ALU cascade evaluates `a*b ± c` in a single block. This
//!    turns the 7-node Fig. 3(a) into the 5-node Fig. 3(b).
//! 2. **Clustering** ([`cluster`]): with two DSP blocks per FU, a
//!    producer feeding its sole consumer can share the consumer's FU
//!    (Fig. 3(d): {N4,N5} and {N3,N6}). The cluster graph is what
//!    placement and routing operate on.
//!
//! The result is a [`FuGraph`]: the fused DFG plus the op→FU
//! assignment. `FuGraph::nets()` derives the inter-FU nets for the
//! VPR-style netlist.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::dfg::{Dfg, DfgOp, NodeId, NodeKind};

/// One functional unit: 1 or 2 DFG op nodes executed on its DSP block(s),
/// in dataflow order (ops[0] feeds ops[1] when len == 2).
#[derive(Debug, Clone)]
pub struct Fu {
    pub id: usize,
    pub ops: Vec<NodeId>,
}

impl Fu {
    /// DSP blocks this FU consumes.
    pub fn dsp_count(&self) -> usize {
        self.ops.len()
    }
}

/// The clustered, FU-aware graph handed to placement.
#[derive(Debug, Clone)]
pub struct FuGraph {
    /// The fused DFG (post-[`fuse_muladd`]).
    pub dfg: Dfg,
    pub fus: Vec<Fu>,
    /// op node → FU index.
    pub fu_of: HashMap<NodeId, usize>,
}

/// A point-to-point net between placeable endpoints.
#[derive(Debug, Clone, PartialEq)]
pub struct FuNet {
    pub src: NetEndpoint,
    /// (sink endpoint, FU input pin) pairs.
    pub sinks: Vec<(NetEndpoint, u8)>,
}

/// Net endpoints: FUs or I/O pads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetEndpoint {
    Fu(usize),
    InPad(usize),
    OutPad(usize),
}

/// Stage 1: fuse mul→add / mul→sub pairs into DSP `mul_add`/`mul_sub`
/// capabilities. Returns the rewritten DFG (Fig. 3(a) → Fig. 3(b)).
pub fn fuse_muladd(g: &Dfg) -> Result<Dfg> {
    let order = g.topo_order()?;
    // mul -> consumer it fuses into; consumer -> mul it hosts
    let mut fused_into: HashMap<NodeId, NodeId> = HashMap::new();
    let mut host_of: HashMap<NodeId, NodeId> = HashMap::new();

    for &id in &order {
        let NodeKind::Op { op, .. } = &g.nodes[id].kind else { continue };
        if !matches!(op, DfgOp::Add | DfgOp::Sub) {
            continue;
        }
        for e in &g.preds(id) {
            // subtraction only folds when the product is the minuend:
            // DSP gives a*b - c, not c - a*b.
            if *op == DfgOp::Sub && e.dst_port != 0 {
                continue;
            }
            let src = e.src;
            if fused_into.contains_key(&src) || host_of.contains_key(&id) {
                continue;
            }
            let NodeKind::Op { op: DfgOp::Mul, .. } = &g.nodes[src].kind else {
                continue;
            };
            if g.succs(src).len() != 1 {
                continue; // product used elsewhere: must stay a full node
            }
            fused_into.insert(src, id);
            host_of.insert(id, src);
            break;
        }
    }

    // rebuild
    let mut out = Dfg::new(g.name.clone());
    out.input_names = g.input_names.clone();
    out.output_names = g.output_names.clone();
    let mut remap: HashMap<NodeId, NodeId> = HashMap::new();

    for &id in &order {
        match &g.nodes[id].kind {
            NodeKind::InVar { port } => {
                remap.insert(id, out.add_node(NodeKind::InVar { port: *port }));
            }
            NodeKind::OutVar { port } => {
                let nid = out.add_node(NodeKind::OutVar { port: *port });
                for e in g.preds(id) {
                    out.add_edge(remap[&e.src], nid, e.dst_port);
                }
                remap.insert(id, nid);
            }
            NodeKind::Op { op, imm } => {
                if fused_into.contains_key(&id) {
                    continue; // absorbed into its consumer
                }
                if let Some(&mul) = host_of.get(&id) {
                    // fused node: ports 0,1 from the mul; port 2 = the
                    // add/sub operand that wasn't the product.
                    let NodeKind::Op { imm: mul_imm, .. } = &g.nodes[mul].kind else {
                        unreachable!()
                    };
                    let fused_op =
                        if *op == DfgOp::Add { DfgOp::MulAdd } else { DfgOp::MulSub };
                    let mut new_imm = [mul_imm[0], mul_imm[1], None];
                    let mul_port = g
                        .preds(id)
                        .iter()
                        .find(|e| e.src == mul)
                        .map(|e| e.dst_port)
                        .unwrap();
                    new_imm[2] = imm[1 - mul_port as usize];
                    let nid = out.add_node(NodeKind::Op { op: fused_op, imm: new_imm });
                    for e in g.preds(mul) {
                        out.add_edge(remap[&e.src], nid, e.dst_port);
                    }
                    for e in g.preds(id) {
                        if e.src != mul {
                            out.add_edge(remap[&e.src], nid, 2);
                        }
                    }
                    remap.insert(id, nid);
                } else {
                    let nid = out.add_node(NodeKind::Op { op: *op, imm: *imm });
                    for e in g.preds(id) {
                        out.add_edge(remap[&e.src], nid, e.dst_port);
                    }
                    remap.insert(id, nid);
                }
            }
        }
    }
    out.validate()?;
    Ok(out)
}

/// Maximum external data inputs of one FU (2-DSP FUs expose four
/// operand ports through the tile's connection boxes [14]).
pub const MAX_FU_INPUTS: usize = 4;

/// Stage 2: cluster the fused DFG onto FUs with `dsps_per_fu` DSP
/// blocks (Fig. 3(b) → Fig. 3(d) when `dsps_per_fu == 2`).
pub fn cluster(dfg: &Dfg, dsps_per_fu: usize) -> Result<FuGraph> {
    if !(1..=2).contains(&dsps_per_fu) {
        bail!("dsps_per_fu must be 1 or 2 (got {dsps_per_fu})");
    }
    let order = dfg.topo_order()?;
    let mut fus: Vec<Fu> = Vec::new();
    let mut fu_of: HashMap<NodeId, usize> = HashMap::new();

    for &id in &order {
        if !matches!(dfg.nodes[id].kind, NodeKind::Op { .. }) {
            continue;
        }
        if fu_of.contains_key(&id) {
            continue;
        }
        let mut ops = vec![id];
        if dsps_per_fu == 2 {
            // chain this op with its sole consumer if legal
            let succs = dfg.succs(id);
            if succs.len() == 1 {
                let next = succs[0].dst;
                if matches!(dfg.nodes[next].kind, NodeKind::Op { .. })
                    && !fu_of.contains_key(&next)
                    && external_inputs(dfg, &[id, next]) <= MAX_FU_INPUTS
                {
                    ops.push(next);
                }
            }
        }
        let fu_id = fus.len();
        for &op in &ops {
            fu_of.insert(op, fu_id);
        }
        fus.push(Fu { id: fu_id, ops });
    }

    Ok(FuGraph { dfg: dfg.clone(), fus, fu_of })
}

/// Count external data edges into a prospective cluster — each needs
/// its own physical FU input pin through the connection box.
fn external_inputs(dfg: &Dfg, ops: &[NodeId]) -> usize {
    let mut n = 0;
    for &op in ops {
        for e in dfg.preds(op) {
            if !ops.contains(&e.src) {
                n += 1;
            }
        }
    }
    n
}

impl FuGraph {
    pub fn num_fus(&self) -> usize {
        self.fus.len()
    }

    /// Total DSP blocks consumed.
    pub fn dsp_count(&self) -> usize {
        self.fus.iter().map(Fu::dsp_count).sum()
    }

    /// Derive the inter-FU / IO nets. Edges internal to one FU vanish
    /// (they ride the intra-FU DSP cascade).
    pub fn nets(&self) -> Vec<FuNet> {
        let mut by_src: HashMap<NetEndpoint, Vec<(NetEndpoint, u8)>> = HashMap::new();
        for e in &self.dfg.edges {
            let src_ep = match &self.dfg.nodes[e.src].kind {
                NodeKind::InVar { port } => NetEndpoint::InPad(*port),
                NodeKind::Op { .. } => NetEndpoint::Fu(self.fu_of[&e.src]),
                NodeKind::OutVar { .. } => unreachable!("edge out of outvar"),
            };
            let dst_ep = match &self.dfg.nodes[e.dst].kind {
                NodeKind::OutVar { port } => NetEndpoint::OutPad(*port),
                NodeKind::Op { .. } => NetEndpoint::Fu(self.fu_of[&e.dst]),
                NodeKind::InVar { .. } => unreachable!("edge into invar"),
            };
            if src_ep == dst_ep {
                continue; // intra-FU cascade
            }
            by_src.entry(src_ep).or_default().push((dst_ep, e.dst_port));
        }
        let mut nets: Vec<FuNet> = by_src
            .into_iter()
            .map(|(src, sinks)| FuNet { src, sinks })
            .collect();
        nets.sort_by_key(|n| match n.src {
            NetEndpoint::InPad(p) => (0, p),
            NetEndpoint::Fu(f) => (1, f),
            NetEndpoint::OutPad(p) => (2, p),
        });
        nets
    }
}

/// An external input edge of an FU: where it comes from and which op
/// port it feeds. Order within one FU defines the physical pin index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FuInputEdge {
    pub src: NetEndpoint,
    pub op: NodeId,
    pub port: u8,
}

impl FuGraph {
    /// Deterministic external-input pin assignment for `fu`:
    /// `result[pin] = (source endpoint, op node, op port)`.
    pub fn input_pins(&self, fu: usize) -> Vec<FuInputEdge> {
        let mut pins = Vec::new();
        for &op in &self.fus[fu].ops {
            for e in self.dfg.preds(op) {
                if self.fus[fu].ops.contains(&e.src) {
                    continue; // internal cascade
                }
                let src = match &self.dfg.nodes[e.src].kind {
                    NodeKind::InVar { port } => NetEndpoint::InPad(*port),
                    NodeKind::Op { .. } => NetEndpoint::Fu(self.fu_of[&e.src]),
                    NodeKind::OutVar { .. } => unreachable!(),
                };
                pins.push(FuInputEdge { src, op, port: e.dst_port });
            }
        }
        pins
    }
}

/// Convenience: full FU-aware pipeline (fuse then cluster).
pub fn to_fu_graph(dfg: &Dfg, dsps_per_fu: usize) -> Result<FuGraph> {
    let fused = fuse_muladd(dfg)?;
    cluster(&fused, dsps_per_fu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::ir::{lower_kernel, optimize};

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn paper_dfg() -> Dfg {
        let f = lower_kernel(&parse_kernel(PAPER).unwrap()).unwrap();
        crate::dfg::extract_dfg(&optimize(&f).0).unwrap()
    }

    #[test]
    fn fusion_reaches_fig3b_five_nodes() {
        // Fig 3(a) has 7 op nodes; Fig 3(b) has 5 (two mul±imm pairs fused)
        let fused = fuse_muladd(&paper_dfg()).unwrap();
        assert_eq!(fused.num_ops(), 5);
        let fma = fused
            .nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.kind,
                    NodeKind::Op { op: DfgOp::MulAdd, .. }
                        | NodeKind::Op { op: DfgOp::MulSub, .. }
                )
            })
            .count();
        assert_eq!(fma, 2);
        fused.validate().unwrap();
    }

    #[test]
    fn fused_nodes_carry_immediates_fig3b_labels() {
        let fused = fuse_muladd(&paper_dfg()).unwrap();
        let labels: Vec<String> =
            fused.nodes.iter().map(|n| fused.label(n.id)).collect();
        let has = |frag: &str| labels.iter().any(|l| l.contains(frag));
        // Table II(b): mul_Imm_16, mul_sub_Imm_20, mul_add_Imm_5
        assert!(has("mul_Imm_16"), "{labels:?}");
        assert!(has("mul_sub_Imm_20"), "{labels:?}");
        assert!(has("mul_add_Imm_5"), "{labels:?}");
    }

    #[test]
    fn one_dsp_clustering_gives_5_fus() {
        let g = to_fu_graph(&paper_dfg(), 1).unwrap();
        assert_eq!(g.num_fus(), 5);
        assert_eq!(g.dsp_count(), 5);
    }

    #[test]
    fn two_dsp_clustering_gives_3_fus_fig3d() {
        // Fig 3(d): {N4,N5}, {N3,N6}, {N2} — 3 FUs, 5 DSPs
        let g = to_fu_graph(&paper_dfg(), 2).unwrap();
        assert_eq!(g.num_fus(), 3);
        assert_eq!(g.dsp_count(), 5);
        let sizes: Vec<usize> = g.fus.iter().map(|f| f.ops.len()).collect();
        assert_eq!(sizes.iter().filter(|&&s| s == 2).count(), 2);
        assert_eq!(sizes.iter().filter(|&&s| s == 1).count(), 1);
    }

    #[test]
    fn shared_product_is_not_fused() {
        // t = a*b used by two adds: the mul must stay a separate node
        let src = "__kernel void k(__global int *A, __global int *B, __global int *C) {
            int i = get_global_id(0);
            int t = A[i] * A[i];
            B[i] = t + 1;
            C[i] = t + 2;
        }";
        let f = lower_kernel(&parse_kernel(src).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        let fused = fuse_muladd(&dfg).unwrap();
        let muls = fused
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { op: DfgOp::Mul, .. }))
            .count();
        assert_eq!(muls, 1);
        let adds = fused
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { op: DfgOp::Add, .. }))
            .count();
        assert_eq!(adds, 2);
    }

    #[test]
    fn sub_with_product_as_subtrahend_not_fused() {
        // c - a*b cannot fold into the DSP (no rsub-mul mode)
        let src = "__kernel void k(__global int *A, __global int *B) {
            int i = get_global_id(0);
            B[i] = A[i+1] - A[i] * 3;
        }";
        let f = lower_kernel(&parse_kernel(src).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        let fused = fuse_muladd(&dfg).unwrap();
        let subs = fused
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { op: DfgOp::Sub, .. }))
            .count();
        assert_eq!(subs, 1, "sub must survive unfused");
    }

    #[test]
    fn nets_exclude_intra_fu_edges() {
        let g = to_fu_graph(&paper_dfg(), 2).unwrap();
        let nets = g.nets();
        for n in &nets {
            for (sink, _) in &n.sinks {
                assert_ne!(n.src, *sink);
            }
        }
        let in_net = nets
            .iter()
            .find(|n| matches!(n.src, NetEndpoint::InPad(0)))
            .unwrap();
        assert!(!in_net.sinks.is_empty());
        let out_sinks: usize = nets
            .iter()
            .flat_map(|n| &n.sinks)
            .filter(|(s, _)| matches!(s, NetEndpoint::OutPad(_)))
            .count();
        assert_eq!(out_sinks, 1);
    }

    #[test]
    fn cluster_respects_input_port_cap() {
        let src = "__kernel void k(__global int *A, __global int *B, __global int *C,
                                   __global int *D, __global int *E) {
            int i = get_global_id(0);
            E[i] = (A[i] + B[i]) + (C[i] + D[i]);
        }";
        let f = lower_kernel(&parse_kernel(src).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        let g = cluster(&dfg, 2).unwrap();
        for fu in &g.fus {
            assert!(external_inputs(&g.dfg, &fu.ops) <= MAX_FU_INPUTS);
        }
    }

    #[test]
    fn clustering_with_one_dsp_never_pairs() {
        let g = to_fu_graph(&paper_dfg(), 1).unwrap();
        assert!(g.fus.iter().all(|f| f.ops.len() == 1));
    }
}
