//! Flat stream arenas and the pooled dispatch scratch — the zero-copy
//! data plane under [`crate::runtime_ocl`] and [`crate::coordinator`].
//!
//! The original dispatch path shuttled work-item streams around as
//! `Vec<Vec<i32>>`: one heap allocation per stream per dispatch, plus
//! whole-argument clones in `pack_streams` / `scatter_outputs`, plus
//! fresh output vectors inside the simulator. None of that models the
//! overlay (whose streams are DMA bursts over a fixed buffer) and all
//! of it dominated serving time. This module replaces the plumbing:
//!
//! * [`StreamArena`] — one contiguous `i32` buffer holding `streams`
//!   equal-length lanes (stream-major). Packing writes **into** the
//!   arena at a lane offset, so a fused batch concatenates jobs by
//!   offset instead of re-copying their streams; splitting results
//!   back out is a borrowed sub-slice, not a copy. `reset` keeps the
//!   allocation, so a warmed arena performs zero heap allocation.
//! * [`DispatchScratch`] — everything one dispatch needs to run
//!   without touching the allocator: an input arena, an output arena,
//!   and the blocked simulator's [`crate::sim::SimScratch`].
//! * [`ScratchPool`] — a checkout/checkin pool of dispatch scratches
//!   shared by the coordinator's partition workers and the synchronous
//!   [`crate::runtime_ocl::CommandQueue`]. [`PoolStats::grow_events`]
//!   counts the (warm-up only) heap growth, which the hot-path tests
//!   pin to prove the steady state allocates nothing per work-item.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sim::SimScratch;

/// A flat, reusable stream matrix: `streams` lanes of `items` `i32`s
/// in one contiguous buffer (stream-major), standing in for the
/// overlay's DMA staging buffer.
#[derive(Debug, Default)]
pub struct StreamArena {
    data: Vec<i32>,
    streams: usize,
    items: usize,
    grow_events: u64,
}

impl StreamArena {
    pub fn new() -> StreamArena {
        StreamArena::default()
    }

    /// An arena pre-sized for `streams × items` (no warm-up growth).
    pub fn with_shape(streams: usize, items: usize) -> StreamArena {
        let mut a = StreamArena::new();
        a.reset(streams, items);
        a.grow_events = 0;
        a
    }

    /// Reshape for a new dispatch: `streams` lanes × `items` columns,
    /// all zeroed. Keeps the existing allocation whenever it is large
    /// enough; growth is counted in [`StreamArena::grow_events`].
    pub fn reset(&mut self, streams: usize, items: usize) {
        let need = streams * items;
        let cap0 = self.data.capacity();
        self.data.clear();
        self.data.resize(need, 0);
        if self.data.capacity() > cap0 {
            self.grow_events += 1;
        }
        self.streams = streams;
        self.items = items;
    }

    /// Number of streams (lanes).
    pub fn streams(&self) -> usize {
        self.streams
    }

    /// Items per stream.
    pub fn items(&self) -> usize {
        self.items
    }

    /// Borrow stream `s` (length [`StreamArena::items`]).
    pub fn stream(&self, s: usize) -> &[i32] {
        &self.data[s * self.items..(s + 1) * self.items]
    }

    /// Mutably borrow stream `s`.
    pub fn stream_mut(&mut self, s: usize) -> &mut [i32] {
        &mut self.data[s * self.items..(s + 1) * self.items]
    }

    /// The live `streams × items` region as one flat slice.
    pub fn as_flat(&self) -> &[i32] {
        &self.data[..self.streams * self.items]
    }

    /// Heap (re)allocations this arena has performed — stable after
    /// warm-up on a fixed dispatch shape.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }

    /// Copy the arena out into per-stream vectors (compatibility with
    /// the legacy `Vec<Vec<i32>>` plumbing and the PJRT FFI boundary).
    pub fn to_vecs(&self) -> Vec<Vec<i32>> {
        (0..self.streams).map(|s| self.stream(s).to_vec()).collect()
    }

    /// Fill the arena from per-stream slices (shape taken from the
    /// input; every stream must be `items` long).
    pub fn fill_from(&mut self, streams: &[Vec<i32>], items: usize) {
        self.reset(streams.len(), items);
        for (s, v) in streams.iter().enumerate() {
            self.stream_mut(s).copy_from_slice(&v[..items]);
        }
    }
}

/// Everything one dispatch needs to execute with zero heap traffic
/// once warm: pack target, simulator scratch, output staging.
#[derive(Debug, Default)]
pub struct DispatchScratch {
    /// Packed input streams (written by `pack_streams_into`).
    pub inputs: StreamArena,
    /// Backend output streams (written by `sim::execute_into`).
    pub outputs: StreamArena,
    /// Simulator re-execution target for cross-checking a non-sim
    /// backend's outputs (idle on cycle-sim partitions).
    pub verify: StreamArena,
    /// The blocked simulator's slot-table block and lane buffers.
    pub sim: SimScratch,
}

impl DispatchScratch {
    pub fn new() -> DispatchScratch {
        DispatchScratch::default()
    }

    /// Total heap growth across the scratch's components.
    pub fn grow_events(&self) -> u64 {
        self.inputs.grow_events()
            + self.outputs.grow_events()
            + self.verify.grow_events()
            + self.sim.grow_events()
    }
}

/// Counters of a [`ScratchPool`] — the evidence behind the "zero
/// allocations per work-item after warm-up" claim (§E11).
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Scratches ever constructed (warm-up; bounded by peak
    /// concurrency, not by dispatch count).
    pub created: u64,
    /// Checkouts served (≥ `created`; the difference is reuse).
    pub checkouts: u64,
    /// Checkouts satisfied from the free list without allocating.
    pub reuses: u64,
    /// Scratches currently parked in the pool.
    pub pooled: usize,
    /// Heap growth summed over the parked scratches — stable once the
    /// fleet has seen its working set of dispatch shapes.
    pub grow_events: u64,
}

/// A checkout/checkin pool of [`DispatchScratch`]es. The lock guards
/// only a `Vec` push/pop (nanoseconds); one checkout serves a whole
/// fused run, so the pool never becomes a per-job serialization point.
#[derive(Debug, Default)]
pub struct ScratchPool {
    free: Mutex<Vec<DispatchScratch>>,
    created: AtomicU64,
    checkouts: AtomicU64,
    reuses: AtomicU64,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Take a scratch (reusing a parked one when available).
    pub fn checkout(&self) -> DispatchScratch {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.free.lock().unwrap().pop() {
            self.reuses.fetch_add(1, Ordering::Relaxed);
            s
        } else {
            self.created.fetch_add(1, Ordering::Relaxed);
            DispatchScratch::new()
        }
    }

    /// Return a scratch (its warmed allocations come back with it).
    pub fn checkin(&self, scratch: DispatchScratch) {
        self.free.lock().unwrap().push(scratch);
    }

    pub fn stats(&self) -> PoolStats {
        let free = self.free.lock().unwrap();
        PoolStats {
            created: self.created.load(Ordering::Relaxed),
            checkouts: self.checkouts.load(Ordering::Relaxed),
            reuses: self.reuses.load(Ordering::Relaxed),
            pooled: free.len(),
            grow_events: free.iter().map(|s| s.grow_events()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_shapes_zeroes_and_reuses_its_allocation() {
        let mut a = StreamArena::new();
        a.reset(2, 4);
        assert_eq!((a.streams(), a.items()), (2, 4));
        assert_eq!(a.grow_events(), 1);
        a.stream_mut(1).copy_from_slice(&[1, 2, 3, 4]);
        assert_eq!(a.stream(0), &[0, 0, 0, 0]);
        assert_eq!(a.stream(1), &[1, 2, 3, 4]);
        assert_eq!(a.as_flat(), &[0, 0, 0, 0, 1, 2, 3, 4]);
        // reshaping within capacity allocates nothing and re-zeroes
        a.reset(4, 2);
        assert_eq!(a.grow_events(), 1);
        assert!(a.as_flat().iter().all(|&v| v == 0));
        // growth is counted
        a.reset(8, 64);
        assert_eq!(a.grow_events(), 2);
        assert_eq!(a.to_vecs().len(), 8);
    }

    #[test]
    fn arena_round_trips_vec_plumbing() {
        let streams = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let mut a = StreamArena::new();
        a.fill_from(&streams, 3);
        assert_eq!(a.to_vecs(), streams);
        // with_shape starts warm: a same-shape reset never grows
        let mut b = StreamArena::with_shape(2, 3);
        assert_eq!(b.grow_events(), 0);
        b.reset(2, 3);
        assert_eq!(b.grow_events(), 0);
    }

    #[test]
    fn pool_reuses_scratches_and_tracks_growth() {
        let pool = ScratchPool::new();
        let mut s = pool.checkout();
        s.inputs.reset(4, 128);
        s.outputs.reset(4, 128);
        pool.checkin(s);
        let stats = pool.stats();
        assert_eq!((stats.created, stats.checkouts, stats.reuses), (1, 1, 0));
        assert_eq!(stats.pooled, 1);
        let warm_growth = stats.grow_events;
        assert!(warm_growth >= 2);
        // the second checkout reuses the warmed scratch; a same-shape
        // reset adds no growth
        let mut s = pool.checkout();
        assert_eq!(pool.stats().reuses, 1);
        s.inputs.reset(4, 128);
        s.outputs.reset(4, 128);
        pool.checkin(s);
        assert_eq!(pool.stats().grow_events, warm_growth);
    }
}
