//! # overlay-jit
//!
//! A resource-aware just-in-time OpenCL compiler for coarse-grained FPGA
//! overlays — a full-system reproduction of Jain, Maskell & Fahmy,
//! *"Resource-Aware Just-in-Time OpenCL Compiler for Coarse-Grained FPGA
//! Overlays"* (2017).
//!
//! The crate implements the paper's entire stack:
//!
//! * [`frontend`] — an OpenCL-C subset front-end (lexer, parser, semantic
//!   analysis), standing in for Clang.
//! * [`ir`] — an SSA intermediate representation with the optimization
//!   passes the paper applies via LLVM (mem2reg, constant folding,
//!   algebraic simplification, CSE, DCE).
//! * [`dfg`] — dataflow-graph extraction from the optimized IR and the
//!   DOT interchange format of Table II.
//! * [`fuaware`] — the DFG → FU-aware DFG transform: fusing multiply–add /
//!   multiply–subtract pairs into single DSP-block capabilities and
//!   clustering op pairs onto two-DSP functional units (Fig. 3).
//! * [`overlay`] — the island-style overlay architecture model: tiles,
//!   functional units, switch/connection boxes, the routing-resource
//!   graph, and the configuration word format.
//! * [`netlist`] — the VPR-style FU netlist interchange format.
//! * [`place`] / [`route`] — a simulated-annealing placer and a
//!   PathFinder negotiated-congestion router (the VPR stand-in).
//! * [`latency`] — latency balancing: assigning FU input delay-chain
//!   settings so all FU inputs arrive in the same cycle (II = 1).
//! * [`configgen`] — overlay bitstream generation plus the levelized
//!   FU *slot schedule* consumed by the execution backends.
//! * [`replicate`] — resource-aware kernel replication driven by the
//!   overlay size / FU type exposed by the OpenCL runtime.
//! * [`compiler`] — the JIT pipeline driver tying it all together.
//! * [`fpga`] — the fine-grained (direct FPGA) baseline: LUT-level
//!   technology mapping and PAR at fabric granularity, standing in for
//!   Vivado in Fig. 7 / Table III.
//! * [`sim`] — a cycle-level functional + timing simulator of the
//!   configured overlay: a blocked structure-of-arrays executor
//!   (slot-major inner loops over [`sim::SIM_BLOCK`]-lane blocks,
//!   reusable [`sim::SimScratch`], zero allocation once warm) pinned
//!   bit-exact against the scalar reference walker.
//! * [`arena`] — the zero-copy dispatch data plane: flat
//!   [`arena::StreamArena`] stream matrices packed in place (fused
//!   batches concatenate by lane offset), plus the
//!   [`arena::ScratchPool`] of warmed per-dispatch scratches shared
//!   by the command queue and the coordinator workers.
//! * [`runtime`] — the XLA/PJRT execution backend that loads the
//!   AOT-compiled overlay-emulator artifacts (`artifacts/*.hlo.txt`).
//! * [`runtime_ocl`] — an OpenCL-flavoured host API (platform, device,
//!   context, queue, buffer, program, kernel, events), including the
//!   multi-partition platform the coordinator serves across.
//! * [`fleet`] — the heterogeneous-fleet layer: one compilation shard
//!   (JIT compiler + kernel cache) per distinct overlay spec, keyed by
//!   spec fingerprint, plus a resource-aware router that scores specs
//!   with the kernel's replication plan (FU/IO demand, limit reason)
//!   — small kernels onto small overlays, wide data-parallel kernels
//!   where copies × throughput peaks.
//! * [`coordinator`] — the overlay serving layer: per-spec kernel
//!   caches keyed by (source hash, overlay fingerprint, options
//!   fingerprint) with disk snapshots for warm restarts (periodic, in
//!   the background, on a submit-count cadence), a slot-aware
//!   scheduler that treats configured partitions as a cache (affinity
//!   dispatch, deadline-shielded victims, batch-class-first eviction
//!   paying the modeled 42 µs-class reconfiguration cost), and async
//!   per-partition dispatch queues with two QoS lanes, same-kernel
//!   batch fusion (plus a bounded cross-batch fusion window),
//!   completion handles and serving statistics. Fused batch runs are
//!   preemptible at chunk boundaries: when interactive work queues on
//!   a burning partition the worker checkpoints mid-run and requeues
//!   the remainder as a typed [`coordinator::ContinuationRecord`]ed
//!   continuation on the least-loaded sibling (bounded by
//!   [`coordinator::MAX_PREEMPTIONS`] bounces per job; interactive
//!   runs are never preempted).
//! * [`autoscale`] — adaptive runtime performance scaling: per-
//!   (kernel, spec) sliding-window load signals fed from both ends of
//!   the dispatch path, a hysteresis + cooldown scale policy that
//!   provably cannot oscillate, and a background rescale lane that
//!   re-replicates hot kernels (or shrinks over-provisioned ones)
//!   while serving — variants are cache-keyed per factor, swaps are
//!   atomic, and every decision lands in a bounded `ScaleEvent` audit
//!   log. With an [`obs::SloPolicy`] armed the scale-*up* trigger is
//!   SLO-targeted instead of demand-band: the coordinator feeds the
//!   windowed interactive p99 + target into the policy each
//!   `slo_tick`, which scales up (at-least-doubling) while the
//!   objective is missed and holds capacity until p99 clears the
//!   0.8× hysteresis band.
//! * [`admission`] — overload-safe admission control: per-tenant token
//!   buckets on submit, a pressure-stall signal from queue depth + p99,
//!   deadline-based early rejection with typed reject reasons, batch-
//!   first load shedding, and a deterministic seeded fault-injection
//!   plan (worker kills, reconfiguration failures, verify corruption,
//!   transient compile failures) the dispatch plane must recover from;
//!   its shedding signal ([`admission::AdmissionController::overloaded`])
//!   doubles as one of the two batch-preemption arm conditions.
//! * [`cluster`] — the cluster serving tier: N in-process coordinator
//!   nodes behind one front door, a consistent-hash ring over stable
//!   kernel fingerprints (virtual nodes; minimal remapping on
//!   membership change) keeping each kernel's compiled variants hot on
//!   its home node, pressure-threshold spill to the least-loaded live
//!   sibling, heartbeat-driven health with failover to ring successors
//!   and warm snapshot rejoin, and cluster-wide merged serving stats.
//! * [`bench_kernels`] — the paper's six benchmark kernels as OpenCL-C
//!   sources with their Table III metadata.
//! * [`metrics`] — the GOPS / resource / configuration-time models behind
//!   Figs. 6–7 and Table III, plus the coordinator's serving stats
//!   (cache hit rate, reconfigurations, utilization, p50/p99 latency)
//!   and their Prometheus text exposition
//!   (`metrics::ServingStats::prometheus`).
//! * [`obs`] — continuous telemetry and end-to-end dispatch tracing:
//!   per-submit [`obs::TraceId`]s with phase spans across every serving
//!   layer (admission, route, cache/compile, slot pick, queue wait,
//!   pack, exec, scatter, verify, retries, preemption checkpoints,
//!   cluster hops), collected in
//!   lock-light per-worker span rings (tracing off is a no-op recorder,
//!   tracing on can head-sample 1/N submits via [`obs::Sampler`]), a
//!   flight recorder pinning exemplar traces per anomaly class, and a
//!   Chrome-trace-event JSON exporter ([`obs::chrome_trace`]); plus the
//!   metrics substrate underneath: [`obs::LatencyHist`] log-bucketed
//!   histograms (2 buckets/octave, fixed memory, lossless bucket-wise
//!   merge — the canonical latency carrier in `ServingStats`),
//!   [`obs::TimeSeries`] snapshot windows on a caller-advanced clock,
//!   and [`obs::SloPolicy`] burn-rate alerting (multi-window Google-SRE
//!   style, typed [`obs::SloAlert`]s, feeds admission pressure and the
//!   autoscaler).
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts`
//! AOT-lowers the overlay-datapath emulator to HLO text which the
//! [`runtime`] module loads through the PJRT C API. Nothing on the
//! request path touches Python.

pub mod admission;
pub mod arena;
pub mod autoscale;
pub mod bench_kernels;
pub mod cluster;
pub mod compiler;
pub mod configgen;
pub mod coordinator;
pub mod dfg;
pub mod fleet;
pub mod fpga;
pub mod frontend;
pub mod fuaware;
pub mod ir;
pub mod latency;
pub mod metrics;
pub mod netlist;
pub mod obs;
pub mod overlay;
pub mod place;
pub mod replicate;
pub mod route;
pub mod runtime;
pub mod runtime_ocl;
pub mod sim;
pub mod util;

/// Convenient re-exports for the common compile-and-run flow.
pub mod prelude {
    pub use crate::admission::{
        AdmissionConfig, AdmissionStats, FaultKind, FaultPlanConfig, FaultTally,
        RejectReason,
    };
    pub use crate::arena::{DispatchScratch, PoolStats, ScratchPool, StreamArena};
    pub use crate::autoscale::{AutoscalePolicy, ScaleDirection, ScaleEvent};
    pub use crate::cluster::{
        ClusterConfig, ClusterFrontend, ClusterStats, HashRing, Health, Node,
        SpillReason,
    };
    pub use crate::compiler::{
        CompileOptions, CompileReport, CompiledKernel, JitCompiler, KernelCost,
        Replication,
    };
    pub use crate::coordinator::{
        Admission, ContinuationRecord, Coordinator, CoordinatorConfig,
        DispatchError, DispatchHandle, DispatchResult, FailReason, Priority,
        RoutingPolicy, SubmitArg, MAX_PREEMPTIONS,
    };
    pub use crate::fleet::RouteReason;
    pub use crate::obs::{
        chrome_trace, AlertState, Exemplar, LatencyHist, Phase, Sampler, SloAlert,
        SloPolicy, SloStats, Span, TimeSeries, TraceHandle, TraceId, TraceSink,
    };
    pub use crate::overlay::{FuType, OverlaySpec};
    pub use crate::replicate::ReplicationPlan;
    pub use crate::runtime_ocl::{
        Backend, Buffer, CommandQueue, Context, Device, Event, Kernel, Platform,
        Program,
    };
}
