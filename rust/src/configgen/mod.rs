//! Configuration generation (paper §III-E tail: "generate the
//! configuration data … loaded onto the overlay at runtime using the
//! OpenCL API").
//!
//! Two artifacts come out of a compiled kernel:
//!
//! 1. [`OverlayBitstream`] — the physical per-tile configuration
//!    (opcodes, immediates, delay chains, switch-box words) whose byte
//!    size and load time reproduce §IV's 1061 B / 42.4 µs.
//! 2. [`SlotSchedule`] — the *execution* encoding consumed by both the
//!    Rust cycle simulator and the AOT XLA/PJRT emulator: a levelized
//!    sequence of FU op slots with value-table column routing, exactly
//!    the instruction layout `python/compile/kernels/geometry.py`
//!    freezes at AOT time.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::dfg::{Dfg, NodeKind};
use crate::fuaware::FuGraph;
use crate::latency::LatencyReport;
use crate::overlay::{OverlayBitstream, OverlaySpec, RoutingGraph};
use crate::place::Placement;
use crate::route::RouteResult;

/// Static geometry of the AOT-compiled emulator. Must match
/// `python/compile/kernels/geometry.py` (checked against
/// `artifacts/geometry.json` at runtime start-up).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EmuGeometry {
    pub num_inputs: usize,
    pub max_fus: usize,
    pub batch: usize,
}

impl EmuGeometry {
    pub const DEFAULT: EmuGeometry =
        EmuGeometry { num_inputs: 32, max_fus: 128, batch: 1024 };

    pub fn imm_base(&self) -> usize {
        self.num_inputs
    }

    pub fn out_base(&self) -> usize {
        self.num_inputs + self.max_fus
    }

    pub fn num_slots(&self) -> usize {
        self.num_inputs + 2 * self.max_fus
    }
}

/// The levelized op-slot program of a (replicated) kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotSchedule {
    /// Opcode per used slot (emulator encoding, see `DfgOp::opcode`).
    pub ops: Vec<i32>,
    pub src_a: Vec<i32>,
    pub src_b: Vec<i32>,
    pub src_c: Vec<i32>,
    /// Constant-pool columns: (column index, bit value).
    pub imm_pool: Vec<(usize, i32)>,
    /// Input stream port → value-table column (identity layout).
    pub num_inputs: usize,
    /// Output stream port → value-table column.
    pub out_col: Vec<usize>,
    pub geometry: EmuGeometry,
}

impl SlotSchedule {
    pub fn n_slots(&self) -> usize {
        self.ops.len()
    }
}

/// Levelize a (replicated) DFG into the emulator slot program.
pub fn slot_schedule(dfg: &Dfg, geom: EmuGeometry) -> Result<SlotSchedule> {
    let ops_order: Vec<_> = dfg
        .topo_order()?
        .into_iter()
        .filter(|&id| matches!(dfg.nodes[id].kind, NodeKind::Op { .. }))
        .collect();
    if ops_order.len() > geom.max_fus {
        bail!(
            "kernel needs {} op slots but the AOT emulator has {}",
            ops_order.len(),
            geom.max_fus
        );
    }
    if dfg.num_inputs() > geom.num_inputs {
        bail!(
            "kernel needs {} input columns but the AOT emulator has {}",
            dfg.num_inputs(),
            geom.num_inputs
        );
    }

    let mut slot_of: HashMap<usize, usize> = HashMap::new();
    for (t, &id) in ops_order.iter().enumerate() {
        slot_of.insert(id, t);
    }

    // constant pool, allocated from the top of the imm block, deduped
    let mut pool: HashMap<i32, usize> = HashMap::new();
    let mut imm_pool: Vec<(usize, i32)> = Vec::new();
    let n_slots_used = ops_order.len();
    let alloc_imm = |bits: i32,
                         pool: &mut HashMap<i32, usize>,
                         imm_pool: &mut Vec<(usize, i32)>|
     -> Result<usize> {
        if let Some(&col) = pool.get(&bits) {
            return Ok(col);
        }
        let k = pool.len();
        let idx = geom.max_fus.checked_sub(1 + k).ok_or_else(|| {
            anyhow::anyhow!("immediate pool exhausted")
        })?;
        if idx < n_slots_used {
            bail!(
                "op slots ({}) and immediate pool ({}) overflow the {}-slot \
                 emulator",
                n_slots_used,
                k + 1,
                geom.max_fus
            );
        }
        let col = geom.imm_base() + idx;
        pool.insert(bits, col);
        imm_pool.push((col, bits));
        Ok(col)
    };

    let mut ops = vec![0i32; n_slots_used];
    let mut src = [
        vec![0i32; n_slots_used],
        vec![0i32; n_slots_used],
        vec![0i32; n_slots_used],
    ];

    for (t, &id) in ops_order.iter().enumerate() {
        let NodeKind::Op { op, imm } = &dfg.nodes[id].kind else { unreachable!() };
        ops[t] = op.opcode();
        // default sources: column 0 (harmless for unused ports)
        let mut cols = [0usize; 3];
        let mut driven = [false; 3];
        for e in dfg.preds(id) {
            let col = match &dfg.nodes[e.src].kind {
                NodeKind::InVar { port } => *port,
                NodeKind::Op { .. } => geom.out_base() + slot_of[&e.src],
                NodeKind::OutVar { .. } => unreachable!(),
            };
            cols[e.dst_port as usize] = col;
            driven[e.dst_port as usize] = true;
        }
        for (p, v) in imm.iter().enumerate() {
            if let Some(value) = v {
                cols[p] = alloc_imm(value.to_bits_i32(), &mut pool, &mut imm_pool)?;
                driven[p] = true;
            }
        }
        for p in 0..op.arity() {
            if !driven[p] {
                bail!("op N{id} port {p} undriven at schedule time");
            }
        }
        src[0][t] = cols[0] as i32;
        src[1][t] = cols[1] as i32;
        src[2][t] = cols[2] as i32;
    }

    // output port -> column of its driving slot (or the input column
    // when optimization reduced the output to a passthrough)
    let mut out_col = vec![0usize; dfg.num_outputs()];
    for node in &dfg.nodes {
        if let NodeKind::OutVar { port } = node.kind {
            let driver = dfg.preds(node.id)[0].src;
            out_col[port] = match &dfg.nodes[driver].kind {
                NodeKind::InVar { port: p } => *p,
                _ => geom.out_base() + slot_of[&driver],
            };
        }
    }

    Ok(SlotSchedule {
        ops,
        src_a: src[0].clone(),
        src_b: src[1].clone(),
        src_c: src[2].clone(),
        imm_pool,
        num_inputs: dfg.num_inputs(),
        out_col,
        geometry: geom,
    })
}

/// Assemble the physical overlay bitstream of a placed & routed kernel.
pub fn bitstream(
    fg: &FuGraph,
    spec: &OverlaySpec,
    g: &RoutingGraph,
    pl: &Placement,
    routes: &RouteResult,
    lat: &LatencyReport,
) -> OverlayBitstream {
    let mut bs = OverlayBitstream::empty(spec);

    for fu in &fg.fus {
        let (x, y) = pl.fu_tile[fu.id];
        let tile = &mut bs.tiles[y * spec.cols + x];
        tile.fu_mode = fu.ops.len() as u8;
        for (i, &op) in fu.ops.iter().enumerate().take(2) {
            if let NodeKind::Op { op, imm } = &fg.dfg.nodes[op].kind {
                tile.opcodes[i] = op.opcode() as u8;
                if tile.imm == 0 {
                    if let Some(v) = imm.iter().flatten().next() {
                        tile.imm = v.to_bits_i32();
                    }
                }
            }
        }
        // pack per-pin delay settings (2 pins per byte, 4 bits each)
        let mut pin_delays = [0u8; 4];
        for (k, entry) in fg.input_pins(fu.id).iter().enumerate().take(4) {
            // stored at half resolution (4 bits/pin keeps the 16-byte
            // tile word; authoritative values live in LatencyReport)
            let d = lat
                .delays
                .get(&(entry.op, entry.port))
                .copied()
                .unwrap_or(0);
            pin_delays[k] = ((d / 2).min(15)) as u8;
        }
        tile.delays = [
            (pin_delays[0] << 4) | pin_delays[1],
            (pin_delays[2] << 4) | pin_delays[3],
        ];
    }

    // switch-box words: count of used wires per tile side (a compact
    // stand-in for per-mux select bits; sizes are what §IV compares)
    for rn in &routes.nets {
        for node in rn.tree_nodes() {
            if let crate::overlay::RrgNode::Wire { x, y, side, track } = g.nodes[node] {
                let tile = &mut bs.tiles[y * spec.cols + x];
                tile.sb[side.index()] |= 1 << (track % 8);
            }
        }
    }

    // pad words: direction bit + stream id
    for (p, &slot) in pl.in_slot.iter().enumerate() {
        bs.pads[slot] = 0x80 | (p as u8 & 0x3F);
    }
    for (o, &slot) in pl.out_slot.iter().enumerate() {
        bs.pads[slot] = 0x40 | (o as u8 & 0x3F);
    }
    bs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::fuaware::to_fu_graph;
    use crate::ir::{lower_kernel, optimize};
    use crate::netlist::build_netlist;
    use crate::overlay::FuType;
    use crate::place::place;
    use crate::route::{bind_nets, route, RouterOptions};

    const CHEB: &str = "__kernel void chebyshev(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn cheb_dfg() -> Dfg {
        let f = lower_kernel(&parse_kernel(CHEB).unwrap()).unwrap();
        crate::dfg::extract_dfg(&optimize(&f).0).unwrap()
    }

    #[test]
    fn schedule_has_topological_sources() {
        let dfg = crate::fuaware::fuse_muladd(&cheb_dfg()).unwrap();
        let s = slot_schedule(&dfg, EmuGeometry::DEFAULT).unwrap();
        assert_eq!(s.n_slots(), 5);
        let out_base = s.geometry.out_base();
        for t in 0..s.n_slots() {
            for col in [s.src_a[t], s.src_b[t], s.src_c[t]] {
                let col = col as usize;
                if col >= out_base {
                    assert!(col - out_base < t, "slot {t} reads a later slot");
                }
            }
        }
    }

    #[test]
    fn immediates_are_pooled_and_deduped() {
        // chebyshev constants 16, 20, 5 -> three pool entries at the top
        let dfg = crate::fuaware::fuse_muladd(&cheb_dfg()).unwrap();
        let s = slot_schedule(&dfg, EmuGeometry::DEFAULT).unwrap();
        assert_eq!(s.imm_pool.len(), 3);
        let vals: Vec<i32> = s.imm_pool.iter().map(|&(_, v)| v).collect();
        assert!(vals.contains(&16) && vals.contains(&20) && vals.contains(&5));
        for &(col, _) in &s.imm_pool {
            assert!(col >= s.geometry.imm_base() + s.geometry.max_fus - 3);
        }
        // replicating 16x must still dedupe to 3 constants
        let rep = crate::replicate::replicate_dfg(&dfg, 16);
        let s16 = slot_schedule(&rep, EmuGeometry::DEFAULT).unwrap();
        assert_eq!(s16.imm_pool.len(), 3);
        assert_eq!(s16.n_slots(), 80);
    }

    #[test]
    fn out_cols_point_at_driver_slots() {
        let dfg = crate::fuaware::fuse_muladd(&cheb_dfg()).unwrap();
        let s = slot_schedule(&dfg, EmuGeometry::DEFAULT).unwrap();
        assert_eq!(s.out_col.len(), 1);
        let col = s.out_col[0];
        assert!(col >= s.geometry.out_base());
        assert!(col < s.geometry.out_base() + s.n_slots());
    }

    #[test]
    fn overflowing_slots_is_reported() {
        let dfg = crate::fuaware::fuse_muladd(&cheb_dfg()).unwrap();
        let rep = crate::replicate::replicate_dfg(&dfg, 26); // 130 ops > 128
        assert!(slot_schedule(&rep, EmuGeometry::DEFAULT).is_err());
    }

    #[test]
    fn bitstream_of_routed_kernel_has_configured_tiles() {
        let dfg = cheb_dfg();
        let fg = to_fu_graph(&dfg, 2).unwrap();
        let nl = build_netlist(&fg);
        let spec = OverlaySpec::new(5, 5, FuType::Dsp2);
        let g = RoutingGraph::build(&spec);
        let pl = place(&nl, &spec, &g, 3).unwrap();
        let bound = bind_nets(&fg, &nl, &pl, &g).unwrap();
        let routes = route(&g, &bound.route_nets, &RouterOptions::default()).unwrap();
        let lat = crate::latency::balance(&fg, &spec, &g, &bound, &routes).unwrap();
        let bs = bitstream(&fg, &spec, &g, &pl, &routes, &lat);

        let configured = bs.tiles.iter().filter(|t| t.fu_mode > 0).count();
        assert_eq!(configured, 3);
        // at least one tile must carry a routed-wire SB word
        assert!(bs.tiles.iter().any(|t| t.sb.iter().any(|&b| b != 0)));
        // pads: 1 input + 1 output marked
        let ins = bs.pads.iter().filter(|&&p| p & 0x80 != 0).count();
        let outs = bs.pads.iter().filter(|&&p| p & 0x40 != 0).count();
        assert_eq!((ins, outs), (1, 1));
        // serialization round-trips
        let bytes = bs.to_bytes();
        assert_eq!(OverlayBitstream::from_bytes(&bytes).unwrap(), bs);
    }

    #[test]
    fn nop_only_kernel_schedules() {
        let f = lower_kernel(
            &parse_kernel(
                "__kernel void c(__global int *B) {
                    int i = get_global_id(0);
                    B[i] = 7;
                 }",
            )
            .unwrap(),
        )
        .unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        let s = slot_schedule(&dfg, EmuGeometry::DEFAULT).unwrap();
        assert_eq!(s.n_slots(), 1);
        assert_eq!(s.ops[0], 0); // NOP
        assert_eq!(s.imm_pool.len(), 1);
        assert_eq!(s.imm_pool[0].1, 7);
    }
}
