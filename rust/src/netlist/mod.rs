//! VPR-style FU netlist interchange (paper §III-C: "VPR compatible FU
//! netlist generation").
//!
//! A textual block/net format in the spirit of the classic VPR `.net`
//! dialect, with FU blocks instead of CLBs:
//!
//! ```text
//! # netlist example_kernel
//! .input I0
//! pinlist: n_I0
//!
//! .fu FU0 ops=mul,mul_sub
//! pinlist: n_I0 n_I0 open open n_FU0
//!
//! .output O0
//! pinlist: n_FU2
//! ```
//!
//! Each `.fu` pinlist carries `MAX_FU_INPUTS` input nets (or `open`)
//! followed by the output net. [`emit_netlist`] / [`parse_netlist`]
//! round-trip; the placer consumes the in-memory [`FuNetlist`].

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::dfg::{DfgOp, NodeKind};
use crate::fuaware::{FuGraph, NetEndpoint, MAX_FU_INPUTS};

/// A placeable block.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub name: String,
    pub kind: BlockKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum BlockKind {
    /// FU with its op names (1 or 2).
    Fu { ops: Vec<String> },
    InPad,
    OutPad,
}

/// One net: a driving block and its sink pins.
#[derive(Debug, Clone, PartialEq)]
pub struct NetDecl {
    pub name: String,
    pub src: NetEndpoint,
    pub sinks: Vec<(NetEndpoint, u8)>,
}

/// The netlist handed to placement/routing, plus its interchange form.
#[derive(Debug, Clone, PartialEq)]
pub struct FuNetlist {
    pub name: String,
    pub blocks: Vec<Block>,
    pub nets: Vec<NetDecl>,
    pub num_fus: usize,
    pub num_inputs: usize,
    pub num_outputs: usize,
}

/// Build the netlist of a (possibly replicated) FU graph.
pub fn build_netlist(fg: &FuGraph) -> FuNetlist {
    let mut blocks = Vec::new();
    for (i, _) in fg.dfg.input_names.iter().enumerate() {
        blocks.push(Block { name: format!("I{i}"), kind: BlockKind::InPad });
    }
    for fu in &fg.fus {
        let ops = fu
            .ops
            .iter()
            .map(|&op| match &fg.dfg.nodes[op].kind {
                NodeKind::Op { op, .. } => op.name().to_string(),
                _ => unreachable!("FU contains a non-op node"),
            })
            .collect();
        blocks.push(Block { name: format!("FU{}", fu.id), kind: BlockKind::Fu { ops } });
    }
    for (o, _) in fg.dfg.output_names.iter().enumerate() {
        blocks.push(Block { name: format!("O{o}"), kind: BlockKind::OutPad });
    }

    let nets = fg
        .nets()
        .into_iter()
        .map(|n| {
            let name = match n.src {
                NetEndpoint::InPad(p) => format!("n_I{p}"),
                NetEndpoint::Fu(f) => format!("n_FU{f}"),
                NetEndpoint::OutPad(_) => unreachable!("net driven by output pad"),
            };
            NetDecl { name, src: n.src, sinks: n.sinks }
        })
        .collect();

    FuNetlist {
        name: fg.dfg.name.clone(),
        blocks,
        nets,
        num_fus: fg.num_fus(),
        num_inputs: fg.dfg.num_inputs(),
        num_outputs: fg.dfg.num_outputs(),
    }
}

impl FuNetlist {
    /// Net index driven by each endpoint (placer helper).
    pub fn nets_by_src(&self) -> HashMap<NetEndpoint, usize> {
        self.nets.iter().enumerate().map(|(i, n)| (n.src, i)).collect()
    }
}

/// Render the interchange text.
pub fn emit_netlist(nl: &FuNetlist) -> String {
    let mut out = String::new();
    out.push_str(&format!("# netlist {}\n", nl.name));

    // input-pin nets of every FU, precomputed: fu -> [net name per pin]
    let mut fu_pins: Vec<Vec<String>> = vec![vec!["open".into(); MAX_FU_INPUTS]; nl.num_fus];
    let mut out_net: Vec<String> = vec!["open".into(); nl.num_outputs];
    for net in &nl.nets {
        for (sink, _port) in &net.sinks {
            match sink {
                NetEndpoint::Fu(f) => {
                    if let Some(slot) = fu_pins[*f].iter_mut().find(|p| *p == "open") {
                        *slot = net.name.clone();
                    }
                }
                NetEndpoint::OutPad(o) => out_net[*o] = net.name.clone(),
                NetEndpoint::InPad(_) => {}
            }
        }
    }

    for b in &nl.blocks {
        match &b.kind {
            BlockKind::InPad => {
                out.push_str(&format!("\n.input {}\npinlist: n_{}\n", b.name, b.name));
            }
            BlockKind::Fu { ops } => {
                let id: usize = b.name[2..].parse().unwrap();
                out.push_str(&format!(
                    "\n.fu {} ops={}\npinlist: {} n_{}\n",
                    b.name,
                    ops.join(","),
                    fu_pins[id].join(" "),
                    b.name
                ));
            }
            BlockKind::OutPad => {
                let id: usize = b.name[1..].parse().unwrap();
                out.push_str(&format!("\n.output {}\npinlist: {}\n", b.name, out_net[id]));
            }
        }
    }
    out
}

/// Parse text produced by [`emit_netlist`]. Reconstructs blocks and
/// nets (sink pin order follows pinlist position).
pub fn parse_netlist(text: &str) -> Result<FuNetlist> {
    let mut name = String::from("netlist");
    let mut blocks = Vec::new();
    // net name -> (src endpoint, sinks)
    let mut nets: HashMap<String, (Option<NetEndpoint>, Vec<(NetEndpoint, u8)>)> =
        HashMap::new();
    let mut num_fus = 0;
    let mut num_inputs = 0;
    let mut num_outputs = 0;

    let mut lines = text.lines().peekable();
    while let Some(line) = lines.next() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# netlist ") {
            name = rest.trim().to_string();
            continue;
        }
        if let Some(rest) = line.strip_prefix(".input ") {
            let bname = rest.trim().to_string();
            let pl = pinlist(lines.next())?;
            if pl.len() != 1 {
                bail!("input {bname}: expected 1 pin");
            }
            let port: usize = bname
                .strip_prefix('I')
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow!("bad input name {bname}"))?;
            nets.entry(pl[0].clone()).or_default().0 = Some(NetEndpoint::InPad(port));
            blocks.push(Block { name: bname, kind: BlockKind::InPad });
            num_inputs += 1;
        } else if let Some(rest) = line.strip_prefix(".output ") {
            let bname = rest.trim().to_string();
            let pl = pinlist(lines.next())?;
            let port: usize = bname
                .strip_prefix('O')
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow!("bad output name {bname}"))?;
            nets.entry(pl[0].clone())
                .or_default()
                .1
                .push((NetEndpoint::OutPad(port), 0));
            blocks.push(Block { name: bname, kind: BlockKind::OutPad });
            num_outputs += 1;
        } else if let Some(rest) = line.strip_prefix(".fu ") {
            let mut parts = rest.split_whitespace();
            let bname = parts.next().ok_or_else(|| anyhow!("missing fu name"))?.to_string();
            let ops: Vec<String> = parts
                .next()
                .and_then(|s| s.strip_prefix("ops="))
                .map(|s| s.split(',').map(String::from).collect())
                .unwrap_or_default();
            let id: usize = bname
                .strip_prefix("FU")
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow!("bad fu name {bname}"))?;
            let pl = pinlist(lines.next())?;
            if pl.len() != MAX_FU_INPUTS + 1 {
                bail!("fu {bname}: expected {} pins", MAX_FU_INPUTS + 1);
            }
            for (pin, netname) in pl[..MAX_FU_INPUTS].iter().enumerate() {
                if netname != "open" {
                    nets.entry(netname.clone())
                        .or_default()
                        .1
                        .push((NetEndpoint::Fu(id), pin as u8));
                }
            }
            nets.entry(pl[MAX_FU_INPUTS].clone()).or_default().0 = Some(NetEndpoint::Fu(id));
            blocks.push(Block { name: bname, kind: BlockKind::Fu { ops } });
            num_fus += 1;
        } else {
            bail!("unparseable netlist line: '{line}'");
        }
    }

    let mut net_list: Vec<NetDecl> = Vec::new();
    for (nname, (src, sinks)) in nets {
        let src = src.ok_or_else(|| anyhow!("net {nname} has no driver"))?;
        if sinks.is_empty() {
            continue; // an FU output net with no consumer (trailing op)
        }
        net_list.push(NetDecl { name: nname, src, sinks });
    }
    net_list.sort_by(|a, b| a.name.cmp(&b.name));

    Ok(FuNetlist { name, blocks, nets: net_list, num_fus, num_inputs, num_outputs })
}

fn pinlist(line: Option<&str>) -> Result<Vec<String>> {
    let line = line.ok_or_else(|| anyhow!("missing pinlist"))?.trim();
    let rest = line
        .strip_prefix("pinlist:")
        .ok_or_else(|| anyhow!("expected 'pinlist:', got '{line}'"))?;
    Ok(rest.split_whitespace().map(String::from).collect())
}

/// Human-readable op name table (paper Table II node labels → ops).
pub fn op_display(op: DfgOp) -> &'static str {
    op.name()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::fuaware::to_fu_graph;
    use crate::ir::{lower_kernel, optimize};

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn paper_netlist(dsps: usize) -> FuNetlist {
        let f = lower_kernel(&parse_kernel(PAPER).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        build_netlist(&to_fu_graph(&dfg, dsps).unwrap())
    }

    #[test]
    fn paper_netlist_block_counts() {
        let nl = paper_netlist(2);
        assert_eq!(nl.num_fus, 3);
        assert_eq!(nl.num_inputs, 1);
        assert_eq!(nl.num_outputs, 1);
        assert_eq!(nl.blocks.len(), 5);
    }

    #[test]
    fn netlist_nets_have_drivers_and_sinks() {
        let nl = paper_netlist(1);
        assert!(!nl.nets.is_empty());
        for n in &nl.nets {
            assert!(!n.sinks.is_empty(), "net {} has no sinks", n.name);
        }
        let in_net = nl
            .nets
            .iter()
            .find(|n| matches!(n.src, NetEndpoint::InPad(0)))
            .unwrap();
        assert!(in_net.sinks.len() >= 4);
    }

    #[test]
    fn emit_contains_vpr_sections() {
        let text = emit_netlist(&paper_netlist(2));
        assert!(text.contains(".input I0"));
        assert!(text.contains(".output O0"));
        assert!(text.contains(".fu FU0"));
        assert!(text.contains("pinlist:"));
        assert!(text.contains("ops="));
    }

    #[test]
    fn netlist_round_trips_block_and_net_counts() {
        let nl = paper_netlist(2);
        let parsed = parse_netlist(&emit_netlist(&nl)).unwrap();
        assert_eq!(parsed.num_fus, nl.num_fus);
        assert_eq!(parsed.num_inputs, nl.num_inputs);
        assert_eq!(parsed.num_outputs, nl.num_outputs);
        assert_eq!(parsed.nets.len(), nl.nets.len());
        let pins = |n: &FuNetlist| n.nets.iter().map(|x| x.sinks.len()).sum::<usize>();
        assert_eq!(pins(&parsed), pins(&nl));
    }

    #[test]
    fn parse_rejects_driverless_net() {
        let text = ".output O0\npinlist: n_phantom\n";
        assert!(parse_netlist(text).is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_netlist("hello world").is_err());
    }
}
