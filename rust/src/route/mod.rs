//! PathFinder negotiated-congestion routing — the VPR router stand-in
//! (§III-D: "DFG edges [map] to the overlay routing paths").
//!
//! Classic formulation (McMurchie & Ebeling): every routing-resource
//! node carries a *present* congestion penalty (applies while a node is
//! over capacity this iteration) and a *history* penalty (accumulates
//! across iterations). All nets are ripped up and re-routed each
//! iteration with node cost
//!
//! ```text
//! cost(n) = (1 + hist(n)) · (1 + pres_fac · overuse(n))
//! ```
//!
//! until no node is shared. Multi-terminal nets are routed as Steiner
//! trees: each sink is reached by a Dijkstra wavefront seeded with the
//! entire tree routed so far (zero cost), so branches reuse wires.
//!
//! The inner Dijkstra uses version-stamped distance arrays (no
//! per-net clearing) and an A* lower bound of the remaining Manhattan
//! distance — the §Perf hot path of the whole JIT flow.

mod bind;

pub use bind::{bind_nets, BoundNets, NetBinding, SinkKey};

use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::overlay::{RoutingGraph, RrgNodeId};

/// A net to route: one source node, one or more sink nodes.
#[derive(Debug, Clone)]
pub struct RouteNet {
    pub source: RrgNodeId,
    pub sinks: Vec<RrgNodeId>,
}

/// The routed form of one net.
#[derive(Debug, Clone, Default)]
pub struct RoutedNet {
    /// Per sink (same order as the request): the node path
    /// `source → … → sink`, inclusive.
    pub paths: Vec<Vec<RrgNodeId>>,
}

impl RoutedNet {
    /// All distinct nodes of the net's routing tree.
    pub fn tree_nodes(&self) -> Vec<RrgNodeId> {
        let mut v: Vec<RrgNodeId> = self.paths.iter().flatten().copied().collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Registered-hop count (pipeline latency) to sink `i`.
    pub fn regs_to_sink(&self, g: &RoutingGraph, i: usize) -> u32 {
        self.paths[i].iter().filter(|&&n| g.is_registered(n)).count() as u32
    }
}

/// Result of routing a whole netlist.
#[derive(Debug, Clone)]
pub struct RouteResult {
    pub nets: Vec<RoutedNet>,
    /// PathFinder iterations until legal.
    pub iterations: usize,
    /// Total wire segments used (resource metric).
    pub wire_count: usize,
}

/// Router tuning knobs (defaults follow VPR's timing-driven router).
#[derive(Debug, Clone)]
pub struct RouterOptions {
    pub max_iterations: usize,
    pub first_pres_fac: f64,
    pub pres_fac_mult: f64,
    pub hist_fac: f64,
    /// A* admissible-heuristic weight (0 disables A*).
    pub astar_fac: f64,
}

impl Default for RouterOptions {
    fn default() -> Self {
        RouterOptions {
            max_iterations: 60,
            first_pres_fac: 0.6,
            pres_fac_mult: 1.8,
            hist_fac: 1.0,
            astar_fac: 1.0,
        }
    }
}

/// Route all `nets` on `g`. Fails if congestion cannot be resolved in
/// `max_iterations`.
pub fn route(g: &RoutingGraph, nets: &[RouteNet], opts: &RouterOptions) -> Result<RouteResult> {
    let n_nodes = g.num_nodes();
    let mut occ = vec![0u16; n_nodes];
    let mut hist = vec![0.0f64; n_nodes];
    let mut routed: Vec<RoutedNet> = vec![RoutedNet::default(); nets.len()];
    let mut pres_fac = opts.first_pres_fac;

    // version-stamped Dijkstra state (allocated once)
    let mut dist = vec![f64::INFINITY; n_nodes];
    let mut prev = vec![u32::MAX; n_nodes];
    let mut stamp = vec![0u32; n_nodes];
    let mut cur_stamp = 0u32;

    for iter in 1..=opts.max_iterations {
        for (ni, net) in nets.iter().enumerate() {
            // rip up this net
            for &node in &routed[ni].tree_nodes() {
                occ[node] = occ[node].saturating_sub(1);
            }
            routed[ni] = route_one(
                g,
                net,
                &occ,
                &hist,
                pres_fac,
                opts.astar_fac,
                &mut dist,
                &mut prev,
                &mut stamp,
                &mut cur_stamp,
            )?;
            for &node in &routed[ni].tree_nodes() {
                occ[node] += 1;
            }
        }

        // congestion check
        let mut overused = 0usize;
        for n in 0..n_nodes {
            if occ[n] > 1 {
                overused += 1;
                hist[n] += opts.hist_fac * (occ[n] - 1) as f64;
            }
        }
        if overused == 0 {
            let wire_count = routed
                .iter()
                .flat_map(|r| r.tree_nodes())
                .filter(|&n| g.is_registered(n))
                .count();
            return Ok(RouteResult { nets: routed, iterations: iter, wire_count });
        }
        pres_fac *= opts.pres_fac_mult;
    }
    bail!(
        "unroutable: congestion unresolved after {} PathFinder iterations \
         (channel width {} too small for this netlist)",
        opts.max_iterations,
        g.spec.channel_width
    )
}

/// Ordered float for the heap.
#[derive(PartialEq)]
struct HeapEntry {
    cost: f64,
    node: u32,
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap via reversed comparison
        other.cost.partial_cmp(&self.cost).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[allow(clippy::too_many_arguments)]
fn route_one(
    g: &RoutingGraph,
    net: &RouteNet,
    occ: &[u16],
    hist: &[f64],
    pres_fac: f64,
    astar_fac: f64,
    dist: &mut [f64],
    prev: &mut [u32],
    stamp: &mut [u32],
    cur_stamp: &mut u32,
) -> Result<RoutedNet> {
    let node_cost = |n: usize| -> f64 {
        let over = occ[n] as f64; // entering n adds 1; penalize if already used
        (1.0 + hist[n]) * (1.0 + pres_fac * over)
    };

    // route sinks nearest-first (cheaper trees, better reuse)
    let src_tile = g.tile_of(net.source);
    let mut order: Vec<usize> = (0..net.sinks.len()).collect();
    order.sort_by_key(|&i| RoutingGraph::tile_dist(src_tile, g.tile_of(net.sinks[i])));

    let mut tree: Vec<RrgNodeId> = vec![net.source];
    let mut paths: Vec<Vec<RrgNodeId>> = vec![Vec::new(); net.sinks.len()];

    for &si in &order {
        let sink = net.sinks[si];
        let sink_tile = g.tile_of(sink);
        *cur_stamp += 1;
        let st = *cur_stamp;
        let mut heap = BinaryHeap::new();
        for &t in &tree {
            dist[t] = 0.0;
            prev[t] = u32::MAX;
            stamp[t] = st;
            let h = astar_fac * RoutingGraph::tile_dist(g.tile_of(t), sink_tile) as f64;
            heap.push(HeapEntry { cost: h, node: t as u32 });
        }
        let mut found = false;
        while let Some(HeapEntry { cost: _, node }) = heap.pop() {
            let u = node as usize;
            if u == sink {
                found = true;
                break;
            }
            let du = dist[u];
            for &v in &g.edges[u] {
                // terminal resources (FU pins, output pads) are leaves:
                // only the net's own sink may be entered
                if v != sink && is_terminal(g, v) {
                    continue;
                }
                let nd = du + node_cost(v);
                if stamp[v] != st || nd < dist[v] {
                    stamp[v] = st;
                    dist[v] = nd;
                    prev[v] = u as u32;
                    let h = astar_fac
                        * RoutingGraph::tile_dist(g.tile_of(v), sink_tile) as f64;
                    heap.push(HeapEntry { cost: nd + h, node: v as u32 });
                }
            }
        }
        if !found {
            bail!("no path from source to sink (disconnected RRG?)");
        }
        // backtrack
        let mut path = vec![sink];
        let mut cur = sink;
        while prev[cur] != u32::MAX {
            cur = prev[cur] as usize;
            path.push(cur);
        }
        path.reverse();
        // extend the tree with the new segment (path[0] is on the tree)
        for &n in &path {
            if !tree.contains(&n) {
                tree.push(n);
            }
        }
        // full path from the net source: path starts at some tree node;
        // for latency we need the source→sink route. Since every tree
        // node's own path from the source is known (it lies on a
        // previously recorded path), splice it.
        let join = path[0];
        if join == net.source {
            paths[si] = path;
        } else {
            // find a recorded path containing `join`
            let mut prefix: Option<Vec<RrgNodeId>> = None;
            for p in paths.iter() {
                if let Some(pos) = p.iter().position(|&n| n == join) {
                    prefix = Some(p[..=pos].to_vec());
                    break;
                }
            }
            let mut full =
                prefix.ok_or_else(|| anyhow::anyhow!("tree join node not on any path"))?;
            full.extend_from_slice(&path[1..]);
            paths[si] = full;
        }
    }

    Ok(RoutedNet { paths })
}

/// Is `v` a routing terminal (sink-type node)?
fn is_terminal(g: &RoutingGraph, v: RrgNodeId) -> bool {
    use crate::overlay::RrgNode::*;
    matches!(g.nodes[v], FuIn { .. } | PadIn { .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overlay::{FuType, OverlaySpec, RrgNode};

    fn rrg(n: usize, w: usize) -> RoutingGraph {
        let mut spec = OverlaySpec::new(n, n, FuType::Dsp2);
        spec.channel_width = w;
        RoutingGraph::build(&spec)
    }

    #[test]
    fn routes_single_net_across_grid() {
        let g = rrg(4, 2);
        let net = RouteNet {
            source: g.fu_out(0, 0),
            sinks: vec![g.fu_in(3, 3, 0)],
        };
        let r = route(&g, &[net], &RouterOptions::default()).unwrap();
        assert_eq!(r.iterations, 1);
        let path = &r.nets[0].paths[0];
        assert_eq!(path[0], g.fu_out(0, 0));
        assert_eq!(*path.last().unwrap(), g.fu_in(3, 3, 0));
        // at least manhattan-distance wires
        assert!(r.nets[0].regs_to_sink(&g, 0) >= 6);
        // consecutive nodes are actually connected in the RRG
        for w in path.windows(2) {
            assert!(g.edges[w[0]].contains(&w[1]), "broken path edge");
        }
    }

    #[test]
    fn multi_sink_net_builds_a_tree() {
        let g = rrg(4, 2);
        let net = RouteNet {
            source: g.pad_out(0),
            sinks: vec![g.fu_in(1, 1, 0), g.fu_in(2, 2, 1), g.fu_in(3, 0, 2)],
        };
        let r = route(&g, &[net], &RouterOptions::default()).unwrap();
        let rn = &r.nets[0];
        assert_eq!(rn.paths.len(), 3);
        for (i, sink) in [g.fu_in(1, 1, 0), g.fu_in(2, 2, 1), g.fu_in(3, 0, 2)]
            .iter()
            .enumerate()
        {
            assert_eq!(rn.paths[i].last(), Some(sink));
            assert_eq!(rn.paths[i][0], g.pad_out(0));
            for w in rn.paths[i].windows(2) {
                assert!(g.edges[w[0]].contains(&w[1]), "broken path edge");
            }
        }
        // tree reuse: total tree nodes < sum of path lengths
        let total: usize = rn.paths.iter().map(|p| p.len()).sum();
        assert!(rn.tree_nodes().len() < total);
    }

    #[test]
    fn congestion_is_negotiated() {
        // W=1: two nets from adjacent sources to adjacent sinks across
        // the grid must not share any wire; PathFinder needs >1 iter or
        // disjoint paths.
        let g = rrg(3, 1);
        let nets = vec![
            RouteNet { source: g.fu_out(0, 0), sinks: vec![g.fu_in(2, 0, 0)] },
            RouteNet { source: g.fu_out(0, 1), sinks: vec![g.fu_in(2, 1, 0)] },
            RouteNet { source: g.fu_out(0, 2), sinks: vec![g.fu_in(2, 2, 0)] },
        ];
        let r = route(&g, &nets, &RouterOptions::default()).unwrap();
        // no wire shared between different nets
        let mut used = std::collections::HashMap::new();
        for (ni, rn) in r.nets.iter().enumerate() {
            for n in rn.tree_nodes() {
                if matches!(g.nodes[n], RrgNode::Wire { .. }) {
                    if let Some(prev) = used.insert(n, ni) {
                        panic!("wire shared by nets {prev} and {ni}");
                    }
                }
            }
        }
    }

    #[test]
    fn reports_unroutable_when_overconstrained() {
        // W=1 grid, force 5 nets into the same column of wires
        let g = rrg(2, 1);
        let mut nets = Vec::new();
        for pin in 0..4 {
            nets.push(RouteNet {
                source: g.fu_out(0, 0),
                sinks: vec![g.fu_in(1, 1, pin)],
            });
        }
        // 4 nets from the SAME source is legal (shared fanout would be
        // one net); as distinct nets they fight for the source's wires.
        let opts = RouterOptions { max_iterations: 8, ..Default::default() };
        let r = route(&g, &nets, &opts);
        assert!(r.is_err());
    }

    #[test]
    fn router_is_deterministic() {
        let g = rrg(4, 2);
        let nets = vec![
            RouteNet { source: g.fu_out(0, 0), sinks: vec![g.fu_in(3, 3, 0)] },
            RouteNet { source: g.fu_out(3, 0), sinks: vec![g.fu_in(0, 3, 1)] },
        ];
        let a = route(&g, &nets, &RouterOptions::default()).unwrap();
        let b = route(&g, &nets, &RouterOptions::default()).unwrap();
        for (x, y) in a.nets.iter().zip(b.nets.iter()) {
            assert_eq!(x.paths, y.paths);
        }
    }

    #[test]
    fn terminal_pins_are_not_thoroughfares() {
        // route two nets; neither may pass through the other's FU pin
        let g = rrg(3, 2);
        let nets = vec![
            RouteNet { source: g.fu_out(0, 0), sinks: vec![g.fu_in(1, 1, 0)] },
            RouteNet { source: g.fu_out(2, 2), sinks: vec![g.fu_in(1, 1, 1)] },
        ];
        let r = route(&g, &nets, &RouterOptions::default()).unwrap();
        for rn in &r.nets {
            for p in &rn.paths {
                let terminals = p
                    .iter()
                    .filter(|&&n| is_terminal(&g, n))
                    .count();
                assert_eq!(terminals, 1, "path passes through a terminal");
            }
        }
    }

    #[test]
    fn astar_disabled_still_routes() {
        let g = rrg(4, 2);
        let net = RouteNet { source: g.fu_out(0, 0), sinks: vec![g.fu_in(3, 3, 0)] };
        let opts = RouterOptions { astar_fac: 0.0, ..Default::default() };
        let r = route(&g, &[net], &opts).unwrap();
        assert_eq!(r.nets[0].paths[0][0], g.fu_out(0, 0));
    }
}
