//! Bind logical nets to physical RRG endpoints for a given placement.
//!
//! Converts each [`crate::netlist::NetDecl`] into a [`RouteNet`] whose
//! source/sink node ids reflect the placement, and records which DFG
//! (op, port) every sink pin corresponds to — the correspondence the
//! latency-balancing pass needs to annotate delay chains.
//!
//! FU input *pins* are assigned deterministically from
//! [`crate::fuaware::FuGraph::input_pins`]: the k-th external edge of an
//! FU occupies physical pin k.

use anyhow::{bail, Result};

use crate::fuaware::{FuGraph, NetEndpoint};
use crate::netlist::FuNetlist;
use crate::overlay::{RoutingGraph, RrgNodeId};
use crate::place::Placement;

use super::RouteNet;

/// What a routed sink terminal corresponds to in the kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SinkKey {
    /// FU input pin feeding operand `port` of DFG op `op`.
    FuPin { fu: usize, pin: u8, op: crate::dfg::NodeId, port: u8 },
    /// Kernel output stream.
    OutPad(usize),
}

/// One net's binding metadata (parallel to its [`RouteNet`] sinks).
#[derive(Debug, Clone)]
pub struct NetBinding {
    /// Index into `FuNetlist::nets`.
    pub decl_index: usize,
    pub src: NetEndpoint,
    pub sink_keys: Vec<SinkKey>,
}

/// The physical routing problem plus its kernel-level annotations.
#[derive(Debug, Clone)]
pub struct BoundNets {
    pub route_nets: Vec<RouteNet>,
    pub bindings: Vec<NetBinding>,
}

/// Build the physical nets for `nl` under placement `pl`.
pub fn bind_nets(
    fg: &FuGraph,
    nl: &FuNetlist,
    pl: &Placement,
    g: &RoutingGraph,
) -> Result<BoundNets> {
    // per-FU pin tables (pin index = position in input_pins)
    let pin_tables: Vec<_> = (0..fg.num_fus()).map(|f| fg.input_pins(f)).collect();
    for (f, pins) in pin_tables.iter().enumerate() {
        if pins.len() > crate::fuaware::MAX_FU_INPUTS {
            bail!("FU{} needs {} input pins (max {})", f, pins.len(),
                  crate::fuaware::MAX_FU_INPUTS);
        }
    }
    // how many pins of (fu) with src==S have been consumed per net build
    let mut route_nets = Vec::with_capacity(nl.nets.len());
    let mut bindings = Vec::with_capacity(nl.nets.len());

    for (di, decl) in nl.nets.iter().enumerate() {
        let source: RrgNodeId = match decl.src {
            NetEndpoint::Fu(f) => {
                let (x, y) = pl.fu_tile[f];
                g.fu_out(x, y)
            }
            NetEndpoint::InPad(p) => g.pad_out(pl.in_slot[p]),
            NetEndpoint::OutPad(_) => bail!("net driven by an output pad"),
        };

        let mut sinks = Vec::with_capacity(decl.sinks.len());
        let mut keys = Vec::with_capacity(decl.sinks.len());
        // per-FU cursor over matching pin entries for THIS net
        let mut cursors: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        for (ep, _port) in &decl.sinks {
            match ep {
                NetEndpoint::Fu(f) => {
                    let pins = &pin_tables[*f];
                    let cur = cursors.entry(*f).or_insert(0);
                    // next pin of f whose source is this net's driver
                    let mut found = None;
                    for (pin, entry) in pins.iter().enumerate().skip(*cur) {
                        if entry.src == decl.src {
                            found = Some((pin, entry));
                            *cur = pin + 1;
                            break;
                        }
                    }
                    let Some((pin, entry)) = found else {
                        bail!("no free pin on FU{} for net {}", f, decl.name);
                    };
                    let (x, y) = pl.fu_tile[*f];
                    sinks.push(g.fu_in(x, y, pin));
                    keys.push(SinkKey::FuPin {
                        fu: *f,
                        pin: pin as u8,
                        op: entry.op,
                        port: entry.port,
                    });
                }
                NetEndpoint::OutPad(o) => {
                    sinks.push(g.pad_in(pl.out_slot[*o]));
                    keys.push(SinkKey::OutPad(*o));
                }
                NetEndpoint::InPad(_) => bail!("net sinks at an input pad"),
            }
        }
        route_nets.push(RouteNet { source, sinks });
        bindings.push(NetBinding { decl_index: di, src: decl.src, sink_keys: keys });
    }
    Ok(BoundNets { route_nets, bindings })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::fuaware::to_fu_graph;
    use crate::ir::{lower_kernel, optimize};
    use crate::netlist::build_netlist;
    use crate::overlay::{FuType, OverlaySpec};
    use crate::place::place;

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn setup(dsps: usize) -> (FuGraph, FuNetlist, OverlaySpec, RoutingGraph, Placement) {
        let f = lower_kernel(&parse_kernel(PAPER).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        let fg = to_fu_graph(&dfg, dsps).unwrap();
        let nl = build_netlist(&fg);
        let spec = OverlaySpec::new(5, 5, if dsps == 2 { FuType::Dsp2 } else { FuType::Dsp1 });
        let g = RoutingGraph::build(&spec);
        let pl = place(&nl, &spec, &g, 7).unwrap();
        (fg, nl, spec, g, pl)
    }

    #[test]
    fn every_sink_is_bound_to_a_distinct_terminal() {
        let (fg, nl, _spec, g, pl) = setup(2);
        let bound = bind_nets(&fg, &nl, &pl, &g).unwrap();
        let mut all_sinks = Vec::new();
        for rn in &bound.route_nets {
            all_sinks.extend(rn.sinks.iter().copied());
        }
        let n = all_sinks.len();
        all_sinks.sort_unstable();
        all_sinks.dedup();
        assert_eq!(all_sinks.len(), n, "two nets share a physical terminal");
    }

    #[test]
    fn bindings_parallel_route_nets() {
        let (fg, nl, _spec, g, pl) = setup(1);
        let bound = bind_nets(&fg, &nl, &pl, &g).unwrap();
        assert_eq!(bound.route_nets.len(), bound.bindings.len());
        for (rn, b) in bound.route_nets.iter().zip(&bound.bindings) {
            assert_eq!(rn.sinks.len(), b.sink_keys.len());
        }
        // exactly one OutPad sink overall (single-output kernel)
        let outs = bound
            .bindings
            .iter()
            .flat_map(|b| &b.sink_keys)
            .filter(|k| matches!(k, SinkKey::OutPad(_)))
            .count();
        assert_eq!(outs, 1);
    }

    #[test]
    fn pins_respect_input_pin_tables() {
        let (fg, nl, _spec, g, pl) = setup(2);
        let bound = bind_nets(&fg, &nl, &pl, &g).unwrap();
        for b in &bound.bindings {
            for k in &b.sink_keys {
                if let SinkKey::FuPin { fu, pin, op, port } = k {
                    let table = fg.input_pins(*fu);
                    let entry = table[*pin as usize];
                    assert_eq!(entry.op, *op);
                    assert_eq!(entry.port, *port);
                    assert_eq!(entry.src, b.src);
                }
            }
        }
    }
}
