//! Deterministic fault injection for the dispatch plane.
//!
//! A [`FaultPlan`] decides, purely as a function of `(seed, dispatch
//! sequence number, fault kind, salt)`, whether a given event is struck
//! by a fault. Because the decision is a pure hash — not a shared
//! mutable RNG — every worker thread sees the same verdict for the same
//! dispatch regardless of interleaving, which is what makes fault runs
//! replayable from a seed alone.
//!
//! Two trigger modes compose:
//!
//! * **Scripted** entries `(seq, kind)` fire exactly once at a known
//!   dispatch sequence number — tests and the `e2e_serve -- overload`
//!   harness use these to guarantee at least one of each fault kind.
//! * **Rate-based** injection draws a per-event uniform from a
//!   [`XorShiftRng`](crate::util::rng::XorShiftRng) seeded by the mixed
//!   key, firing with the configured probability.
//!
//! Faults strike only a job's *first* attempt (`attempt == 0`), so
//! bounded retry-with-backoff is guaranteed to converge: the recovery
//! path never chases a fault that re-fires forever.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::XorShiftRng;

/// The failure modes the plan can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// The worker serving a batch dies mid-batch; every in-flight job of
    /// the run must be requeued onto a sibling partition.
    WorkerKill,
    /// A partition reconfiguration fails; the scheduler must re-place
    /// the dispatch on a sibling and strike the failing partition.
    ReconfigFail,
    /// The dispatch's sim-verify comes back corrupted; the job must be
    /// re-executed rather than served with a bad verdict.
    VerifyCorrupt,
    /// The JIT compile of a kernel on a shard fails transiently; the
    /// router poisons the `(kernel, spec)` pair and must later re-probe.
    CompileFail,
}

impl FaultKind {
    /// Stable name for logs and stats.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::WorkerKill => "worker_kill",
            FaultKind::ReconfigFail => "reconfig_fail",
            FaultKind::VerifyCorrupt => "verify_corrupt",
            FaultKind::CompileFail => "compile_fail",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultKind::WorkerKill => 0,
            FaultKind::ReconfigFail => 1,
            FaultKind::VerifyCorrupt => 2,
            FaultKind::CompileFail => 3,
        }
    }
}

/// All four kinds, for matrix-style iteration in tests.
pub const ALL_FAULT_KINDS: [FaultKind; 4] = [
    FaultKind::WorkerKill,
    FaultKind::ReconfigFail,
    FaultKind::VerifyCorrupt,
    FaultKind::CompileFail,
];

/// Declarative description of a fault campaign.
#[derive(Debug, Clone, Default)]
pub struct FaultPlanConfig {
    /// Seed for the per-event hash; the whole campaign replays from it.
    pub seed: u64,
    /// Probability per served run that the worker dies mid-batch.
    pub worker_kill_rate: f64,
    /// Probability per reconfiguring pick that the reconfiguration fails.
    pub reconfig_fail_rate: f64,
    /// Probability per dispatched job that its sim-verify is corrupted.
    pub verify_corrupt_rate: f64,
    /// Probability per first-time compile that the JIT fails.
    pub compile_fail_rate: f64,
    /// Scripted `(sequence number, kind)` strikes, checked before rates.
    pub scripted: Vec<(u64, FaultKind)>,
}

/// Counters per fault kind: how many were injected and how many of the
/// struck dispatches subsequently completed (recovered).
#[derive(Debug, Default)]
struct KindCounters {
    injected: AtomicU64,
    recovered: AtomicU64,
}

/// Snapshot of a plan's injection/recovery tallies.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTally {
    /// Faults injected, per [`FaultKind::index`] order
    /// (worker_kill, reconfig_fail, verify_corrupt, compile_fail).
    pub injected: [u64; 4],
    /// Struck dispatches that later completed, same order.
    pub recovered: [u64; 4],
}

impl FaultTally {
    /// Injected count for one kind.
    pub fn injected_of(&self, kind: FaultKind) -> u64 {
        self.injected[kind.index()]
    }

    /// Recovered count for one kind.
    pub fn recovered_of(&self, kind: FaultKind) -> u64 {
        self.recovered[kind.index()]
    }

    /// Total faults injected across kinds.
    pub fn total_injected(&self) -> u64 {
        self.injected.iter().sum()
    }

    /// Total struck dispatches that recovered.
    pub fn total_recovered(&self) -> u64 {
        self.recovered.iter().sum()
    }
}

/// A live, thread-safe fault campaign. Decision methods are pure in the
/// inputs; only the tally counters mutate.
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultPlanConfig,
    counters: [KindCounters; 4],
}

impl FaultPlan {
    /// Instantiate a campaign from its config.
    pub fn new(cfg: FaultPlanConfig) -> Self {
        FaultPlan { cfg, counters: Default::default() }
    }

    fn rate_of(&self, kind: FaultKind) -> f64 {
        match kind {
            FaultKind::WorkerKill => self.cfg.worker_kill_rate,
            FaultKind::ReconfigFail => self.cfg.reconfig_fail_rate,
            FaultKind::VerifyCorrupt => self.cfg.verify_corrupt_rate,
            FaultKind::CompileFail => self.cfg.compile_fail_rate,
        }
    }

    /// Should `kind` strike the event identified by `(seq, salt)` on
    /// attempt `attempt`? Pure in its inputs. `salt` disambiguates
    /// events that share a sequence number (e.g. compile attempts on
    /// different shards); scripted entries fire only at `salt == 0`.
    pub fn strikes(&self, kind: FaultKind, seq: u64, salt: u64, attempt: u32) -> bool {
        if attempt > 0 {
            return false; // retries are clean: recovery converges
        }
        if salt == 0 && self.cfg.scripted.iter().any(|&(s, k)| s == seq && k == kind) {
            return true;
        }
        let rate = self.rate_of(kind);
        if rate <= 0.0 {
            return false;
        }
        // Independent stream per (seed, seq, kind, salt); one draw.
        let mixed = self
            .cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ seq.wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ ((kind.index() as u64 + 1).wrapping_mul(0x94D0_49BB_1331_11EB))
            ^ salt.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        XorShiftRng::new(mixed).gen_f64() < rate
    }

    /// Record that `kind` was injected.
    pub fn note_injected(&self, kind: FaultKind) {
        self.counters[kind.index()].injected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record that a dispatch struck by `kind` later completed.
    pub fn note_recovered(&self, kind: FaultKind) {
        self.counters[kind.index()].recovered.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot the tallies.
    pub fn tally(&self) -> FaultTally {
        let mut t = FaultTally::default();
        for (i, c) in self.counters.iter().enumerate() {
            t.injected[i] = c.injected.load(Ordering::Relaxed);
            t.recovered[i] = c.recovered.load(Ordering::Relaxed);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_in_the_seed() {
        let cfg = FaultPlanConfig { seed: 77, worker_kill_rate: 0.3, ..Default::default() };
        let a = FaultPlan::new(cfg.clone());
        let b = FaultPlan::new(cfg);
        for seq in 0..200 {
            assert_eq!(
                a.strikes(FaultKind::WorkerKill, seq, 0, 0),
                b.strikes(FaultKind::WorkerKill, seq, 0, 0),
            );
        }
    }

    #[test]
    fn kinds_draw_independent_streams() {
        let plan = FaultPlan::new(FaultPlanConfig {
            seed: 5,
            worker_kill_rate: 0.5,
            reconfig_fail_rate: 0.5,
            ..Default::default()
        });
        let same = (0..256)
            .filter(|&s| {
                plan.strikes(FaultKind::WorkerKill, s, 0, 0)
                    == plan.strikes(FaultKind::ReconfigFail, s, 0, 0)
            })
            .count();
        assert!(same < 200, "streams must not be mirror images");
    }

    #[test]
    fn scripted_strikes_fire_exactly_where_placed() {
        let plan = FaultPlan::new(FaultPlanConfig {
            seed: 1,
            scripted: vec![(3, FaultKind::VerifyCorrupt), (7, FaultKind::WorkerKill)],
            ..Default::default()
        });
        assert!(plan.strikes(FaultKind::VerifyCorrupt, 3, 0, 0));
        assert!(plan.strikes(FaultKind::WorkerKill, 7, 0, 0));
        assert!(!plan.strikes(FaultKind::VerifyCorrupt, 4, 0, 0));
        assert!(!plan.strikes(FaultKind::WorkerKill, 3, 0, 0));
        // Scripted entries only hit the primary salt stream.
        assert!(!plan.strikes(FaultKind::VerifyCorrupt, 3, 1, 0));
    }

    #[test]
    fn retries_are_never_struck() {
        let plan = FaultPlan::new(FaultPlanConfig {
            seed: 9,
            worker_kill_rate: 1.0,
            scripted: vec![(0, FaultKind::WorkerKill)],
            ..Default::default()
        });
        assert!(plan.strikes(FaultKind::WorkerKill, 0, 0, 0));
        assert!(!plan.strikes(FaultKind::WorkerKill, 0, 0, 1));
        assert!(!plan.strikes(FaultKind::WorkerKill, 0, 0, 2));
    }

    #[test]
    fn rate_zero_never_strikes_and_rate_one_always_does() {
        let off = FaultPlan::new(FaultPlanConfig { seed: 2, ..Default::default() });
        let on = FaultPlan::new(FaultPlanConfig {
            seed: 2,
            verify_corrupt_rate: 1.0,
            ..Default::default()
        });
        for seq in 0..100 {
            assert!(!off.strikes(FaultKind::VerifyCorrupt, seq, 0, 0));
            assert!(on.strikes(FaultKind::VerifyCorrupt, seq, 0, 0));
        }
    }

    #[test]
    fn tally_tracks_injections_and_recoveries() {
        let plan = FaultPlan::new(FaultPlanConfig::default());
        plan.note_injected(FaultKind::ReconfigFail);
        plan.note_injected(FaultKind::ReconfigFail);
        plan.note_recovered(FaultKind::ReconfigFail);
        plan.note_injected(FaultKind::CompileFail);
        let t = plan.tally();
        assert_eq!(t.injected_of(FaultKind::ReconfigFail), 2);
        assert_eq!(t.recovered_of(FaultKind::ReconfigFail), 1);
        assert_eq!(t.injected_of(FaultKind::CompileFail), 1);
        assert_eq!(t.total_injected(), 3);
        assert_eq!(t.total_recovered(), 1);
    }
}
