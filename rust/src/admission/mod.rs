//! Overload-safe admission control for the serving coordinator.
//!
//! The paper's runtime adapts *compilation* to what the hardware can
//! sustain; this module makes the *serving* layer do the same for load.
//! Every submit passes through an [`AdmissionController`] before any
//! compilation or scheduling work is spent on it:
//!
//! 1. **Deadline triage** — a dispatch whose deadline cannot be met even
//!    on an idle fleet ("will miss anyway") is failed fast with
//!    [`RejectReason::DeadlineUnmeetable`] instead of wasting a slot.
//! 2. **Per-tenant token buckets** — each tenant draws from its own
//!    [`TokenBucket`]; a bursting tenant exhausts only its own bucket
//!    ([`RejectReason::QuotaExhausted`]) and cannot raise a compliant
//!    tenant's reject rate.
//! 3. **Pressure-driven batch shedding** — a pressure signal in `[0, 1]`
//!    is derived from per-partition queue depth (the pressure-stall
//!    idiom: the fraction of recent submits that observed a stalled
//!    queue) combined with the serving p99 against the interactive SLO.
//!    When pressure crosses the shed threshold, `Priority::Batch` work
//!    is shed first ([`RejectReason::Shed`]) so interactive p99 holds;
//!    interactive work is never shed, only quota- or deadline-rejected.
//!
//! All clocks are caller-supplied nanosecond counters, so every decision
//! is deterministic under test. The fault-injection plane lives in the
//! [`fault`] submodule.

pub mod fault;

pub use fault::{FaultKind, FaultPlan, FaultPlanConfig, FaultTally, ALL_FAULT_KINDS};

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Why a submit was refused. Returned as a *value* (not an error): a
/// rejection is a normal overload outcome with a typed cause, so callers
/// can count, retry, or back off per reason instead of string-matching.
#[derive(Debug, Clone, PartialEq)]
pub enum RejectReason {
    /// The tenant's token bucket is empty: it exceeded its sustained
    /// rate plus burst allowance. Other tenants are unaffected.
    QuotaExhausted {
        /// Tenant whose bucket ran dry.
        tenant: String,
    },
    /// The dispatch cannot meet its deadline even if admitted now:
    /// estimated service time (queue backlog + reconfiguration +
    /// execution) already exceeds the remaining budget.
    DeadlineUnmeetable {
        /// Estimated time to completion if admitted, in milliseconds.
        needed_ms: f64,
        /// Deadline budget the caller supplied, in milliseconds.
        budget_ms: f64,
    },
    /// Batch-lane load shedding: the fleet is under pressure and this
    /// submit is `Priority::Batch`, which is shed first so interactive
    /// latency holds.
    Shed {
        /// Pressure in `[0, 1]` at the moment of rejection.
        pressure: f64,
    },
}

impl RejectReason {
    /// Short stable tag for logs and counters.
    pub fn kind(&self) -> &'static str {
        match self {
            RejectReason::QuotaExhausted { .. } => "quota",
            RejectReason::DeadlineUnmeetable { .. } => "deadline",
            RejectReason::Shed { .. } => "shed",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QuotaExhausted { tenant } => {
                write!(f, "rejected[quota]: tenant '{tenant}' exhausted its token bucket")
            }
            RejectReason::DeadlineUnmeetable { needed_ms, budget_ms } => write!(
                f,
                "rejected[deadline]: needs ~{needed_ms:.3} ms but only {budget_ms:.3} ms of budget remain"
            ),
            RejectReason::Shed { pressure } => {
                write!(f, "rejected[shed]: batch lane shed at pressure {pressure:.2}")
            }
        }
    }
}

/// Tuning knobs for the admission layer. All thresholds have serving-
/// oriented defaults; tests override them for determinism.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Sustained per-tenant submit rate (tokens per second).
    pub tenant_rate_per_sec: f64,
    /// Per-tenant burst allowance (bucket capacity, in submits).
    pub tenant_burst: f64,
    /// Pressure in `[0, 1]` at which batch submits start being shed.
    pub shed_pressure: f64,
    /// Interactive p99 SLO in milliseconds; p99 above this contributes
    /// saturating pressure.
    pub interactive_slo_ms: f64,
    /// A submit that observes a best-candidate queue at or above this
    /// depth counts as a stall sample.
    pub queue_stall_depth: usize,
    /// Number of recent submits over which the stall fraction is taken.
    pub pressure_window: usize,
    /// Cap on distinct tenant buckets kept; beyond it, unknown tenants
    /// share the overflow bucket keyed by the empty string.
    pub max_tenants: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            tenant_rate_per_sec: 256.0,
            tenant_burst: 64.0,
            shed_pressure: 0.5,
            interactive_slo_ms: 250.0,
            queue_stall_depth: 4,
            pressure_window: 64,
            max_tenants: 256,
        }
    }
}

/// Classic token bucket with a caller-supplied nanosecond clock, so
/// refill is deterministic under test.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    capacity: f64,
    tokens: f64,
    rate_per_sec: f64,
    last_ns: u64,
}

impl TokenBucket {
    /// A bucket that starts full.
    pub fn new(capacity: f64, rate_per_sec: f64) -> Self {
        TokenBucket { capacity, tokens: capacity, rate_per_sec, last_ns: 0 }
    }

    /// Refill for the elapsed time and try to take one token.
    pub fn try_take(&mut self, now_ns: u64) -> bool {
        let dt = now_ns.saturating_sub(self.last_ns) as f64 / 1e9;
        self.last_ns = now_ns;
        self.tokens = (self.tokens + dt * self.rate_per_sec).min(self.capacity);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (after the last refill).
    pub fn available(&self) -> f64 {
        self.tokens
    }
}

/// Pressure-stall gauge: a ring of 0/1 samples ("did this submit observe
/// a stalled queue?") whose mean is the stall fraction, blended with the
/// p99-vs-SLO ratio. Both components saturate at 1.0.
#[derive(Debug)]
struct PressureGauge {
    window: usize,
    samples: Vec<u8>,
    next: usize,
    filled: usize,
}

impl PressureGauge {
    fn new(window: usize) -> Self {
        let window = window.max(1);
        PressureGauge { window, samples: vec![0; window], next: 0, filled: 0 }
    }

    fn record(&mut self, stalled: bool) {
        self.samples[self.next] = stalled as u8;
        self.next = (self.next + 1) % self.window;
        self.filled = (self.filled + 1).min(self.window);
    }

    fn stall_fraction(&self) -> f64 {
        if self.filled == 0 {
            return 0.0;
        }
        let hits: u32 = self.samples[..self.filled].iter().map(|&s| s as u32).sum();
        f64::from(hits) / self.filled as f64
    }
}

/// Live counters for the admission layer, snapshot into
/// `metrics::ServingStats`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdmissionStats {
    /// Submits that passed admission.
    pub admitted: u64,
    /// Rejections due to an exhausted tenant bucket.
    pub rejected_quota: u64,
    /// Rejections due to an unmeetable deadline.
    pub rejected_deadline: u64,
    /// Batch submits shed under pressure.
    pub shed: u64,
    /// Pressure at the most recent admission decision.
    pub pressure: f64,
    /// Distinct tenants with a bucket.
    pub tenants: u64,
}

/// The gate in front of `Coordinator::submit`. Thread-safe; every check
/// takes the caller's clock so decisions replay deterministically.
#[derive(Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    buckets: Mutex<HashMap<String, TokenBucket>>,
    gauge: Mutex<PressureGauge>,
    admitted: AtomicU64,
    rejected_quota: AtomicU64,
    rejected_deadline: AtomicU64,
    shed: AtomicU64,
    /// Last computed pressure, stored as `f64::to_bits`.
    pressure_bits: AtomicU64,
    /// Latest SLO burn rate fed by `Coordinator::slo_tick`, stored as
    /// `f64::to_bits`. Folded (clamped to `[0, 1]`) into the pressure
    /// max: a tenant burning error budget sheds batch work even while
    /// queues look shallow.
    slo_burn_bits: AtomicU64,
}

/// Everything the controller needs to know about one submit. The caller
/// (the coordinator) computes these from its scheduler observations
/// before any compilation happens.
#[derive(Debug, Clone, Copy)]
pub struct AdmitRequest<'a> {
    /// Tenant name; unknown tenants get a bucket on first sight.
    pub tenant: &'a str,
    /// True for `Priority::Interactive`, false for batch.
    pub interactive: bool,
    /// Caller clock in nanoseconds since coordinator start.
    pub now_ns: u64,
    /// Best-candidate queue depth observed for this submit.
    pub queue_depth: usize,
    /// Current serving p99 in milliseconds (0 when unwarmed).
    pub p99_ms: f64,
    /// Estimated service time if admitted now, in milliseconds
    /// (backlog + reconfiguration + modeled execution).
    pub est_service_ms: f64,
    /// Remaining deadline budget in milliseconds, if any.
    pub budget_ms: Option<f64>,
}

impl AdmissionController {
    /// Build a controller from its config.
    pub fn new(cfg: AdmissionConfig) -> Self {
        let gauge = PressureGauge::new(cfg.pressure_window);
        AdmissionController {
            cfg,
            buckets: Mutex::new(HashMap::new()),
            gauge: Mutex::new(gauge),
            admitted: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            pressure_bits: AtomicU64::new(0),
            slo_burn_bits: AtomicU64::new(0),
        }
    }

    /// Feed the latest SLO burn rate (from the coordinator's SLO tick)
    /// into the pressure signal. A burn ≥ 1.0 — budget being spent
    /// faster than it accrues — saturates the pressure contribution.
    pub fn set_slo_burn(&self, burn: f64) {
        let burn = if burn.is_finite() { burn.max(0.0) } else { 0.0 };
        self.slo_burn_bits.store(burn.to_bits(), Ordering::Relaxed);
    }

    /// Decide one submit. `Ok(())` admits; `Err(reason)` carries the
    /// typed cause. Checks run cheapest-and-most-specific first:
    /// deadline triage (spend nothing on doomed work), then the tenant
    /// bucket, then pressure shedding for batch work.
    pub fn admit(&self, req: &AdmitRequest<'_>) -> Result<(), RejectReason> {
        // 1. Deadline triage: will miss anyway -> fail fast, and do not
        // charge the tenant a token for work we refused to queue.
        if let Some(budget_ms) = req.budget_ms {
            if req.est_service_ms > budget_ms {
                self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                return Err(RejectReason::DeadlineUnmeetable {
                    needed_ms: req.est_service_ms,
                    budget_ms,
                });
            }
        }

        // 2. Per-tenant quota.
        {
            let mut buckets = self.buckets.lock().unwrap();
            let key = if buckets.len() >= self.cfg.max_tenants
                && !buckets.contains_key(req.tenant)
            {
                String::new() // overflow bucket for the long tail
            } else {
                req.tenant.to_string()
            };
            let bucket = buckets.entry(key).or_insert_with(|| {
                TokenBucket::new(self.cfg.tenant_burst, self.cfg.tenant_rate_per_sec)
            });
            if !bucket.try_take(req.now_ns) {
                self.rejected_quota.fetch_add(1, Ordering::Relaxed);
                return Err(RejectReason::QuotaExhausted {
                    tenant: req.tenant.to_string(),
                });
            }
        }

        // 3. Pressure: stall fraction from queue depth, blended with the
        // p99-vs-SLO ratio. Batch is shed first; interactive rides out
        // the pressure so its p99 holds while batch degrades.
        let stall = {
            let mut gauge = self.gauge.lock().unwrap();
            gauge.record(req.queue_depth >= self.cfg.queue_stall_depth);
            gauge.stall_fraction()
        };
        let slo = (req.p99_ms / self.cfg.interactive_slo_ms).clamp(0.0, 1.0);
        let burn = f64::from_bits(self.slo_burn_bits.load(Ordering::Relaxed))
            .clamp(0.0, 1.0);
        let pressure = stall.max(slo).max(burn);
        self.pressure_bits.store(pressure.to_bits(), Ordering::Relaxed);
        if !req.interactive && pressure >= self.cfg.shed_pressure {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(RejectReason::Shed { pressure });
        }

        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Pressure at the most recent decision, in `[0, 1]`.
    pub fn pressure(&self) -> f64 {
        f64::from_bits(self.pressure_bits.load(Ordering::Relaxed))
    }

    /// Whether the gate is at or past its batch-shedding threshold —
    /// the same line that sheds batch submits also makes in-flight
    /// batch runs preemption-eligible.
    pub fn overloaded(&self) -> bool {
        self.pressure() >= self.cfg.shed_pressure
    }

    /// Snapshot the live counters.
    pub fn stats(&self) -> AdmissionStats {
        AdmissionStats {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected_quota: self.rejected_quota.load(Ordering::Relaxed),
            rejected_deadline: self.rejected_deadline.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            pressure: self.pressure(),
            tenants: self.buckets.lock().unwrap().len() as u64,
        }
    }
}

/// Estimate, in milliseconds, how long a dispatch would take if admitted
/// now: queued work ahead of it, the reconfiguration it would trigger,
/// and its own modeled execution. Deliberately pessimistic (assumes the
/// backlog is same-shaped work) — admission only fails fast on submits
/// that are hopeless even under this rough model.
pub fn estimate_service_ms(
    ops_total: f64,
    gops: f64,
    queue_depth: usize,
    config_seconds: f64,
    resident: bool,
) -> f64 {
    let exec_ms = if gops > 0.0 { ops_total / (gops * 1e9) * 1e3 } else { 0.0 };
    let config_ms = if resident { 0.0 } else { config_seconds * 1e3 };
    exec_ms * (queue_depth as f64 + 1.0) + config_ms
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strict_cfg() -> AdmissionConfig {
        AdmissionConfig {
            tenant_rate_per_sec: 1.0,
            tenant_burst: 4.0,
            shed_pressure: 0.5,
            interactive_slo_ms: 100.0,
            queue_stall_depth: 2,
            pressure_window: 4,
            max_tenants: 8,
        }
    }

    fn idle(tenant: &str, now_ns: u64) -> AdmitRequest<'_> {
        AdmitRequest {
            tenant,
            interactive: true,
            now_ns,
            queue_depth: 0,
            p99_ms: 0.0,
            est_service_ms: 0.0,
            budget_ms: None,
        }
    }

    #[test]
    fn token_bucket_refills_deterministically() {
        let mut b = TokenBucket::new(2.0, 1.0);
        assert!(b.try_take(0));
        assert!(b.try_take(0));
        assert!(!b.try_take(0), "burst exhausted");
        // One second refills exactly one token.
        assert!(b.try_take(1_000_000_000));
        assert!(!b.try_take(1_000_000_000));
        // Refill never exceeds capacity.
        assert!(b.try_take(100_000_000_000));
        assert!(b.try_take(100_000_000_000));
        assert!(!b.try_take(100_000_000_000));
    }

    #[test]
    fn burst_tenant_exhausts_only_its_own_bucket() {
        let ctl = AdmissionController::new(strict_cfg());
        let mut spammer_rejects = 0;
        for _ in 0..40 {
            if ctl.admit(&idle("spammer", 0)).is_err() {
                spammer_rejects += 1;
            }
        }
        assert_eq!(spammer_rejects, 36, "burst of 4 then dry at t=0");
        // Compliant tenants still have full buckets.
        for t in ["a", "b", "c"] {
            for _ in 0..4 {
                assert!(ctl.admit(&idle(t, 0)).is_ok(), "tenant {t} must not be rejected");
            }
        }
        let stats = ctl.stats();
        assert_eq!(stats.rejected_quota, 36);
        assert_eq!(stats.admitted, 16);
    }

    #[test]
    fn deadline_triage_fails_fast_without_charging_quota() {
        let ctl = AdmissionController::new(strict_cfg());
        let mut req = idle("t", 0);
        req.est_service_ms = 50.0;
        req.budget_ms = Some(10.0);
        match ctl.admit(&req) {
            Err(RejectReason::DeadlineUnmeetable { needed_ms, budget_ms }) => {
                assert!((needed_ms - 50.0).abs() < 1e-9);
                assert!((budget_ms - 10.0).abs() < 1e-9);
            }
            other => panic!("expected deadline reject, got {other:?}"),
        }
        // The doomed submit consumed no token: the full burst remains.
        for _ in 0..4 {
            assert!(ctl.admit(&idle("t", 0)).is_ok());
        }
        assert_eq!(ctl.stats().rejected_deadline, 1);
    }

    #[test]
    fn pressure_sheds_batch_first_and_never_interactive() {
        let ctl = AdmissionController::new(AdmissionConfig {
            tenant_rate_per_sec: 1000.0,
            tenant_burst: 1000.0,
            ..strict_cfg()
        });
        // Saturate the stall window: every submit sees a deep queue.
        let mut req = idle("t", 0);
        req.queue_depth = 10;
        for _ in 0..4 {
            assert!(ctl.admit(&req).is_ok(), "interactive rides out pressure");
        }
        assert!(ctl.pressure() >= 0.5);
        req.interactive = false;
        match ctl.admit(&req) {
            Err(RejectReason::Shed { pressure }) => assert!(pressure >= 0.5),
            other => panic!("expected shed, got {other:?}"),
        }
        // Interactive is still admitted at the same pressure.
        req.interactive = true;
        assert!(ctl.admit(&req).is_ok());
        assert_eq!(ctl.stats().shed, 1);
    }

    #[test]
    fn slo_burn_sheds_batch_even_with_shallow_queues() {
        let ctl = AdmissionController::new(AdmissionConfig {
            tenant_rate_per_sec: 1000.0,
            tenant_burst: 1000.0,
            ..strict_cfg()
        });
        let mut req = idle("t", 0);
        req.interactive = false;
        // No queue stall, p99 fine — but the SLO engine reports the
        // error budget burning 2x faster than it accrues.
        ctl.set_slo_burn(2.0);
        match ctl.admit(&req) {
            Err(RejectReason::Shed { pressure }) => {
                assert!((pressure - 1.0).abs() < 1e-9, "burn clamps to 1.0");
            }
            other => panic!("expected shed, got {other:?}"),
        }
        // Interactive still rides through; the burn only sheds batch.
        req.interactive = true;
        assert!(ctl.admit(&req).is_ok());
        // A recovered budget releases the shed.
        ctl.set_slo_burn(0.0);
        req.interactive = false;
        assert!(ctl.admit(&req).is_ok());
        // Degenerate inputs are ignored, not poisonous.
        ctl.set_slo_burn(f64::NAN);
        assert!(ctl.admit(&req).is_ok());
    }

    #[test]
    fn p99_above_slo_contributes_pressure() {
        let ctl = AdmissionController::new(AdmissionConfig {
            tenant_rate_per_sec: 1000.0,
            tenant_burst: 1000.0,
            ..strict_cfg()
        });
        let mut req = idle("t", 0);
        req.interactive = false;
        req.p99_ms = 100.0; // == SLO -> ratio 1.0 -> shed
        match ctl.admit(&req) {
            Err(RejectReason::Shed { pressure }) => assert!((pressure - 1.0).abs() < 1e-9),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn overflow_tenants_share_one_bucket() {
        let mut cfg = strict_cfg();
        cfg.max_tenants = 2;
        let ctl = AdmissionController::new(cfg);
        assert!(ctl.admit(&idle("a", 0)).is_ok());
        assert!(ctl.admit(&idle("b", 0)).is_ok());
        // "c" and "d" both land in the overflow bucket (4 tokens total).
        for i in 0..4 {
            let t = if i % 2 == 0 { "c" } else { "d" };
            assert!(ctl.admit(&idle(t, 0)).is_ok());
        }
        assert!(ctl.admit(&idle("e", 0)).is_err(), "overflow bucket dry");
        // Named tenants keep their own tokens.
        assert!(ctl.admit(&idle("a", 0)).is_ok());
    }

    #[test]
    fn reject_reason_display_is_stable() {
        let q = RejectReason::QuotaExhausted { tenant: "t3".into() };
        assert!(q.to_string().contains("rejected[quota]"));
        assert_eq!(q.kind(), "quota");
        let d = RejectReason::DeadlineUnmeetable { needed_ms: 5.0, budget_ms: 1.0 };
        assert!(d.to_string().contains("rejected[deadline]"));
        let s = RejectReason::Shed { pressure: 0.75 };
        assert!(s.to_string().contains("rejected[shed]"));
    }

    #[test]
    fn service_estimate_charges_backlog_and_config() {
        // 1e9 ops at 1 GOPS = 1 ms execution.
        let ms = estimate_service_ms(1e9, 1.0, 0, 0.0, true);
        assert!((ms - 1.0).abs() < 1e-9);
        // Three queued ahead quadruples the wait; a cold partition adds
        // its reconfiguration cost.
        let ms = estimate_service_ms(1e9, 1.0, 3, 0.002, false);
        assert!((ms - 6.0).abs() < 1e-9);
        // Unknown throughput estimates only the config cost.
        let ms = estimate_service_ms(1e9, 0.0, 5, 0.002, false);
        assert!((ms - 2.0).abs() < 1e-9);
    }
}
