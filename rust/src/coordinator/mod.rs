//! The overlay serving coordinator (the paper's L3 coordination
//! contribution, grown into a subsystem).
//!
//! The paper's claim is that seconds-class JIT compilation plus
//! µs-class overlay reconfiguration make *run-time* kernel management
//! practical. This module is that management layer: a serving front
//! end that owns a fleet of overlay partitions and turns the two paper
//! numbers into steady-state throughput:
//!
//! * [`CompileCache`] — a **compile cache** keyed by (source hash,
//!   overlay fingerprint, options fingerprint): repeat builds are
//!   O(lookup) instead of the Fig. 7 seconds;
//! * [`SlotScheduler`] — a **slot-aware scheduler** that treats
//!   configured partitions as a cache: dispatches land on a partition
//!   already holding the kernel's bitstream when possible, otherwise
//!   an idle LRU victim pays the modeled
//!   [`ConfigSizeModel`] load cost (42.4 µs for the 8×8 overlay);
//! * [`DispatchHandle`] — an **async dispatch queue**: one worker
//!   thread per partition, per-partition batching, completion handles
//!   carrying the same timing breakdown as synchronous
//!   [`crate::runtime_ocl`] events plus an optional cycle-simulator
//!   verification verdict.
//!
//! ```text
//! submit(source, args, n) ──┐
//!                           ▼
//!                  compile cache ── miss ──▶ JitCompiler (seconds)
//!                       │ hit                      │
//!                       ▼                          ▼
//!                 slot-aware scheduler  ◀── CompiledKernel
//!                  │ resident? │ victim (LRU, + config µs)
//!                  ▼           ▼
//!         partition 0 queue   partition 1 queue   …   (worker threads)
//!                  ▼           ▼
//!             DispatchHandle.wait() → DispatchResult
//! ```
//!
//! The fleet must currently be homogeneous (identical
//! [`OverlaySpec`]s): a compiled kernel's placement, routing and
//! bitstream are spec-bound, so heterogeneous partition sizes need
//! per-spec compilation — an explicit ROADMAP follow-on.

mod cache;
mod dispatch;
mod scheduler;

pub use cache::{CacheKey, CompileCache};
pub use dispatch::{DispatchHandle, DispatchResult, SubmitArg};
pub use scheduler::{Decision, PartitionState, SlotScheduler};

/// Re-exported for convenience: the compile-cache counters live in
/// [`crate::metrics`] with the rest of the serving statistics.
pub use crate::metrics::CacheStats;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::compiler::{CompileOptions, JitCompiler};
use crate::metrics::{LatencyStats, PartitionServingStats, ServingStats};
use crate::overlay::{ConfigSizeModel, OverlaySpec};
use crate::runtime_ocl::{Device, Kernel, Platform};

use dispatch::{HandleInner, Job, Msg, ServeLog, Worker};

/// Configuration of a serving fleet.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// The overlay partitions (devices) to serve across. All must
    /// share one [`OverlaySpec`] for now (see module docs).
    pub devices: Vec<Device>,
    /// Maximum compiled kernels held by the compile cache.
    pub cache_capacity: usize,
    /// JIT options used for every compile (part of the cache key).
    pub compile_options: CompileOptions,
    /// Verify every dispatch against the cycle simulator: the
    /// scattered output buffers must hold the simulator's values
    /// bit-for-bit (PJRT partitions additionally re-execute on the
    /// simulator and require raw-stream agreement). Recorded in
    /// [`DispatchResult::verified`].
    pub verify: bool,
}

impl CoordinatorConfig {
    /// A homogeneous cycle-simulated fleet of `partitions` overlays.
    pub fn sim_fleet(spec: OverlaySpec, partitions: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            devices: Platform::multi_sim(spec, partitions).devices().to_vec(),
            cache_capacity: 32,
            compile_options: CompileOptions::default(),
            verify: true,
        }
    }

    /// Serve across an existing platform's devices.
    pub fn for_platform(platform: &Platform) -> CoordinatorConfig {
        CoordinatorConfig {
            devices: platform.devices().to_vec(),
            cache_capacity: 32,
            compile_options: CompileOptions::default(),
            verify: true,
        }
    }
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)
    }
}

/// The multi-overlay serving coordinator. See module docs.
pub struct Coordinator {
    jit: JitCompiler,
    spec: OverlaySpec,
    cache: Mutex<CompileCache>,
    scheduler: Arc<Mutex<SlotScheduler>>,
    log: Arc<Mutex<ServeLog>>,
    workers: Vec<Worker>,
    partition_names: Vec<String>,
    start: Instant,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator")
            .field("overlay", &self.spec.name())
            .field("partitions", &self.partition_names)
            .finish()
    }
}

impl Coordinator {
    /// Bring a fleet up: one JIT compiler (and routing-resource graph)
    /// for the shared spec, one worker thread per partition.
    pub fn new(config: CoordinatorConfig) -> Result<Coordinator> {
        let CoordinatorConfig { devices, cache_capacity, compile_options, verify } = config;
        if devices.is_empty() {
            bail!("coordinator needs at least one overlay partition");
        }
        let spec = devices[0].spec.clone();
        for d in &devices[1..] {
            if d.spec.fingerprint() != spec.fingerprint() {
                bail!(
                    "heterogeneous fleet: partition '{}' is {} but the fleet is {} — \
                     per-spec compilation is not implemented yet (see ROADMAP)",
                    d.name,
                    d.spec.name(),
                    spec.name()
                );
            }
        }
        let jit = JitCompiler::with_options(spec.clone(), compile_options);
        let scheduler = Arc::new(Mutex::new(SlotScheduler::new(devices.len())));
        let log = Arc::new(Mutex::new(ServeLog::default()));
        let partition_names: Vec<String> = devices.iter().map(|d| d.name.clone()).collect();
        let workers: Vec<Worker> = devices
            .into_iter()
            .enumerate()
            .map(|(i, d)| dispatch::spawn_worker(i, d, scheduler.clone(), log.clone(), verify))
            .collect();
        Ok(Coordinator {
            jit,
            spec,
            cache: Mutex::new(CompileCache::new(cache_capacity)),
            scheduler,
            log,
            workers,
            partition_names,
            start: Instant::now(),
        })
    }

    /// The fleet's shared overlay description.
    pub fn spec(&self) -> &OverlaySpec {
        &self.spec
    }

    /// Number of partitions served.
    pub fn partitions(&self) -> usize {
        self.workers.len()
    }

    /// Asynchronously serve one kernel dispatch: cache-or-compile,
    /// schedule onto a partition, enqueue, return a completion handle.
    pub fn submit(
        &self,
        source: &str,
        args: &[SubmitArg],
        global_size: usize,
    ) -> Result<DispatchHandle> {
        let key = CacheKey::new(source, &self.spec, &self.jit.options);

        let cached = self.cache.lock().unwrap().get(&key);
        let (compiled, cache_hit) = match cached {
            Some(k) => (k, true),
            None => {
                // the seconds-class step — paid once per distinct
                // (source, overlay, options)
                let t0 = Instant::now();
                let k = Arc::new(self.jit.compile(source)?);
                self.log.lock().unwrap().compile_seconds += t0.elapsed().as_secs_f64();
                self.cache.lock().unwrap().insert(key, k.clone());
                (k, false)
            }
        };

        if args.len() != compiled.params.len() {
            bail!(
                "kernel '{}' takes {} arguments, got {}",
                compiled.name,
                compiled.params.len(),
                args.len()
            );
        }
        let kernel = Kernel::from_compiled(compiled.clone());
        for (i, a) in args.iter().enumerate() {
            match a {
                SubmitArg::Buffer(b) => kernel.set_arg(i, b)?,
                SubmitArg::Scalar(v) => kernel.set_arg_scalar(i, *v)?,
            }
        }

        let config_cost =
            ConfigSizeModel::overlay_config_seconds(&self.spec, compiled.bitstream.byte_size());
        let decision = self.scheduler.lock().unwrap().pick(key, config_cost);

        let handle = HandleInner::new();
        let job = Job {
            kernel,
            global_size,
            partition: decision.partition,
            config_seconds: decision.config_seconds,
            cache_hit,
            enqueued: Instant::now(),
            handle: handle.clone(),
        };
        if self.workers[decision.partition]
            .sender
            .send(Msg::Job(Box::new(job)))
            .is_err()
        {
            // dead worker: the dispatch never ran, undo its accounting
            self.scheduler.lock().unwrap().cancel(&decision);
            bail!("partition {} worker is gone", decision.partition);
        }
        Ok(DispatchHandle { inner: handle })
    }

    /// Snapshot of the serving statistics.
    pub fn stats(&self) -> ServingStats {
        let cache = self.cache.lock().unwrap().stats();
        let sched = self.scheduler.lock().unwrap();
        let log = self.log.lock().unwrap();
        let elapsed = self.start.elapsed().as_secs_f64().max(1e-9);
        let partitions = sched
            .partitions()
            .iter()
            .enumerate()
            .map(|(i, p)| PartitionServingStats {
                partition: i,
                overlay: self.partition_names[i].clone(),
                dispatches: p.dispatches,
                reconfigs: p.reconfigs,
                busy_seconds: p.busy_seconds,
                utilization: (p.busy_seconds / elapsed).min(1.0),
            })
            .collect();
        ServingStats {
            cache,
            reconfig_count: sched.reconfig_count(),
            reconfig_seconds: sched.reconfig_seconds,
            latency: LatencyStats::from_samples_ms(log.latencies_ms.clone()),
            partitions,
            total_dispatches: log.total_dispatches,
            total_items: log.total_items,
            verify_failures: log.verify_failures,
            dispatch_errors: log.errors,
            compile_seconds: log.compile_seconds,
        }
    }

    /// Graceful shutdown: finish queued work, stop workers. (Also
    /// runs on drop.)
    pub fn shutdown(self) {}
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.sender.send(Msg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Wait on a batch of handles, preserving submission order.
pub fn wait_all(handles: Vec<DispatchHandle>) -> Result<Vec<DispatchResult>> {
    handles.into_iter().map(DispatchHandle::wait).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_kernels::{CHEBYSHEV, POLY1};
    use crate::runtime_ocl::{Backend, Context};

    fn cheb_ref(x: i32) -> i32 {
        x.wrapping_mul(
            x.wrapping_mul(16i32.wrapping_mul(x).wrapping_mul(x).wrapping_sub(20))
                .wrapping_mul(x)
                .wrapping_add(5),
        )
    }

    fn host_ctx() -> Context {
        let dev = Device {
            spec: OverlaySpec::zynq_default(),
            backend: Backend::CycleSim,
            name: "host".into(),
        };
        Context::new(&dev)
    }

    #[test]
    fn serves_correct_results_with_cache_hits() {
        let coord =
            Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2))
                .unwrap();
        let ctx = host_ctx();

        let n = 256;
        let mut handles = Vec::new();
        let mut outputs = Vec::new();
        for round in 0..3 {
            let a = ctx.create_buffer(n);
            let b = ctx.create_buffer(n);
            let xs: Vec<i32> = (0..n as i32).map(|i| (i % 11) - 5 + round).collect();
            a.write(&xs);
            let h = coord
                .submit(CHEBYSHEV, &[SubmitArg::Buffer(a), SubmitArg::Buffer(b.clone())], n)
                .unwrap();
            handles.push(h);
            outputs.push((xs, b));
        }
        let results = wait_all(handles).unwrap();
        assert_eq!(results.len(), 3);
        assert!(!results[0].cache_hit, "first dispatch must compile");
        assert!(results[1].cache_hit && results[2].cache_hit);
        assert!(results.iter().all(|r| r.verified == Some(true)));
        for (xs, b) in outputs {
            let out = b.read();
            for (x, y) in xs.iter().zip(&out) {
                assert_eq!(*y, cheb_ref(*x));
            }
        }
        let stats = coord.stats();
        assert_eq!(stats.cache.misses, 1);
        assert_eq!(stats.cache.hits, 2);
        assert_eq!(stats.total_dispatches, 3);
        assert_eq!(stats.verify_failures, 0);
        assert!(stats.cache.hit_rate() > 0.6);
        coord.shutdown();
    }

    #[test]
    fn distinct_kernels_spread_across_partitions() {
        let coord =
            Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2))
                .unwrap();
        let ctx = host_ctx();
        let n = 64;
        let submit = |src: &str, params: usize| {
            let args: Vec<SubmitArg> = (0..params)
                .map(|_| {
                    let b = ctx.create_buffer(n + 8);
                    b.write(&vec![1; n + 8]);
                    SubmitArg::Buffer(b)
                })
                .collect();
            coord.submit(src, &args, n).unwrap()
        };
        let r1 = submit(CHEBYSHEV, 2).wait().unwrap();
        let r2 = submit(POLY1, 2).wait().unwrap();
        assert_ne!(r1.partition, r2.partition, "cold fleet spreads kernels");
        // both resident now: repeats hit their partitions with zero
        // config cost
        let r1b = submit(CHEBYSHEV, 2).wait().unwrap();
        let r2b = submit(POLY1, 2).wait().unwrap();
        assert_eq!(r1b.partition, r1.partition);
        assert_eq!(r2b.partition, r2.partition);
        assert_eq!(r1b.event.config_seconds, 0.0);
        assert_eq!(r2b.event.config_seconds, 0.0);
        assert!(r1.event.config_seconds > 0.0);
        let stats = coord.stats();
        assert_eq!(stats.reconfig_count, 2);
    }

    #[test]
    fn argument_mismatch_is_reported() {
        let coord =
            Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1))
                .unwrap();
        let err = coord.submit(CHEBYSHEV, &[], 16).unwrap_err().to_string();
        assert!(err.contains("takes 2 arguments"), "{err}");
    }

    #[test]
    fn heterogeneous_fleet_is_rejected() {
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2);
        cfg.devices[1].spec = OverlaySpec::new(4, 4, crate::overlay::FuType::Dsp2);
        let err = Coordinator::new(cfg).unwrap_err().to_string();
        assert!(err.contains("heterogeneous"), "{err}");
    }

    #[test]
    fn empty_fleet_is_rejected() {
        let cfg = CoordinatorConfig {
            devices: Vec::new(),
            cache_capacity: 4,
            compile_options: CompileOptions::default(),
            verify: false,
        };
        assert!(Coordinator::new(cfg).is_err());
    }
}
