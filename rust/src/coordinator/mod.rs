//! The overlay serving coordinator (the paper's L3 coordination
//! contribution, grown into a subsystem).
//!
//! The paper's claim is that seconds-class JIT compilation plus
//! µs-class overlay reconfiguration make *run-time* kernel management
//! practical. This module is that management layer: a serving front
//! end that owns a — possibly **heterogeneous** — fleet of overlay
//! partitions and turns the two paper numbers into steady-state
//! throughput:
//!
//! * [`crate::fleet::Fleet`] — one **compilation shard** per distinct
//!   [`OverlaySpec`] ([`KernelCache`] + `JitCompiler`, keyed by spec
//!   fingerprint) plus a **resource-aware router** that places small
//!   kernels on small overlays and wide data-parallel kernels where
//!   `copies × throughput` peaks;
//! * [`SlotScheduler`] — a **slot-aware scheduler** that treats
//!   configured partitions as a cache: dispatches land on a
//!   same-spec partition already holding the kernel's bitstream when
//!   possible, otherwise an idle victim (batch-class residents first)
//!   pays the modeled [`ConfigSizeModel`] load cost;
//! * [`DispatchHandle`] — an **async dispatch queue**: one worker
//!   thread per partition with two QoS lanes (interactive drains
//!   first), same-kernel batch fusion, completion handles carrying
//!   the same timing breakdown as synchronous [`crate::runtime_ocl`]
//!   events plus an optional cycle-simulator verification verdict.
//!
//! ```text
//! submit(source, args, n, priority) ──┐
//!                                     ▼
//!                        fleet router (per-spec replication plans,
//!                         queue depths, reconfiguration cost)
//!                    8x8 shard │              │ 4x4 shard
//!                              ▼              ▼
//!                    kernel cache ── miss ──▶ JitCompiler (seconds)
//!                        │ hit                     │
//!                        ▼                         ▼
//!                  slot-aware scheduler  ◀── ServableKernel
//!                   │ resident? │ victim (batch-first, + config µs)
//!                   ▼           ▼
//!        partition queues (interactive lane ▶ batch lane) per spec
//!                   ▼
//!              DispatchHandle.wait() → DispatchResult (spec, fused…)
//! ```

mod cache;
mod dispatch;
mod scheduler;

pub use cache::{CacheKey, CompileCache, KernelCache};
pub use dispatch::{
    ContinuationRecord, DispatchError, DispatchHandle, DispatchResult, FailReason,
    SubmitArg, MAX_PREEMPTIONS,
};
pub use scheduler::{Decision, PartitionState, SlotScheduler};

/// Re-exported from [`crate::fleet`]: the QoS class of a dispatch and
/// the routing knobs.
pub use crate::fleet::{Priority, RoutingPolicy};

/// Re-exported from [`crate::admission`]: the gate's knobs, its typed
/// rejections, and the deterministic fault plan.
pub use crate::admission::{
    AdmissionConfig, AdmissionStats, FaultKind, FaultPlanConfig, FaultTally,
    RejectReason,
};

/// Re-exported for convenience: the serving statistics live in
/// [`crate::metrics`].
pub use crate::metrics::CacheStats;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::admission::{
    estimate_service_ms, AdmissionController, AdmitRequest, FaultPlan,
};
use crate::arena::{PoolStats, ScratchPool};
use crate::autoscale::{
    ActiveVariant, AutoscalePolicy, Autoscaler, BgTask, Rescaler, ScaleEvent,
    SubmitObservation,
};
use crate::compiler::CompileOptions;
use crate::fleet::{
    apply_poison_mask, rank_specs, Fleet, RouteRecord, Router, SpecObservation,
};
use crate::metrics::{
    achieved_gops, LatencyStats, PartitionServingStats, ServingStats, SpecServingStats,
};
use crate::obs::{
    ParentCtx, Phase, SloAlert, SloCollector, SloPolicy, SloProbe, SubmitTrace,
    TraceHandle,
};
use crate::overlay::{ConfigSizeModel, OverlaySpec};
use crate::runtime_ocl::{Device, Kernel, Platform};

use dispatch::{HandleInner, Job, LaneQueue, RecoveryPlane, ServeLog, Worker};

/// How many times the recovery plane re-places a struck dispatch
/// before failing its handle with a typed [`DispatchError`].
const MAX_DISPATCH_RETRIES: u32 = 3;

/// Tenant charged by the ungated [`Coordinator::submit`] entry points
/// when an admission controller is configured.
const DEFAULT_TENANT: &str = "default";

/// Outcome of a gated submit ([`Coordinator::submit_gated`]): either a
/// completion handle or a typed, non-fatal admission rejection. A
/// rejection is part of normal overload operation — callers retry
/// later, downshift to batch, or surface it to the tenant — so it is
/// `Ok(Rejected)` rather than an `Err`.
#[derive(Debug)]
pub enum Admission {
    /// The dispatch was admitted and queued.
    Admitted(DispatchHandle),
    /// The dispatch was refused before consuming fleet resources.
    Rejected(RejectReason),
}

/// Configuration of a serving fleet.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// The overlay partitions (devices) to serve across. Specs may be
    /// mixed freely: partitions are grouped into per-spec shards.
    pub devices: Vec<Device>,
    /// Maximum compiled kernels held by **each** spec's kernel cache.
    pub cache_capacity: usize,
    /// JIT options used for every compile (part of the cache key).
    pub compile_options: CompileOptions,
    /// Verify every dispatch against the cycle simulator: the
    /// scattered output buffers must hold the simulator's values
    /// bit-for-bit (PJRT partitions additionally re-execute on the
    /// simulator and require raw-stream agreement). Recorded in
    /// [`DispatchResult::verified`].
    pub verify: bool,
    /// Resource-aware routing knobs (see [`RoutingPolicy`]).
    pub routing: RoutingPolicy,
    /// When set, warm-start every shard's kernel cache from the
    /// snapshot files under this directory at construction (missing
    /// files are fine). Write snapshots with
    /// [`Coordinator::save_snapshot`].
    pub snapshot_dir: Option<PathBuf>,
    /// When set (requires `snapshot_dir`), flush kernel-cache
    /// snapshots **in the background** every N accepted submits — a
    /// long-running fleet keeps its warm-start state fresh without a
    /// shutdown hook. Must be ≥ 1.
    pub snapshot_every: Option<u64>,
    /// Feedback-driven runtime rescaling ([`crate::autoscale`]):
    /// `Some(policy)` re-replicates kernels whose observed load
    /// persistently disagrees with their frozen plan; `None` (the
    /// default) keeps every factor fixed at first compile.
    pub autoscale: Option<AutoscalePolicy>,
    /// Cross-batch fusion window: a worker whose queue ran dry waits
    /// up to this long for more same-kernel batch-lane jobs before
    /// launching, so trickle arrivals still fuse into one backend
    /// invocation. Zero (the default) launches immediately —
    /// exactly the pre-window behavior. Interactive work is never
    /// delayed by the window.
    pub fusion_window: Duration,
    /// Overload-safe admission control ([`crate::admission`]):
    /// `Some(cfg)` gates every submit behind per-tenant token buckets,
    /// deadline triage and pressure-driven batch shedding; `None` (the
    /// default) admits everything — exactly the pre-gate behavior.
    pub admission: Option<AdmissionConfig>,
    /// Deterministic fault injection ([`crate::admission::FaultPlan`]):
    /// `Some(cfg)` arms seeded worker-kill / reconfig-fail /
    /// verify-corrupt / compile-fail strikes so the recovery plane can
    /// be exercised reproducibly; `None` (the default) injects
    /// nothing. Recovery itself is always armed — real worker deaths
    /// are requeued whether or not faults are injected.
    pub faults: Option<FaultPlanConfig>,
    /// End-to-end dispatch tracing ([`crate::obs`]): `Some(handle)`
    /// records a phase span for every serving stage of every submit
    /// into the handle's sink; `None` (the default) serves through the
    /// allocation-free no-op recorder.
    pub trace: Option<TraceHandle>,
    /// SLO burn-rate alerting ([`crate::obs::slo`]): `Some(policy)`
    /// tracks every admission outcome and completion against the
    /// policy's objectives; the owner advances the deterministic
    /// window clock with [`Coordinator::slo_tick`]. `None` (the
    /// default) keeps the SLO plane entirely out of the hot path.
    pub slo: Option<SloPolicy>,
    /// Chunk-boundary batch preemption: when `true`, an interactive
    /// submit landing on a partition while the fleet is under SLO burn
    /// (burn ≥ 1) or admission pressure (≥ shed threshold) raises that
    /// partition's preemption flag; the worker checkpoints its current
    /// batch run at the next chunk boundary, requeues the un-run
    /// remainder as a typed continuation, and serves the interactive
    /// lane first. `false` (the default) never checks the flag —
    /// exactly the run-to-completion behavior.
    pub preempt: bool,
}

impl CoordinatorConfig {
    /// A homogeneous cycle-simulated fleet of `partitions` overlays.
    pub fn sim_fleet(spec: OverlaySpec, partitions: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            devices: Platform::multi_sim(spec, partitions).devices().to_vec(),
            cache_capacity: 32,
            compile_options: CompileOptions::default(),
            verify: true,
            routing: RoutingPolicy::default(),
            snapshot_dir: None,
            snapshot_every: None,
            autoscale: None,
            fusion_window: Duration::ZERO,
            admission: None,
            faults: None,
            trace: None,
            slo: None,
            preempt: false,
        }
    }

    /// A heterogeneous cycle-simulated fleet: `n` partitions per
    /// overlay spec, e.g. `[(8×8, 2), (4×4, 2)]` — the mixed fleet
    /// the resource-aware router places kernels across.
    pub fn sim_fleet_mixed(groups: Vec<(OverlaySpec, usize)>) -> CoordinatorConfig {
        CoordinatorConfig {
            devices: Platform::sim_mixed(&groups).devices().to_vec(),
            cache_capacity: 32,
            compile_options: CompileOptions::default(),
            verify: true,
            routing: RoutingPolicy::default(),
            snapshot_dir: None,
            snapshot_every: None,
            autoscale: None,
            fusion_window: Duration::ZERO,
            admission: None,
            faults: None,
            trace: None,
            slo: None,
            preempt: false,
        }
    }

    /// Serve across an existing platform's devices.
    pub fn for_platform(platform: &Platform) -> CoordinatorConfig {
        CoordinatorConfig {
            devices: platform.devices().to_vec(),
            cache_capacity: 32,
            compile_options: CompileOptions::default(),
            verify: true,
            routing: RoutingPolicy::default(),
            snapshot_dir: None,
            snapshot_every: None,
            autoscale: None,
            fusion_window: Duration::ZERO,
            admission: None,
            faults: None,
            trace: None,
            slo: None,
            preempt: false,
        }
    }
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2)
    }
}

/// The multi-overlay serving coordinator. See module docs.
pub struct Coordinator {
    fleet: Arc<Fleet>,
    /// Guards only the decision history ([`Router::commit`]); ranking
    /// itself runs lock-free through [`rank_specs`].
    router: Mutex<Router>,
    /// The routing knobs, copied out so the submit path can rank
    /// without touching the router lock.
    routing_policy: RoutingPolicy,
    scheduler: Arc<Mutex<SlotScheduler>>,
    /// Per-worker counter shards, merged on read — the submit and
    /// completion hot paths never share a log mutex.
    log: ServeLog,
    /// Warmed dispatch scratches (flat stream arenas + simulator
    /// blocks) shared by every partition worker.
    pool: Arc<ScratchPool>,
    workers: Vec<Worker>,
    partition_names: Vec<String>,
    /// The feedback loop from serving metrics back into the JIT
    /// compiler; absent when the config froze replication plans.
    autoscaler: Option<Arc<Autoscaler>>,
    /// Background compile/snapshot lane; spawned only when the
    /// autoscaler or the snapshot cadence needs it (and it owns the
    /// snapshot directory).
    bg: Option<Rescaler>,
    snapshot_every: Option<u64>,
    /// Accepted submits — drives the snapshot cadence.
    submitted: AtomicU64,
    /// The overload gate; absent when the config admits everything.
    admission: Option<AdmissionController>,
    /// The seeded fault plan; absent when no faults are injected.
    faults: Option<Arc<FaultPlan>>,
    /// The recovery half of the fault plane, shared with every worker.
    recovery: Arc<RecoveryPlane>,
    /// Coordinator-wide dispatch sequence — the fault plan's
    /// deterministic strike key. Counts every gated submit, admitted
    /// or not, so scripted strike sequences are stable under load.
    seq: AtomicU64,
    /// Gated submits since start — paces the p99 refresh below.
    gate_count: AtomicU64,
    /// Cached serving p99 (f64 bits), refreshed every few gated
    /// submits so admission never pays a full log merge per submit.
    p99_bits: AtomicU64,
    /// Span recorder for the whole serving stack; the no-op handle
    /// when tracing is off.
    trace: TraceHandle,
    /// SLO burn-rate engine; absent when the config set no policy.
    slo: Option<Arc<SloCollector>>,
    /// Chunk-boundary batch preemption armed (config knob). Flags are
    /// always registered on the recovery plane so tests can raise them
    /// directly, but workers only check them when this is set.
    preempt: bool,
    start: Instant,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let specs: Vec<String> =
            self.fleet.shards().iter().map(|s| s.spec().name()).collect();
        f.debug_struct("Coordinator")
            .field("specs", &specs)
            .field("partitions", &self.partition_names)
            .finish()
    }
}

impl Coordinator {
    /// Bring a fleet up: one compilation shard (JIT compiler, routing
    /// resource graph, kernel cache) per distinct spec, one worker
    /// thread per partition.
    pub fn new(config: CoordinatorConfig) -> Result<Coordinator> {
        let CoordinatorConfig {
            devices,
            cache_capacity,
            compile_options,
            verify,
            routing,
            snapshot_dir,
            snapshot_every,
            autoscale,
            fusion_window,
            admission,
            faults,
            trace,
            slo,
            preempt,
        } = config;
        let trace = trace.unwrap_or_else(TraceHandle::disabled);
        if let Some(policy) = &slo {
            policy.validate().context("slo policy")?;
        }
        let slo = slo.map(SloCollector::new);
        if devices.is_empty() {
            bail!("coordinator needs at least one overlay partition");
        }
        if snapshot_every == Some(0) {
            bail!("snapshot_every must be at least 1 submit");
        }
        if snapshot_every.is_some() && snapshot_dir.is_none() {
            bail!("snapshot_every requires snapshot_dir");
        }
        if let Some(policy) = &autoscale {
            policy.validate().context("autoscale policy")?;
        }
        // group partitions by spec fingerprint, first-seen order
        let mut groups: Vec<(OverlaySpec, Vec<usize>)> = Vec::new();
        for (i, d) in devices.iter().enumerate() {
            match groups
                .iter_mut()
                .find(|(s, _)| s.fingerprint() == d.spec.fingerprint())
            {
                Some((_, parts)) => parts.push(i),
                None => groups.push((d.spec.clone(), vec![i])),
            }
        }
        let fleet = Arc::new(Fleet::new(groups, &compile_options, cache_capacity)?);
        if let Some(dir) = &snapshot_dir {
            // infallible: unusable snapshot files are logged and cost a
            // cold start, never a failed restart
            fleet.load_snapshot(dir);
        }
        let scheduler = Arc::new(Mutex::new(SlotScheduler::with_specs(
            devices.iter().map(|d| d.spec.fingerprint()).collect(),
        )));
        let routing_policy = routing.clone();
        let router = Mutex::new(Router::new(routing));
        let log = ServeLog::new(devices.len());
        let pool = Arc::new(ScratchPool::new());
        let partition_names: Vec<String> = devices.iter().map(|d| d.name.clone()).collect();
        let autoscaler = autoscale.map(|policy| Arc::new(Autoscaler::new(policy)));
        let bg = if autoscaler.is_some() || snapshot_every.is_some() {
            Some(Rescaler::spawn(fleet.clone(), autoscaler.clone(), snapshot_dir))
        } else {
            None
        };
        let start = Instant::now();
        let faults = faults.map(|cfg| Arc::new(FaultPlan::new(cfg)));
        let recovery = Arc::new(RecoveryPlane::new(
            faults.clone(),
            MAX_DISPATCH_RETRIES,
            scheduler.clone(),
        ));
        // queues exist before workers so the recovery plane can requeue
        // a struck job onto any sibling partition
        let queues: Vec<Arc<LaneQueue<Box<Job>>>> =
            (0..devices.len()).map(|_| LaneQueue::new()).collect();
        recovery.register_queues(queues.clone());
        // Per-partition preemption flags are always registered (so
        // `raise_preempt` works for tests and operators), but workers
        // only poll them when the config armed preemption — a disabled
        // coordinator is the run-to-completion baseline.
        let preempt_flags: Vec<Arc<std::sync::atomic::AtomicBool>> = (0..devices.len())
            .map(|_| Arc::new(std::sync::atomic::AtomicBool::new(false)))
            .collect();
        recovery.register_preempt_flags(preempt_flags.clone());
        let workers: Vec<Worker> = devices
            .into_iter()
            .enumerate()
            .map(|(i, d)| {
                dispatch::spawn_worker(
                    i,
                    d,
                    queues[i].clone(),
                    scheduler.clone(),
                    log.shard(i),
                    pool.clone(),
                    verify,
                    fusion_window,
                    autoscaler.clone(),
                    recovery.clone(),
                    preempt.then(|| preempt_flags[i].clone()),
                    start,
                )
            })
            .collect();
        Ok(Coordinator {
            fleet,
            router,
            routing_policy,
            scheduler,
            log,
            pool,
            workers,
            partition_names,
            autoscaler,
            bg,
            snapshot_every,
            submitted: AtomicU64::new(0),
            admission: admission.map(AdmissionController::new),
            faults,
            recovery,
            seq: AtomicU64::new(0),
            gate_count: AtomicU64::new(0),
            p99_bits: AtomicU64::new(0),
            trace,
            slo,
            preempt,
            start,
        })
    }

    /// The coordinator's trace handle (the no-op recorder when the
    /// config left tracing off).
    pub fn trace(&self) -> &TraceHandle {
        &self.trace
    }

    /// The fleet's primary (first-configured) overlay description.
    pub fn spec(&self) -> &OverlaySpec {
        self.fleet.shards()[0].spec()
    }

    /// Every distinct overlay spec served, in shard order.
    pub fn specs(&self) -> Vec<OverlaySpec> {
        self.fleet.shards().iter().map(|s| s.spec().clone()).collect()
    }

    /// Number of partitions served.
    pub fn partitions(&self) -> usize {
        self.workers.len()
    }

    /// Asynchronously serve one kernel dispatch: route to a spec
    /// (resource-aware, at each spec's **live** replication factor),
    /// cache-or-compile on that spec's shard, schedule onto a
    /// same-spec partition, enqueue on its priority lane, return a
    /// completion handle.
    pub fn submit(
        &self,
        source: &str,
        args: &[SubmitArg],
        global_size: usize,
        priority: Priority,
    ) -> Result<DispatchHandle> {
        self.submit_with_deadline(source, args, global_size, priority, None)
    }

    /// [`Coordinator::submit`] with an optional completion deadline
    /// ("due in" relative to now). The deadline does not preempt
    /// anything; it shields the dispatch's partition from being
    /// chosen as a reconfiguration victim while the job is queued —
    /// a resident with imminent queued deadlines is never evicted in
    /// favor of slack batch work.
    pub fn submit_with_deadline(
        &self,
        source: &str,
        args: &[SubmitArg],
        global_size: usize,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<DispatchHandle> {
        match self.submit_gated(DEFAULT_TENANT, source, args, global_size, priority, deadline)? {
            Admission::Admitted(h) => Ok(h),
            Admission::Rejected(r) => Err(anyhow!("{}", r)),
        }
    }

    /// [`Coordinator::submit_with_deadline`] with explicit tenant
    /// attribution and a non-fatal rejection channel. When the config
    /// carries an [`AdmissionConfig`], every submit is triaged before
    /// any fleet resource is consumed — deadline feasibility first (no
    /// token charged for work that would miss anyway), then the
    /// tenant's token bucket, then pressure-driven batch shedding —
    /// and refused work comes back as [`Admission::Rejected`] with a
    /// typed [`RejectReason`]. `Err` is reserved for real failures
    /// (unknown kernel, argument mismatch, fleet-wide compile
    /// failure).
    pub fn submit_gated(
        &self,
        tenant: &str,
        source: &str,
        args: &[SubmitArg],
        global_size: usize,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Admission> {
        self.submit_traced(tenant, source, args, global_size, priority, deadline, None)
    }

    /// [`Coordinator::submit_gated`] with trace-context propagation:
    /// when tracing is on, the whole submit is recorded as one trace —
    /// a root `submit` span plus a child per serving stage — parented
    /// to `parent` when a cluster front door passed one down.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_traced(
        &self,
        tenant: &str,
        source: &str,
        args: &[SubmitArg],
        global_size: usize,
        priority: Priority,
        deadline: Option<Duration>,
        parent: Option<ParentCtx>,
    ) -> Result<Admission> {
        // every gated submit gets a sequence number — admitted or not —
        // so a fault plan's scripted strikes stay deterministic even
        // when admission decisions change upstream of them
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let trace = SubmitTrace::begin(&self.trace, parent);
        let result = self.submit_inner(
            tenant,
            source,
            args,
            global_size,
            priority,
            deadline,
            seq,
            trace.as_ref(),
        );
        if let Some(t) = &trace {
            // the root is recorded last, on every exit path, so a
            // complete trace always has exactly one
            let tag = match &result {
                Ok(Admission::Admitted(_)) => "admitted",
                Ok(Admission::Rejected(_)) => "rejected",
                Err(_) => "error",
            };
            t.finish_root(Phase::Submit, tag, seq);
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn submit_inner(
        &self,
        tenant: &str,
        source: &str,
        args: &[SubmitArg],
        global_size: usize,
        priority: Priority,
        deadline: Option<Duration>,
        seq: u64,
        trace: Option<&SubmitTrace>,
    ) -> Result<Admission> {
        let t_route = trace.map(|t| t.now()).unwrap_or(0);
        let profile = self.fleet.profile(source)?;
        let deadline_nanos =
            deadline.map(|d| (self.start.elapsed() + d).as_nanos() as u64);

        // live (possibly rescaled) variant per shard — one autoscaler
        // lock for the whole fleet, taken before the scheduler lock so
        // the two never nest
        let variants: Vec<Option<ActiveVariant>> = match &self.autoscaler {
            Some(a) => {
                let fps: Vec<u64> =
                    self.fleet.shards().iter().map(|s| s.fingerprint()).collect();
                a.active_all(profile.source_hash, &fps)
            }
            None => vec![None; self.fleet.shards().len()],
        };

        // per-spec cache keys at the live factor, computed before any
        // lock is taken
        let keys: Vec<CacheKey> = self
            .fleet
            .shards()
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                variants[i]
                    .as_ref()
                    .map(|v| v.key)
                    .unwrap_or_else(|| shard.cache_key_for_hash(profile.source_hash))
            })
            .collect();

        // the scheduler lock is held only for the raw (queue depth,
        // residency) reads — the decision itself; everything derived
        // from the profile's plans is assembled outside it
        let sched_obs: Vec<(usize, bool)> = {
            let sched = self.scheduler.lock().unwrap();
            self.fleet
                .shards()
                .iter()
                .zip(&keys)
                .map(|(shard, key)| sched.observe(shard.fingerprint(), key))
                .collect()
        };
        let mut observations: Vec<SpecObservation> = self
            .fleet
            .shards()
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let (min_queue_depth, resident) = sched_obs[i];
                let fit = profile.fits[i];
                let factor = match (&variants[i], fit) {
                    (Some(v), _) => v.factor,
                    (None, Some(f)) => f.factor,
                    (None, None) => 0,
                };
                let gops = if fit.is_some() {
                    achieved_gops(factor, profile.ops_per_copy, shard.spec().fmax_mhz())
                } else {
                    0.0
                };
                SpecObservation {
                    fingerprint: shard.fingerprint(),
                    spec: shard.spec().name(),
                    fits: fit.is_some(),
                    adequate: false,
                    factor,
                    limit: fit.map(|f| f.limit),
                    gops,
                    peak_gops: shard.spec().peak_gops(),
                    min_queue_depth,
                    resident,
                    config_seconds: shard.config_seconds_estimate(),
                }
            })
            .collect();

        // withhold poisoned (kernel, spec) pairs from ranking — expired
        // entries pass through once as a re-probe (see `Fleet::poison`)
        let mask = self.fleet.poison_mask(profile.source_hash);
        let withheld = apply_poison_mask(&mut observations, &mask);

        // ranking is pure — no router lock held (the lock guards only
        // the decision history appended by `commit` below)
        let (ranked, reason, copies_wanted) =
            match rank_specs(&self.routing_policy, &profile, &mut observations, global_size) {
                Ok(r) => r,
                Err(e) if withheld > 0 => {
                    // distinguish "fits nowhere" from "every fitting
                    // spec is cooling off after repeated failures"
                    return Err(anyhow!(
                        "{e:#}; {withheld} fitting spec(s) are poisoned and awaiting re-probe"
                    ));
                }
                Err(e) => return Err(e),
            };
        if let Some(t) = trace {
            // a0 = winning spec fingerprint, a1 = copies wanted
            t.child(
                Phase::Route,
                reason.name(),
                t_route,
                observations[ranked[0]].fingerprint,
                copies_wanted as u64,
            );
        }

        // the admission gate sits after ranking (it needs the best
        // candidate's queue depth and throughput to price the dispatch)
        // but before compilation — refused work never touches the JIT
        if let Some(ctrl) = &self.admission {
            let t_admit = trace.map(|t| t.now()).unwrap_or(0);
            let best = &observations[ranked[0]];
            let est_service_ms = estimate_service_ms(
                (profile.ops_per_copy * global_size) as f64,
                best.gops,
                best.min_queue_depth,
                best.config_seconds,
                best.resident,
            );
            let req = AdmitRequest {
                tenant,
                interactive: matches!(priority, Priority::Interactive),
                now_ns: self.start.elapsed().as_nanos() as u64,
                queue_depth: best.min_queue_depth,
                p99_ms: self.gate_p99_ms(),
                est_service_ms,
                budget_ms: deadline.map(|d| d.as_secs_f64() * 1e3),
            };
            if let Err(reject) = ctrl.admit(&req) {
                if let Some(t) = trace {
                    t.child(Phase::Admission, reject.kind(), t_admit, 0, 0);
                    t.pin(crate::obs::CLASS_REJECT, reject.kind());
                }
                // a refused submit is a bad event for availability
                // objectives — the tenant asked and was turned away
                if let Some(s) = &self.slo {
                    s.rejected(tenant, req.interactive);
                }
                // rejections still feed the autoscaler's load signal:
                // refused demand is demand the fleet failed to absorb,
                // and re-replicating the hot kernel relieves it
                if let Some(a) = &self.autoscaler {
                    if let Some(fit) = profile.fits[ranked[0]] {
                        let best = &observations[ranked[0]];
                        a.note_reject(&SubmitObservation {
                            kernel: &profile.name,
                            source,
                            source_hash: profile.source_hash,
                            spec: &best.spec,
                            spec_fp: best.fingerprint,
                            demand: copies_wanted,
                            queue_depth: best.min_queue_depth,
                            factor: best.factor,
                            ceiling: fit.factor,
                        });
                    }
                }
                return Ok(Admission::Rejected(reject));
            }
            if let Some(t) = trace {
                t.child(Phase::Admission, "admitted", t_admit, 0, 0);
            }
        }

        // cache-or-compile on the ranked shards — through the live
        // variant where one is installed; a compile failure poisons
        // that (kernel, spec) pair and falls through
        let t_cache = trace.map(|t| t.now()).unwrap_or(0);
        let mut chosen = None;
        let mut fallback = false;
        let mut last_err: Option<anyhow::Error> = None;
        for (pos, &si) in ranked.iter().enumerate() {
            if let Some(v) = &variants[si] {
                let shard = &self.fleet.shards()[si];
                let (servable, cache_hit) = match shard.get_cached(&v.key) {
                    Some(k) => (k, true),
                    None => {
                        // the LRU evicted the variant's entry; the
                        // autoscaler still holds the artifact, so
                        // re-admit it instead of recompiling
                        shard.admit(v.key, v.servable.clone());
                        (v.servable.clone(), false)
                    }
                };
                chosen = Some((si, (servable, cache_hit, v.key)));
                break;
            }
            let shard = &self.fleet.shards()[si];
            // injected compile fault: only a *cold* compile can fail
            // (a cached kernel never re-enters the JIT), and only the
            // first-ranked spec honors scripted strikes (salt = rank)
            if let Some(f) = &self.faults {
                if !shard.contains(&keys[si])
                    && f.strikes(FaultKind::CompileFail, seq, pos as u64, 0)
                {
                    f.note_injected(FaultKind::CompileFail);
                    if let Some(t) = trace {
                        t.child(
                            Phase::Compile,
                            FaultKind::CompileFail.name(),
                            t_cache,
                            si as u64,
                            0,
                        );
                        t.pin(crate::obs::CLASS_FAULT, FaultKind::CompileFail.name());
                    }
                    self.fleet.poison(profile.source_hash, si);
                    fallback = true;
                    last_err = Some(anyhow!(
                        "injected compile fault for kernel '{}' on spec {}",
                        profile.name,
                        shard.spec().name()
                    ));
                    continue;
                }
            }
            match shard.get_or_compile(source) {
                Ok(hit) => {
                    // a success on a previously poisoned pair is the
                    // re-probe paying off — lift the poison and credit
                    // the recovery
                    if self.fleet.clear_poison(profile.source_hash, si) {
                        if let Some(f) = &self.faults {
                            f.note_recovered(FaultKind::CompileFail);
                        }
                    }
                    chosen = Some((si, hit));
                    break;
                }
                Err(e) => {
                    self.fleet.poison(profile.source_hash, si);
                    fallback = true;
                    last_err = Some(e);
                }
            }
        }
        let Some((shard_index, (servable, cache_hit, key))) = chosen else {
            return Err(last_err
                .unwrap_or_else(|| anyhow!("no routable overlay spec"))
                .context(format!(
                    "kernel '{}' failed to compile on every candidate spec",
                    profile.name
                )));
        };
        let shard = &self.fleet.shards()[shard_index];
        let queue_depth_seen = observations[shard_index].min_queue_depth;
        if let Some(t) = trace {
            let (phase, tag) = if cache_hit {
                (Phase::CacheLookup, "hit")
            } else {
                (Phase::Compile, "miss")
            };
            t.child(phase, tag, t_cache, shard_index as u64, cache_hit as u64);
        }

        if args.len() != servable.params.len() {
            bail!(
                "kernel '{}' takes {} arguments, got {}",
                servable.name,
                servable.params.len(),
                args.len()
            );
        }
        let kernel = Kernel::from_servable(servable.clone());
        for (i, a) in args.iter().enumerate() {
            match a {
                SubmitArg::Buffer(b) => kernel.set_arg(i, b)?,
                SubmitArg::Scalar(v) => kernel.set_arg_scalar(i, *v)?,
            }
        }

        let config_cost = ConfigSizeModel::overlay_config_seconds(
            shard.spec(),
            servable.bitstream.byte_size(),
        );
        // place the dispatch; an injected reconfiguration failure
        // strikes the chosen partition and re-places onto the
        // least-loaded sibling (attempt > 0 is never struck, so the
        // loop is bounded by the partition count)
        let t_slot = trace.map(|t| t.now()).unwrap_or(0);
        let (decision, place_attempts) = {
            let mut attempt: u32 = 0;
            let mut struck_partition = 0;
            loop {
                let d = {
                    let mut sched = self.scheduler.lock().unwrap();
                    if attempt == 0 {
                        sched.pick_with_deadline(
                            shard.fingerprint(),
                            key,
                            config_cost,
                            priority,
                            deadline_nanos,
                        )
                    } else {
                        // re-place away from the partition whose load
                        // just failed (falls back to it only when it
                        // is the spec's sole partition)
                        match sched.requeue_sibling(
                            shard.fingerprint(),
                            key,
                            config_cost,
                            priority,
                            deadline_nanos,
                            struck_partition,
                        ) {
                            Some(d) => d,
                            None => bail!(
                                "no partition of spec {} left to configure",
                                shard.spec().name()
                            ),
                        }
                    }
                };
                let struck = d.reconfigure
                    && self.faults.as_ref().is_some_and(|f| {
                        f.strikes(FaultKind::ReconfigFail, seq, 0, attempt)
                    });
                if struck {
                    let f = self.faults.as_ref().unwrap();
                    f.note_injected(FaultKind::ReconfigFail);
                    let quarantined = {
                        let mut sched = self.scheduler.lock().unwrap();
                        // the load never happened: undo the pick's
                        // accounting and charge the partition a strike
                        // so repeat offenders quarantine
                        sched.cancel(&d, deadline_nanos);
                        sched.note_partition_failure(d.partition)
                    };
                    if let Some(t) = trace {
                        t.child(
                            Phase::Retry,
                            FaultKind::ReconfigFail.name(),
                            t_slot,
                            attempt as u64,
                            d.partition as u64,
                        );
                        t.pin(crate::obs::CLASS_FAULT, FaultKind::ReconfigFail.name());
                        if quarantined {
                            t.pin(crate::obs::CLASS_QUARANTINE, "partition");
                        }
                    }
                    struck_partition = d.partition;
                    attempt += 1;
                    continue;
                }
                if attempt > 0 {
                    // the re-pick configured cleanly somewhere else
                    if let Some(f) = &self.faults {
                        f.note_recovered(FaultKind::ReconfigFail);
                    }
                }
                break (d, attempt);
            }
        };
        if let Some(t) = trace {
            let tag = if decision.reconfigure { "reconfigure" } else { "resident" };
            t.child(
                Phase::SlotPick,
                tag,
                t_slot,
                decision.partition as u64,
                place_attempts as u64,
            );
        }

        let handle = HandleInner::new();
        let job = Job {
            kernel,
            global_size,
            partition: decision.partition,
            key,
            spec: shard.spec().name(),
            source_hash: profile.source_hash,
            spec_fp: shard.fingerprint(),
            priority,
            config_seconds: decision.config_seconds,
            deadline_nanos,
            cache_hit,
            enqueued: Instant::now(),
            handle: handle.clone(),
            seq,
            attempts: 0,
            preemptions: 0,
            last_fault: None,
            config_cost,
            trace: trace.map(|t| t.job_trace()),
            slo: self.slo.as_ref().map(|c| SloProbe {
                collector: c.clone(),
                tenant: Arc::from(tenant),
                interactive: matches!(priority, Priority::Interactive),
            }),
        };
        if let Some(s) = &self.slo {
            s.admitted(tenant, matches!(priority, Priority::Interactive));
        }
        if self.workers[decision.partition]
            .queue
            .push(Box::new(job), priority)
            .is_err()
        {
            // dead worker: the dispatch never ran, undo its accounting
            // (the route record is only committed below, on success)
            self.scheduler.lock().unwrap().cancel(&decision, deadline_nanos);
            bail!("partition {} worker is gone", decision.partition);
        }
        // Preemption eligibility: an interactive arrival under SLO
        // burn (burn ≥ 1) or admission pressure (≥ shed threshold)
        // raises the target partition's flag so a batch run in flight
        // there checkpoints at its next chunk boundary and yields.
        if self.preempt && matches!(priority, Priority::Interactive) {
            let burning = self
                .slo
                .as_ref()
                .map(|s| s.burn() >= 1.0)
                .unwrap_or(false);
            let pressured = self
                .admission
                .as_ref()
                .map(|a| a.overloaded())
                .unwrap_or(false);
            if burning || pressured {
                self.recovery.raise_preempt(decision.partition);
            }
        }

        self.router.lock().unwrap().commit(
            RouteRecord {
                kernel: profile.name.clone(),
                tenant: tenant.to_string(),
                source_hash: profile.source_hash,
                global_size,
                copies_wanted,
                chosen: shard.fingerprint(),
                chosen_spec: shard.spec().name(),
                reason,
                fallback,
                priority,
                specs: observations,
            },
            servable.factor,
        );

        // post-accept hooks: feed the autoscaler's submit-side load
        // signal (possibly enqueueing a background rescale) and
        // advance the periodic-snapshot cadence
        if let (Some(a), Some(bg)) = (&self.autoscaler, &self.bg) {
            // the plan (compile-free front-half) factor is the FU/IO
            // ceiling scale-ups may grow back toward
            if let Some(fit) = profile.fits[shard_index] {
                let spec_name = shard.spec().name();
                let proposal = a.note_submit(&SubmitObservation {
                    kernel: &profile.name,
                    source,
                    source_hash: profile.source_hash,
                    spec: &spec_name,
                    spec_fp: shard.fingerprint(),
                    demand: copies_wanted,
                    queue_depth: queue_depth_seen,
                    factor: servable.factor,
                    ceiling: fit.factor,
                });
                if let Some(p) = proposal {
                    bg.push(BgTask::Rescale(p));
                }
            }
        }
        if let (Some(every), Some(bg)) = (self.snapshot_every, &self.bg) {
            // the constructor guarantees snapshot_every implies
            // snapshot_dir, so the cadence alone decides
            let n = self.submitted.fetch_add(1, Ordering::Relaxed) + 1;
            if n % every == 0 {
                bg.push(BgTask::Snapshot);
            }
        }
        Ok(Admission::Admitted(DispatchHandle { inner: handle }))
    }

    /// Serving p99 for the admission gate, refreshed every few gated
    /// submits (a full log merge per submit would put an O(shards)
    /// histogram walk on the hot path).
    fn gate_p99_ms(&self) -> f64 {
        let g = self.gate_count.fetch_add(1, Ordering::Relaxed);
        if g % 32 == 0 {
            let p99 = self.log.totals().latency_hist.p99_ms();
            self.p99_bits.store(p99.to_bits(), Ordering::Relaxed);
        }
        f64::from_bits(self.p99_bits.load(Ordering::Relaxed))
    }

    /// Close the current SLO window at caller time `now_ns`, evaluate
    /// every objective's fast+slow burn rate, and feed the worst burn
    /// back into the control surfaces: the admission gate's pressure
    /// signal (burning budget sheds batch work sooner) and the
    /// autoscaler's load boost (a burning fleet scales up). Returns
    /// the alert transitions this tick produced; a no-op `vec![]`
    /// when no SLO policy is configured.
    ///
    /// The clock is caller-advanced — `now_ns` on any monotone basis
    /// the caller likes — which is what makes scripted SLO tests (and
    /// replayed campaigns) fully deterministic.
    pub fn slo_tick(&self, now_ns: u64) -> Vec<SloAlert> {
        let Some(s) = &self.slo else {
            return Vec::new();
        };
        let alerts = s.tick(now_ns);
        let burn = s.burn();
        if let Some(ctrl) = &self.admission {
            ctrl.set_slo_burn(burn);
        }
        if let Some(a) = &self.autoscaler {
            a.set_slo_burn(burn);
            // SLO-targeted scaling: feed the windowed latency signal
            // (p99 over the slow window vs the declared target) so
            // scale-ups are driven by the SLO, not demand bands.
            if let Some((p99_ms, target_ms)) = s.latency_control_signal() {
                a.set_slo_latency(p99_ms, target_ms);
            }
        }
        alerts
    }

    /// Raise the preemption flag on one partition: a batch run in
    /// flight there checkpoints at its next chunk boundary and
    /// requeues its un-run remainder as a typed continuation. Normally
    /// raised by the submit path (interactive arrival under burn or
    /// pressure); exposed so tests and operators can force one.
    /// Ignored for out-of-range partitions; workers only honor it when
    /// [`CoordinatorConfig::preempt`] is set.
    pub fn raise_preempt(&self, partition: usize) {
        self.recovery.raise_preempt(partition);
    }

    /// The typed continuation records of every preempted-and-requeued
    /// batch remainder (oldest first, bounded), plus the count of
    /// records dropped past the bound.
    pub fn preemption_continuations(&self) -> (Vec<ContinuationRecord>, u64) {
        self.recovery.continuation_records()
    }

    /// The SLO engine's retained alert transitions, oldest first
    /// (empty when no SLO policy is configured).
    pub fn slo_alerts(&self) -> Vec<SloAlert> {
        self.slo.as_ref().map_or_else(Vec::new, |s| s.alerts())
    }

    /// "p99 over the last `n` ticks" for the named SLO objective.
    pub fn slo_windowed_p99_ms(&self, objective: &str, n: usize) -> Option<f64> {
        self.slo.as_ref().and_then(|s| s.windowed_p99_ms(objective, n))
    }

    /// Snapshot of the serving statistics. Locks are taken one at a
    /// time, briefly: the sharded log merges without any global
    /// mutex, and the router/scheduler are each held only long enough
    /// to copy their counters out.
    pub fn stats(&self) -> ServingStats {
        let elapsed = self.start.elapsed().as_secs_f64().max(1e-9);
        let log = self.log.totals();

        let mut cache = CacheStats::default();
        let mut compile_seconds = 0.0;
        let mut per_spec = Vec::with_capacity(self.fleet.shards().len());
        {
            let router = self.router.lock().unwrap();
            for shard in self.fleet.shards() {
                let c = shard.cache_stats();
                cache.hits += c.hits;
                cache.misses += c.misses;
                cache.evictions += c.evictions;
                cache.entries += c.entries;
                cache.capacity += c.capacity;
                let cs = shard.compile_seconds();
                compile_seconds += cs;
                let r = router.spec_stats(shard.fingerprint());
                per_spec.push(SpecServingStats {
                    spec: shard.spec().name(),
                    fingerprint: shard.fingerprint(),
                    partitions: shard.partitions().len(),
                    cache: c,
                    compile_seconds: cs,
                    routed: r.map_or(0, |r| r.routed),
                    best_fit: r.map_or(0, |r| r.best_fit),
                    widest: r.map_or(0, |r| r.widest),
                    only_fit: r.map_or(0, |r| r.only_fit),
                    fallbacks: r.map_or(0, |r| r.fallbacks),
                    cross_spec_hits: shard.cross_spec_hits(),
                    replication_histogram: r.map_or_else(Vec::new, |r| {
                        r.histogram.iter().map(|(&f, &n)| (f, n)).collect()
                    }),
                });
            }
        }

        let (partitions, reconfig_count, reconfig_seconds, quarantine_events, quarantined) = {
            let sched = self.scheduler.lock().unwrap();
            let partitions: Vec<PartitionServingStats> = sched
                .partitions()
                .iter()
                .enumerate()
                .map(|(i, p)| PartitionServingStats {
                    partition: i,
                    overlay: self.partition_names[i].clone(),
                    dispatches: p.dispatches,
                    reconfigs: p.reconfigs,
                    busy_seconds: p.busy_seconds,
                    utilization: (p.busy_seconds / elapsed).min(1.0),
                })
                .collect();
            (
                partitions,
                sched.reconfig_count(),
                sched.reconfig_seconds,
                sched.quarantine_events(),
                sched.quarantined_count(),
            )
        };

        let admission = self.admission.as_ref().map(|a| a.stats());
        let rejected_submits = admission
            .as_ref()
            .map_or(0, |a| a.rejected_quota + a.rejected_deadline);
        let shed_submits = admission.as_ref().map_or(0, |a| a.shed);

        ServingStats {
            cache,
            reconfig_count,
            reconfig_seconds,
            latency: LatencyStats::from_hist(&log.latency_hist),
            latency_hist: log.latency_hist,
            partitions,
            per_spec,
            total_dispatches: log.total_dispatches,
            total_items: log.total_items,
            verify_failures: log.verify_failures,
            dispatch_errors: log.errors,
            fused_batches: log.fused_batches,
            compile_seconds,
            scratch_pool: self.pool.stats(),
            autoscale: self.autoscaler.as_ref().map(|a| a.stats()),
            rejected_submits,
            shed_submits,
            retried_dispatches: self.recovery.retried_count(),
            preempted_runs: self.recovery.preempted_run_count(),
            preempted_continuations: self.recovery.preempted_requeue_count(),
            quarantine_events,
            quarantined_partitions: quarantined,
            admission,
            faults: self.faults.as_ref().map(|f| f.tally()),
            poison: self.fleet.poison_stats(),
            slo: self.slo.as_ref().map(|s| s.stats()),
        }
    }

    /// Scratch-pool counters of the dispatch data plane (arena reuse
    /// and warm-up heap growth; see [`crate::arena::PoolStats`]).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// The fault plan's injected/recovered tallies; `None` when no
    /// faults are configured.
    pub fn fault_tally(&self) -> Option<FaultTally> {
        self.faults.as_ref().map(|f| f.tally())
    }

    /// The admission gate's live counters; `None` when every submit
    /// is admitted.
    pub fn admission_stats(&self) -> Option<AdmissionStats> {
        self.admission.as_ref().map(|a| a.stats())
    }

    /// The retained scale events (oldest first, bounded by
    /// [`AutoscalePolicy::max_events`]) — the autoscaler's audit
    /// trail, mirroring [`Coordinator::routing_log`]. Empty when no
    /// autoscaler is configured.
    pub fn scale_log(&self) -> Vec<ScaleEvent> {
        self.autoscaler
            .as_ref()
            .map_or_else(Vec::new, |a| a.events())
    }

    /// Block until the background lane is idle: every proposed
    /// rescale has installed (or failed) and every periodic snapshot
    /// has flushed. A no-op without a background lane. Phase-shifting
    /// drivers and tests call this to make swap timing deterministic;
    /// serving itself never needs it.
    pub fn drain_background(&self) {
        if let Some(bg) = &self.bg {
            bg.drain();
        }
    }

    /// Periodic snapshots flushed by the background lane (see
    /// [`CoordinatorConfig::snapshot_every`]).
    pub fn background_snapshots_written(&self) -> u64 {
        self.bg.as_ref().map_or(0, |b| b.snapshots_written())
    }

    /// Periodic snapshot flushes that errored (disk trouble; serving
    /// is unaffected, but warm-start state is going stale — monitor
    /// this on long-running fleets).
    pub fn background_snapshot_errors(&self) -> u64 {
        self.bg.as_ref().map_or(0, |b| b.snapshot_errors())
    }

    /// The retained routing decisions (oldest first, bounded by
    /// [`RoutingPolicy::max_records`]) with the per-spec observations
    /// each was made from — the audit trail the fleet tests assert
    /// placement properties on.
    pub fn routing_log(&self) -> Vec<RouteRecord> {
        self.router.lock().unwrap().records().to_vec()
    }

    /// Persist every shard's kernel cache under `dir` (one JSON file
    /// per spec). A fleet constructed with
    /// [`CoordinatorConfig::snapshot_dir`] pointing here warm-starts
    /// with these kernels resident. Returns entries written.
    pub fn save_snapshot(&self, dir: &Path) -> Result<usize> {
        self.fleet.save_snapshot(dir)
    }

    /// Jobs currently queued or executing summed across every
    /// partition — the cluster tier's cheap pressure signal. One
    /// scheduler lock, no log merge: a full [`Coordinator::stats`]
    /// per routing decision would put an O(dispatches) walk on the
    /// cluster front door's hot path.
    pub fn queue_depth(&self) -> usize {
        let sched = self.scheduler.lock().unwrap();
        sched.partitions().iter().map(|p| p.queue_depth).sum()
    }

    /// Graceful, deterministic shutdown: stop the background
    /// rescale/snapshot lane (its `Drop` drains and joins), close
    /// every partition's lane queue so workers finish what's queued
    /// and exit, then join the worker threads. Queued jobs a worker
    /// cannot finish are failed with typed reasons by its teardown
    /// guard — `wait()`ing callers never hang. Also runs on drop;
    /// both paths share the same idempotent teardown.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
        // Drop re-runs shutdown_impl; every step below is a no-op the
        // second time (`take()`d options, idempotent queue close)
    }

    fn shutdown_impl(&mut self) {
        // stop the background lane first so no rescale installs race
        // worker teardown (Rescaler's own Drop closes and joins)
        self.bg.take();
        for w in &self.workers {
            w.queue.close();
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Wait on a batch of handles, preserving submission order.
pub fn wait_all(handles: Vec<DispatchHandle>) -> Result<Vec<DispatchResult>> {
    handles.into_iter().map(DispatchHandle::wait).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_kernels::{CHEBYSHEV, POLY1};
    use crate::overlay::FuType;
    use crate::runtime_ocl::{Backend, Context};

    fn cheb_ref(x: i32) -> i32 {
        x.wrapping_mul(
            x.wrapping_mul(16i32.wrapping_mul(x).wrapping_mul(x).wrapping_sub(20))
                .wrapping_mul(x)
                .wrapping_add(5),
        )
    }

    fn host_ctx() -> Context {
        let dev = Device {
            spec: OverlaySpec::zynq_default(),
            backend: Backend::CycleSim,
            name: "host".into(),
        };
        Context::new(&dev)
    }

    #[test]
    fn serves_correct_results_with_cache_hits() {
        let coord =
            Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2))
                .unwrap();
        let ctx = host_ctx();

        let n = 256;
        let mut handles = Vec::new();
        let mut outputs = Vec::new();
        for round in 0..3 {
            let a = ctx.create_buffer(n);
            let b = ctx.create_buffer(n);
            let xs: Vec<i32> = (0..n as i32).map(|i| (i % 11) - 5 + round).collect();
            a.write(&xs);
            let h = coord
                .submit(
                    CHEBYSHEV,
                    &[SubmitArg::Buffer(a), SubmitArg::Buffer(b.clone())],
                    n,
                    Priority::Interactive,
                )
                .unwrap();
            handles.push(h);
            outputs.push((xs, b));
        }
        let results = wait_all(handles).unwrap();
        assert_eq!(results.len(), 3);
        assert!(!results[0].cache_hit, "first dispatch must compile");
        assert!(results[1].cache_hit && results[2].cache_hit);
        assert!(results.iter().all(|r| r.verified == Some(true)));
        assert!(results.iter().all(|r| r.spec == "8x8-dsp2"));
        for (xs, b) in outputs {
            let out = b.read();
            for (x, y) in xs.iter().zip(&out) {
                assert_eq!(*y, cheb_ref(*x));
            }
        }
        let stats = coord.stats();
        assert_eq!(stats.cache.misses, 1);
        assert_eq!(stats.cache.hits, 2);
        assert_eq!(stats.total_dispatches, 3);
        assert_eq!(stats.verify_failures, 0);
        assert!(stats.cache.hit_rate() > 0.6);
        assert_eq!(stats.per_spec.len(), 1);
        assert_eq!(stats.per_spec[0].routed, 3);
        assert_eq!(stats.per_spec[0].cross_spec_hits, 0);
        coord.shutdown();
    }

    #[test]
    fn distinct_kernels_spread_across_partitions() {
        let coord =
            Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2))
                .unwrap();
        let ctx = host_ctx();
        let n = 64;
        let submit = |src: &str, params: usize| {
            let args: Vec<SubmitArg> = (0..params)
                .map(|_| {
                    let b = ctx.create_buffer(n + 8);
                    b.write(&vec![1; n + 8]);
                    SubmitArg::Buffer(b)
                })
                .collect();
            coord.submit(src, &args, n, Priority::Interactive).unwrap()
        };
        let r1 = submit(CHEBYSHEV, 2).wait().unwrap();
        let r2 = submit(POLY1, 2).wait().unwrap();
        assert_ne!(r1.partition, r2.partition, "cold fleet spreads kernels");
        // both resident now: repeats hit their partitions with zero
        // config cost
        let r1b = submit(CHEBYSHEV, 2).wait().unwrap();
        let r2b = submit(POLY1, 2).wait().unwrap();
        assert_eq!(r1b.partition, r1.partition);
        assert_eq!(r2b.partition, r2.partition);
        assert_eq!(r1b.event.config_seconds, 0.0);
        assert_eq!(r2b.event.config_seconds, 0.0);
        assert!(r1.event.config_seconds > 0.0);
        let stats = coord.stats();
        assert_eq!(stats.reconfig_count, 2);
    }

    #[test]
    fn argument_mismatch_is_reported() {
        let coord =
            Coordinator::new(CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1))
                .unwrap();
        let err = coord
            .submit(CHEBYSHEV, &[], 16, Priority::Interactive)
            .unwrap_err()
            .to_string();
        assert!(err.contains("takes 2 arguments"), "{err}");
    }

    #[test]
    fn heterogeneous_fleet_is_served_by_per_spec_shards() {
        // a mixed 8×8 + 4×4 fleet comes up and serves both specs —
        // the capability the homogeneous coordinator used to reject
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 2);
        cfg.devices[1].spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let coord = Coordinator::new(cfg).unwrap();
        assert_eq!(coord.specs().len(), 2);
        let ctx = host_ctx();
        let n = 64;
        let a = ctx.create_buffer(n + 8);
        let b = ctx.create_buffer(n + 8);
        a.write(&(0..(n as i32) + 8).map(|i| i % 7 - 3).collect::<Vec<_>>());
        // a small dispatch best-fits the 4×4 tier
        let r = coord
            .submit(
                CHEBYSHEV,
                &[SubmitArg::Buffer(a), SubmitArg::Buffer(b.clone())],
                n,
                Priority::Interactive,
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.spec, "4x4-dsp2");
        assert_eq!(r.verified, Some(true));
        let out = b.read();
        for i in 0..n as i32 {
            assert_eq!(out[i as usize], cheb_ref(i % 7 - 3));
        }
        let stats = coord.stats();
        assert_eq!(stats.per_spec.len(), 2);
        assert!(stats.per_spec.iter().all(|s| s.cross_spec_hits == 0));
    }

    #[test]
    fn empty_fleet_is_rejected() {
        let cfg = CoordinatorConfig {
            devices: Vec::new(),
            cache_capacity: 4,
            compile_options: CompileOptions::default(),
            verify: false,
            routing: RoutingPolicy::default(),
            snapshot_dir: None,
            snapshot_every: None,
            autoscale: None,
            fusion_window: Duration::ZERO,
            admission: None,
            faults: None,
            trace: None,
            slo: None,
            preempt: false,
        };
        assert!(Coordinator::new(cfg).is_err());
    }

    #[test]
    fn invalid_background_configs_are_rejected() {
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
        cfg.snapshot_every = Some(4); // cadence without a directory
        assert!(Coordinator::new(cfg).is_err());
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
        cfg.snapshot_dir = Some(std::env::temp_dir());
        cfg.snapshot_every = Some(0);
        assert!(Coordinator::new(cfg).is_err());
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
        cfg.autoscale = Some(crate::autoscale::AutoscalePolicy {
            down_ratio: 0.9, // overlapping hysteresis bands
            ..Default::default()
        });
        assert!(Coordinator::new(cfg).is_err());
    }

    #[test]
    fn snapshot_warm_starts_a_restarted_coordinator() {
        let dir = std::env::temp_dir().join(format!(
            "overlay-jit-coord-snapshot-{}",
            std::process::id()
        ));
        let ctx = host_ctx();
        let n = 128;
        let submit_cheb = |coord: &Coordinator| {
            let a = ctx.create_buffer(n);
            let b = ctx.create_buffer(n);
            a.write(&(0..n as i32).map(|i| i % 9 - 4).collect::<Vec<_>>());
            coord
                .submit(
                    CHEBYSHEV,
                    &[SubmitArg::Buffer(a), SubmitArg::Buffer(b.clone())],
                    n,
                    Priority::Interactive,
                )
                .unwrap()
                .wait()
                .unwrap();
            b
        };
        {
            let coord = Coordinator::new(CoordinatorConfig::sim_fleet(
                OverlaySpec::zynq_default(),
                1,
            ))
            .unwrap();
            submit_cheb(&coord);
            assert_eq!(coord.save_snapshot(&dir).unwrap(), 1);
        }
        // restart: the warm fleet serves the kernel without compiling
        let mut cfg = CoordinatorConfig::sim_fleet(OverlaySpec::zynq_default(), 1);
        cfg.snapshot_dir = Some(dir.clone());
        let warm = Coordinator::new(cfg).unwrap();
        let b = submit_cheb(&warm);
        let out = b.read();
        for i in 0..n as i32 {
            assert_eq!(out[i as usize], cheb_ref(i % 9 - 4));
        }
        let stats = warm.stats();
        assert_eq!(stats.cache.misses, 0, "warm start must not compile");
        assert_eq!(stats.cache.hits, 1);
        assert_eq!(stats.compile_seconds, 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
