//! The slot-aware scheduler: configured overlays as a cache.
//!
//! A partition that already holds a kernel's bitstream executes it
//! with **zero** configuration cost; any other partition must first
//! pay the modeled reconfiguration time (µs-class, from
//! [`crate::overlay::ConfigSizeModel`] — the paper's 42.4 µs for the
//! 8×8 overlay). The scheduler therefore treats the fleet's configured
//! state exactly like a cache:
//!
//! 1. **Affinity** — prefer a partition whose resident bitstream
//!    matches the request (least queue depth among them);
//! 2. **Cold fill** — otherwise prefer a never-configured partition;
//! 3. **Victim** — otherwise evict by (queue depth, queued deadlines,
//!    priority class, last-use): among equally loaded candidates, a
//!    partition with **deadline-carrying work still queued** is never
//!    evicted in favor of one holding only slack batch work (and
//!    sooner deadlines protect harder), then batch-class residents
//!    give way before interactive ones, then least-recently-used
//!    wins.
//!
//! Deadlines are optional per dispatch
//! ([`crate::coordinator::Coordinator::submit_with_deadline`]),
//! expressed in nanoseconds on the coordinator's monotonic clock, and
//! tracked per partition from pick to completion.
//!
//! In a heterogeneous fleet every partition carries the
//! [`crate::overlay::OverlaySpec::fingerprint`] it was built from and
//! a dispatch only ever lands on a partition matching its compiled
//! spec — a bitstream for one geometry cannot configure another.
//!
//! All decisions are deterministic: logical-clock timestamps are
//! unique and ties fall back to the lowest partition index.
//!
//! **Quarantine.** Partitions that repeatedly fail (dead workers,
//! failed reconfigurations — real or injected by a
//! [`crate::admission::FaultPlan`]) accumulate strikes; at
//! [`QUARANTINE_STRIKES`] the partition is quarantined for
//! [`QUARANTINE_PROBE_TICKS`] logical ticks, during which no dispatch
//! is routed to it while a sibling exists. When the window expires the
//! partition becomes probe-eligible again: one success clears its
//! strikes, another failure re-quarantines it. Availability beats
//! purity — if *every* matching partition is quarantined, the fleet
//! keeps serving on all of them rather than refusing work.

use crate::fleet::Priority;

use super::cache::CacheKey;

/// Consecutive failures before a partition is quarantined.
pub const QUARANTINE_STRIKES: u32 = 3;

/// Logical-clock ticks a quarantined partition sits out before it is
/// re-probed with live traffic.
pub const QUARANTINE_PROBE_TICKS: u64 = 64;

/// Mutable serving state of one overlay partition.
#[derive(Debug, Clone)]
pub struct PartitionState {
    /// Spec fingerprint this partition was built from; only kernels
    /// compiled for the same fingerprint may run here.
    pub spec_fingerprint: u64,
    /// Cache key of the kernel whose bitstream is currently loaded.
    pub loaded: Option<CacheKey>,
    /// Priority class of the most recent dispatch of the loaded
    /// kernel — batch-only partitions are preferred eviction victims.
    pub loaded_class: Priority,
    /// Logical time of the last dispatch routed here.
    pub last_used: u64,
    /// Dispatches enqueued but not yet completed.
    pub queue_depth: usize,
    /// Deadlines (monotonic nanos) of the queued-but-incomplete
    /// dispatches that carry one — the victim-selection shield.
    pub queued_deadlines: Vec<u64>,
    pub dispatches: u64,
    pub reconfigs: u64,
    /// Modeled overlay-busy seconds (execution + reconfiguration).
    pub busy_seconds: f64,
    /// Consecutive failures charged to this partition (cleared by the
    /// first success).
    pub strikes: u32,
    /// Logical tick until which this partition is quarantined
    /// (0 = never quarantined / shield lifted).
    pub quarantined_until: u64,
}

impl PartitionState {
    fn new(spec_fingerprint: u64) -> PartitionState {
        PartitionState {
            spec_fingerprint,
            loaded: None,
            loaded_class: Priority::Batch,
            last_used: 0,
            queue_depth: 0,
            queued_deadlines: Vec::new(),
            dispatches: 0,
            reconfigs: 0,
            busy_seconds: 0.0,
            strikes: 0,
            quarantined_until: 0,
        }
    }
}

/// Outcome of a scheduling decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub partition: usize,
    /// Whether the partition must load a new bitstream first.
    pub reconfigure: bool,
    /// Modeled configuration-load seconds charged to this dispatch
    /// (0.0 on an affinity hit).
    pub config_seconds: f64,
}

/// Slot-aware scheduler over a fleet of overlay partitions (possibly
/// spanning several specs).
#[derive(Debug)]
pub struct SlotScheduler {
    parts: Vec<PartitionState>,
    clock: u64,
    /// Total modeled seconds spent loading bitstreams.
    pub reconfig_seconds: f64,
    /// Times any partition entered quarantine.
    quarantine_events: u64,
}

impl SlotScheduler {
    /// A homogeneous scheduler (every partition fingerprint 0); pass
    /// spec fingerprint 0 to [`SlotScheduler::pick`].
    pub fn new(partitions: usize) -> SlotScheduler {
        SlotScheduler::with_specs(vec![0; partitions.max(1)])
    }

    /// One partition per entry, carrying its overlay-spec fingerprint.
    pub fn with_specs(spec_fingerprints: Vec<u64>) -> SlotScheduler {
        let fps = if spec_fingerprints.is_empty() {
            vec![0]
        } else {
            spec_fingerprints
        };
        SlotScheduler {
            parts: fps.into_iter().map(PartitionState::new).collect(),
            clock: 0,
            reconfig_seconds: 0.0,
            quarantine_events: 0,
        }
    }

    pub fn partitions(&self) -> &[PartitionState] {
        &self.parts
    }

    /// Total reconfiguration loads across the fleet.
    pub fn reconfig_count(&self) -> u64 {
        self.parts.iter().map(|p| p.reconfigs).sum()
    }

    /// What the router sees of one spec's partitions: the shallowest
    /// queue and whether some partition already holds `key`'s
    /// bitstream.
    pub fn observe(&self, spec: u64, key: &CacheKey) -> (usize, bool) {
        let mut min_queue = usize::MAX;
        let mut resident = false;
        for p in self.parts.iter().filter(|p| p.spec_fingerprint == spec) {
            min_queue = min_queue.min(p.queue_depth);
            if p.loaded == Some(*key) {
                resident = true;
            }
        }
        (if min_queue == usize::MAX { 0 } else { min_queue }, resident)
    }

    /// Route one dispatch of the kernel identified by `key` onto a
    /// partition of the matching `spec`. `config_seconds_if_load` is
    /// the modeled cost of loading its bitstream (paid only when no
    /// matching partition already holds it).
    ///
    /// # Panics
    /// If no partition carries `spec` — the coordinator only ever
    /// routes to specs its fleet was built with.
    pub fn pick(
        &mut self,
        spec: u64,
        key: CacheKey,
        config_seconds_if_load: f64,
        priority: Priority,
    ) -> Decision {
        self.pick_with_deadline(spec, key, config_seconds_if_load, priority, None)
    }

    /// [`SlotScheduler::pick`] with an optional per-job deadline
    /// (nanoseconds on the caller's monotonic clock). The deadline
    /// does not change *where* this dispatch lands; it shields the
    /// chosen partition from eviction while the job is queued —
    /// victim selection never sacrifices a resident with imminent
    /// queued deadlines to make room for slack batch work.
    pub fn pick_with_deadline(
        &mut self,
        spec: u64,
        key: CacheKey,
        config_seconds_if_load: f64,
        priority: Priority,
        deadline_nanos: Option<u64>,
    ) -> Decision {
        self.pick_inner(spec, key, config_seconds_if_load, priority, deadline_nanos, None)
            .unwrap_or_else(|| {
                panic!("no partition matches spec fingerprint {spec:#018x}")
            })
    }

    /// Re-place a dispatch that failed on `from` (dead worker, failed
    /// reconfiguration, corrupted verify) onto the least-loaded sibling
    /// partition of the same spec. Falls back to `from` itself when it
    /// is the spec's only partition — a restarted worker can still
    /// recover the job. Returns `None` only if the spec has no
    /// partitions at all.
    pub fn requeue_sibling(
        &mut self,
        spec: u64,
        key: CacheKey,
        config_seconds_if_load: f64,
        priority: Priority,
        deadline_nanos: Option<u64>,
        from: usize,
    ) -> Option<Decision> {
        self.pick_inner(spec, key, config_seconds_if_load, priority, deadline_nanos, Some(from))
            .or_else(|| {
                self.pick_inner(spec, key, config_seconds_if_load, priority, deadline_nanos, None)
            })
    }

    fn pick_inner(
        &mut self,
        spec: u64,
        key: CacheKey,
        config_seconds_if_load: f64,
        priority: Priority,
        deadline_nanos: Option<u64>,
        exclude: Option<usize>,
    ) -> Option<Decision> {
        self.clock += 1;
        let all: Vec<usize> = (0..self.parts.len())
            .filter(|&i| self.parts[i].spec_fingerprint == spec && Some(i) != exclude)
            .collect();
        if all.is_empty() {
            return None;
        }
        // Quarantined partitions sit out while a sibling exists;
        // availability beats purity when every candidate is struck.
        let clock = self.clock;
        let cand: Vec<usize> = {
            let open: Vec<usize> = all
                .iter()
                .copied()
                .filter(|&i| self.parts[i].quarantined_until <= clock)
                .collect();
            if open.is_empty() { all } else { open }
        };

        // 1) affinity: a partition already configured with this kernel
        let resident = cand
            .iter()
            .copied()
            .filter(|&i| self.parts[i].loaded == Some(key))
            .min_by_key(|&i| (self.parts[i].queue_depth, self.parts[i].last_used, i));

        let (idx, reconfigure) = if let Some(i) = resident {
            (i, false)
        } else if let Some(i) = cand
            .iter()
            .copied()
            .filter(|&i| self.parts[i].loaded.is_none())
            .min_by_key(|&i| (self.parts[i].queue_depth, i))
        {
            // 2) cold fill: a never-configured partition
            (i, true)
        } else {
            // 3) victim: idle-most, deadline-free first (sooner queued
            //    deadlines protect harder), batch-class next, then LRU
            let i = cand
                .iter()
                .copied()
                .min_by_key(|&i| {
                    (
                        self.parts[i].queue_depth,
                        self.parts[i]
                            .queued_deadlines
                            .iter()
                            .min()
                            .map(|&d| u64::MAX - d)
                            .unwrap_or(0),
                        self.parts[i].loaded_class == Priority::Interactive,
                        self.parts[i].last_used,
                        i,
                    )
                })
                .expect("scheduler has at least one matching partition");
            (i, true)
        };

        let p = &mut self.parts[idx];
        p.last_used = self.clock;
        p.queue_depth += 1;
        if let Some(d) = deadline_nanos {
            p.queued_deadlines.push(d);
        }
        p.dispatches += 1;
        p.loaded_class = priority;
        let config_seconds = if reconfigure {
            p.loaded = Some(key);
            p.reconfigs += 1;
            self.reconfig_seconds += config_seconds_if_load;
            config_seconds_if_load
        } else {
            0.0
        };
        Some(Decision { partition: idx, reconfigure, config_seconds })
    }

    /// Charge one failure (dead worker, failed reconfiguration —
    /// real or injected) to `partition`. At [`QUARANTINE_STRIKES`]
    /// consecutive failures the partition is quarantined for
    /// [`QUARANTINE_PROBE_TICKS`] logical ticks. Returns `true` when
    /// this call (re-)entered quarantine.
    pub fn note_partition_failure(&mut self, partition: usize) -> bool {
        let clock = self.clock;
        let p = &mut self.parts[partition];
        p.strikes += 1;
        if p.strikes >= QUARANTINE_STRIKES && p.quarantined_until <= clock {
            p.quarantined_until = clock + QUARANTINE_PROBE_TICKS;
            self.quarantine_events += 1;
            return true;
        }
        false
    }

    /// Clear `partition`'s strikes after a successful dispatch; an
    /// expired quarantine whose probe succeeded lifts fully.
    pub fn note_partition_success(&mut self, partition: usize) {
        let p = &mut self.parts[partition];
        p.strikes = 0;
        p.quarantined_until = 0;
    }

    /// Partitions currently sitting out a quarantine window.
    pub fn quarantined_count(&self) -> usize {
        let clock = self.clock;
        self.parts.iter().filter(|p| p.quarantined_until > clock).count()
    }

    /// Total times any partition entered quarantine.
    pub fn quarantine_events(&self) -> u64 {
        self.quarantine_events
    }

    /// Record completion of a dispatch on `partition`, crediting the
    /// modeled busy time.
    pub fn complete(&mut self, partition: usize, busy_seconds: f64) {
        self.complete_with_deadline(partition, busy_seconds, None)
    }

    /// [`SlotScheduler::complete`] for a dispatch that carried a
    /// deadline: the completed job stops shielding its partition.
    pub fn complete_with_deadline(
        &mut self,
        partition: usize,
        busy_seconds: f64,
        deadline_nanos: Option<u64>,
    ) {
        let p = &mut self.parts[partition];
        p.queue_depth = p.queue_depth.saturating_sub(1);
        p.busy_seconds += busy_seconds;
        if let Some(d) = deadline_nanos {
            if let Some(pos) = p.queued_deadlines.iter().position(|&x| x == d) {
                p.queued_deadlines.swap_remove(pos);
            }
        }
    }

    /// Roll a [`SlotScheduler::pick`] back after a failed enqueue
    /// (dead worker) or a failed reconfiguration: the dispatch never
    /// ran, so its queue/dispatch/reconfiguration/deadline accounting
    /// must not stick. A cancelled reconfiguration also clears the
    /// `loaded` mark — the bitstream load did not complete, so the
    /// partition's configuration is cold, not resident.
    pub fn cancel(&mut self, d: &Decision, deadline_nanos: Option<u64>) {
        let p = &mut self.parts[d.partition];
        p.queue_depth = p.queue_depth.saturating_sub(1);
        p.dispatches = p.dispatches.saturating_sub(1);
        if let Some(dl) = deadline_nanos {
            if let Some(pos) = p.queued_deadlines.iter().position(|&x| x == dl) {
                p.queued_deadlines.swap_remove(pos);
            }
        }
        if d.reconfigure {
            p.reconfigs = p.reconfigs.saturating_sub(1);
            self.reconfig_seconds -= d.config_seconds;
            p.loaded = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(tag: u64) -> CacheKey {
        CacheKey { source: tag, spec: 7, options: 7 }
    }

    fn pick(s: &mut SlotScheduler, tag: u64, cost: f64) -> Decision {
        s.pick(0, key(tag), cost, Priority::Interactive)
    }

    #[test]
    fn affinity_beats_reconfiguration() {
        let mut s = SlotScheduler::new(2);
        let a = pick(&mut s, 1, 42e-6);
        assert!(a.reconfigure);
        assert_eq!(a.config_seconds, 42e-6);
        s.complete(a.partition, 1e-3);
        // same kernel again → same partition, no reconfig
        let b = pick(&mut s, 1, 42e-6);
        assert_eq!(b.partition, a.partition);
        assert!(!b.reconfigure);
        assert_eq!(b.config_seconds, 0.0);
    }

    #[test]
    fn cold_partitions_fill_before_eviction() {
        let mut s = SlotScheduler::new(2);
        let a = pick(&mut s, 1, 1e-6);
        let b = pick(&mut s, 2, 1e-6);
        assert_ne!(a.partition, b.partition);
        assert!(a.reconfigure && b.reconfigure);
        assert_eq!(s.reconfig_count(), 2);
    }

    #[test]
    fn victim_is_idle_lru_partition() {
        let mut s = SlotScheduler::new(2);
        let a = pick(&mut s, 1, 1e-6); // p0 ← k1
        let b = pick(&mut s, 2, 1e-6); // p1 ← k2
        s.complete(a.partition, 0.0);
        s.complete(b.partition, 0.0);
        // touch k1 so its partition is most recently used
        let c = pick(&mut s, 1, 1e-6);
        s.complete(c.partition, 0.0);
        // a third kernel must evict k2's partition (LRU)
        let d = pick(&mut s, 3, 1e-6);
        assert_eq!(d.partition, b.partition);
        assert!(d.reconfigure);
        // k2 was evicted: dispatching it again reconfigures somewhere
        s.complete(d.partition, 0.0);
        let e = pick(&mut s, 2, 1e-6);
        assert!(e.reconfigure);
    }

    #[test]
    fn contention_prefers_shallow_queues() {
        let mut s = SlotScheduler::new(3);
        // two partitions resident with k1, one busy
        let a = pick(&mut s, 1, 1e-6); // p0 ← k1, depth 1
        let b = pick(&mut s, 2, 1e-6); // p1 ← k2, depth 1
        let _ = b;
        s.complete(a.partition, 0.0); // p0 idle again
        // k1 resident on p0 only; p0 idle → affinity hit on p0
        let c = pick(&mut s, 1, 1e-6);
        assert_eq!(c.partition, a.partition);
        assert!(!c.reconfigure);
        // now p0 busy (depth 1). another k1 dispatch: p0 still the only
        // resident partition; affinity keeps it there (queue depth 2)
        let d = pick(&mut s, 1, 1e-6);
        assert_eq!(d.partition, a.partition);
        assert!(!d.reconfigure);
        // a brand-new kernel goes to the cold p2, not the busy ones
        let e = pick(&mut s, 3, 1e-6);
        assert_eq!(e.partition, 2);
        assert!(e.reconfigure);
    }

    #[test]
    fn cancel_reverses_pick_accounting() {
        let mut s = SlotScheduler::new(1);
        let d = pick(&mut s, 1, 3e-6);
        assert_eq!(s.partitions()[0].queue_depth, 1);
        assert_eq!(s.reconfig_count(), 1);
        s.cancel(&d, None);
        let p = &s.partitions()[0];
        assert_eq!(p.queue_depth, 0);
        assert_eq!(p.dispatches, 0);
        assert_eq!(s.reconfig_count(), 0);
        assert!(s.reconfig_seconds.abs() < 1e-15);
    }

    #[test]
    fn busy_time_and_queue_depths_account() {
        let mut s = SlotScheduler::new(1);
        let a = pick(&mut s, 1, 2e-6);
        assert_eq!(s.partitions()[0].queue_depth, 1);
        s.complete(a.partition, 5e-3);
        let p = &s.partitions()[0];
        assert_eq!(p.queue_depth, 0);
        assert!((p.busy_seconds - 5e-3).abs() < 1e-12);
        assert!((s.reconfig_seconds - 2e-6).abs() < 1e-15);
        assert_eq!(p.dispatches, 1);
    }

    #[test]
    fn dispatches_only_land_on_matching_spec_partitions() {
        // partitions 0,1 are spec A; partition 2 is spec B
        let mut s = SlotScheduler::with_specs(vec![0xA, 0xA, 0xB]);
        for tag in 0..6 {
            let d = s.pick(0xA, key(tag), 1e-6, Priority::Interactive);
            assert!(d.partition < 2, "spec A dispatch on partition {}", d.partition);
            s.complete(d.partition, 0.0);
        }
        let d = s.pick(0xB, key(9), 1e-6, Priority::Interactive);
        assert_eq!(d.partition, 2);
        // observe() is per spec
        let (q_a, _) = s.observe(0xA, &key(0));
        let (q_b, _) = s.observe(0xB, &key(9));
        assert_eq!(q_a, 0);
        assert_eq!(q_b, 1);
    }

    #[test]
    fn observe_reports_residency_and_min_queue() {
        let mut s = SlotScheduler::new(2);
        let (q, resident) = s.observe(0, &key(1));
        assert_eq!((q, resident), (0, false));
        let d = s.pick(0, key(1), 1e-6, Priority::Interactive);
        let (q, resident) = s.observe(0, &key(1));
        // one partition busy, the other idle → min queue 0, resident
        assert_eq!((q, resident), (0, true));
        s.complete(d.partition, 0.0);
        // an unknown spec fingerprint observes an empty fleet
        assert_eq!(s.observe(0xFFF, &key(1)), (0, false));
    }

    #[test]
    fn queued_deadlines_shield_a_partition_from_eviction() {
        let mut s = SlotScheduler::new(2);
        // p0 queues a deadline-carrying interactive job; p1 queues
        // slack batch work. Equal queue depths, and p1 is the *more*
        // recently used (so plain LRU would evict p0).
        let a = s.pick_with_deadline(0, key(1), 1e-6, Priority::Interactive, Some(5_000));
        let b = s.pick(0, key(2), 1e-6, Priority::Batch);
        assert_ne!(a.partition, b.partition);
        // a third kernel must evict the slack-batch partition, not the
        // one with an imminent queued deadline
        let c = s.pick(0, key(3), 1e-6, Priority::Interactive);
        assert_eq!(c.partition, b.partition);
        assert!(c.reconfigure);
    }

    #[test]
    fn sooner_deadlines_protect_harder_and_completion_lifts_the_shield() {
        let mut s = SlotScheduler::new(2);
        let a = s.pick_with_deadline(0, key(1), 1e-6, Priority::Batch, Some(1_000));
        let b = s.pick_with_deadline(0, key(2), 1e-6, Priority::Batch, Some(9_000));
        // both shielded: the one whose deadline is further out yields
        let c = s.pick(0, key(3), 1e-6, Priority::Batch);
        assert_eq!(c.partition, b.partition);
        s.complete_with_deadline(c.partition, 0.0, None);
        // the soon-deadline job completes: its shield lifts, and with
        // depths equal again the partition becomes evictable
        s.complete_with_deadline(a.partition, 0.0, Some(1_000));
        assert!(s.partitions()[a.partition].queued_deadlines.is_empty());
        let d = s.pick(0, key(4), 1e-6, Priority::Batch);
        assert_eq!(d.partition, a.partition);
    }

    #[test]
    fn cancel_removes_the_queued_deadline() {
        let mut s = SlotScheduler::new(1);
        let d = s.pick_with_deadline(0, key(1), 1e-6, Priority::Interactive, Some(42));
        assert_eq!(s.partitions()[0].queued_deadlines, vec![42]);
        s.cancel(&d, Some(42));
        assert!(s.partitions()[0].queued_deadlines.is_empty());
        assert_eq!(s.partitions()[0].queue_depth, 0);
    }

    #[test]
    fn batch_only_partitions_are_preferred_victims() {
        let mut s = SlotScheduler::new(2);
        // p0 holds a batch-class kernel, p1 an interactive one; make
        // p0 the *most* recently used so plain LRU would spare it
        let a = s.pick(0, key(1), 1e-6, Priority::Interactive); // p0 ← k1 (interactive)
        let b = s.pick(0, key(2), 1e-6, Priority::Batch); // p1 ← k2 (batch)
        s.complete(a.partition, 0.0);
        s.complete(b.partition, 0.0);
        let c = s.pick(0, key(2), 1e-6, Priority::Batch); // touch batch partition (MRU)
        s.complete(c.partition, 0.0);
        assert_eq!(c.partition, b.partition);
        // new kernel: the batch-class partition is evicted despite
        // being most recently used
        let d = s.pick(0, key(3), 1e-6, Priority::Interactive);
        assert_eq!(d.partition, b.partition);
        assert!(d.reconfigure);
    }

    #[test]
    fn repeated_failures_quarantine_and_divert_traffic() {
        let mut s = SlotScheduler::new(2);
        let a = pick(&mut s, 1, 1e-6); // p? ← k1 resident
        s.complete(a.partition, 0.0);
        for i in 0..QUARANTINE_STRIKES {
            let entered = s.note_partition_failure(a.partition);
            assert_eq!(entered, i + 1 == QUARANTINE_STRIKES);
        }
        assert_eq!(s.quarantined_count(), 1);
        assert_eq!(s.quarantine_events(), 1);
        // Even an affinity hit is refused while quarantined: the job
        // pays a reconfiguration on the sibling instead.
        let b = pick(&mut s, 1, 1e-6);
        assert_ne!(b.partition, a.partition);
        assert!(b.reconfigure);
        s.complete(b.partition, 0.0);
    }

    #[test]
    fn expired_quarantine_is_probed_and_success_clears_it() {
        let mut s = SlotScheduler::new(2);
        let a = pick(&mut s, 1, 1e-6);
        s.complete(a.partition, 0.0);
        for _ in 0..QUARANTINE_STRIKES {
            s.note_partition_failure(a.partition);
        }
        // Sit out the window on the sibling.
        for _ in 0..=QUARANTINE_PROBE_TICKS {
            let d = pick(&mut s, 2, 1e-6);
            assert_ne!(d.partition, a.partition, "no traffic while quarantined");
            s.complete(d.partition, 0.0);
        }
        // Window expired: the partition is probe-eligible again and the
        // old affinity wins.
        let probe = pick(&mut s, 1, 1e-6);
        assert_eq!(probe.partition, a.partition);
        s.complete(probe.partition, 0.0);
        s.note_partition_success(probe.partition);
        assert_eq!(s.quarantined_count(), 0);
        assert_eq!(s.partitions()[a.partition].strikes, 0);
    }

    #[test]
    fn fully_quarantined_spec_still_serves() {
        let mut s = SlotScheduler::new(1);
        for _ in 0..QUARANTINE_STRIKES {
            s.note_partition_failure(0);
        }
        assert_eq!(s.quarantined_count(), 1);
        // Availability beats purity: the only partition keeps serving.
        let d = pick(&mut s, 1, 1e-6);
        assert_eq!(d.partition, 0);
        s.complete(d.partition, 0.0);
    }

    #[test]
    fn requeue_lands_on_least_loaded_sibling() {
        let mut s = SlotScheduler::new(3);
        let a = pick(&mut s, 1, 1e-6); // the partition that "failed"
        let b = pick(&mut s, 2, 1e-6); // a busy sibling
        let _ = b;
        let r = s
            .requeue_sibling(0, key(1), 1e-6, Priority::Interactive, None, a.partition)
            .expect("siblings exist");
        assert_ne!(r.partition, a.partition);
        // the idle cold sibling wins over the busy one
        assert_eq!(s.partitions()[r.partition].queue_depth, 1);
        assert!(r.reconfigure);
    }

    #[test]
    fn requeue_falls_back_to_the_sole_partition() {
        let mut s = SlotScheduler::new(1);
        let a = pick(&mut s, 1, 1e-6);
        s.complete(a.partition, 0.0);
        let r = s
            .requeue_sibling(0, key(1), 1e-6, Priority::Interactive, None, 0)
            .expect("sole partition still usable");
        assert_eq!(r.partition, 0);
        assert!(!r.reconfigure, "bitstream still resident");
        // an unknown spec genuinely has nowhere to go
        assert!(s
            .requeue_sibling(0xDEAD, key(1), 1e-6, Priority::Interactive, None, 0)
            .is_none());
    }
}
