//! Asynchronous dispatch: one worker thread per overlay partition.
//!
//! Each partition owns an in-order, **two-lane** work queue (an OpenCL
//! command queue with a QoS split, in the paper's terms): the
//! interactive lane drains completely before any batch-lane job runs,
//! so latency-sensitive dispatches never queue behind throughput work
//! ([`crate::fleet::Priority`]). `submit` is non-blocking: it routes
//! the request through the fleet router and the slot-aware scheduler,
//! enqueues a job on the chosen partition's lane and returns a
//! [`DispatchHandle`] the caller can later `wait()` on.
//!
//! Workers drain their queue in batches, and **fuse** consecutive
//! drained jobs that share a kernel fingerprint into one wider
//! simulator invocation. The data plane is zero-copy: every job packs
//! its argument buffers **directly into one flat
//! [`crate::arena::StreamArena`]** at its own lane offset (drawn from
//! the coordinator's [`crate::arena::ScratchPool`]), so a fused batch
//! concatenates by offset instead of building per-job vectors and
//! re-copying them; results split back out as borrowed arena views.
//! This amortizes dispatch overhead exactly the way the paper's
//! runtime reuses a loaded overlay configuration across
//! `clEnqueueNDRangeKernel` calls ([`LogShard::fused_batches`] counts
//! these). Outputs are scattered into each job's own buffers and
//! verified per job.
//!
//! Batch-class runs on the cycle-sim backend are **preemptible at
//! chunk boundaries**: the fused invocation executes one job's chunk
//! at a time ([`crate::sim::execute_slice_into`]) and consults a
//! per-partition preemption flag between chunks. When the coordinator
//! raises it — an interactive job queued on the partition while the
//! SLO error budget burns, or admission pressure reaches the shed
//! threshold — the run checkpoints at the boundary: completed chunks
//! scatter and verify normally, the un-run remainder requeues as a
//! typed `Preempted` continuation on the same or least-loaded sibling
//! partition, and the yielded slot goes to the interactive lane the
//! worker drains first. Interactive runs are never preemptible, a
//! per-job budget ([`MAX_PREEMPTIONS`]) caps livelock, and slicing is
//! bit-exact vs an unpreempted run by construction.
//!
//! Serving counters are **sharded per worker** ([`LogShard`]: plain
//! atomics plus a worker-private log-bucketed
//! [`crate::obs::LatencyHist`]) and merged only when statistics are
//! read — bucket-wise addition, lossless and order-invariant — so the
//! submit/complete hot path never contends on a global log mutex.
//!
//! Completion carries the same timing breakdown as a synchronous
//! [`crate::runtime_ocl::Event`] (wall time, pack/scatter split,
//! modeled configuration load, modeled II=1 overlay timing) plus
//! serving metadata: queue wait, compile-cache hit flag, serving
//! spec, priority class, batch and fusion sizes, and the optional
//! cycle-simulator verification verdict. For a fused run the measured
//! wall time spans from the run's pack start to each job's own
//! scatter/verify completion; the modeled timing is always per job.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::admission::{FaultKind, FaultPlan};
use crate::arena::{DispatchScratch, ScratchPool};
use crate::autoscale::Autoscaler;
use crate::fleet::Priority;
use crate::obs::{
    JobTrace, LatencyHist, Phase, SloProbe, CLASS_FAULT, CLASS_PREEMPT, CLASS_QUARANTINE,
    CLASS_TAIL, NO_WORKER,
};
use crate::runtime_ocl::{ArgSnapshot, Backend, Buffer, Device, Event, Kernel};
use crate::sim;
use crate::util::BoundedLog;

use super::cache::CacheKey;
use super::scheduler::SlotScheduler;

/// Typed cause of a failed dispatch, so callers can tell shed work
/// from crashes (and both from deadline losses) without parsing error
/// strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// The worker owning the dispatch died (really, or by injected
    /// fault) and recovery could not complete it elsewhere.
    WorkerDied,
    /// The dispatch was batch-lane work dropped under load or at
    /// teardown — deliberate degradation, not a crash.
    Shed,
    /// The dispatch's deadline passed before it could run.
    DeadlineRejected,
    /// The dispatch's simulator verification was corrupted and retries
    /// were exhausted.
    VerifyCorrupted,
    /// The kernel itself failed to execute (unset arguments, backend
    /// error) — retrying elsewhere would not help.
    ExecFailed,
    /// The dispatch was preempted at a chunk boundary to yield the
    /// partition to interactive work, and its continuation could not
    /// be requeued (every queue was already closed). Only reachable
    /// at shutdown; a live fleet always re-places continuations.
    Preempted,
}

impl FailReason {
    /// Stable tag for logs and assertions.
    pub fn name(self) -> &'static str {
        match self {
            FailReason::WorkerDied => "worker_died",
            FailReason::Shed => "shed",
            FailReason::DeadlineRejected => "deadline_rejected",
            FailReason::VerifyCorrupted => "verify_corrupted",
            FailReason::ExecFailed => "exec_failed",
            FailReason::Preempted => "preempted",
        }
    }
}

/// The error type a [`DispatchHandle`] resolves to: a [`FailReason`]
/// plus a human-readable message. Converts into `anyhow::Error` (for
/// the classic `wait()` path) without losing the message.
#[derive(Debug, Clone)]
pub struct DispatchError {
    reason: FailReason,
    message: String,
}

impl DispatchError {
    pub(crate) fn new(reason: FailReason, message: String) -> DispatchError {
        DispatchError { reason, message }
    }

    /// Why the dispatch failed.
    pub fn reason(&self) -> FailReason {
        self.reason
    }
}

impl std::fmt::Display for DispatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for DispatchError {}

/// An argument to [`crate::coordinator::Coordinator::submit`].
#[derive(Debug, Clone)]
pub enum SubmitArg {
    /// A global-memory buffer (read and/or written by the kernel).
    Buffer(Buffer),
    /// A broadcast scalar.
    Scalar(i32),
}

/// Measured worker-side stage boundaries, µs on the trace-sink clock.
///
/// Captured with `now()` reads **at** the pack/exec/scatter/verify
/// boundaries while the run executes — not reconstructed afterwards
/// from duration arithmetic — so consecutive stamps are monotone by
/// construction and worker spans nest exactly inside the measured
/// timeline. All-zero when the run carried no trace context (tracing
/// off or every job head-sampled out): no clock is read at all.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageStamps {
    /// Run picked up by the worker; argument packing begins.
    pub run_start_us: u64,
    /// Packing done; the fused backend invocation begins.
    pub exec_start_us: u64,
    /// This job's output scatter begins (for a fused run this is
    /// after the shared invocation *and* any earlier jobs' scatters).
    pub scatter_start_us: u64,
    /// This job's scatter + verification read-back completed.
    pub done_us: u64,
}

/// Completed-dispatch report: the event an OpenCL profiling query
/// would return, plus the coordinator's serving metadata.
#[derive(Debug, Clone)]
pub struct DispatchResult {
    /// Timing breakdown identical to the synchronous runtime path.
    /// For a fused run, `event.wall` spans from the run's pack start
    /// to this job's scatter/verify completion (the fused backend
    /// invocation is shared; scatter and verification are per job).
    pub event: Event,
    /// Partition (fleet index) that executed the dispatch.
    pub partition: usize,
    /// Overlay spec name (e.g. `"8x8-dsp2"`) that served the dispatch.
    pub spec: String,
    /// QoS lane the dispatch rode in.
    pub priority: Priority,
    /// Whether the compiled kernel came from the kernel cache.
    pub cache_hit: bool,
    /// Time spent queued before the worker picked the job up.
    pub queue_wait: Duration,
    /// Jobs drained in the same worker batch, including any absorbed
    /// through the cross-batch fusion window (≥ 1, always ≥ `fused`).
    pub batch_size: usize,
    /// Same-kernel jobs co-executed in one backend invocation with
    /// this one (≥ 1; > 1 means the dispatch was batch-fused).
    pub fused: usize,
    /// `Some(true)` when the dispatch verified against the cycle
    /// simulator: the scattered output buffers hold the simulator's
    /// values bit-for-bit (and, on PJRT partitions, the backend's raw
    /// streams agreed with a simulator re-execution). `None` when
    /// verification is disabled.
    pub verified: Option<bool>,
    /// Measured stage-boundary stamps (all-zero when untraced).
    pub stamps: StageStamps,
}

pub(crate) struct HandleInner {
    slot: Mutex<Option<std::result::Result<DispatchResult, DispatchError>>>,
    cv: Condvar,
    /// Set by the first `fulfill`; later calls (the panic guards'
    /// blanket error sweeps) are no-ops, so a delivered result is
    /// never overwritten.
    delivered: std::sync::atomic::AtomicBool,
}

impl HandleInner {
    pub(crate) fn new() -> Arc<HandleInner> {
        Arc::new(HandleInner {
            slot: Mutex::new(None),
            cv: Condvar::new(),
            delivered: std::sync::atomic::AtomicBool::new(false),
        })
    }

    /// Deliver the result exactly once; first caller wins.
    pub(crate) fn fulfill(&self, result: std::result::Result<DispatchResult, DispatchError>) {
        if self
            .delivered
            .swap(true, std::sync::atomic::Ordering::SeqCst)
        {
            return;
        }
        *self.slot.lock().unwrap() = Some(result);
        self.cv.notify_all();
    }
}

/// Completion handle for an asynchronously dispatched kernel.
pub struct DispatchHandle {
    pub(crate) inner: Arc<HandleInner>,
}

impl DispatchHandle {
    /// Block until the dispatch completes and return its result.
    pub fn wait(self) -> Result<DispatchResult> {
        self.wait_typed().map_err(anyhow::Error::from)
    }

    /// [`DispatchHandle::wait`], but a failure keeps its typed
    /// [`FailReason`] so callers can tell shed work from crashes.
    pub fn wait_typed(self) -> std::result::Result<DispatchResult, DispatchError> {
        let mut slot = self.inner.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.inner.cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking poll: `Some(result)` once the dispatch completed.
    pub fn try_wait(&self) -> Option<Result<DispatchResult>> {
        self.try_wait_typed().map(|r| r.map_err(anyhow::Error::from))
    }

    /// Non-blocking poll preserving the typed [`FailReason`].
    pub fn try_wait_typed(
        &self,
    ) -> Option<std::result::Result<DispatchResult, DispatchError>> {
        self.inner.slot.lock().unwrap().take()
    }
}

/// One queued dispatch.
pub(crate) struct Job {
    pub kernel: Kernel,
    pub global_size: usize,
    pub partition: usize,
    /// Kernel-cache key — jobs sharing it are fusion candidates.
    pub key: CacheKey,
    /// Serving spec name, echoed into the result.
    pub spec: String,
    /// Stable source hash + spec fingerprint — the autoscaler's
    /// load-signal key, fed on completion.
    pub source_hash: u64,
    pub spec_fp: u64,
    pub priority: Priority,
    /// Modeled bitstream-load seconds charged by the scheduler
    /// (0.0 when the partition already held the configuration).
    pub config_seconds: f64,
    /// Optional deadline (coordinator-monotonic nanos) — shields the
    /// partition from eviction while queued (see
    /// [`SlotScheduler::pick_with_deadline`]).
    pub deadline_nanos: Option<u64>,
    pub cache_hit: bool,
    pub enqueued: Instant,
    pub handle: Arc<HandleInner>,
    /// Coordinator-wide dispatch sequence number — the fault plan's
    /// deterministic strike key.
    pub seq: u64,
    /// Times this job has been requeued by the recovery plane.
    pub attempts: u32,
    /// Times this job has been preempted at a chunk boundary and
    /// requeued as a continuation. Budgeted separately from
    /// `attempts`: preemption is deliberate policy, not a fault — it
    /// earns no quarantine strike and no backoff — but the budget is
    /// capped the same way ([`MAX_PREEMPTIONS`]) so a batch job under
    /// sustained interactive pressure cannot be bounced forever; once
    /// exhausted it becomes non-preemptible and runs to completion.
    pub preemptions: u32,
    /// The fault that last struck this job, if any — a completion
    /// after a strike counts as a recovery.
    pub last_fault: Option<FaultKind>,
    /// Modeled bitstream-load cost of this kernel on its spec — what a
    /// recovery re-pick charges if the sibling must reconfigure.
    pub config_cost: f64,
    /// Trace context carried from the submit path: worker-side phase
    /// spans (queue wait, pack, exec, scatter, verify, retries) parent
    /// to the submit's root span. `None` when tracing is off.
    pub trace: Option<JobTrace>,
    /// SLO completion hook (mirrors `trace`): reports this job's
    /// end-to-end latency and outcome into the coordinator's SLO
    /// engine under the submitting tenant. `None` when no SLO policy
    /// is configured.
    pub slo: Option<SloProbe>,
}

/// Maximum chunk-boundary preemptions per dispatch before it turns
/// non-preemptible and runs to completion wherever it sits — the
/// anti-livelock budget, attempt-capped like fault recovery
/// ([`crate::coordinator::MAX_DISPATCH_RETRIES`]) but accounted
/// separately: a preempted job is healthy, so its fault-retry budget
/// stays untouched.
pub const MAX_PREEMPTIONS: u32 = 3;

/// Retained [`ContinuationRecord`]s before the audit log starts
/// counting instead of storing.
pub(crate) const MAX_CONTINUATION_RECORDS: usize = 1024;

/// One typed `Preempted` continuation: a batch job checkpointed at a
/// chunk boundary and re-placed so an interactive arrival could take
/// the partition. The preemption counters in
/// [`crate::metrics::ServingStats`] are defined to agree with these
/// records (`preempted_continuations` counts every record ever
/// created, stored or dropped past the log bound).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContinuationRecord {
    /// Coordinator-wide dispatch sequence number of the preempted job.
    pub seq: u64,
    /// Partition that yielded the job at the chunk boundary.
    pub from: usize,
    /// Partition the continuation was requeued onto (the same or the
    /// least-loaded sibling of the same spec).
    pub to: usize,
    /// The job's preemption count after this bounce (1-based).
    pub preemptions: u32,
}

/// The recovery half of the fault plane: shared by every worker, it
/// re-places a struck job onto the least-loaded sibling partition of
/// the same spec (bounded retries, short exponential backoff) and
/// fails the handle with a typed [`DispatchError`] only when retries
/// run out or no partition remains.
///
/// The same machinery carries **preemption continuations**: a batch
/// job checkpointed at a chunk boundary is requeued through
/// [`RecoveryPlane::requeue_preempted`] — same sibling pick, but no
/// attempt bump, no quarantine strike and no backoff, because a
/// preempted job is healthy work the coordinator *chose* to move.
/// The per-partition preemption flags live here too: the coordinator
/// raises a partition's flag when an interactive job lands on it
/// under SLO burn or shed-level pressure, and that partition's worker
/// consumes it at the next chunk boundary.
pub(crate) struct RecoveryPlane {
    pub(crate) faults: Option<Arc<FaultPlan>>,
    pub(crate) max_retries: u32,
    scheduler: Arc<Mutex<SlotScheduler>>,
    /// Per-partition queues, registered once the coordinator has
    /// spawned every worker (workers never requeue before serving).
    queues: Mutex<Vec<Arc<LaneQueue<Box<Job>>>>>,
    /// Total recovery requeues performed.
    pub(crate) retried: AtomicU64,
    /// Per-partition preemption flags (raise-side; each worker holds
    /// its own `Arc` for the boundary checks). Registered with the
    /// queues.
    preempt_flags: Mutex<Vec<Arc<AtomicBool>>>,
    /// Runs interrupted at a chunk boundary (≥ 1 job handed back).
    preempted_runs: AtomicU64,
    /// Continuations created (== every `ContinuationRecord`, stored
    /// or dropped past the log bound).
    preempted_requeues: AtomicU64,
    /// Bounded keep-first audit log of the continuations.
    continuations: Mutex<BoundedLog<ContinuationRecord>>,
}

impl RecoveryPlane {
    pub(crate) fn new(
        faults: Option<Arc<FaultPlan>>,
        max_retries: u32,
        scheduler: Arc<Mutex<SlotScheduler>>,
    ) -> RecoveryPlane {
        RecoveryPlane {
            faults,
            max_retries,
            scheduler,
            queues: Mutex::new(Vec::new()),
            retried: AtomicU64::new(0),
            preempt_flags: Mutex::new(Vec::new()),
            preempted_runs: AtomicU64::new(0),
            preempted_requeues: AtomicU64::new(0),
            continuations: Mutex::new(BoundedLog::new(MAX_CONTINUATION_RECORDS)),
        }
    }

    /// Late-bind the worker queues (the plane is created before the
    /// workers so each worker can hold a reference to it).
    pub(crate) fn register_queues(&self, queues: Vec<Arc<LaneQueue<Box<Job>>>>) {
        *self.queues.lock().unwrap() = queues;
    }

    /// Late-bind the per-partition preemption flags (created with the
    /// queues; each worker also holds its own flag directly).
    pub(crate) fn register_preempt_flags(&self, flags: Vec<Arc<AtomicBool>>) {
        *self.preempt_flags.lock().unwrap() = flags;
    }

    /// Raise partition `partition`'s preemption flag: its worker
    /// checkpoints the in-flight batch run at the next chunk boundary
    /// and yields the slot to the interactive lane. Idempotent; a
    /// no-op for unknown partitions or before the flags register.
    pub(crate) fn raise_preempt(&self, partition: usize) {
        if let Some(f) = self.preempt_flags.lock().unwrap().get(partition) {
            f.store(true, Ordering::SeqCst);
        }
    }

    pub(crate) fn retried_count(&self) -> u64 {
        self.retried.load(Ordering::Relaxed)
    }

    pub(crate) fn preempted_run_count(&self) -> u64 {
        self.preempted_runs.load(Ordering::Relaxed)
    }

    pub(crate) fn note_preempted_run(&self) {
        self.preempted_runs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn preempted_requeue_count(&self) -> u64 {
        self.preempted_requeues.load(Ordering::Relaxed)
    }

    /// Snapshot of the retained continuation records plus the count
    /// dropped past the bound.
    pub(crate) fn continuation_records(&self) -> (Vec<ContinuationRecord>, u64) {
        let log = self.continuations.lock().unwrap();
        (log.items().to_vec(), log.dropped())
    }

    fn fail_reason_for(kind: FaultKind) -> FailReason {
        match kind {
            FaultKind::VerifyCorrupt => FailReason::VerifyCorrupted,
            _ => FailReason::WorkerDied,
        }
    }

    /// Requeue a struck job onto a sibling partition. The caller must
    /// already have released the job's scheduler accounting on the
    /// failed partition (via `complete_with_deadline`).
    pub(crate) fn requeue(&self, mut job: Box<Job>, kind: FaultKind, from: usize) {
        job.attempts += 1;
        job.last_fault = Some(kind);
        if let Some(t) = &job.trace {
            let now = t.now();
            t.span(
                Phase::Retry,
                kind.name(),
                NO_WORKER,
                now,
                0,
                job.attempts as u64,
                from as u64,
            );
            t.pin(CLASS_FAULT, kind.name(), job.attempts as u64);
        }
        if job.attempts > self.max_retries {
            job.handle.fulfill(Err(DispatchError::new(
                Self::fail_reason_for(kind),
                format!(
                    "dispatch on partition {from} failed {} times (last fault: {}); retries exhausted",
                    job.attempts,
                    kind.name()
                ),
            )));
            return;
        }
        // Short exponential backoff: a "restarted" worker gets a beat
        // to come back before the retry lands.
        thread::sleep(Duration::from_micros(50u64 << job.attempts.min(4) as u64));
        let decision = self.scheduler.lock().unwrap().requeue_sibling(
            job.spec_fp,
            job.key,
            job.config_cost,
            job.priority,
            job.deadline_nanos,
            from,
        );
        let decision = match decision {
            Some(d) => d,
            None => {
                job.handle.fulfill(Err(DispatchError::new(
                    FailReason::WorkerDied,
                    format!(
                        "no partition left to recover the dispatch struck by {} on partition {from}",
                        kind.name()
                    ),
                )));
                return;
            }
        };
        job.partition = decision.partition;
        job.config_seconds = decision.config_seconds;
        self.retried.fetch_add(1, Ordering::Relaxed);
        let queue = {
            let queues = self.queues.lock().unwrap();
            queues.get(decision.partition).cloned()
        };
        let priority = job.priority;
        let deadline = job.deadline_nanos;
        let pushed = match queue {
            Some(q) => q.push(job, priority),
            None => Err(job), // queues not registered: treat as closed
        };
        if let Err(job) = pushed {
            self.scheduler.lock().unwrap().cancel(&decision, deadline);
            job.handle.fulfill(Err(DispatchError::new(
                FailReason::WorkerDied,
                format!(
                    "partition {} worker is gone; dispatch dropped during recovery",
                    decision.partition
                ),
            )));
        }
    }

    /// Requeue a batch job preempted at a chunk boundary as a typed
    /// continuation on the same or least-loaded sibling partition.
    ///
    /// Deliberately **not** [`RecoveryPlane::requeue`]: the job is
    /// healthy, so there is no attempt bump (its fault-retry budget
    /// survives preemption), no quarantine strike against the yielding
    /// partition, and no backoff sleep — the continuation should be
    /// runnable the moment the interactive lane drains. The caller
    /// guarantees `job.preemptions < MAX_PREEMPTIONS` (the worker
    /// never preempts a budget-exhausted job) and has already released
    /// the job's accounting on `from`.
    pub(crate) fn requeue_preempted(&self, mut job: Box<Job>, from: usize) {
        job.preemptions += 1;
        if let Some(t) = &job.trace {
            let now = t.now();
            t.span(
                Phase::Preempt,
                "chunk_boundary",
                NO_WORKER,
                now,
                0,
                job.preemptions as u64,
                from as u64,
            );
            t.pin(CLASS_PREEMPT, "chunk_boundary", job.preemptions as u64);
        }
        let decision = self.scheduler.lock().unwrap().requeue_sibling(
            job.spec_fp,
            job.key,
            job.config_cost,
            job.priority,
            job.deadline_nanos,
            from,
        );
        // requeue_sibling falls back to `from` itself on a
        // single-partition spec, so None means the spec lost every
        // partition — nowhere to resume.
        let decision = match decision {
            Some(d) => d,
            None => {
                job.handle.fulfill(Err(DispatchError::new(
                    FailReason::Preempted,
                    format!(
                        "no partition left to resume the continuation preempted on partition {from}"
                    ),
                )));
                return;
            }
        };
        job.partition = decision.partition;
        job.config_seconds = decision.config_seconds;
        let record = ContinuationRecord {
            seq: job.seq,
            from,
            to: decision.partition,
            preemptions: job.preemptions,
        };
        let priority = job.priority;
        let deadline = job.deadline_nanos;
        let queue = {
            let queues = self.queues.lock().unwrap();
            queues.get(decision.partition).cloned()
        };
        let pushed = match queue {
            Some(q) => q.push(job, priority),
            None => Err(job), // queues not registered: treat as closed
        };
        match pushed {
            Ok(()) => {
                self.preempted_requeues.fetch_add(1, Ordering::Relaxed);
                self.continuations.lock().unwrap().push(record);
            }
            Err(job) => {
                // only reachable at shutdown (a closed lane): the
                // continuation fails typed rather than hanging
                self.scheduler.lock().unwrap().cancel(&decision, deadline);
                job.handle.fulfill(Err(DispatchError::new(
                    FailReason::Preempted,
                    format!(
                        "partition {} closed before the preempted continuation could resume",
                        decision.partition
                    ),
                )));
            }
        }
    }
}

/// A two-lane (interactive / batch) MPSC queue with blocking drain.
/// Interactive jobs always drain ahead of batch jobs; `close` lets
/// queued work finish, then wakes the worker to exit.
pub(crate) struct LaneQueue<T> {
    inner: Mutex<Lanes<T>>,
    cv: Condvar,
}

struct Lanes<T> {
    interactive: VecDeque<T>,
    batch: VecDeque<T>,
    closed: bool,
}

impl<T> LaneQueue<T> {
    pub(crate) fn new() -> Arc<LaneQueue<T>> {
        Arc::new(LaneQueue {
            inner: Mutex::new(Lanes {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Enqueue; `Err(item)` back if the queue is closed (dead worker).
    pub(crate) fn push(&self, item: T, priority: Priority) -> std::result::Result<(), T> {
        let mut l = self.inner.lock().unwrap();
        if l.closed {
            return Err(item);
        }
        match priority {
            Priority::Interactive => l.interactive.push_back(item),
            Priority::Batch => l.batch.push_back(item),
        }
        drop(l);
        self.cv.notify_one();
        Ok(())
    }

    /// Stop accepting work; the worker drains what's queued, then its
    /// next `drain` returns `None`.
    pub(crate) fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Block until work is available (interactive first, then batch)
    /// or the queue is closed and empty (`None`).
    pub(crate) fn drain(&self) -> Option<Vec<T>> {
        let mut l = self.inner.lock().unwrap();
        loop {
            if !l.interactive.is_empty() || !l.batch.is_empty() {
                let mut out: Vec<T> = l.interactive.drain(..).collect();
                out.extend(l.batch.drain(..));
                return Some(out);
            }
            if l.closed {
                return None;
            }
            l = self.cv.wait(l).unwrap();
        }
    }

    /// Non-blocking: drain only the interactive lane. Workers call
    /// this before starting each batch-class fusion run so
    /// interactive work that arrived after the batch was drained
    /// still jumps the line.
    pub(crate) fn take_interactive(&self) -> Vec<T> {
        self.inner.lock().unwrap().interactive.drain(..).collect()
    }

    /// Cross-batch fusion window: wait up to `window` for more
    /// batch-lane jobs matching `matches` (same kernel key) to
    /// trickle in, popping matching jobs off the **front** of the
    /// batch lane so lane FIFO order is preserved. Stops immediately
    /// when the interactive lane is non-empty (QoS: fusion must never
    /// delay latency-sensitive work), when the batch-lane head stops
    /// matching (head-of-line work must not starve behind a fusion
    /// hunt), on close, or at the deadline — the wait is bounded by
    /// construction.
    pub(crate) fn absorb_batch_front<F: Fn(&T) -> bool>(
        &self,
        window: Duration,
        matches: F,
    ) -> Vec<T> {
        let deadline = Instant::now() + window;
        let mut out = Vec::new();
        let mut l = self.inner.lock().unwrap();
        loop {
            if !l.interactive.is_empty() {
                break;
            }
            while let Some(front) = l.batch.front() {
                if !matches(front) {
                    break;
                }
                out.push(l.batch.pop_front().expect("matched front exists"));
            }
            if !l.batch.is_empty() || l.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(l, deadline - now).unwrap();
            l = guard;
        }
        out
    }

    /// Close and return whatever was still queued (worker teardown:
    /// the jobs never ran and must be failed, not dropped).
    pub(crate) fn close_and_drain(&self) -> Vec<T> {
        let mut l = self.inner.lock().unwrap();
        l.closed = true;
        let mut out: Vec<T> = l.interactive.drain(..).collect();
        out.extend(l.batch.drain(..));
        drop(l);
        self.cv.notify_all();
        out
    }
}

/// Latency samples kept per worker shard before the legacy reservoir
/// halves its resolution. Retained (with [`LatencyReservoir`]) only as
/// the comparison baseline for the histogram-agreement test and the
/// `obs_overhead` bench.
pub(crate) const MAX_LATENCY_SAMPLES: usize = 65_536;

/// **Legacy** bounded, decimating latency sample buffer — the carrier
/// [`crate::obs::LatencyHist`] replaced. Kept (test/bench-only) so the
/// percentile-agreement test can check the histogram against the exact
/// sample path it displaced.
#[derive(Debug)]
pub(crate) struct LatencyReservoir {
    pub(crate) samples: Vec<f64>,
    /// Every `stride`-th sample is kept; doubles each time the buffer
    /// fills (decimation keeps percentiles representative).
    pub(crate) stride: u64,
    seen: u64,
}

impl Default for LatencyReservoir {
    fn default() -> Self {
        LatencyReservoir { samples: Vec::new(), stride: 1, seen: 0 }
    }
}

impl LatencyReservoir {
    pub(crate) fn record(&mut self, ms: f64) {
        self.seen += 1;
        if self.seen % self.stride != 0 {
            return;
        }
        if self.samples.len() >= MAX_LATENCY_SAMPLES {
            let mut i = 0usize;
            self.samples.retain(|_| {
                i += 1;
                i % 2 == 1
            });
            self.stride *= 2;
        }
        self.samples.push(ms);
    }
}

/// One worker's shard of the serving counters: plain atomics bumped
/// lock-free on the completion path, plus the worker-private latency
/// histogram. Nothing here is shared between workers — the global
/// view is assembled by [`ServeLog::totals`] when someone asks.
#[derive(Debug, Default)]
pub(crate) struct LogShard {
    pub total_items: AtomicU64,
    pub total_dispatches: AtomicU64,
    pub verify_failures: AtomicU64,
    pub errors: AtomicU64,
    /// Runs in which ≥ 2 same-kernel jobs were fused into one backend
    /// invocation.
    pub fused_batches: AtomicU64,
    /// Log-bucketed end-to-end latency histogram: fixed memory, every
    /// completion counted, lossless on merge (no reservoir decimation).
    latencies: Mutex<LatencyHist>,
}

impl LogShard {
    /// Record one end-to-end dispatch latency into the shard's
    /// log-bucketed histogram.
    pub(crate) fn record_latency(&self, ms: f64) {
        self.latencies.lock().unwrap().record_ms(ms);
    }

    /// Snapshot of the shard's latency histogram.
    pub(crate) fn latency_hist(&self) -> LatencyHist {
        self.latencies.lock().unwrap().clone()
    }
}

/// Merged view of every shard — what [`ServeLog::totals`] returns.
#[derive(Debug, Default)]
pub(crate) struct LogTotals {
    /// Bucket-wise sum of every shard's latency histogram — lossless,
    /// order-invariant, covers every recorded completion.
    pub latency_hist: LatencyHist,
    pub total_items: u64,
    pub total_dispatches: u64,
    pub verify_failures: u64,
    pub errors: u64,
    pub fused_batches: u64,
}

/// The sharded serving log: one [`LogShard`] per partition worker,
/// merged on read.
#[derive(Debug)]
pub(crate) struct ServeLog {
    shards: Vec<Arc<LogShard>>,
}

impl ServeLog {
    pub(crate) fn new(partitions: usize) -> ServeLog {
        ServeLog {
            shards: (0..partitions.max(1)).map(|_| Arc::new(LogShard::default())).collect(),
        }
    }

    /// The shard owned by partition `i`'s worker.
    pub(crate) fn shard(&self, i: usize) -> Arc<LogShard> {
        self.shards[i].clone()
    }

    /// Merge every shard into one snapshot (read-side only; the write
    /// path never takes a cross-shard lock).
    ///
    /// Latency merging is bucket-wise histogram addition — lossless
    /// and order-invariant, unlike the stride-aligned reservoir
    /// thinning this replaced: every shard's every completion is
    /// weighted identically in the merged percentiles.
    pub(crate) fn totals(&self) -> LogTotals {
        let mut t = LogTotals::default();
        for s in &self.shards {
            t.total_items += s.total_items.load(Ordering::Relaxed);
            t.total_dispatches += s.total_dispatches.load(Ordering::Relaxed);
            t.verify_failures += s.verify_failures.load(Ordering::Relaxed);
            t.errors += s.errors.load(Ordering::Relaxed);
            t.fused_batches += s.fused_batches.load(Ordering::Relaxed);
            t.latency_hist.merge(&s.latency_hist());
        }
        t
    }
}

pub(crate) struct Worker {
    pub queue: Arc<LaneQueue<Box<Job>>>,
    pub join: Option<thread::JoinHandle<()>>,
}

/// Fails whatever is still queued when the worker thread exits (panic
/// included) so `wait()`ing callers see an error instead of hanging.
/// Jobs already drained out of the queue are covered by
/// [`BatchGuard`]; `fulfill` is first-wins, so the sweeps never
/// clobber a delivered result.
///
/// Each drained job carries a typed [`FailReason`]: work whose
/// deadline already passed is `DeadlineRejected`, still-viable batch
/// work dropped at teardown is `Shed` (deliberate degradation), and
/// still-viable interactive work is `WorkerDied` — callers can tell a
/// crash from load shedding without parsing messages.
struct WorkerTeardown {
    queue: Arc<LaneQueue<Box<Job>>>,
    partition: usize,
    /// The coordinator's monotonic epoch, for evaluating deadlines.
    start: Instant,
}

impl Drop for WorkerTeardown {
    fn drop(&mut self) {
        let now_ns = self.start.elapsed().as_nanos() as u64;
        for job in self.queue.close_and_drain() {
            let (reason, message) = match job.deadline_nanos {
                Some(d) if d <= now_ns => (
                    FailReason::DeadlineRejected,
                    format!(
                        "partition {} worker shut down; the dispatch deadline had already passed",
                        self.partition
                    ),
                ),
                _ if job.priority == Priority::Batch => (
                    FailReason::Shed,
                    format!(
                        "partition {} worker shut down; queued batch dispatch shed",
                        self.partition
                    ),
                ),
                _ => (
                    FailReason::WorkerDied,
                    format!(
                        "partition {} worker terminated before running this dispatch",
                        self.partition
                    ),
                ),
            };
            job.handle.fulfill(Err(DispatchError::new(reason, message)));
        }
    }
}

/// Covers the jobs a worker has drained but not yet fulfilled: if the
/// worker panics mid-batch (e.g. a poisoned mutex), every in-flight
/// handle gets an error instead of leaving `wait()` blocked forever.
struct BatchGuard {
    partition: usize,
    handles: Vec<Arc<HandleInner>>,
}

impl Drop for BatchGuard {
    fn drop(&mut self) {
        if !thread::panicking() {
            return;
        }
        for h in &self.handles {
            h.fulfill(Err(DispatchError::new(
                FailReason::WorkerDied,
                format!(
                    "partition {} worker panicked before completing this dispatch",
                    self.partition
                ),
            )));
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_worker(
    partition: usize,
    device: Device,
    queue: Arc<LaneQueue<Box<Job>>>,
    scheduler: Arc<Mutex<SlotScheduler>>,
    log: Arc<LogShard>,
    pool: Arc<ScratchPool>,
    verify: bool,
    fusion_window: Duration,
    autoscaler: Option<Arc<Autoscaler>>,
    recovery: Arc<RecoveryPlane>,
    preempt_flag: Option<Arc<AtomicBool>>,
    start: Instant,
) -> Worker {
    let worker_queue = queue.clone();
    let join = thread::Builder::new()
        .name(format!("overlay-part{partition}"))
        .spawn(move || {
            let _teardown =
                WorkerTeardown { queue: worker_queue.clone(), partition, start };
            worker_loop(
                partition,
                device,
                worker_queue,
                scheduler,
                log,
                pool,
                verify,
                fusion_window,
                autoscaler,
                recovery,
                preempt_flag,
            )
        })
        .expect("spawning coordinator worker thread");
    Worker { queue, join: Some(join) }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    partition: usize,
    device: Device,
    queue: Arc<LaneQueue<Box<Job>>>,
    scheduler: Arc<Mutex<SlotScheduler>>,
    log: Arc<LogShard>,
    pool: Arc<ScratchPool>,
    verify: bool,
    fusion_window: Duration,
    autoscaler: Option<Arc<Autoscaler>>,
    recovery: Arc<RecoveryPlane>,
    preempt_flag: Option<Arc<AtomicBool>>,
) {
    while let Some(batch) = queue.drain() {
        let batch_size = batch.len();
        let mut guard = BatchGuard {
            partition,
            handles: batch.iter().map(|j| j.handle.clone()).collect(),
        };
        // (run, batch size it was drained with, fusion window already
        // spent) — the window is one-shot per run, so a batch run that
        // keeps getting preempted by interactive arrivals never waits
        // a fresh window on each re-pop (that would let a steady
        // interactive stream starve batch work indefinitely)
        let mut pending: VecDeque<(Vec<Box<Job>>, usize, bool)> = group_runs(batch)
            .into_iter()
            .map(|r| (r, batch_size, false))
            .collect();
        while let Some((mut run, mut run_batch_size, mut window_spent)) = pending.pop_front() {
            // interactive work that arrived after this batch was
            // drained jumps ahead of any batch-class run — the QoS
            // guarantee holds across drains, not just within one
            if run[0].priority == Priority::Batch {
                let arrivals = queue.take_interactive();
                if !arrivals.is_empty() {
                    let n = arrivals.len();
                    guard
                        .handles
                        .extend(arrivals.iter().map(|j| j.handle.clone()));
                    pending.push_front((run, run_batch_size, window_spent));
                    for r in group_runs(arrivals).into_iter().rev() {
                        pending.push_front((r, n, false));
                    }
                    continue;
                }
                // cross-batch fusion window: with nothing else queued
                // on this worker, wait a bounded interval for more
                // same-kernel batch jobs to trickle in and ride the
                // same backend invocation
                if !fusion_window.is_zero() && pending.is_empty() && !window_spent {
                    window_spent = true;
                    let absorbed = queue.absorb_batch_front(fusion_window, |j| {
                        j.key == run[0].key && j.priority == Priority::Batch
                    });
                    if !absorbed.is_empty() {
                        guard
                            .handles
                            .extend(absorbed.iter().map(|j| j.handle.clone()));
                        // absorbed jobs join this run's batch for
                        // reporting too, so batch_size ≥ fused holds
                        run_batch_size += absorbed.len();
                        run.extend(absorbed);
                    }
                    // interactive work that arrived during the wait
                    // still jumps the line
                    let arrivals = queue.take_interactive();
                    if !arrivals.is_empty() {
                        let n = arrivals.len();
                        guard
                            .handles
                            .extend(arrivals.iter().map(|j| j.handle.clone()));
                        pending.push_front((run, run_batch_size, window_spent));
                        for r in group_runs(arrivals).into_iter().rev() {
                            pending.push_front((r, n, false));
                        }
                        continue;
                    }
                }
            }
            // injected worker death: the worker "crashes" before the
            // run executes. Every in-flight job is released from this
            // partition's accounting and requeued onto the least-loaded
            // sibling; the partition takes a quarantine strike. The
            // thread itself then continues — modeling a supervisor
            // restart — so the partition count stays stable.
            if let Some(faults) = &recovery.faults {
                let struck = run
                    .iter()
                    .any(|j| faults.strikes(FaultKind::WorkerKill, j.seq, 0, j.attempts));
                if struck {
                    faults.note_injected(FaultKind::WorkerKill);
                    let quarantined = {
                        let mut s = scheduler.lock().unwrap();
                        let q = s.note_partition_failure(partition);
                        for j in &run {
                            s.complete_with_deadline(partition, 0.0, j.deadline_nanos);
                        }
                        q
                    };
                    if quarantined {
                        for j in &run {
                            if let Some(t) = &j.trace {
                                t.pin(CLASS_QUARANTINE, "partition", 0);
                            }
                        }
                    }
                    for job in run {
                        recovery.requeue(job, FaultKind::WorkerKill, partition);
                    }
                    continue;
                }
            }
            // interactive runs are never preemptible: the flag is only
            // consulted while a batch-class run holds the partition
            let boundary_flag = if run[0].priority == Priority::Batch {
                preempt_flag.as_deref()
            } else {
                None
            };
            let mut scratch = pool.checkout();
            let outcomes =
                serve_run(&device, &run, run_batch_size, verify, &mut scratch, boundary_flag);
            pool.checkin(scratch);
            let live = outcomes
                .iter()
                .filter(|o| matches!(o, RunOutcome::Done(Ok(_))))
                .count();
            if live >= 2 {
                log.fused_batches.fetch_add(1, Ordering::Relaxed);
            }
            let any_ok = live > 0;
            if outcomes.iter().any(|o| matches!(o, RunOutcome::Preempted)) {
                recovery.note_preempted_run();
            }
            for (job, outcome) in run.into_iter().zip(outcomes) {
                let result = match outcome {
                    RunOutcome::Done(result) => result,
                    RunOutcome::Preempted => {
                        // checkpointed at the chunk boundary: this
                        // job's slice never ran here, so release the
                        // partition's accounting and hand the job to
                        // the recovery plane as a typed continuation.
                        // The interactive arrival that raised the flag
                        // rides this worker's interactive lane, which
                        // the next drain serves first — the yield is
                        // the requeue itself.
                        scheduler
                            .lock()
                            .unwrap()
                            .complete_with_deadline(partition, 0.0, job.deadline_nanos);
                        recovery.requeue_preempted(job, partition);
                        continue;
                    }
                };
                let busy = match &result {
                    Ok(r) => r.event.modeled.seconds + r.event.config_seconds,
                    Err(_) => 0.0,
                };
                scheduler
                    .lock()
                    .unwrap()
                    .complete_with_deadline(partition, busy, job.deadline_nanos);
                // injected verify corruption: the dispatch executed but
                // its simulator verdict is untrustworthy — re-execute
                // on a sibling instead of delivering a lie (or a
                // spurious failure) to the caller.
                if let Some(faults) = &recovery.faults {
                    if result.is_ok()
                        && faults.strikes(FaultKind::VerifyCorrupt, job.seq, 0, job.attempts)
                    {
                        faults.note_injected(FaultKind::VerifyCorrupt);
                        let quarantined = scheduler
                            .lock()
                            .unwrap()
                            .note_partition_failure(partition);
                        if quarantined {
                            if let Some(t) = &job.trace {
                                t.pin(CLASS_QUARANTINE, "partition", 0);
                            }
                        }
                        recovery.requeue(job, FaultKind::VerifyCorrupt, partition);
                        continue;
                    }
                }
                log.total_dispatches.fetch_add(1, Ordering::Relaxed);
                // SLO completion feed (per job, success and failure):
                // end-to-end latency plus whether the dispatch met its
                // contract (a corrupt verify verdict is a bad event)
                if let Some(p) = &job.slo {
                    match &result {
                        Ok(r) => {
                            let e2e = r.queue_wait + r.event.wall;
                            p.complete(
                                e2e.as_secs_f64() * 1e3,
                                r.verified != Some(false),
                            );
                        }
                        Err(_) => p.complete(0.0, false),
                    }
                }
                match &result {
                    Ok(r) => {
                        let e2e = r.queue_wait + r.event.wall;
                        log.record_latency(e2e.as_secs_f64() * 1e3);
                        log.total_items
                            .fetch_add(r.event.global_size as u64, Ordering::Relaxed);
                        if r.verified == Some(false) {
                            log.verify_failures.fetch_add(1, Ordering::Relaxed);
                        }
                        if let Some(t) = &job.trace {
                            // worker-side timeline from the *measured*
                            // stage-boundary stamps serve_run captured
                            // on the sink clock — spans share the
                            // submit's root and are monotone by
                            // construction (each boundary was stamped
                            // after the previous one, on one clock)
                            let st = r.stamps;
                            let w = partition as i32;
                            let lane = match job.priority {
                                Priority::Interactive => "interactive",
                                Priority::Batch => "batch",
                            };
                            t.span(
                                Phase::QueueWait,
                                lane,
                                w,
                                t.enq_us,
                                st.run_start_us.saturating_sub(t.enq_us),
                                job.attempts as u64,
                                0,
                            );
                            t.span(
                                Phase::Pack,
                                "pack",
                                w,
                                st.run_start_us,
                                st.exec_start_us.saturating_sub(st.run_start_us),
                                r.batch_size as u64,
                                r.fused as u64,
                            );
                            t.span(
                                Phase::Exec,
                                if r.cache_hit { "warm" } else { "cold" },
                                w,
                                st.exec_start_us,
                                st.scatter_start_us.saturating_sub(st.exec_start_us),
                                r.event.global_size as u64,
                                0,
                            );
                            t.span(
                                Phase::Scatter,
                                "scatter",
                                w,
                                st.scatter_start_us,
                                st.done_us.saturating_sub(st.scatter_start_us),
                                0,
                                0,
                            );
                            let vtag = match r.verified {
                                Some(true) => "ok",
                                Some(false) => "corrupt",
                                None => "skipped",
                            };
                            t.span(Phase::Verify, vtag, w, st.done_us, 0, 0, 0);
                            t.pin(CLASS_TAIL, "e2e", st.done_us.saturating_sub(t.enq_us));
                        }
                    }
                    Err(_) => {
                        log.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                // feed the autoscaler's completion-side load signal
                if let (Some(a), Ok(r)) = (&autoscaler, &result) {
                    let e2e = r.queue_wait + r.event.wall;
                    a.note_complete(
                        job.source_hash,
                        job.spec_fp,
                        e2e.as_secs_f64() * 1e3,
                        r.event.modeled.seconds * 1e3,
                    );
                }
                // a completion after a fault strike is a recovery
                if result.is_ok() {
                    if let (Some(faults), Some(kind)) = (&recovery.faults, job.last_fault) {
                        faults.note_recovered(kind);
                    }
                }
                job.handle.fulfill(result.map_err(|e| {
                    DispatchError::new(FailReason::ExecFailed, format!("{e:#}"))
                }));
            }
            if any_ok {
                scheduler.lock().unwrap().note_partition_success(partition);
            }
        }
    }
}

/// Group a drained batch into fusion runs: maximal sequences of
/// consecutive jobs sharing a kernel-cache key **and** priority
/// class. Priority matters: fusing an interactive dispatch into a
/// batch payload would make its completion wait on (and its wall
/// time include) throughput work, voiding the QoS lanes.
fn group_runs(batch: Vec<Box<Job>>) -> Vec<Vec<Box<Job>>> {
    let mut runs: Vec<Vec<Box<Job>>> = Vec::new();
    for job in batch {
        let fuses = runs
            .last()
            .is_some_and(|run| run[0].key == job.key && run[0].priority == job.priority);
        if fuses {
            runs.last_mut().expect("non-empty runs").push(job);
        } else {
            runs.push(vec![job]);
        }
    }
    runs
}

/// Per-job outcome of [`serve_run`], index-aligned with the run.
pub(crate) enum RunOutcome {
    /// The job's slice executed (or failed); the worker completes it
    /// on this partition as before.
    Done(Result<DispatchResult>),
    /// The run was checkpointed at a chunk boundary before this job's
    /// slice ran: nothing of it executed here, and the worker must
    /// requeue it as a typed continuation
    /// ([`RecoveryPlane::requeue_preempted`]).
    Preempted,
}

/// Execute one fusion run (1..N same-kernel jobs) on this worker's
/// device in a single backend invocation and assemble the per-job
/// completion reports (index-aligned with `run`). Every job packs
/// directly into the run's shared input arena at its own lane offset
/// and reads its outputs back from the shared output arena at the
/// same offset — the fused batch is concatenated and split without
/// any intermediate stream copies.
///
/// With `preempt_flag` armed (batch-class runs on a preemption-enabled
/// coordinator), the cycle-sim backend executes the run **chunk by
/// chunk** — one [`sim::execute_slice_into`] per job's lane range —
/// and consults the flag between chunks. When the coordinator raised
/// it, the run checkpoints at that boundary: every chunk already
/// executed scatters and verifies exactly as usual, and the un-run
/// remainder comes back as [`RunOutcome::Preempted`]. Two exceptions
/// keep the checkpoint safe and live: the first chunk always executes
/// (a preempted run makes progress, so a requeue cycle terminates),
/// and a job whose preemption budget is exhausted executes even after
/// the flag fired (non-preemptible, the livelock cap). Slicing is
/// bit-exact by construction — each lane's result depends only on its
/// own input column — so a preempted-and-resumed dispatch returns the
/// same bytes as an unpreempted one. The PJRT backend is a single
/// opaque FFI invocation and is never preempted mid-run.
fn serve_run(
    device: &Device,
    run: &[Box<Job>],
    batch_size: usize,
    verify: bool,
    scratch: &mut DispatchScratch,
    preempt_flag: Option<&AtomicBool>,
) -> Vec<RunOutcome> {
    let queue_waits: Vec<Duration> = run.iter().map(|j| j.enqueued.elapsed()).collect();
    // stage-boundary stamps ride the trace-sink clock; any traced job
    // in the run supplies it (one sink per coordinator, so the clock
    // is shared). Untraced runs never read a clock.
    let clock: Option<&JobTrace> = run.iter().find_map(|j| j.trace.as_ref());
    let stamp = || clock.map_or(0, |t| t.now());
    // wall clock covers the whole serve — pack, execute, cross-check,
    // and (per job) scatter + verification — matching the synchronous
    // runtime path's event semantics
    let t0 = Instant::now();
    let run_start_us = stamp();
    let mut exec_start_us = 0u64;
    // one argument snapshot per job (one short lock each); a job with
    // unset arguments fails alone, not the run
    let snaps: Vec<Result<ArgSnapshot>> =
        run.iter().map(|j| j.kernel.snapshot_args()).collect();
    let live: Vec<usize> = (0..run.len()).filter(|&i| snaps[i].is_ok()).collect();
    let chunks: Vec<usize> =
        run.iter().map(|j| j.kernel.chunk_for(j.global_size)).collect();

    // pack every live job into one flat arena and run one backend
    // invocation over the concatenation
    let mut pack_ns = 0u64;
    // per-run-index: true when the run checkpointed before this job's
    // chunk executed (set only on the cycle-sim slice path below)
    let mut preempted = vec![false; run.len()];
    let exec: Result<bool> = if live.is_empty() {
        Err(anyhow!("no dispatch in this run packed successfully"))
    } else {
        (|| -> Result<bool> {
            let k = &run[live[0]].kernel.compiled;
            let total: usize = live.iter().map(|&i| chunks[i]).sum();
            let tp = Instant::now();
            scratch.inputs.reset(k.factor.max(1) * k.n_inputs, total);
            let mut off = 0usize;
            for &i in &live {
                let snap = snaps[i].as_ref().expect("live job has a snapshot");
                run[i].kernel.pack_streams_into(
                    snap,
                    run[i].global_size,
                    &mut scratch.inputs,
                    off,
                )?;
                off += chunks[i];
            }
            pack_ns = tp.elapsed().as_nanos() as u64;
            exec_start_us = stamp();
            match &device.backend {
                Backend::CycleSim => {
                    scratch.outputs.reset(k.schedule.out_col.len(), total);
                    let mut yielding = false;
                    let mut off = 0usize;
                    for (pos, &i) in live.iter().enumerate() {
                        // chunk boundary: consume the partition's
                        // preemption flag, but never before the first
                        // chunk — the run always makes progress
                        if pos > 0 && !yielding {
                            if let Some(flag) = preempt_flag {
                                yielding = flag.swap(false, Ordering::SeqCst);
                            }
                        }
                        if yielding && run[i].preemptions < MAX_PREEMPTIONS {
                            preempted[i] = true;
                            off += chunks[i];
                            continue;
                        }
                        // budget-exhausted jobs fall through and
                        // execute: non-preemptible by budget
                        sim::execute_slice_into(
                            &k.schedule,
                            &scratch.inputs,
                            off,
                            chunks[i],
                            &mut scratch.sim,
                            &mut scratch.outputs,
                        )?;
                        off += chunks[i];
                    }
                }
                Backend::Pjrt(rt) => {
                    // the PJRT FFI boundary still wants owned vectors;
                    // the invocation is opaque, so PJRT runs are
                    // non-preemptible (no chunk boundary to stop at)
                    let outs =
                        rt.execute_overlay(&k.schedule, &scratch.inputs.to_vecs(), total)?;
                    scratch.outputs.fill_from(&outs, total);
                }
            }
            // cross-check: PJRT partitions re-execute on the cycle
            // simulator and must agree stream-for-stream; on cycle-sim
            // partitions the output arena *is* the simulator's output,
            // so the cross check is free. The re-execution reuses the
            // pooled sim scratch (idle on the PJRT path) and the
            // scratch's dedicated verify arena — no per-run heap
            // traffic once warm.
            if verify {
                if let Backend::Pjrt(_) = &device.backend {
                    sim::execute_into(
                        &k.schedule,
                        &scratch.inputs,
                        total,
                        &mut scratch.sim,
                        &mut scratch.verify,
                    )?;
                    return Ok(scratch.verify.as_flat() == scratch.outputs.as_flat());
                }
            }
            Ok(true)
        })()
    };

    // split outputs per job by lane offset, scatter, verify, report
    let mut results: Vec<RunOutcome> = Vec::with_capacity(run.len());
    match exec {
        Err(e) => {
            let msg = format!("{e:#}");
            for s in snaps {
                results.push(RunOutcome::Done(match s {
                    Err(snap_err) => Err(snap_err),
                    Ok(_) => Err(anyhow!("{msg}")),
                }));
            }
        }
        Ok(cross) => {
            let fused_count = live.len() - preempted.iter().filter(|&&p| p).count();
            let mut off = 0usize;
            for (i, s) in snaps.into_iter().enumerate() {
                match s {
                    Err(snap_err) => results.push(RunOutcome::Done(Err(snap_err))),
                    Ok(_) if preempted[i] => {
                        // the chunk was packed but never executed; its
                        // lanes stay un-scattered and the job resumes
                        // elsewhere from its own (untouched) buffers
                        results.push(RunOutcome::Preempted);
                        off += chunks[i];
                    }
                    Ok(snap) => {
                        let job = &run[i];
                        let scatter_start_us = stamp();
                        let ts = Instant::now();
                        job.kernel.scatter_outputs_from(
                            &snap,
                            &scratch.outputs,
                            off,
                            job.global_size,
                        );
                        // scatter_ns covers the scatter alone (same
                        // meaning as the synchronous path); the
                        // verification read-back below is deliberately
                        // outside the attribution window
                        let scatter_ns = ts.elapsed().as_nanos() as u64;
                        // read the scattered buffers back and require
                        // the simulator-verified values exactly — this
                        // catches pack/scatter/fusion indexing bugs a
                        // re-execution alone cannot.
                        let verified = if verify {
                            Some(
                                cross
                                    && job.kernel.outputs_match_from(
                                        &snap,
                                        &scratch.outputs,
                                        off,
                                        job.global_size,
                                    ),
                            )
                        } else {
                            None
                        };
                        off += chunks[i];
                        let k = &job.kernel.compiled;
                        let modeled = sim::timing(
                            &device.spec,
                            &k.latency,
                            k.factor,
                            k.ops_per_copy,
                            job.global_size as u64,
                        );
                        results.push(RunOutcome::Done(Ok(DispatchResult {
                            event: Event {
                                wall: t0.elapsed(),
                                pack_ns,
                                scatter_ns,
                                config_seconds: job.config_seconds,
                                modeled,
                                global_size: job.global_size,
                            },
                            partition: job.partition,
                            spec: job.spec.clone(),
                            priority: job.priority,
                            cache_hit: job.cache_hit,
                            queue_wait: queue_waits[i],
                            batch_size,
                            fused: fused_count,
                            verified,
                            stamps: StageStamps {
                                run_start_us,
                                exec_start_us,
                                scatter_start_us,
                                done_us: stamp(),
                            },
                        })));
                    }
                }
            }
        }
    }
    results
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_queue_drains_interactive_before_batch() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        q.push(1, Priority::Batch).unwrap();
        q.push(2, Priority::Interactive).unwrap();
        q.push(3, Priority::Batch).unwrap();
        q.push(4, Priority::Interactive).unwrap();
        let drained = q.drain().unwrap();
        // interactive lane first (FIFO within a lane), then batch
        assert_eq!(drained, vec![2, 4, 1, 3]);
    }

    #[test]
    fn closed_queue_rejects_pushes_and_drains_remainder() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        q.push(1, Priority::Interactive).unwrap();
        q.close();
        assert_eq!(q.push(2, Priority::Interactive), Err(2));
        // queued work still drains, then the worker sees shutdown
        assert_eq!(q.drain(), Some(vec![1]));
        assert_eq!(q.drain(), None);
    }

    #[test]
    fn take_interactive_skips_the_batch_lane() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        q.push(1, Priority::Batch).unwrap();
        q.push(2, Priority::Interactive).unwrap();
        assert_eq!(q.take_interactive(), vec![2]);
        assert_eq!(q.take_interactive(), Vec::<i32>::new());
        // the batch job is still queued
        assert_eq!(q.drain(), Some(vec![1]));
    }

    #[test]
    fn close_and_drain_returns_leftovers() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        q.push(1, Priority::Batch).unwrap();
        q.push(2, Priority::Interactive).unwrap();
        assert_eq!(q.close_and_drain(), vec![2, 1]);
        assert_eq!(q.drain(), None);
    }

    #[test]
    fn drain_blocks_until_work_arrives() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        let q2 = q.clone();
        let t = thread::spawn(move || q2.drain());
        thread::sleep(Duration::from_millis(10));
        q.push(7, Priority::Batch).unwrap();
        assert_eq!(t.join().unwrap(), Some(vec![7]));
    }

    #[test]
    fn absorb_batch_front_takes_matching_trickle_only() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        q.push(2, Priority::Batch).unwrap();
        q.push(2, Priority::Batch).unwrap();
        q.push(3, Priority::Batch).unwrap();
        // matching front items pop immediately; the non-matching head
        // ends the hunt without waiting out the window
        let t0 = Instant::now();
        let got = q.absorb_batch_front(Duration::from_millis(400), |&x| x == 2);
        assert_eq!(got, vec![2, 2]);
        assert!(t0.elapsed() < Duration::from_millis(300));
        assert_eq!(q.drain(), Some(vec![3]));
    }

    #[test]
    fn absorb_batch_front_yields_to_interactive_arrivals() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        q.push(9, Priority::Interactive).unwrap();
        let t0 = Instant::now();
        assert!(q.absorb_batch_front(Duration::from_millis(400), |_| true).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(300));
        // the interactive job is untouched
        assert_eq!(q.take_interactive(), vec![9]);
    }

    #[test]
    fn absorb_batch_front_catches_a_trickle_arrival() {
        let q: Arc<LaneQueue<i32>> = LaneQueue::new();
        let q2 = q.clone();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            q2.push(5, Priority::Batch).unwrap();
        });
        let got = q.absorb_batch_front(Duration::from_millis(2_000), |&x| x == 5);
        t.join().unwrap();
        assert_eq!(got, vec![5]);
    }

    #[test]
    fn handle_preserves_typed_fail_reason() {
        let inner = HandleInner::new();
        let h = DispatchHandle { inner: inner.clone() };
        inner.fulfill(Err(DispatchError::new(FailReason::Shed, "dropped".into())));
        // first-wins: later deliveries are ignored
        inner.fulfill(Err(DispatchError::new(FailReason::WorkerDied, "late".into())));
        let err = h.wait_typed().unwrap_err();
        assert_eq!(err.reason(), FailReason::Shed);
        assert_eq!(err.to_string(), "dropped");
        assert_eq!(err.reason().name(), "shed");
    }

    #[test]
    fn wait_converts_dispatch_error_to_anyhow() {
        let inner = HandleInner::new();
        let h = DispatchHandle { inner: inner.clone() };
        inner.fulfill(Err(DispatchError::new(
            FailReason::WorkerDied,
            "partition 3 worker terminated before running this dispatch".into(),
        )));
        let err = h.wait().unwrap_err();
        assert!(err.to_string().contains("partition 3"));
    }

    #[test]
    fn try_wait_typed_polls_without_blocking() {
        let inner = HandleInner::new();
        let h = DispatchHandle { inner: inner.clone() };
        assert!(h.try_wait_typed().is_none());
        inner.fulfill(Err(DispatchError::new(
            FailReason::DeadlineRejected,
            "too late".into(),
        )));
        let err = h.try_wait_typed().expect("delivered").unwrap_err();
        assert_eq!(err.reason(), FailReason::DeadlineRejected);
        // the slot is a take(): a second poll sees nothing
        assert!(h.try_wait_typed().is_none());
    }

    #[test]
    fn preempted_fail_reason_is_typed_and_named() {
        let inner = HandleInner::new();
        let h = DispatchHandle { inner: inner.clone() };
        inner.fulfill(Err(DispatchError::new(
            FailReason::Preempted,
            "partition 1 closed before the preempted continuation could resume".into(),
        )));
        let err = h.wait_typed().unwrap_err();
        assert_eq!(err.reason(), FailReason::Preempted);
        assert_eq!(err.reason().name(), "preempted");
    }

    #[test]
    fn recovery_plane_preempt_flags_raise_and_counters_track_records() {
        let scheduler =
            Arc::new(Mutex::new(super::super::scheduler::SlotScheduler::new(2)));
        let plane = RecoveryPlane::new(None, 3, scheduler);
        // raising before registration is a harmless no-op
        plane.raise_preempt(0);
        let flags: Vec<Arc<AtomicBool>> =
            (0..2).map(|_| Arc::new(AtomicBool::new(false))).collect();
        plane.register_preempt_flags(flags.clone());
        plane.raise_preempt(1);
        assert!(!flags[0].load(Ordering::SeqCst));
        assert!(flags[1].load(Ordering::SeqCst));
        // out-of-range partitions are ignored, not a panic
        plane.raise_preempt(99);
        // the worker consumes the flag with a swap at the boundary
        assert!(flags[1].swap(false, Ordering::SeqCst));
        assert!(!flags[1].load(Ordering::SeqCst));
        // counters start consistent with the (empty) record log
        let (records, dropped) = plane.continuation_records();
        assert!(records.is_empty());
        assert_eq!(dropped, 0);
        assert_eq!(plane.preempted_requeue_count(), 0);
        assert_eq!(plane.preempted_run_count(), 0);
    }

    #[test]
    fn histogram_counts_every_sample_where_the_reservoir_decimated() {
        // The legacy reservoir halves its resolution past capacity;
        // the histogram shard must keep an exact count forever.
        let shard = LogShard::default();
        let mut reservoir = LatencyReservoir::default();
        let n = MAX_LATENCY_SAMPLES + 10;
        for i in 0..n {
            shard.record_latency(i as f64);
            reservoir.record(i as f64);
        }
        assert!(reservoir.stride >= 2, "filling the reservoir raises its stride");
        assert!(reservoir.samples.len() < n, "the reservoir dropped samples");
        let h = shard.latency_hist();
        assert_eq!(h.count(), n as u64, "the histogram dropped none");
    }

    #[test]
    fn histogram_percentiles_agree_with_the_reservoir_within_a_bucket() {
        // Same deterministic long-tailed stream into both carriers:
        // the histogram's percentile must land within one log bucket
        // (a factor of sqrt(2)) of the exact sample percentile the
        // reservoir path computed.
        let shard = LogShard::default();
        let mut exact: Vec<f64> = Vec::new();
        for i in 1..=2000u64 {
            let ms = 0.05 * i as f64 + ((i * i) % 251) as f64 * 0.2;
            shard.record_latency(ms);
            exact.push(ms);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let h = shard.latency_hist();
        for &p in &[0.5, 0.9, 0.99, 0.999] {
            let idx = ((exact.len() - 1) as f64 * p).round() as usize;
            let want = exact[idx];
            let ratio = h.percentile_ms(p) / want;
            assert!(
                (0.70..=1.42).contains(&ratio),
                "p{p}: hist {} vs exact {want}",
                h.percentile_ms(p)
            );
        }
    }

    #[test]
    fn merged_shard_histograms_are_lossless_and_order_invariant() {
        // One shard is busy, one idle: the old stride-aligned merge
        // thinned the idle shard; bucket addition keeps every sample
        // from both, and shard order cannot matter.
        let log = ServeLog::new(2);
        let busy = 4096usize;
        for i in 0..busy {
            log.shard(0).record_latency(1.0 + (i % 7) as f64);
        }
        let idle = 64usize;
        for i in 0..idle {
            log.shard(1).record_latency(1e3 + i as f64);
        }
        let t = log.totals();
        assert_eq!(t.latency_hist.count(), (busy + idle) as u64);
        let mut swapped = log.shard(1).latency_hist();
        swapped.merge(&log.shard(0).latency_hist());
        assert_eq!(t.latency_hist, swapped, "merge order is invisible");
        // the idle shard's slow tail survives the busy shard's volume
        assert!(t.latency_hist.max_ms() >= 1e3);
        assert!(t.latency_hist.p999_ms() > 100.0);
    }

    #[test]
    fn sharded_log_merges_counter_and_latency_shards() {
        let log = ServeLog::new(3);
        for (i, items) in [(0usize, 10u64), (1, 20), (2, 30)] {
            let shard = log.shard(i);
            shard.total_dispatches.fetch_add(1, Ordering::Relaxed);
            shard.total_items.fetch_add(items, Ordering::Relaxed);
            shard.record_latency(items as f64);
        }
        log.shard(1).fused_batches.fetch_add(1, Ordering::Relaxed);
        log.shard(2).errors.fetch_add(2, Ordering::Relaxed);
        let t = log.totals();
        assert_eq!(t.total_dispatches, 3);
        assert_eq!(t.total_items, 60);
        assert_eq!(t.fused_batches, 1);
        assert_eq!(t.errors, 2);
        assert_eq!(t.verify_failures, 0);
        assert_eq!(t.latency_hist.count(), 3);
        assert_eq!(t.latency_hist.max_ms(), 30.0);
        // p0/p100 bracket the recorded range within bucket resolution
        assert!(t.latency_hist.percentile_ms(0.0) <= 10.0 * 1.42);
        assert!(t.latency_hist.percentile_ms(1.0) <= 30.0);
    }
}
