//! Asynchronous dispatch: one worker thread per overlay partition.
//!
//! Each partition owns an in-order work queue (an OpenCL command
//! queue, in the paper's terms). `submit` is non-blocking: it routes
//! the request through the slot-aware scheduler, enqueues a job on the
//! chosen partition's channel and returns a [`DispatchHandle`] the
//! caller can later `wait()` on. Workers drain their channel in
//! batches — consecutive enqueues against an already-configured
//! partition amortize the (already µs-class) configuration cost to
//! zero, mirroring how the paper's runtime reuses a loaded overlay
//! configuration across `clEnqueueNDRangeKernel` calls.
//!
//! Completion carries the same timing breakdown as a synchronous
//! [`crate::runtime_ocl::Event`] (wall time, modeled configuration
//! load, modeled II=1 overlay timing) plus serving metadata: queue
//! wait, compile-cache hit flag, batch size, and the optional
//! cycle-simulator verification verdict.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime_ocl::{Backend, Buffer, Device, Event, Kernel};
use crate::sim;

use super::scheduler::SlotScheduler;

/// An argument to [`crate::coordinator::Coordinator::submit`].
#[derive(Debug, Clone)]
pub enum SubmitArg {
    /// A global-memory buffer (read and/or written by the kernel).
    Buffer(Buffer),
    /// A broadcast scalar.
    Scalar(i32),
}

/// Completed-dispatch report: the event an OpenCL profiling query
/// would return, plus the coordinator's serving metadata.
#[derive(Debug, Clone)]
pub struct DispatchResult {
    /// Timing breakdown identical to the synchronous runtime path.
    pub event: Event,
    /// Partition (fleet index) that executed the dispatch.
    pub partition: usize,
    /// Whether the compiled kernel came from the compile cache.
    pub cache_hit: bool,
    /// Time spent queued before the worker picked the job up.
    pub queue_wait: Duration,
    /// Jobs drained in the same worker batch (≥ 1).
    pub batch_size: usize,
    /// `Some(true)` when the dispatch verified against the cycle
    /// simulator: the scattered output buffers hold the simulator's
    /// values bit-for-bit (and, on PJRT partitions, the backend's raw
    /// streams agreed with a simulator re-execution). `None` when
    /// verification is disabled.
    pub verified: Option<bool>,
}

pub(crate) struct HandleInner {
    slot: Mutex<Option<Result<DispatchResult>>>,
    cv: Condvar,
}

impl HandleInner {
    pub(crate) fn new() -> Arc<HandleInner> {
        Arc::new(HandleInner { slot: Mutex::new(None), cv: Condvar::new() })
    }

    pub(crate) fn fulfill(&self, result: Result<DispatchResult>) {
        *self.slot.lock().unwrap() = Some(result);
        self.cv.notify_all();
    }
}

/// Completion handle for an asynchronously dispatched kernel.
pub struct DispatchHandle {
    pub(crate) inner: Arc<HandleInner>,
}

impl DispatchHandle {
    /// Block until the dispatch completes and return its result.
    pub fn wait(self) -> Result<DispatchResult> {
        let mut slot = self.inner.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.inner.cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking poll: `Some(result)` once the dispatch completed.
    pub fn try_wait(&self) -> Option<Result<DispatchResult>> {
        self.inner.slot.lock().unwrap().take()
    }
}

/// One queued dispatch.
pub(crate) struct Job {
    pub kernel: Kernel,
    pub global_size: usize,
    pub partition: usize,
    /// Modeled bitstream-load seconds charged by the scheduler
    /// (0.0 when the partition already held the configuration).
    pub config_seconds: f64,
    pub cache_hit: bool,
    pub enqueued: Instant,
    pub handle: Arc<HandleInner>,
}

pub(crate) enum Msg {
    Job(Box<Job>),
    Shutdown,
}

/// Latency samples kept before the buffer halves its resolution —
/// bounds coordinator memory on long-running fleets.
pub(crate) const MAX_LATENCY_SAMPLES: usize = 65_536;

/// Shared serving counters the workers append to.
#[derive(Debug)]
pub(crate) struct ServeLog {
    pub latencies_ms: Vec<f64>,
    /// Every `lat_stride`-th dispatch is sampled; doubles each time
    /// the buffer fills (decimation keeps percentiles representative).
    lat_stride: u64,
    lat_seen: u64,
    pub total_items: u64,
    pub total_dispatches: u64,
    pub verify_failures: u64,
    pub errors: u64,
    /// Wall seconds of JIT compilation on cache misses (recorded by
    /// the coordinator, not the workers).
    pub compile_seconds: f64,
}

impl Default for ServeLog {
    fn default() -> Self {
        ServeLog {
            latencies_ms: Vec::new(),
            lat_stride: 1,
            lat_seen: 0,
            total_items: 0,
            total_dispatches: 0,
            verify_failures: 0,
            errors: 0,
            compile_seconds: 0.0,
        }
    }
}

impl ServeLog {
    /// Record one end-to-end dispatch latency, downsampling once the
    /// buffer reaches [`MAX_LATENCY_SAMPLES`].
    pub(crate) fn record_latency(&mut self, ms: f64) {
        self.lat_seen += 1;
        if self.lat_seen % self.lat_stride != 0 {
            return;
        }
        if self.latencies_ms.len() >= MAX_LATENCY_SAMPLES {
            let mut i = 0usize;
            self.latencies_ms.retain(|_| {
                i += 1;
                i % 2 == 1
            });
            self.lat_stride *= 2;
        }
        self.latencies_ms.push(ms);
    }
}

pub(crate) struct Worker {
    pub sender: Sender<Msg>,
    pub join: Option<thread::JoinHandle<()>>,
}

pub(crate) fn spawn_worker(
    partition: usize,
    device: Device,
    scheduler: Arc<Mutex<SlotScheduler>>,
    log: Arc<Mutex<ServeLog>>,
    verify: bool,
) -> Worker {
    let (sender, receiver) = mpsc::channel::<Msg>();
    let join = thread::Builder::new()
        .name(format!("overlay-part{partition}"))
        .spawn(move || worker_loop(partition, device, receiver, scheduler, log, verify))
        .expect("spawning coordinator worker thread");
    Worker { sender, join: Some(join) }
}

fn worker_loop(
    partition: usize,
    device: Device,
    receiver: Receiver<Msg>,
    scheduler: Arc<Mutex<SlotScheduler>>,
    log: Arc<Mutex<ServeLog>>,
    verify: bool,
) {
    loop {
        // block for work, then drain whatever else queued up — the
        // per-partition batch
        let first = match receiver.recv() {
            Ok(m) => m,
            Err(_) => return, // coordinator dropped
        };
        let mut batch = vec![first];
        while let Ok(m) = receiver.try_recv() {
            batch.push(m);
        }
        let batch_size = batch.iter().filter(|m| matches!(m, Msg::Job(_))).count();
        let mut shutdown = false;
        for msg in batch {
            match msg {
                Msg::Shutdown => shutdown = true,
                Msg::Job(job) => {
                    let result = run_job(&device, &job, batch_size, verify);
                    let busy = match &result {
                        Ok(r) => r.event.modeled.seconds + r.event.config_seconds,
                        Err(_) => 0.0,
                    };
                    scheduler.lock().unwrap().complete(partition, busy);
                    {
                        let mut lg = log.lock().unwrap();
                        lg.total_dispatches += 1;
                        match &result {
                            Ok(r) => {
                                let e2e = r.queue_wait + r.event.wall;
                                lg.record_latency(e2e.as_secs_f64() * 1e3);
                                lg.total_items += r.event.global_size as u64;
                                if r.verified == Some(false) {
                                    lg.verify_failures += 1;
                                }
                            }
                            Err(_) => lg.errors += 1,
                        }
                    }
                    job.handle.fulfill(result);
                }
            }
        }
        if shutdown {
            return;
        }
    }
}

/// Execute one dispatch on this worker's device and assemble the
/// completion report.
fn run_job(device: &Device, job: &Job, batch_size: usize, verify: bool) -> Result<DispatchResult> {
    let queue_wait = job.enqueued.elapsed();
    let t0 = Instant::now();
    let k = &job.kernel.compiled;

    let (streams, chunk) = job.kernel.pack_streams(job.global_size)?;
    let outs = match &device.backend {
        Backend::CycleSim => sim::execute(&k.schedule, &streams, chunk)?,
        Backend::Pjrt(rt) => rt.execute_overlay(&k.schedule, &streams, chunk)?,
    };
    job.kernel.scatter_outputs(&outs, job.global_size);

    // verification: for PJRT partitions, re-execute on the cycle
    // simulator and require bit-exact agreement (the serving-path
    // analogue of the backend agreement suite); on cycle-sim
    // partitions `outs` *is* the simulator's output, so the cross
    // check is free. Either way, read the scattered buffers back and
    // require them to hold the simulator-verified values exactly —
    // this catches pack/scatter indexing bugs, which a re-execution
    // alone cannot.
    let verified = if verify {
        let cross = match &device.backend {
            Backend::CycleSim => true,
            Backend::Pjrt(_) => sim::execute(&k.schedule, &streams, chunk)? == outs,
        };
        Some(cross && job.kernel.outputs_match(&outs, job.global_size))
    } else {
        None
    };

    let modeled = sim::timing(
        &device.spec,
        &k.latency,
        k.plan.factor,
        k.ops_per_copy(),
        job.global_size as u64,
    );
    Ok(DispatchResult {
        event: Event {
            wall: t0.elapsed(),
            config_seconds: job.config_seconds,
            modeled,
            global_size: job.global_size,
        },
        partition: job.partition,
        cache_hit: job.cache_hit,
        queue_wait,
        batch_size,
        verified,
    })
}
