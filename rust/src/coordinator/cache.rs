//! The kernel cache: content-addressed reuse of JIT artifacts.
//!
//! The paper's JIT compile is seconds-class (Fig. 7); a serving
//! deployment cannot afford to pay it per request. Compiled kernels
//! are therefore cached under a **stable key** — (kernel source hash,
//! overlay fingerprint, compile-options fingerprint) — so a repeat
//! build is O(hash lookup) and only genuinely new (source, overlay,
//! options) combinations hit the compiler. Eviction is LRU over a
//! bounded capacity with deterministic tie-breaking (a monotonic
//! logical clock stamps every touch), which the tests rely on.
//!
//! In a heterogeneous fleet each [`crate::fleet::CompileShard`] owns
//! one `KernelCache`, so entries for different overlay specs never
//! share a shard — the per-spec isolation the fleet tests assert.
//!
//! Because cache keys are stable across processes, the cache can be
//! **snapshotted**: [`KernelCache::save_snapshot`] spills every
//! entry's executable slice — slot schedule, bitstream words, host
//! binding metadata — through [`crate::util::JsonValue`], and
//! [`KernelCache::load_snapshot`] warm-starts a restarted fleet
//! without re-paying the seconds-class JIT.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context as _, Result};

use crate::compiler::{stable_source_hash, CompileOptions, Replication, ServableKernel};
use crate::configgen::{EmuGeometry, SlotSchedule};
use crate::frontend::{Param, ParamKind, Type};
use crate::latency::LatencyReport;
use crate::metrics::CacheStats;
use crate::overlay::{OverlayBitstream, OverlaySpec};
use crate::replicate::LimitReason;
use crate::util::JsonValue;

/// Stable kernel-cache key. Every component survives process
/// restarts (FNV-1a, not `DefaultHasher`), so keys can be logged,
/// persisted and compared across runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// FNV-1a of the kernel source text.
    pub source: u64,
    /// [`OverlaySpec::fingerprint`] of the target overlay.
    pub spec: u64,
    /// [`CompileOptions::fingerprint`] of the build options.
    pub options: u64,
}

impl CacheKey {
    pub fn new(source: &str, spec: &OverlaySpec, options: &CompileOptions) -> CacheKey {
        CacheKey {
            source: stable_source_hash(source),
            spec: spec.fingerprint(),
            options: options.fingerprint(),
        }
    }
}

struct Entry {
    kernel: Arc<ServableKernel>,
    /// Logical time of the last hit or insert (unique — ties are
    /// impossible, so eviction order is deterministic).
    last_used: u64,
}

/// Bounded LRU cache of compiled (servable) kernels.
pub struct KernelCache {
    map: HashMap<CacheKey, Entry>,
    capacity: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Former name of [`KernelCache`], kept for older call sites.
pub type CompileCache = KernelCache;

impl std::fmt::Debug for KernelCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelCache")
            .field("entries", &self.map.len())
            .field("capacity", &self.capacity)
            .field("hits", &self.hits)
            .field("misses", &self.misses)
            .field("evictions", &self.evictions)
            .finish()
    }
}

impl KernelCache {
    /// A cache holding at most `capacity` compiled kernels
    /// (`capacity` is clamped to ≥ 1).
    pub fn new(capacity: usize) -> KernelCache {
        KernelCache {
            map: HashMap::new(),
            capacity: capacity.max(1),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look a key up, counting a hit or miss and refreshing LRU order
    /// on hit.
    pub fn get(&mut self, key: &CacheKey) -> Option<Arc<ServableKernel>> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = self.tick;
                self.hits += 1;
                Some(e.kernel.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Check residency without touching counters or LRU order.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.map.contains_key(key)
    }

    /// Insert a compiled kernel, evicting the least-recently-used
    /// entry if the cache is full. Returns the evicted key, if any.
    pub fn insert(&mut self, key: CacheKey, kernel: Arc<ServableKernel>) -> Option<CacheKey> {
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&key) {
            // refresh (racing compilers may insert the same key twice)
            e.kernel = kernel;
            e.last_used = self.tick;
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                self.map.remove(&victim);
                self.evictions += 1;
                evicted = Some(victim);
            }
        }
        self.map.insert(key, Entry { kernel, last_used: self.tick });
        evicted
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.map.len(),
            capacity: self.capacity,
        }
    }

    /// Persist every resident entry (key + executable kernel slice) to
    /// `path` as JSON. Entries are written in deterministic key order,
    /// so identical cache contents produce identical snapshot bytes.
    /// Returns the number of entries serialized. Format version 2 is
    /// byte-compatible with version 1 (the kernel object has always
    /// carried its replication factor); the bump marks the
    /// variant-aware load semantics below.
    pub fn save_snapshot(&self, path: &Path) -> Result<usize> {
        let mut pairs: Vec<(&CacheKey, &Entry)> = self.map.iter().collect();
        pairs.sort_by_key(|(k, _)| (k.source, k.spec, k.options));
        let written = pairs.len();
        let entries: Vec<JsonValue> = pairs
            .into_iter()
            .map(|(key, e)| {
                let mut obj = std::collections::BTreeMap::new();
                obj.insert("source".to_string(), hex64(key.source));
                obj.insert("spec".to_string(), hex64(key.spec));
                obj.insert("options".to_string(), hex64(key.options));
                obj.insert("kernel".to_string(), servable_to_json(&e.kernel));
                JsonValue::Object(obj)
            })
            .collect();
        let mut root = std::collections::BTreeMap::new();
        root.insert("version".to_string(), JsonValue::Number(2.0));
        root.insert("entries".to_string(), JsonValue::Array(entries));
        // Atomic replace: write a sibling temp file, then rename over
        // the target. A crash mid-write leaves the previous snapshot
        // intact (plus a harmless `.tmp` sibling the next load cleans
        // up) instead of destroying the warm-start artifact.
        let tmp = snapshot_tmp_path(path);
        std::fs::write(&tmp, JsonValue::Object(root).render())
            .with_context(|| format!("writing cache snapshot temp {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| {
                format!("installing cache snapshot {}", path.display())
            });
        }
        Ok(written)
    }

    /// Restore entries from a snapshot written by
    /// [`KernelCache::save_snapshot`]. Only entries compiled for
    /// `spec` **and** for these `options` are loaded: either the
    /// options fingerprint matches outright, or the replication
    /// factor recorded in the entry's kernel object re-derives a
    /// matching variant fingerprint (`Replication::Fixed(factor)`
    /// under the same base options — the autoscaler's variants; this
    /// also restores variants from version-1 snapshots). Anything
    /// else — another spec's kernels, or entries built under
    /// since-changed compile options — is skipped rather than
    /// silently mismatched.
    ///
    /// A snapshot is an *optimization*, never a correctness input: a
    /// truncated, unparsable or internally inconsistent file (and an
    /// unknown format version) is logged to stderr and ignored — the
    /// cache simply cold-starts, exactly as if the file were absent.
    /// The decode is two-phase (parse **everything**, then insert),
    /// so corruption anywhere in the file leaves the cache untouched
    /// rather than half-warm. Loading stops at capacity — a snapshot
    /// written by a larger cache neither evicts what was loaded first
    /// nor inflates the eviction counter. Returns how many entries
    /// are actually resident afterwards. Restored entries count
    /// neither hits nor misses.
    pub fn load_snapshot(&mut self, path: &Path, spec: u64, options: &CompileOptions) -> usize {
        // a leftover temp sibling is the residue of a crashed
        // save_snapshot: never loaded (it may be truncated), and
        // removed so it cannot accumulate
        let tmp = snapshot_tmp_path(path);
        if tmp.exists() {
            eprintln!(
                "[kernel-cache] removing leftover snapshot temp {} (crashed write)",
                tmp.display()
            );
            let _ = std::fs::remove_file(&tmp);
        }
        let parsed = match parse_snapshot(path, spec, options) {
            Ok(entries) => entries,
            Err(e) => {
                eprintln!(
                    "[kernel-cache] snapshot {} unusable ({e:#}); cold-starting this shard",
                    path.display()
                );
                return 0;
            }
        };
        let mut loaded = 0usize;
        for (key, kernel) in parsed {
            if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
                continue; // smaller cache than the snapshot's writer
            }
            self.insert(key, kernel);
            loaded += 1;
        }
        loaded
    }
}

/// The sibling temp path [`KernelCache::save_snapshot`] stages its
/// write through before the atomic rename: the target path with
/// `.tmp` appended to its extension, in the same directory (rename
/// across filesystems is not atomic).
fn snapshot_tmp_path(path: &Path) -> std::path::PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    std::path::PathBuf::from(os)
}

/// Strict snapshot decode: read, parse, filter to `(spec, options)`
/// and validate **every** surviving entry before the caller mutates
/// anything. Any defect anywhere fails the whole decode.
fn parse_snapshot(
    path: &Path,
    spec: u64,
    options: &CompileOptions,
) -> Result<Vec<(CacheKey, Arc<ServableKernel>)>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading cache snapshot {}", path.display()))?;
    let doc = JsonValue::parse(&text)
        .with_context(|| format!("parsing cache snapshot {}", path.display()))?;
    let version = doc
        .get("version")
        .and_then(JsonValue::as_i64)
        .ok_or_else(|| anyhow!("snapshot missing version"))?;
    if !(1..=2).contains(&version) {
        bail!("unsupported snapshot version {version}");
    }
    let base_fp = options.fingerprint();
    let variant_fp = |factor: usize| {
        let mut o = options.clone();
        o.replication = Replication::Fixed(factor);
        o.fingerprint()
    };
    let entries = doc
        .get("entries")
        .and_then(JsonValue::as_array)
        .ok_or_else(|| anyhow!("snapshot missing entries array"))?;
    let mut out = Vec::new();
    for ent in entries {
        let key = CacheKey {
            source: get_hex64(ent, "source")?,
            spec: get_hex64(ent, "spec")?,
            options: get_hex64(ent, "options")?,
        };
        let options_ok = key.options == base_fp
            || ent
                .get("kernel")
                .and_then(|k| k.get("factor"))
                .and_then(JsonValue::as_i64)
                .filter(|&f| f > 0)
                .is_some_and(|f| key.options == variant_fp(f as usize));
        if key.spec != spec || !options_ok {
            continue;
        }
        let kernel = ent
            .get("kernel")
            .ok_or_else(|| anyhow!("snapshot entry missing kernel"))?;
        out.push((key, Arc::new(servable_from_json(kernel)?)));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// snapshot codec
// ---------------------------------------------------------------------

fn hex64(v: u64) -> JsonValue {
    JsonValue::String(format!("{v:016x}"))
}

fn get_hex64(v: &JsonValue, key: &str) -> Result<u64> {
    let s = v
        .get(key)
        .and_then(JsonValue::as_str)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not a string"))?;
    u64::from_str_radix(s, 16).with_context(|| format!("snapshot field '{key}'"))
}

fn num(v: usize) -> JsonValue {
    JsonValue::Number(v as f64)
}

fn arr_i32(v: &[i32]) -> JsonValue {
    JsonValue::Array(v.iter().map(|&x| JsonValue::Number(x as f64)).collect())
}

fn arr_usize(v: &[usize]) -> JsonValue {
    JsonValue::Array(v.iter().map(|&x| num(x)).collect())
}

fn arr_u32(v: &[u32]) -> JsonValue {
    JsonValue::Array(v.iter().map(|&x| JsonValue::Number(x as f64)).collect())
}

fn get_i64(v: &JsonValue, key: &str) -> Result<i64> {
    v.get(key)
        .and_then(JsonValue::as_i64)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not a number"))
}

fn get_usize(v: &JsonValue, key: &str) -> Result<usize> {
    let n = get_i64(v, key)?;
    if n < 0 {
        bail!("snapshot field '{key}' is negative");
    }
    Ok(n as usize)
}

fn get_f64(v: &JsonValue, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(JsonValue::as_f64)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not a number"))
}

fn get_str<'a>(v: &'a JsonValue, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(JsonValue::as_str)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not a string"))
}

fn get_arr<'a>(v: &'a JsonValue, key: &str) -> Result<&'a [JsonValue]> {
    v.get(key)
        .and_then(JsonValue::as_array)
        .ok_or_else(|| anyhow!("snapshot field '{key}' missing or not an array"))
}

fn read_i32s(v: &JsonValue, key: &str) -> Result<Vec<i32>> {
    get_arr(v, key)?
        .iter()
        .map(|x| {
            x.as_i64()
                .map(|n| n as i32)
                .ok_or_else(|| anyhow!("snapshot field '{key}' holds a non-number"))
        })
        .collect()
}

fn read_usizes(v: &JsonValue, key: &str) -> Result<Vec<usize>> {
    get_arr(v, key)?
        .iter()
        .map(|x| {
            x.as_i64()
                .filter(|&n| n >= 0)
                .map(|n| n as usize)
                .ok_or_else(|| anyhow!("snapshot field '{key}' holds a bad number"))
        })
        .collect()
}

fn read_u32s(v: &JsonValue, key: &str) -> Result<Vec<u32>> {
    get_arr(v, key)?
        .iter()
        .map(|x| {
            x.as_i64()
                .filter(|&n| n >= 0)
                .map(|n| n as u32)
                .ok_or_else(|| anyhow!("snapshot field '{key}' holds a bad number"))
        })
        .collect()
}

fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn from_hex(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 || !s.is_ascii() {
        bail!("malformed hex string");
    }
    (0..s.len() / 2)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16).map_err(|_| anyhow!("bad hex byte"))
        })
        .collect()
}

fn meta_to_json(m: &crate::dfg::StreamMeta) -> JsonValue {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("param".to_string(), num(m.param));
    obj.insert("offset".to_string(), JsonValue::Number(m.offset as f64));
    obj.insert("is_scalar".to_string(), JsonValue::Bool(m.is_scalar));
    JsonValue::Object(obj)
}

fn meta_from_json(v: &JsonValue) -> Result<crate::dfg::StreamMeta> {
    Ok(crate::dfg::StreamMeta {
        param: get_usize(v, "param")?,
        offset: get_i64(v, "offset")?,
        is_scalar: v
            .get("is_scalar")
            .and_then(JsonValue::as_bool)
            .ok_or_else(|| anyhow!("stream meta missing is_scalar"))?,
    })
}

fn metas_from_json(v: &JsonValue, key: &str) -> Result<Vec<crate::dfg::StreamMeta>> {
    get_arr(v, key)?.iter().map(meta_from_json).collect()
}

fn servable_to_json(k: &ServableKernel) -> JsonValue {
    let params: Vec<JsonValue> = k
        .params
        .iter()
        .map(|p| {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("name".to_string(), JsonValue::String(p.name.clone()));
            obj.insert(
                "ty".to_string(),
                JsonValue::String(
                    match p.ty {
                        Type::Int => "int",
                        Type::Float => "float",
                        Type::Short => "short",
                    }
                    .to_string(),
                ),
            );
            obj.insert(
                "kind".to_string(),
                JsonValue::String(
                    match p.kind {
                        ParamKind::GlobalPtr => "global",
                        ParamKind::Scalar => "scalar",
                    }
                    .to_string(),
                ),
            );
            obj.insert("is_const".to_string(), JsonValue::Bool(p.is_const));
            JsonValue::Object(obj)
        })
        .collect();

    let mut sched = std::collections::BTreeMap::new();
    sched.insert("ops".to_string(), arr_i32(&k.schedule.ops));
    sched.insert("src_a".to_string(), arr_i32(&k.schedule.src_a));
    sched.insert("src_b".to_string(), arr_i32(&k.schedule.src_b));
    sched.insert("src_c".to_string(), arr_i32(&k.schedule.src_c));
    sched.insert(
        "imm_pool".to_string(),
        JsonValue::Array(
            k.schedule
                .imm_pool
                .iter()
                .map(|&(col, bits)| {
                    JsonValue::Array(vec![num(col), JsonValue::Number(bits as f64)])
                })
                .collect(),
        ),
    );
    sched.insert("num_inputs".to_string(), num(k.schedule.num_inputs));
    sched.insert("out_col".to_string(), arr_usize(&k.schedule.out_col));
    let mut geom = std::collections::BTreeMap::new();
    geom.insert("num_inputs".to_string(), num(k.schedule.geometry.num_inputs));
    geom.insert("max_fus".to_string(), num(k.schedule.geometry.max_fus));
    geom.insert("batch".to_string(), num(k.schedule.geometry.batch));
    sched.insert("geometry".to_string(), JsonValue::Object(geom));

    let mut obj = std::collections::BTreeMap::new();
    obj.insert("name".to_string(), JsonValue::String(k.name.clone()));
    obj.insert("params".to_string(), JsonValue::Array(params));
    obj.insert("factor".to_string(), num(k.factor));
    obj.insert(
        "limit".to_string(),
        JsonValue::String(k.limit.short_name().to_string()),
    );
    obj.insert("ops_per_copy".to_string(), num(k.ops_per_copy));
    obj.insert("fus".to_string(), num(k.fus));
    obj.insert("n_inputs".to_string(), num(k.n_inputs));
    obj.insert("n_outputs".to_string(), num(k.n_outputs));
    obj.insert(
        "input_meta".to_string(),
        JsonValue::Array(k.input_meta.iter().map(meta_to_json).collect()),
    );
    obj.insert(
        "output_meta".to_string(),
        JsonValue::Array(k.output_meta.iter().map(meta_to_json).collect()),
    );
    obj.insert("out_latency".to_string(), arr_u32(&k.latency.out_latency));
    obj.insert(
        "pipeline_depth".to_string(),
        JsonValue::Number(k.latency.pipeline_depth as f64),
    );
    obj.insert(
        "max_delay_used".to_string(),
        JsonValue::Number(k.latency.max_delay_used as f64),
    );
    obj.insert(
        "bitstream".to_string(),
        JsonValue::String(to_hex(&k.bitstream.to_bytes())),
    );
    obj.insert("schedule".to_string(), JsonValue::Object(sched));
    JsonValue::Object(obj)
}

fn servable_from_json(v: &JsonValue) -> Result<ServableKernel> {
    let params: Vec<Param> = get_arr(v, "params")?
        .iter()
        .map(|p| {
            Ok(Param {
                name: get_str(p, "name")?.to_string(),
                ty: match get_str(p, "ty")? {
                    "int" => Type::Int,
                    "float" => Type::Float,
                    "short" => Type::Short,
                    other => bail!("unknown param type '{other}'"),
                },
                kind: match get_str(p, "kind")? {
                    "global" => ParamKind::GlobalPtr,
                    "scalar" => ParamKind::Scalar,
                    other => bail!("unknown param kind '{other}'"),
                },
                is_const: p
                    .get("is_const")
                    .and_then(JsonValue::as_bool)
                    .ok_or_else(|| anyhow!("param missing is_const"))?,
            })
        })
        .collect::<Result<_>>()?;

    let sched = v
        .get("schedule")
        .ok_or_else(|| anyhow!("snapshot kernel missing schedule"))?;
    let geom_v = sched
        .get("geometry")
        .ok_or_else(|| anyhow!("schedule missing geometry"))?;
    let geometry = EmuGeometry {
        num_inputs: get_usize(geom_v, "num_inputs")?,
        max_fus: get_usize(geom_v, "max_fus")?,
        batch: get_usize(geom_v, "batch")?,
    };
    let imm_pool = get_arr(sched, "imm_pool")?
        .iter()
        .map(|pair| {
            let items = pair
                .as_array()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("imm_pool entry is not a [col, bits] pair"))?;
            let col = items[0]
                .as_i64()
                .filter(|&n| n >= 0)
                .ok_or_else(|| anyhow!("imm_pool column is not a number"))?;
            let bits = items[1]
                .as_i64()
                .ok_or_else(|| anyhow!("imm_pool bits is not a number"))?;
            Ok((col as usize, bits as i32))
        })
        .collect::<Result<Vec<(usize, i32)>>>()?;
    let schedule = SlotSchedule {
        ops: read_i32s(sched, "ops")?,
        src_a: read_i32s(sched, "src_a")?,
        src_b: read_i32s(sched, "src_b")?,
        src_c: read_i32s(sched, "src_c")?,
        imm_pool,
        num_inputs: get_usize(sched, "num_inputs")?,
        out_col: read_usizes(sched, "out_col")?,
        geometry,
    };

    let bitstream_bytes = from_hex(get_str(v, "bitstream")?)?;
    let bitstream = OverlayBitstream::from_bytes(&bitstream_bytes)
        .ok_or_else(|| anyhow!("snapshot bitstream is malformed"))?;

    let limit_s = get_str(v, "limit")?;
    let limit = LimitReason::from_short_name(limit_s)
        .ok_or_else(|| anyhow!("unknown limit reason '{limit_s}'"))?;

    let latency = LatencyReport {
        delays: HashMap::new(),
        op_output_time: HashMap::new(),
        out_latency: read_u32s(v, "out_latency")?,
        pipeline_depth: get_usize(v, "pipeline_depth")? as u32,
        max_delay_used: get_usize(v, "max_delay_used")? as u32,
    };

    let k = ServableKernel {
        name: get_str(v, "name")?.to_string(),
        params,
        factor: get_usize(v, "factor")?,
        limit,
        ops_per_copy: get_usize(v, "ops_per_copy")?,
        fus: get_usize(v, "fus")?,
        n_inputs: get_usize(v, "n_inputs")?,
        n_outputs: get_usize(v, "n_outputs")?,
        input_meta: metas_from_json(v, "input_meta")?,
        output_meta: metas_from_json(v, "output_meta")?,
        latency,
        bitstream,
        schedule,
        compile_seconds: get_f64(v, "compile_seconds").unwrap_or(0.0),
    };
    validate_servable(&k)?;
    Ok(k)
}

/// Cross-field invariants a well-typed but corrupted snapshot could
/// violate. Serving such an entry would panic a partition worker
/// (out-of-bounds argument or value-table indices) long after the
/// load "succeeded" — fail the load instead.
fn validate_servable(k: &ServableKernel) -> Result<()> {
    if k.input_meta.len() != k.n_inputs || k.output_meta.len() != k.n_outputs {
        bail!("kernel '{}': stream metadata count mismatch", k.name);
    }
    for m in k.input_meta.iter().chain(&k.output_meta) {
        if m.param >= k.params.len() {
            bail!(
                "kernel '{}': stream meta references parameter {} of {}",
                k.name,
                m.param,
                k.params.len()
            );
        }
    }
    let s = &k.schedule;
    let n = s.ops.len();
    if s.src_a.len() != n || s.src_b.len() != n || s.src_c.len() != n {
        bail!("kernel '{}': ragged slot schedule", k.name);
    }
    if n > s.geometry.max_fus {
        bail!("kernel '{}': {} op slots exceed the {}-slot geometry", k.name, n, s.geometry.max_fus);
    }
    let n_slots = s.geometry.num_slots();
    let src_ok = |v: &[i32]| v.iter().all(|&x| x >= 0 && (x as usize) < n_slots);
    if !src_ok(&s.src_a) || !src_ok(&s.src_b) || !src_ok(&s.src_c) {
        bail!("kernel '{}': slot operand column out of range", k.name);
    }
    if !s.out_col.iter().all(|&c| c < n_slots)
        || !s.imm_pool.iter().all(|&(c, _)| c < n_slots)
    {
        bail!("kernel '{}': output/immediate column out of range", k.name);
    }
    if k.factor == 0 || s.num_inputs != k.factor * k.n_inputs {
        bail!(
            "kernel '{}': schedule expects {} input streams, factor {} x {} inputs",
            k.name,
            s.num_inputs,
            k.factor,
            k.n_inputs
        );
    }
    if s.out_col.len() != k.factor * k.n_outputs {
        bail!(
            "kernel '{}': schedule has {} output streams, factor {} x {} outputs",
            k.name,
            s.out_col.len(),
            k.factor,
            k.n_outputs
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::JitCompiler;
    use crate::overlay::FuType;

    fn compiled() -> Arc<ServableKernel> {
        let jit = JitCompiler::new(OverlaySpec::new(4, 4, FuType::Dsp2));
        Arc::new(jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap().servable())
    }

    fn key(tag: u64) -> CacheKey {
        CacheKey { source: tag, spec: 0, options: 0 }
    }

    #[test]
    fn cache_key_components_are_independent() {
        let spec = OverlaySpec::zynq_default();
        let opts = CompileOptions::default();
        let a = CacheKey::new("src-a", &spec, &opts);
        let b = CacheKey::new("src-b", &spec, &opts);
        assert_ne!(a, b);
        assert_eq!(a.spec, b.spec);
        assert_eq!(a.options, b.options);
        let c = CacheKey::new("src-a", &OverlaySpec::new(4, 4, FuType::Dsp1), &opts);
        assert_eq!(a.source, c.source);
        assert_ne!(a.spec, c.spec);
        // stable across constructions
        assert_eq!(a, CacheKey::new("src-a", &spec, &opts));
    }

    #[test]
    fn hit_miss_counters() {
        let mut cache = KernelCache::new(4);
        let k = compiled();
        assert!(cache.get(&key(1)).is_none());
        cache.insert(key(1), k.clone());
        assert!(cache.get(&key(1)).is_some());
        assert!(cache.get(&key(2)).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_is_deterministic() {
        let mut cache = KernelCache::new(2);
        let k = compiled();
        cache.insert(key(1), k.clone());
        cache.insert(key(2), k.clone());
        // touch 1 so 2 becomes the LRU victim
        assert!(cache.get(&key(1)).is_some());
        let evicted = cache.insert(key(3), k.clone());
        assert_eq!(evicted, Some(key(2)));
        assert!(cache.contains(&key(1)));
        assert!(cache.contains(&key(3)));
        assert!(!cache.contains(&key(2)));
        assert_eq!(cache.stats().evictions, 1);
        // repeat the same sequence → same eviction decision
        let mut c2 = KernelCache::new(2);
        c2.insert(key(1), k.clone());
        c2.insert(key(2), k.clone());
        assert!(c2.get(&key(1)).is_some());
        assert_eq!(c2.insert(key(3), k), Some(key(2)));
    }

    #[test]
    fn reinserting_resident_key_does_not_evict() {
        let mut cache = KernelCache::new(2);
        let k = compiled();
        cache.insert(key(1), k.clone());
        cache.insert(key(2), k.clone());
        assert_eq!(cache.insert(key(2), k), None);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 0);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let cache = KernelCache::new(0);
        assert_eq!(cache.capacity(), 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn snapshot_round_trips_executable_kernels() {
        let spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let opts = CompileOptions::default();
        let jit = JitCompiler::new(spec.clone());
        let original = Arc::new(jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap().servable());
        let k = CacheKey::new(crate::bench_kernels::CHEBYSHEV, &spec, &opts);

        let mut cache = KernelCache::new(8);
        cache.insert(k, original.clone());
        let path = std::env::temp_dir().join(format!(
            "overlay-jit-snapshot-test-{}.json",
            std::process::id()
        ));
        cache.save_snapshot(&path).unwrap();

        let mut restored = KernelCache::new(8);
        let n = restored.load_snapshot(&path, spec.fingerprint(), &opts);
        assert_eq!(n, 1);
        let got = restored.get(&k).expect("restored entry resident");
        assert_eq!(got.name, original.name);
        assert_eq!(got.factor, original.factor);
        assert_eq!(got.limit, original.limit);
        assert_eq!(got.params, original.params);
        assert_eq!(got.input_meta, original.input_meta);
        assert_eq!(got.output_meta, original.output_meta);
        assert_eq!(got.schedule, original.schedule);
        assert_eq!(got.bitstream.to_bytes(), original.bitstream.to_bytes());
        assert_eq!(got.latency.pipeline_depth, original.latency.pipeline_depth);
        // restored entries are free: no JIT was paid
        assert_eq!(got.compile_seconds, 0.0);

        // a shard with a different spec fingerprint loads nothing
        let mut other = KernelCache::new(8);
        assert_eq!(other.load_snapshot(&path, 0xdead, &opts), 0);
        assert!(other.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_write_is_atomic_and_leftover_temp_is_cleaned_on_load() {
        let spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let opts = CompileOptions::default();
        let k = CacheKey::new("src", &spec, &opts);
        let mut cache = KernelCache::new(4);
        cache.insert(k, compiled());
        let path = std::env::temp_dir().join(format!(
            "overlay-jit-snapshot-atomic-test-{}.json",
            std::process::id()
        ));
        let tmp = snapshot_tmp_path(&path);

        // a completed save leaves no temp sibling behind
        cache.save_snapshot(&path).unwrap();
        assert!(path.exists());
        assert!(!tmp.exists(), "save must rename the temp into place");
        let good = std::fs::read_to_string(&path).unwrap();

        // simulate a crash mid-write: a truncated temp next to a good
        // snapshot. The load must ignore the temp (use the good file),
        // and clean the residue up.
        std::fs::write(&tmp, &good[..good.len() / 2]).unwrap();
        let mut restored = KernelCache::new(4);
        assert_eq!(restored.load_snapshot(&path, spec.fingerprint(), &opts), 1);
        assert!(!tmp.exists(), "leftover temp must be removed on load");

        // overwriting an existing snapshot goes through the same
        // temp+rename path and the result parses clean
        cache.save_snapshot(&path).unwrap();
        assert!(!tmp.exists());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), good);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupted_snapshot_falls_back_to_cold_start() {
        let spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let opts = CompileOptions::default();
        let mut cache = KernelCache::new(4);
        cache.insert(CacheKey::new("src", &spec, &opts), compiled());
        let path = std::env::temp_dir().join(format!(
            "overlay-jit-snapshot-corrupt-test-{}.json",
            std::process::id()
        ));
        cache.save_snapshot(&path).unwrap();
        // well-typed but inconsistent: stream-metadata count no longer
        // matches the declared input count
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"n_inputs\":1"), "fixture drifted: {text:.120}");
        std::fs::write(&path, text.replace("\"n_inputs\":1", "\"n_inputs\":3")).unwrap();
        // the defect must not fail the load (a restart would never
        // come up); the shard just cold-starts
        let mut restored = KernelCache::new(4);
        assert_eq!(restored.load_snapshot(&path, spec.fingerprint(), &opts), 0);
        assert!(restored.is_empty());
        // the strict decoder still names the defect for the log line
        let err = parse_snapshot(&path, spec.fingerprint(), &opts).unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"), "{err:#}");
        // and the cache remains fully serviceable after the fallback
        restored.insert(CacheKey::new("src", &spec, &opts), compiled());
        assert_eq!(restored.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_snapshot_loads_nothing_not_half_a_cache() {
        let spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let opts = CompileOptions::default();
        let k = compiled();
        let mut cache = KernelCache::new(4);
        for tag in 0..3u64 {
            cache.insert(
                CacheKey { source: tag, spec: spec.fingerprint(), options: opts.fingerprint() },
                k.clone(),
            );
        }
        let path = std::env::temp_dir().join(format!(
            "overlay-jit-snapshot-truncated-test-{}.json",
            std::process::id()
        ));
        cache.save_snapshot(&path).unwrap();
        // chop the file mid-entry, as a crashed writer would leave it
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        // the decode is two-phase: even though entry 0 was intact, the
        // cache stays empty rather than warm-starting half a snapshot
        let mut restored = KernelCache::new(4);
        assert_eq!(restored.load_snapshot(&path, spec.fingerprint(), &opts), 0);
        assert!(restored.is_empty());
        assert!(parse_snapshot(&path, spec.fingerprint(), &opts).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_snapshot_respects_capacity() {
        let spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let opts = CompileOptions::default();
        let k = compiled();
        let mut big = KernelCache::new(8);
        for tag in 0..4u64 {
            big.insert(
                CacheKey { source: tag, spec: spec.fingerprint(), options: opts.fingerprint() },
                k.clone(),
            );
        }
        let path = std::env::temp_dir().join(format!(
            "overlay-jit-snapshot-cap-test-{}.json",
            std::process::id()
        ));
        assert_eq!(big.save_snapshot(&path).unwrap(), 4);

        // a smaller restarted cache keeps only what fits — no silent
        // evictions, an honest loaded count
        let mut small = KernelCache::new(2);
        let n = small.load_snapshot(&path, spec.fingerprint(), &opts);
        assert_eq!(n, 2);
        assert_eq!(small.len(), 2);
        assert_eq!(small.stats().evictions, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_restores_factor_variants_and_invalidates_stale_options() {
        let spec = OverlaySpec::new(4, 4, FuType::Dsp2);
        let opts = CompileOptions::default();
        let jit = JitCompiler::new(spec.clone());
        let base = Arc::new(jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap().servable());
        let v2 = Arc::new(
            jit.compile_at_factor(crate::bench_kernels::CHEBYSHEV, 2)
                .unwrap()
                .servable(),
        );
        let mut variant_opts = opts.clone();
        variant_opts.replication = Replication::Fixed(2);

        let mut cache = KernelCache::new(8);
        let base_key = CacheKey::new(crate::bench_kernels::CHEBYSHEV, &spec, &opts);
        let variant_key = CacheKey::new(crate::bench_kernels::CHEBYSHEV, &spec, &variant_opts);
        cache.insert(base_key, base);
        cache.insert(variant_key, v2);
        let path = std::env::temp_dir().join(format!(
            "overlay-jit-snapshot-variant-test-{}.json",
            std::process::id()
        ));
        assert_eq!(cache.save_snapshot(&path).unwrap(), 2);

        // a restart under the same base options restores the default
        // entry AND the autoscaler's factor-2 variant (its fingerprint
        // is re-derived from the recorded factor)
        let mut warm = KernelCache::new(8);
        let n = warm.load_snapshot(&path, spec.fingerprint(), &opts);
        assert_eq!(n, 2);
        assert!(warm.contains(&base_key));
        assert!(warm.contains(&variant_key));
        assert_eq!(warm.get(&variant_key).unwrap().factor, 2);

        // changed compile options invalidate every stale entry on load
        // instead of silently mismatching
        let changed = CompileOptions { seed: 99, ..Default::default() };
        let mut stale = KernelCache::new(8);
        assert_eq!(stale.load_snapshot(&path, spec.fingerprint(), &changed), 0);
        assert!(stale.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
