//! The compile cache: content-addressed reuse of JIT artifacts.
//!
//! The paper's JIT compile is seconds-class (Fig. 7); a serving
//! deployment cannot afford to pay it per request. Compiled kernels
//! are therefore cached under a **stable key** — (kernel source hash,
//! overlay fingerprint, compile-options fingerprint) — so a repeat
//! build is O(hash lookup) and only genuinely new (source, overlay,
//! options) combinations hit the compiler. Eviction is LRU over a
//! bounded capacity with deterministic tie-breaking (a monotonic
//! logical clock stamps every touch), which the tests rely on.

use std::collections::HashMap;
use std::sync::Arc;

use crate::compiler::{stable_source_hash, CompileOptions, CompiledKernel};
use crate::metrics::CacheStats;
use crate::overlay::OverlaySpec;

/// Stable compile-cache key. Every component survives process
/// restarts (FNV-1a, not `DefaultHasher`), so keys can be logged and
/// compared across runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// FNV-1a of the kernel source text.
    pub source: u64,
    /// [`OverlaySpec::fingerprint`] of the target overlay.
    pub spec: u64,
    /// [`CompileOptions::fingerprint`] of the build options.
    pub options: u64,
}

impl CacheKey {
    pub fn new(source: &str, spec: &OverlaySpec, options: &CompileOptions) -> CacheKey {
        CacheKey {
            source: stable_source_hash(source),
            spec: spec.fingerprint(),
            options: options.fingerprint(),
        }
    }
}

struct Entry {
    kernel: Arc<CompiledKernel>,
    /// Logical time of the last hit or insert (unique — ties are
    /// impossible, so eviction order is deterministic).
    last_used: u64,
}

/// Bounded LRU cache of compiled kernels.
pub struct CompileCache {
    map: HashMap<CacheKey, Entry>,
    capacity: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl std::fmt::Debug for CompileCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompileCache")
            .field("entries", &self.map.len())
            .field("capacity", &self.capacity)
            .field("hits", &self.hits)
            .field("misses", &self.misses)
            .field("evictions", &self.evictions)
            .finish()
    }
}

impl CompileCache {
    /// A cache holding at most `capacity` compiled kernels
    /// (`capacity` is clamped to ≥ 1).
    pub fn new(capacity: usize) -> CompileCache {
        CompileCache {
            map: HashMap::new(),
            capacity: capacity.max(1),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look a key up, counting a hit or miss and refreshing LRU order
    /// on hit.
    pub fn get(&mut self, key: &CacheKey) -> Option<Arc<CompiledKernel>> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = self.tick;
                self.hits += 1;
                Some(e.kernel.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Check residency without touching counters or LRU order.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.map.contains_key(key)
    }

    /// Insert a compiled kernel, evicting the least-recently-used
    /// entry if the cache is full. Returns the evicted key, if any.
    pub fn insert(&mut self, key: CacheKey, kernel: Arc<CompiledKernel>) -> Option<CacheKey> {
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&key) {
            // refresh (racing compilers may insert the same key twice)
            e.kernel = kernel;
            e.last_used = self.tick;
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                self.map.remove(&victim);
                self.evictions += 1;
                evicted = Some(victim);
            }
        }
        self.map.insert(key, Entry { kernel, last_used: self.tick });
        evicted
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.map.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::JitCompiler;
    use crate::overlay::FuType;

    fn compiled() -> Arc<CompiledKernel> {
        let jit = JitCompiler::new(OverlaySpec::new(4, 4, FuType::Dsp2));
        Arc::new(jit.compile(crate::bench_kernels::CHEBYSHEV).unwrap())
    }

    fn key(tag: u64) -> CacheKey {
        CacheKey { source: tag, spec: 0, options: 0 }
    }

    #[test]
    fn cache_key_components_are_independent() {
        let spec = OverlaySpec::zynq_default();
        let opts = CompileOptions::default();
        let a = CacheKey::new("src-a", &spec, &opts);
        let b = CacheKey::new("src-b", &spec, &opts);
        assert_ne!(a, b);
        assert_eq!(a.spec, b.spec);
        assert_eq!(a.options, b.options);
        let c = CacheKey::new("src-a", &OverlaySpec::new(4, 4, FuType::Dsp1), &opts);
        assert_eq!(a.source, c.source);
        assert_ne!(a.spec, c.spec);
        // stable across constructions
        assert_eq!(a, CacheKey::new("src-a", &spec, &opts));
    }

    #[test]
    fn hit_miss_counters() {
        let mut cache = CompileCache::new(4);
        let k = compiled();
        assert!(cache.get(&key(1)).is_none());
        cache.insert(key(1), k.clone());
        assert!(cache.get(&key(1)).is_some());
        assert!(cache.get(&key(2)).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_is_deterministic() {
        let mut cache = CompileCache::new(2);
        let k = compiled();
        cache.insert(key(1), k.clone());
        cache.insert(key(2), k.clone());
        // touch 1 so 2 becomes the LRU victim
        assert!(cache.get(&key(1)).is_some());
        let evicted = cache.insert(key(3), k.clone());
        assert_eq!(evicted, Some(key(2)));
        assert!(cache.contains(&key(1)));
        assert!(cache.contains(&key(3)));
        assert!(!cache.contains(&key(2)));
        assert_eq!(cache.stats().evictions, 1);
        // repeat the same sequence → same eviction decision
        let mut c2 = CompileCache::new(2);
        c2.insert(key(1), k.clone());
        c2.insert(key(2), k.clone());
        assert!(c2.get(&key(1)).is_some());
        assert_eq!(c2.insert(key(3), k), Some(key(2)));
    }

    #[test]
    fn reinserting_resident_key_does_not_evict() {
        let mut cache = CompileCache::new(2);
        let k = compiled();
        cache.insert(key(1), k.clone());
        cache.insert(key(2), k.clone());
        assert_eq!(cache.insert(key(2), k), None);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 0);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let cache = CompileCache::new(0);
        assert_eq!(cache.capacity(), 1);
        assert!(cache.is_empty());
    }
}
