//! Simulated-annealing placement — the VPR placer stand-in (§III-D).
//!
//! Places FU blocks onto overlay tiles and I/O streams onto perimeter
//! pad slots, minimizing total half-perimeter wirelength (HPWL) with
//! the classic VPR adaptive schedule:
//!
//! * `moves_per_T = 10 · movables^{4/3}`;
//! * initial temperature from the standard deviation of random-move
//!   deltas;
//! * cooling factor adapted to the acceptance rate (0.5/0.9/0.95/0.8
//!   bands), range-limit window shrinking as acceptance drops;
//! * exit when `T < 0.005 · cost / nets`.
//!
//! All randomness flows through a seeded [`XorShiftRng`]; a given
//! `(netlist, spec, seed)` reproduces the same placement bit-for-bit.

use anyhow::{bail, Result};

use crate::fuaware::NetEndpoint;
use crate::netlist::FuNetlist;
use crate::overlay::{OverlaySpec, RoutingGraph};
use crate::util::XorShiftRng;

/// A placement of every block in a [`FuNetlist`].
#[derive(Debug, Clone)]
pub struct Placement {
    /// FU id → tile (x, y).
    pub fu_tile: Vec<(usize, usize)>,
    /// Input port → perimeter pad slot.
    pub in_slot: Vec<usize>,
    /// Output port → perimeter pad slot.
    pub out_slot: Vec<usize>,
    /// Final HPWL cost.
    pub cost: f64,
    /// SA moves evaluated (report metric).
    pub moves_evaluated: usize,
}

impl Placement {
    /// Tile position of a net endpoint (pads map to their adjacent tile).
    pub fn position(&self, g: &RoutingGraph, ep: NetEndpoint) -> (usize, usize) {
        match ep {
            NetEndpoint::Fu(f) => self.fu_tile[f],
            NetEndpoint::InPad(p) => g.pad_tile(self.in_slot[p]),
            NetEndpoint::OutPad(p) => g.pad_tile(self.out_slot[p]),
        }
    }
}

/// Movable object: an FU or a pad of either direction.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Movable {
    Fu(usize),
    InPad(usize),
    OutPad(usize),
}

struct State<'a> {
    nl: &'a FuNetlist,
    rrg: &'a RoutingGraph,
    cols: usize,
    rows: usize,
    fu_tile: Vec<usize>,       // fu -> tile index (y*cols+x)
    tile_occ: Vec<Option<usize>>, // tile -> fu
    in_slot: Vec<usize>,
    out_slot: Vec<usize>,
    slot_occ: Vec<Option<Movable>>, // pad slot -> pad
    net_cost: Vec<f64>,
    nets_of: Vec<Vec<usize>>, // movable index -> net ids touching it
    movables: Vec<Movable>,
    // §Perf: per-move scratch (net-id dedup) — no allocation in the
    // annealing inner loop
    net_scratch: Vec<usize>,
    net_seen: Vec<u32>,
    seen_stamp: u32,
}

impl<'a> State<'a> {
    fn movable_index(&self, m: Movable) -> usize {
        match m {
            Movable::Fu(f) => f,
            Movable::InPad(p) => self.nl.num_fus + p,
            Movable::OutPad(p) => self.nl.num_fus + self.nl.num_inputs + p,
        }
    }

    fn pos_of(&self, ep: NetEndpoint) -> (usize, usize) {
        match ep {
            NetEndpoint::Fu(f) => {
                let t = self.fu_tile[f];
                (t % self.cols, t / self.cols)
            }
            NetEndpoint::InPad(p) => self.rrg.pad_tile(self.in_slot[p]),
            NetEndpoint::OutPad(p) => self.rrg.pad_tile(self.out_slot[p]),
        }
    }

    /// HPWL of one net, with VPR's fanout correction q(n).
    fn compute_net_cost(&self, net: usize) -> f64 {
        let n = &self.nl.nets[net];
        let (mut x0, mut y0) = self.pos_of(n.src);
        let (mut x1, mut y1) = (x0, y0);
        for (s, _) in &n.sinks {
            let (x, y) = self.pos_of(*s);
            x0 = x0.min(x);
            y0 = y0.min(y);
            x1 = x1.max(x);
            y1 = y1.max(y);
        }
        let q = 1.0 + 0.05 * (n.sinks.len().saturating_sub(3)) as f64;
        q * ((x1 - x0) + (y1 - y0)) as f64
    }

    fn total_cost(&self) -> f64 {
        self.net_cost.iter().sum()
    }

    /// Apply a move; returns (delta cost, the inverse move).
    fn apply(&mut self, mv: &Move) -> (f64, Move) {
        // collect affected movables before mutating
        let (affected, undo): ([Option<Movable>; 2], Move) = match *mv {
            Move::FuSwap { a, tile_b } => {
                let tile_a = self.fu_tile[a];
                let other = self.tile_occ[tile_b];
                self.tile_occ[tile_a] = other;
                self.tile_occ[tile_b] = Some(a);
                self.fu_tile[a] = tile_b;
                if let Some(b) = other {
                    self.fu_tile[b] = tile_a;
                }
                let aff = [Some(Movable::Fu(a)), other.map(Movable::Fu)];
                (aff, Move::FuSwap { a, tile_b: tile_a })
            }
            Move::PadSwap { a, slot_b } => {
                let slot_a = match a {
                    Movable::InPad(p) => self.in_slot[p],
                    Movable::OutPad(p) => self.out_slot[p],
                    Movable::Fu(_) => unreachable!(),
                };
                let other = self.slot_occ[slot_b];
                self.slot_occ[slot_a] = other;
                self.slot_occ[slot_b] = Some(a);
                match a {
                    Movable::InPad(p) => self.in_slot[p] = slot_b,
                    Movable::OutPad(p) => self.out_slot[p] = slot_b,
                    Movable::Fu(_) => unreachable!(),
                }
                match other {
                    Some(Movable::InPad(p)) => self.in_slot[p] = slot_a,
                    Some(Movable::OutPad(p)) => self.out_slot[p] = slot_a,
                    _ => {}
                }
                let aff = [Some(a), other];
                (aff, Move::PadSwap { a, slot_b: slot_a })
            }
        };

        // recompute nets touching the affected movables (stamped
        // dedup, zero allocation)
        self.seen_stamp += 1;
        let st = self.seen_stamp;
        self.net_scratch.clear();
        for m in affected.into_iter().flatten() {
            for &n in &self.nets_of[self.movable_index(m)] {
                if self.net_seen[n] != st {
                    self.net_seen[n] = st;
                    self.net_scratch.push(n);
                }
            }
        }
        let mut delta = 0.0;
        for i in 0..self.net_scratch.len() {
            let net = self.net_scratch[i];
            let new = self.compute_net_cost(net);
            delta += new - self.net_cost[net];
            self.net_cost[net] = new;
        }
        (delta, undo)
    }
}

#[derive(Debug, Clone, Copy)]
enum Move {
    /// Move FU `a` to `tile_b` (swapping with any occupant).
    FuSwap { a: usize, tile_b: usize },
    /// Move pad `a` to `slot_b` (swapping with any occupant).
    PadSwap { a: Movable, slot_b: usize },
}

/// Placer effort knobs (VPR's `inner_num`: scales moves per
/// temperature; 1.0 = the classic 10·n^{4/3}).
#[derive(Debug, Clone, Copy)]
pub struct PlacerOptions {
    pub inner_num: f64,
}

impl Default for PlacerOptions {
    fn default() -> Self {
        PlacerOptions { inner_num: 1.0 }
    }
}

/// Place `nl` onto `spec`'s overlay. Deterministic for a given seed.
pub fn place(
    nl: &FuNetlist,
    spec: &OverlaySpec,
    rrg: &RoutingGraph,
    seed: u64,
) -> Result<Placement> {
    place_with(nl, spec, rrg, seed, &PlacerOptions::default())
}

/// [`place`] with explicit effort options.
pub fn place_with(
    nl: &FuNetlist,
    spec: &OverlaySpec,
    rrg: &RoutingGraph,
    seed: u64,
    opts: &PlacerOptions,
) -> Result<Placement> {
    let tiles = spec.fu_count();
    let pads = spec.io_pads();
    if nl.num_fus > tiles {
        bail!(
            "kernel needs {} FUs but the {} overlay has {}",
            nl.num_fus,
            spec.name(),
            tiles
        );
    }
    if nl.num_inputs + nl.num_outputs > pads {
        bail!(
            "kernel needs {} I/O streams but the {} overlay has {} pads",
            nl.num_inputs + nl.num_outputs,
            spec.name(),
            pads
        );
    }

    let mut rng = XorShiftRng::new(seed ^ 0x504C_4143); // "PLAC"

    // ---- initial random placement ----
    let mut tile_perm: Vec<usize> = (0..tiles).collect();
    rng.shuffle(&mut tile_perm);
    let fu_tile: Vec<usize> = tile_perm[..nl.num_fus].to_vec();
    let mut tile_occ = vec![None; tiles];
    for (f, &t) in fu_tile.iter().enumerate() {
        tile_occ[t] = Some(f);
    }
    let mut slot_perm: Vec<usize> = (0..pads).collect();
    rng.shuffle(&mut slot_perm);
    let in_slot: Vec<usize> = slot_perm[..nl.num_inputs].to_vec();
    let out_slot: Vec<usize> =
        slot_perm[nl.num_inputs..nl.num_inputs + nl.num_outputs].to_vec();
    let mut slot_occ: Vec<Option<Movable>> = vec![None; pads];
    for (p, &s) in in_slot.iter().enumerate() {
        slot_occ[s] = Some(Movable::InPad(p));
    }
    for (p, &s) in out_slot.iter().enumerate() {
        slot_occ[s] = Some(Movable::OutPad(p));
    }

    let mut movables: Vec<Movable> = (0..nl.num_fus).map(Movable::Fu).collect();
    movables.extend((0..nl.num_inputs).map(Movable::InPad));
    movables.extend((0..nl.num_outputs).map(Movable::OutPad));

    // nets touching each movable
    let n_movables = movables.len();
    let mut nets_of: Vec<Vec<usize>> = vec![Vec::new(); n_movables];
    for (ni, net) in nl.nets.iter().enumerate() {
        let mut eps = vec![net.src];
        eps.extend(net.sinks.iter().map(|(s, _)| *s));
        for ep in eps {
            let idx = match ep {
                NetEndpoint::Fu(f) => f,
                NetEndpoint::InPad(p) => nl.num_fus + p,
                NetEndpoint::OutPad(p) => nl.num_fus + nl.num_inputs + p,
            };
            if !nets_of[idx].contains(&ni) {
                nets_of[idx].push(ni);
            }
        }
    }

    let mut st = State {
        nl,
        rrg,
        cols: spec.cols,
        rows: spec.rows,
        fu_tile,
        tile_occ,
        in_slot,
        out_slot,
        slot_occ,
        net_cost: vec![0.0; nl.nets.len()],
        nets_of,
        movables,
        net_scratch: Vec::with_capacity(16),
        net_seen: vec![0; nl.nets.len()],
        seen_stamp: 0,
    };
    for ni in 0..nl.nets.len() {
        st.net_cost[ni] = st.compute_net_cost(ni);
    }
    let mut cost = st.total_cost();
    let mut moves_evaluated = 0usize;

    if nl.nets.is_empty() || st.movables.is_empty() {
        return Ok(finish(st, cost, moves_evaluated));
    }

    // ---- initial temperature: std-dev of random move deltas ----
    let probe = (20 * st.movables.len()).max(50);
    let mut deltas = Vec::with_capacity(probe);
    for _ in 0..probe {
        let mv = random_move(&st, &mut rng, usize::MAX);
        let (d, _undo) = st.apply(&mv);
        deltas.push(d);
        cost += d;
        moves_evaluated += 1;
    }
    let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
    let var =
        deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / deltas.len() as f64;
    let mut temp = 20.0 * var.sqrt().max(1.0);

    // ---- annealing ----
    let moves_per_t = ((opts.inner_num * 10.0
        * (st.movables.len() as f64).powf(4.0 / 3.0)) as usize)
        .max(60);
    let mut window = st.cols.max(st.rows); // range limit
    let exit_t = |cost: f64, nets: usize| 0.005 * cost / nets.max(1) as f64;

    while temp > exit_t(cost, nl.nets.len()) && cost > 0.0 {
        let mut accepted = 0usize;
        for _ in 0..moves_per_t {
            let mv = random_move(&st, &mut rng, window);
            let (d, undo) = st.apply(&mv);
            moves_evaluated += 1;
            if d <= 0.0 || rng.gen_f64() < (-d / temp).exp() {
                accepted += 1;
                cost += d;
            } else {
                let (back, _) = st.apply(&undo);
                debug_assert!((back + d).abs() < 1e-6);
            }
        }
        let rate = accepted as f64 / moves_per_t as f64;
        temp *= match rate {
            r if r > 0.96 => 0.5,
            r if r > 0.8 => 0.9,
            r if r > 0.15 => 0.95,
            _ => 0.8,
        };
        // shrink the range window as acceptance falls (VPR rule of 0.44)
        if rate < 0.44 && window > 1 {
            window -= 1;
        }
    }

    Ok(finish(st, cost, moves_evaluated))
}

fn finish(st: State<'_>, cost: f64, moves_evaluated: usize) -> Placement {
    Placement {
        fu_tile: st
            .fu_tile
            .iter()
            .map(|&t| (t % st.cols, t / st.cols))
            .collect(),
        in_slot: st.in_slot.clone(),
        out_slot: st.out_slot.clone(),
        cost,
        moves_evaluated,
    }
}

fn random_move(st: &State<'_>, rng: &mut XorShiftRng, window: usize) -> Move {
    let m = *rng.choose(&st.movables);
    match m {
        Movable::Fu(f) => {
            let cur = st.fu_tile[f];
            let (cx, cy) = (cur % st.cols, cur / st.cols);
            // pick a target tile within the range window
            for _ in 0..8 {
                let tx = clamp_window(cx, window, st.cols, rng);
                let ty = clamp_window(cy, window, st.rows, rng);
                let t = ty * st.cols + tx;
                if t != cur {
                    return Move::FuSwap { a: f, tile_b: t };
                }
            }
            Move::FuSwap { a: f, tile_b: rng.gen_range(st.cols * st.rows) }
        }
        pad => {
            let slots = st.slot_occ.len();
            let cur = match pad {
                Movable::InPad(p) => st.in_slot[p],
                Movable::OutPad(p) => st.out_slot[p],
                Movable::Fu(_) => unreachable!(),
            };
            let mut s = rng.gen_range(slots);
            if s == cur {
                s = (s + 1) % slots;
            }
            Move::PadSwap { a: pad, slot_b: s }
        }
    }
}

fn clamp_window(c: usize, window: usize, dim: usize, rng: &mut XorShiftRng) -> usize {
    if window >= dim {
        return rng.gen_range(dim);
    }
    let lo = c.saturating_sub(window);
    let hi = (c + window).min(dim - 1);
    lo + rng.gen_range(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_kernel;
    use crate::fuaware::to_fu_graph;
    use crate::ir::{lower_kernel, optimize};
    use crate::netlist::build_netlist;
    use crate::overlay::FuType;

    const PAPER: &str = "__kernel void example_kernel(__global int *A, __global int *B) {
        int idx = get_global_id(0);
        int x = A[idx];
        B[idx] = (x*(x*(16*x*x-20)*x+5));
    }";

    fn paper_netlist(dsps: usize) -> FuNetlist {
        let f = lower_kernel(&parse_kernel(PAPER).unwrap()).unwrap();
        let dfg = crate::dfg::extract_dfg(&optimize(&f).0).unwrap();
        build_netlist(&to_fu_graph(&dfg, dsps).unwrap())
    }

    fn assert_legal(p: &Placement, spec: &OverlaySpec) {
        // no two FUs on one tile
        let mut seen = std::collections::HashSet::new();
        for &(x, y) in &p.fu_tile {
            assert!(x < spec.cols && y < spec.rows);
            assert!(seen.insert((x, y)), "tile ({x},{y}) double-occupied");
        }
        // no two pads on one slot
        let mut slots = std::collections::HashSet::new();
        for &s in p.in_slot.iter().chain(p.out_slot.iter()) {
            assert!(s < spec.io_pads());
            assert!(slots.insert(s), "pad slot {s} double-occupied");
        }
    }

    #[test]
    fn places_paper_kernel_on_5x5() {
        // Fig. 3(c): the example kernel placed on a 5×5 overlay
        let spec = OverlaySpec::new(5, 5, FuType::Dsp2);
        let rrg = RoutingGraph::build(&spec);
        let nl = paper_netlist(2);
        let p = place(&nl, &spec, &rrg, 1).unwrap();
        assert_legal(&p, &spec);
        assert_eq!(p.fu_tile.len(), 3);
    }

    #[test]
    fn placement_is_deterministic_per_seed() {
        let spec = OverlaySpec::new(5, 5, FuType::Dsp1);
        let rrg = RoutingGraph::build(&spec);
        let nl = paper_netlist(1);
        let a = place(&nl, &spec, &rrg, 42).unwrap();
        let b = place(&nl, &spec, &rrg, 42).unwrap();
        assert_eq!(a.fu_tile, b.fu_tile);
        assert_eq!(a.in_slot, b.in_slot);
        assert_eq!(a.cost, b.cost);
    }

    #[test]
    fn different_seeds_explore_differently() {
        let spec = OverlaySpec::new(8, 8, FuType::Dsp1);
        let rrg = RoutingGraph::build(&spec);
        let nl = paper_netlist(1);
        let a = place(&nl, &spec, &rrg, 1).unwrap();
        let b = place(&nl, &spec, &rrg, 2).unwrap();
        // costs land close but layouts almost surely differ
        assert!(a.fu_tile != b.fu_tile || a.in_slot != b.in_slot);
    }

    #[test]
    fn annealing_beats_random_start() {
        // place on a big grid where the random start is certainly bad
        let spec = OverlaySpec::new(8, 8, FuType::Dsp1);
        let rrg = RoutingGraph::build(&spec);
        let nl = paper_netlist(1);
        let p = place(&nl, &spec, &rrg, 3).unwrap();
        // 5 FUs + 2 pads, all nets should pull into a tight cluster:
        // final HPWL must be small (each net spans <= ~3 tiles)
        assert!(p.cost <= 20.0, "cost {} too high", p.cost);
        assert!(p.moves_evaluated > 100);
    }

    #[test]
    fn rejects_kernel_too_big_for_overlay() {
        let spec = OverlaySpec::new(2, 2, FuType::Dsp1); // 4 FUs
        let rrg = RoutingGraph::build(&spec);
        let nl = paper_netlist(1); // needs 5
        assert!(place(&nl, &spec, &rrg, 1).is_err());
    }

    #[test]
    fn cost_bookkeeping_is_consistent() {
        // incremental cost must match a from-scratch recomputation
        let spec = OverlaySpec::new(6, 6, FuType::Dsp2);
        let rrg = RoutingGraph::build(&spec);
        let nl = paper_netlist(2);
        let p = place(&nl, &spec, &rrg, 9).unwrap();
        // rebuild cost from final positions
        let pos = |ep: NetEndpoint| p.position(&rrg, ep);
        let mut total = 0.0;
        for n in &nl.nets {
            let (mut x0, mut y0) = pos(n.src);
            let (mut x1, mut y1) = (x0, y0);
            for (s, _) in &n.sinks {
                let (x, y) = pos(*s);
                x0 = x0.min(x);
                y0 = y0.min(y);
                x1 = x1.max(x);
                y1 = y1.max(y);
            }
            let q = 1.0 + 0.05 * (n.sinks.len().saturating_sub(3)) as f64;
            total += q * ((x1 - x0) + (y1 - y0)) as f64;
        }
        assert!((total - p.cost).abs() < 1e-9, "{} vs {}", total, p.cost);
    }
}
