//! Stable (process- and version-independent) hashing for cache keys.
//!
//! `std::hash::DefaultHasher` makes no cross-version stability
//! promise, so the coordinator's compile-cache keys — which may be
//! logged, compared across runs, or persisted — are built on FNV-1a
//! instead. Not cryptographic; collision resistance is adequate for a
//! cache keyed additionally by overlay and option fingerprints.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// An incremental FNV-1a hasher for composite fingerprints
/// (overlay specs, compile options).
#[derive(Debug, Clone)]
pub struct StableHasher {
    state: u64,
}

impl Default for StableHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl StableHasher {
    pub fn new() -> Self {
        StableHasher { state: 0xcbf2_9ce4_8422_2325 }
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    /// Hash a float by its bit pattern (configuration floats are exact
    /// constants, never NaN).
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_known_vectors() {
        // published FNV-1a test vectors
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hasher_is_order_sensitive_and_deterministic() {
        let mut a = StableHasher::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = StableHasher::new();
        b.write_u64(2);
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish());

        let mut c = StableHasher::new();
        c.write_u64(1);
        c.write_u64(2);
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn write_str_is_length_prefixed() {
        // ("ab", "c") must differ from ("a", "bc")
        let mut a = StableHasher::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = StableHasher::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
