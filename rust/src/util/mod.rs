//! Small self-contained utilities: a seeded PRNG for the stochastic
//! passes, a stopwatch, and a minimal JSON reader for
//! `artifacts/geometry.json`.
//!
//! (The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so `rand`, `serde` and friends are
//! hand-rolled here — see DESIGN.md §Key design decisions.)

mod json;
mod rng;
mod timer;

pub use json::JsonValue;
pub use rng::XorShiftRng;
pub use timer::Stopwatch;
