//! Small self-contained utilities: a seeded PRNG for the stochastic
//! passes, a stopwatch, a stable FNV-1a hasher for the coordinator's
//! compile-cache keys, a minimal JSON reader for
//! `artifacts/geometry.json`, and the bounded keep-first
//! [`BoundedLog`] shared by every audit trail.
//!
//! (The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so `rand`, `serde` and friends are
//! hand-rolled here — see DESIGN.md §Key design decisions.)

mod bounded_log;
mod hash;
mod json;
mod rng;
mod timer;

pub use bounded_log::BoundedLog;
pub use hash::{fnv1a_64, StableHasher};
pub use json::JsonValue;
pub use rng::XorShiftRng;
pub use timer::Stopwatch;
