//! Wall-clock stopwatch used by the compile pipeline to report
//! per-stage timings (the paper's Fig. 7 metric is PAR wall time).

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now, laps: Vec::new() }
    }

    /// Record the time since the previous lap under `name`.
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    /// Total elapsed time since construction.
    pub fn total(&self) -> Duration {
        Instant::now() - self.start
    }

    /// All recorded laps.
    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    /// Duration of the lap named `name`, if recorded.
    pub fn get(&self, name: &str) -> Option<Duration> {
        self.laps.iter().find(|(n, _)| n == name).map(|(_, d)| *d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_in_order() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert_eq!(sw.laps()[0].0, "a");
        assert!(sw.get("b").is_some());
        assert!(sw.get("missing").is_none());
        assert!(sw.total() >= sw.laps()[0].1);
    }
}
